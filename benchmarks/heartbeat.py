"""§4.2 adaptive heartbeat behaviour: interval trajectory under failure bursts
(halves when >1/3 of TaskTrackers fail within a window; floor 120 s) vs the static
600 s default, and the detection-latency consequence."""

from __future__ import annotations


from benchmarks.common import emit, save_json
from repro.cluster.chaos import ChaosConfig
from repro.cluster.experiment import ExperimentConfig, run_atlas, run_baseline
from repro.cluster.workload import WorkloadConfig


def run():
    cfg = ExperimentConfig(
        workload=WorkloadConfig(n_single=40, n_chains=6, seed=9),
        chaos=ChaosConfig(intensity=6.0, burst_prob=0.10, seed=5))
    base, _, base_sim = run_baseline("fifo", cfg)
    atlas, _, atlas_sim = run_atlas("fifo", cfg)
    out = {
        "static_interval_s": 600.0,
        "atlas_final_interval_s": atlas_sim.heartbeat_interval,
        "adjustments": atlas["atlas"]["hb_adjustments"],
        "dead_probes": atlas["atlas"]["dead_probes"],
        "base_failed_tasks_pct": base["pct_tasks_failed"],
        "atlas_failed_tasks_pct": atlas["pct_tasks_failed"],
    }
    emit("heartbeat/adaptive", atlas_sim.heartbeat_interval * 1e6,
         f"adjustments={out['adjustments']};probes={out['dead_probes']};"
         f"tasks_failed {base['pct_tasks_failed']:.1f}%->"
         f"{atlas['pct_tasks_failed']:.1f}%")
    save_json("heartbeat", out)
    return out


if __name__ == "__main__":
    run()
