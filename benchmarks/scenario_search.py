"""Adversarial scenario search CLI + invariant-overhead guard.

Three modes over ``repro.cluster.search``:

* default — run/resume a budgeted search, writing ``experiments/SEARCH.json``
  (atomic, resumable ledger) and ``experiments/SEARCH.md`` (worst-regime
  ranking).  All search knobs are flags.

* ``--smoke`` — the CI gate: a tiny serial search (8 evals x 1 seed on a
  20-node fleet, invariants ON) that must produce (1) a structurally valid
  ledger, (2) zero invariant violations across every evaluated cell, (3) at
  least one nonzero-regret regime (the search surfaces *something*, in either
  direction), and (4) a byte-identical SEARCH.json when re-run from scratch
  into a temp dir (determinism is load-bearing: it is what makes the ledger
  resumable).  Non-zero exit on any break.

* ``--overhead`` — the ``check_invariants`` cost guard on the bench-smoke
  cell (bursty_tt/smoke, fifo + atlas-fifo): paired on/off runs timed with
  ``time.process_time`` (order alternating, gc reset between), gated on the
  median per-pair overhead.  Same estimator rationale as
  ``benchmarks/obs_overhead.py``: absolute wall times on shared runners swing
  more than the effect; paired CPU-time deltas with a median center an A/A
  control at ~0.  Up to ``--attempts`` independent tries; any within
  ``--gate`` passes (noise storms are transient, regressions persist).

    PYTHONPATH=src python benchmarks/scenario_search.py --smoke
    PYTHONPATH=src python benchmarks/scenario_search.py --overhead \
        --fleet-size 500 --gate 10
"""

from __future__ import annotations

import argparse
import dataclasses
import gc
import pathlib
import statistics
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from common import OUT, save_json  # noqa: E402

from repro.cluster.experiment import (ExperimentConfig,  # noqa: E402
                                      run_scheduler)
from repro.cluster.scenarios import make_spec  # noqa: E402
from repro.cluster.search import SearchConfig, run_search  # noqa: E402


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--smoke", action="store_true",
                   help="CI preset + gates (see module docstring)")
    p.add_argument("--overhead", action="store_true",
                   help="gate check_invariants runtime overhead instead of "
                        "searching")
    p.add_argument("--budget", type=int, default=24)
    p.add_argument("--seeds", type=int, default=2)
    p.add_argument("--base", default="fifo")
    p.add_argument("--scenario", default="baseline")
    p.add_argument("--workload", default="smoke")
    p.add_argument("--fleet-size", type=int, default=None,
                   help="nodes per cell (default: 20; 500 for --overhead)")
    p.add_argument("--scale", type=float, default=0.25)
    p.add_argument("--restart-after", type=int, default=6)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--executor", default="process",
                   choices=("serial", "process", "broker", "async"))
    p.add_argument("--workers", type=int, default=None)
    p.add_argument("--hazard", default="cluster",
                   choices=("cluster", "per-node"))
    p.add_argument("--no-invariants", action="store_true")
    p.add_argument("--min-samples", type=int, default=150)
    p.add_argument("--max-train", type=int, default=20000)
    p.add_argument("--fresh", action="store_true",
                   help="ignore an existing SEARCH.json instead of resuming")
    p.add_argument("--out", default=str(OUT))
    # --overhead knobs
    p.add_argument("--pairs", type=int, default=5)
    p.add_argument("--attempts", type=int, default=3)
    p.add_argument("--gate", type=float, default=10.0,
                   help="max median invariant overhead, percent")
    return p


def _search_config(args) -> SearchConfig:
    return SearchConfig(
        base=args.base, budget=args.budget, seeds=args.seeds,
        fleet_size=args.fleet_size if args.fleet_size is not None else 20,
        scenario=args.scenario, workload=args.workload, scale=args.scale,
        restart_after=args.restart_after, seed=args.seed,
        executor=args.executor, workers=args.workers, hazard=args.hazard,
        check_invariants=not args.no_invariants,
        min_samples=args.min_samples, max_train=args.max_train)


# ---------------------------------------------------------------------------
# --overhead: paired on/off timing of the bench-smoke cell
# ---------------------------------------------------------------------------

def _cell_cfg(fleet_size: int, check: bool) -> ExperimentConfig:
    point = make_spec("bursty_tt", "smoke")
    return ExperimentConfig(workload=point.workload_for_seed(11),
                            chaos=point.chaos_for_seed(7), seed=3,
                            fleet_size=fleet_size, min_samples=40,
                            max_train=2000, check_invariants=check)


def _time_cell(fleet_size: int, check: bool) -> float:
    gc.collect()
    t0 = time.process_time()
    for sched in ("fifo", "atlas-fifo"):
        run_scheduler(sched, _cell_cfg(fleet_size, check), with_trace=True)
    return time.process_time() - t0


def _overhead_attempt(fleet_size: int, pairs: int) -> dict:
    deltas, offs = [], []
    for i in range(pairs):
        if i % 2 == 0:                       # alternate order pair-to-pair
            off = _time_cell(fleet_size, False)
            on = _time_cell(fleet_size, True)
        else:
            on = _time_cell(fleet_size, True)
            off = _time_cell(fleet_size, False)
        deltas.append(on - off)
        offs.append(off)
    off_med = statistics.median(offs)
    return {"overhead_pct": 100.0 * statistics.median(deltas) / off_med,
            "off_median_s": off_med,
            "pair_deltas_s": [round(d, 4) for d in deltas]}


def run_overhead(args) -> int:
    fleet_size = args.fleet_size if args.fleet_size is not None else 500
    attempts = []
    ok = False
    for a in range(args.attempts):
        res = _overhead_attempt(fleet_size, args.pairs)
        attempts.append(res)
        print(f"[search-overhead] attempt {a + 1}/{args.attempts}: "
              f"{res['overhead_pct']:+.2f}% "
              f"(off median {res['off_median_s']:.2f}s, "
              f"gate {args.gate:.1f}%)")
        if res["overhead_pct"] <= args.gate:
            ok = True
            break
    path = save_json("SEARCH_OVERHEAD", {
        "fleet_size": fleet_size, "cell": "bursty_tt/smoke x fifo,atlas-fifo",
        "gate_pct": args.gate, "pairs": args.pairs, "passed": ok,
        "attempts": attempts})
    print(f"[search-overhead] wrote {path}")
    if not ok:
        print(f"[search-overhead] FAIL: invariant overhead above "
              f"{args.gate:.1f}% in all {args.attempts} attempts")
        return 1
    return 0


# ---------------------------------------------------------------------------
# --smoke gates
# ---------------------------------------------------------------------------

def _gate(cond: bool, msg: str) -> bool:
    if not cond:
        print(f"[search-smoke] FAIL: {msg}")
    return cond


def run_smoke(args) -> int:
    cfg = SearchConfig(budget=8, seeds=1, fleet_size=20, scenario="baseline",
                       workload="smoke", executor="serial",
                       check_invariants=True, min_samples=40, max_train=2000)
    out_dir = pathlib.Path(args.out)
    result = run_search(cfg, out_dir=out_dir, resume=not args.fresh)

    ok = _gate(result["n_evals"] == cfg.budget
               and len(result["evals"]) == cfg.budget
               and result["best"] is not None,
               "ledger incomplete")
    violations = sum(e["violations"] for e in result["evals"])
    ok &= _gate(violations == 0,
                f"{violations} invariant violations across the search")
    checks = sum(e["checks"] for e in result["evals"])
    ok &= _gate(checks > 0, "invariant checker never ran")
    ok &= _gate(any(e["regret"] != 0.0 for e in result["evals"]),
                "no nonzero-regret regime surfaced")

    # determinism: a from-scratch rerun must reproduce the ledger bytes
    with tempfile.TemporaryDirectory() as tmp:
        rerun = run_search(cfg, out_dir=tmp, log=lambda *a, **k: None)
        a_bytes = (out_dir / "SEARCH.json").read_bytes()
        b_bytes = (pathlib.Path(tmp) / "SEARCH.json").read_bytes()
        ok &= _gate(a_bytes == b_bytes and rerun["best"] == result["best"],
                    "rerun SEARCH.json differs (non-deterministic search)")

    if ok:
        print(f"[search-smoke] OK: {cfg.budget} evals, {checks} invariant "
              f"checks, 0 violations, best regret "
              f"{result['best']['regret']:+.3f}, deterministic ledger")
    return 0 if ok else 1


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.overhead:
        return run_overhead(args)
    if args.smoke:
        return run_smoke(args)
    cfg = _search_config(args)
    print(f"[search] {dataclasses.asdict(cfg)}")
    result = run_search(cfg, out_dir=args.out, resume=not args.fresh)
    best = result["best"]
    print(f"[search] best regret {best['regret']:+.3f} at eval {best['i']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
