"""Telemetry overhead guard: sim throughput with observability on vs off.

The obs layer's contract is "cheap enough to leave on": the per-event hot
path is one list-indexed counter add + one float compare inlined in the
simulator loop, and the frame path runs only when a frame boundary is
crossed AND the event-density gate passes.  Two cells are measured, both
gated at the same relative budget (``--budget``, default 5%):

* ``sustained`` — a long fifo fleet cell where steady-state per-event cost
  dominates; this is the forcing function for the hot path.
* ``smoke`` — the bench-smoke cell (bursty_tt/smoke) executed the way
  ``fleet --obs`` executes it: every scheduler in the cell (fifo AND
  atlas-fifo), telemetry on each run.  This is the acceptance criterion's
  "telemetry overhead on the bench-smoke cell" — one-time costs (observer
  setup, final frame + job ledger, file close) weigh against the whole
  cell, not against the cheapest single run in it.

Estimator: paired differences on CPU time.  Machine-load drift on shared
runners swings absolute wall times far more than the effect being measured
(block samples spread ~35% run-to-run here), so each sample is an off/on
PAIR taken back-to-back with the order alternating pair-to-pair, timed
with ``time.process_time`` (user+sys CPU — preemption while descheduled
does not pollute a pair) after a ``gc.collect()`` phase reset, and the
reported overhead is the MEDIAN of per-pair deltas over the median off
time.  An A/A control of the same estimator centers on ~0, which min-of-N
and sequential block designs do not achieve on this class of machine.

Gating: noise on shared runners arrives in storms that can push even an
A/A median past a tight budget, so each cell gets up to ``--attempts``
independent measurements and passes if ANY lands within budget.  A real
regression is persistent and fails every attempt; a storm rarely spans
all of them.  All attempts are recorded in the JSON artifact.

    PYTHONPATH=src python benchmarks/obs_overhead.py [--pairs 9]
        [--attempts 3] [--budget 0.05] [--frame-every 60]

Writes ``experiments/OBS_OVERHEAD.json``; ``make obs-smoke`` gates CI on the
exit status.  Frames go to real NDJSON files (fresh names in per-sample
tmp subdirs) so the measured cost includes JSON encoding + disk writes,
not just the counter adds.
"""

from __future__ import annotations

import argparse
import gc
import itertools
import statistics
import sys
import tempfile
import time

sys.path.insert(0, str(__import__("pathlib").Path(__file__).resolve()
                       .parents[1] / "src"))

from common import save_json  # noqa: E402

from repro.cluster.chaos import ChaosConfig  # noqa: E402
from repro.cluster.experiment import (ExperimentConfig,  # noqa: E402
                                      run_scheduler)
from repro.cluster.fleet import cell_seed  # noqa: E402
from repro.cluster.scenarios import make_spec  # noqa: E402
from repro.cluster.workload import WorkloadConfig  # noqa: E402

_counter = itertools.count()


def _sustained_cfg(obs_dir=None, frame_every: float = 60.0):
    """A fleet cell big enough that per-event costs dominate setup."""
    # fresh file per run like the fleet (truncate-rewrite of an existing
    # file is ~8x costlier than create on ext4)
    path = (None if obs_dir is None
            else f"{obs_dir}/sustained_{next(_counter)}.ndjson")
    # sized so steady-state cost dominates AND the true overhead sits well
    # below the budget: gating headroom, not estimator precision, is what
    # survives a noisy shared runner
    return ExperimentConfig(
        workload=WorkloadConfig(n_single=40, n_chains=6, seed=11),
        chaos=ChaosConfig(intensity=3.0, seed=12),
        seed=7, min_samples=32, max_train=256,
        obs_path=path, obs_frame_every=frame_every)


def _smoke_cfg(obs_dir=None, frame_every: float = 60.0):
    """The bench-smoke cell (what ``fleet --obs`` runs per scheduler)."""
    env = ("bursty_tt", "smoke", 0)
    path = (None if obs_dir is None
            else f"{obs_dir}/smoke_{next(_counter)}.ndjson")
    point = make_spec("bursty_tt", "smoke")
    return ExperimentConfig(
        workload=point.workload_for_seed(cell_seed("workload", *env)),
        chaos=point.chaos_for_seed(cell_seed("chaos", *env)),
        seed=cell_seed("sim", *env), min_samples=32,
        obs_path=path, obs_frame_every=frame_every)


def _measure(make_cfg, td, frame_every, pairs, schedulers=("fifo",)):
    """Median paired off/on delta for one cell config.

    Each sample runs every scheduler in the cell once (telemetry on all of
    them when ``obs_dir`` is set, matching ``fleet --obs``).  Off/on within
    a pair run back-to-back and the order alternates across pairs, so slow
    machine-load drift cancels inside each pair instead of biasing a side.
    NDJSON output lands in a fresh subdir per on-sample — ext4 file
    creation slows as a directory accumulates thousands of dirents, and the
    benchmark must not pay for its own litter.
    """
    def sample(obs: bool):
        obs_dir = tempfile.mkdtemp(dir=td) if obs else None
        gc.collect()     # reset GC phase so collections triggered by one
        t0 = time.process_time()    # side's allocations don't land in the
        m = None                    # other side's timing window
        for sched in schedulers:
            m, _, _ = run_scheduler(sched, make_cfg(obs_dir, frame_every))
        return time.process_time() - t0, m

    sample(False)                                     # warm both sides
    sample(True)
    offs, deltas, m_on = [], [], None
    for k in range(pairs):
        if k % 2 == 0:
            off, _ = sample(False)
            on, m_on = sample(True)
        else:
            on, m_on = sample(True)
            off, _ = sample(False)
        offs.append(off)
        deltas.append(on - off)

    # the guard is only meaningful if on/off simulate the same world
    m_off = run_scheduler(schedulers[-1], make_cfg(None, frame_every))[0]
    stripped = {k: v for k, v in m_on.items() if k != "obs"}
    assert stripped == m_off, "telemetry changed simulation results"

    base = statistics.median(offs)
    added = statistics.median(deltas)
    return {"seconds_off": round(base, 6),
            "added_ms": round(added * 1e3, 3),
            "overhead_frac": round(added / base, 4),
            "pairs": pairs, "schedulers": list(schedulers),
            "frames": m_on["obs"]["frames"]}


def _gate(name, make_cfg, td, args, schedulers=("fifo",)):
    """Measure one cell up to ``--attempts`` times; best attempt gates."""
    attempts = []
    for i in range(args.attempts):
        cell = _measure(make_cfg, td, args.frame_every, args.pairs,
                        schedulers=schedulers)
        attempts.append(cell)
        print(f"[obs] {name:10s} attempt {i + 1}: "
              f"base {cell['seconds_off'] * 1e3:8.2f}ms "
              f"{cell['added_ms']:+.2f}ms -> "
              f"{cell['overhead_frac'] * 100:+.2f}% "
              f"(budget {args.budget * 100:.0f}%, {cell['frames']} frames, "
              f"{'+'.join(cell['schedulers'])})")
        if cell["overhead_frac"] <= args.budget:
            break
    best = min(attempts, key=lambda c: c["overhead_frac"])
    return dict(best, attempts=[c["overhead_frac"] for c in attempts],
                ok=best["overhead_frac"] <= args.budget)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pairs", type=int, default=9,
                    help="off/on pairs per attempt (median of deltas)")
    ap.add_argument("--attempts", type=int, default=3,
                    help="independent measurements; any within budget passes")
    ap.add_argument("--budget", type=float, default=0.05,
                    help="max fractional slowdown per cell")
    ap.add_argument("--frame-every", type=float, default=60.0)
    args = ap.parse_args(argv)

    with tempfile.TemporaryDirectory() as td:
        sustained = _gate("sustained", _sustained_cfg, td, args)
        smoke = _gate("smoke cell", _smoke_cfg, td, args,
                      schedulers=("fifo", "atlas-fifo"))

    result = {
        "pairs": args.pairs,
        "attempts": args.attempts,
        "frame_every": args.frame_every,
        "budget_frac": args.budget,
        "sustained": sustained,
        "smoke": smoke,
        "ok": sustained["ok"] and smoke["ok"],
    }
    path = save_json("OBS_OVERHEAD", result)
    print(f"[obs] -> {path}")
    rc = 0
    for name, cell in (("sustained", sustained), ("smoke", smoke)):
        if not cell["ok"]:
            print(f"[obs] FAIL: {name} overhead "
                  f"{cell['overhead_frac'] * 100:.2f}% exceeds "
                  f"{args.budget * 100:.0f}% budget in all "
                  f"{len(cell['attempts'])} attempts", file=sys.stderr)
            rc = 1
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
