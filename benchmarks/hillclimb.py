"""§Perf hillclimb driver: lower+compile VARIANTS of the three chosen cells and
record the roofline-term deltas (hypothesis -> change -> before/after).

Run inside the dryrun environment (512 host devices):
    PYTHONPATH=src REPRO_DRYRUN_XLA_FLAGS=--xla_force_host_platform_device_count=512 \
        python -m benchmarks.hillclimb [cell ...]

Each variant writes experiments/hillclimb/<cell>__<variant>.json.
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      os.environ.get("REPRO_DRYRUN_XLA_FLAGS",
                                     "--xla_force_host_platform_device_count=512"))

import dataclasses
import json
import pathlib
import sys
import time

import jax

from benchmarks.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, model_flops_per_device
from repro.configs import SHAPES, get_arch
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "hillclimb"


def measure(arch, shape_id: str, tag: str, *, multi_pod=False, force=False):
    OUT.mkdir(parents=True, exist_ok=True)
    out_path = OUT / f"{arch.name}__{shape_id}__{tag}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        print(_fmt(rec))
        return rec
    shape = SHAPES[shape_id]
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        cell = build_cell(arch, shape, mesh)
        compiled = jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                           out_shardings=cell["out_shardings"],
                           donate_argnums=cell["donate_argnums"]) \
            .lower(*cell["args"]).compile()
        la = hlo_cost.analyze(compiled.as_text())
        mem = compiled.memory_analysis()
    mf = model_flops_per_device(get_arch(arch.name).name
                                if arch.name in _KNOWN else arch.name,
                                shape_id, mesh.devices.size) \
        if arch.name in _KNOWN else None
    rec = {
        "cell": f"{arch.name} x {shape_id}", "variant": tag,
        "t_compute_s": la["flops"] / PEAK_FLOPS,
        "t_memory_s": la["traffic_bytes"] / HBM_BW,
        "t_collective_s": la["collectives"].get("total", 0) / LINK_BW,
        "collectives": la["collectives"],
        "flops_per_dev": la["flops"],
        "traffic_per_dev": la["traffic_bytes"],
        "temp_gib": getattr(mem, "temp_size_in_bytes", 0) / 2 ** 30,
        "args_gib": getattr(mem, "argument_size_in_bytes", 0) / 2 ** 30,
        "model_flops_per_dev": mf,
        "compile_s": round(time.time() - t0, 1),
    }
    out_path.write_text(json.dumps(rec, indent=2))
    print(_fmt(rec))
    return rec


_KNOWN = set()
try:
    from repro.configs import ARCH_IDS
    _KNOWN = set(ARCH_IDS)
except Exception:  # noqa: BLE001
    pass


def _fmt(rec):
    dom = max(("t_compute_s", "t_memory_s", "t_collective_s"),
              key=lambda k: rec[k])
    return (f"[hc] {rec['cell']} [{rec['variant']}]: "
            f"comp {rec['t_compute_s']:.3g}s mem {rec['t_memory_s']:.3g}s "
            f"coll {rec['t_collective_s']:.3g}s (dom {dom[2:-2]}) "
            f"temp {rec['temp_gib']:.1f} GiB args {rec['args_gib']:.1f} GiB")


# ---------------------------------------------------------------------------
# the three cells + variants
# ---------------------------------------------------------------------------

def yi34b_variants():
    base = get_arch("yi-34b")
    yield "v0-baseline", base
    # H1: 56 heads don't divide 16 -> baseline replicates attention weights AND
    # compute across the model axis (16x redundant attention FLOPs).  Pad the head
    # count to 64 (14% more attention math, but sharded 16 ways).
    yield "v1-pad-heads-64", dataclasses.replace(
        base, name="yi-34b", n_heads=64, sharding_overrides={"kv_heads": None})
    # H2: remat='dots' keeps matmul outputs (less recompute traffic, more memory)
    yield "v2-pad-heads+remat-dots", dataclasses.replace(
        base, name="yi-34b", n_heads=64, sharding_overrides={"kv_heads": None},
        remat="dots")


def qwen_variants():
    base = get_arch("qwen3-moe-235b-a22b")
    yield "v0-baseline-accum16", base  # steps.py clamps 32 -> 16 on 16-way data
    # H1: FSDP regathers scale with microbatch count; fewer accum steps cut the
    # collective term ~linearly while carries grow (memory headroom from the bf16
    # grad accumulator)
    yield "v1-accum8", dataclasses.replace(base, accum_steps=8)
    yield "v2-accum4", dataclasses.replace(base, accum_steps=4)


def rwkv_variants():
    base = get_arch("rwkv6-1.6b")
    yield "v0-baseline", base
    # H1: TP all-reduces on the (B,S,D) residual per layer dominate for a small
    # model; turning off TP for the tiny projections (model-axis replication,
    # data-parallel only) trades replicated params (1.6B*2B = 3.2 GB/dev, fits)
    # for zero per-layer collectives.
    yield "v1-no-tp", dataclasses.replace(
        base, sharding_overrides={"heads_x_dim": None, "ff": None,
                                  "heads": None, "vocab": None})
    # H2: batch-only sharding + fsdp to cut the replicated optimizer memory
    yield "v2-no-tp+fsdp", dataclasses.replace(
        base, fsdp=True,
        sharding_overrides={"heads_x_dim": None, "ff": None, "heads": None,
                            "vocab": None})


CELLS = {
    "yi34b": ("train_4k", yi34b_variants),
    "qwen": ("train_4k", qwen_variants),
    "rwkv": ("train_4k", rwkv_variants),
}


def main():
    want = sys.argv[1:] or list(CELLS)
    for name in want:
        shape_id, gen = CELLS[name]
        for tag, arch in gen():
            measure(arch, shape_id, tag)


if __name__ == "__main__":
    main()
