"""Paper Table 3: accuracy / precision / recall / error / time for the six
prediction algorithms, per scheduler (FIFO/Fair/Capacity) and task type
(map/reduce), via 10-fold random cross-validation on simulator logs."""

from __future__ import annotations


import numpy as np

from benchmarks.common import FULL, Timer, emit, save_json
from repro.cluster.experiment import ExperimentConfig, run_baseline
from repro.cluster.workload import WorkloadConfig
from repro.ml.cv import cross_validate

ALGOS = ["Tree", "Boost", "Glm", "CTree", "R.F.", "N.N."]


def run() -> dict:
    k = 10 if FULL else 4
    max_n = 12000 if FULL else 4000
    n_single = 150 if FULL else 60
    table: dict = {}
    for sched in ("fifo", "fair", "capacity"):
        cfg = ExperimentConfig(workload=WorkloadConfig(n_single=n_single,
                                                       n_chains=12, seed=11))
        _, trace, _ = run_baseline(sched, cfg)
        (mx, my), (rx, ry) = trace.datasets()
        table[sched] = {"n_map": int(len(my)), "n_reduce": int(len(ry))}
        for kind, X, y in (("map", mx, my), ("reduce", rx, ry)):
            if len(y) < 100 or len(np.unique(y)) < 2:
                continue
            for algo in ALGOS:
                with Timer() as t:
                    res = cross_validate(algo, X, y, k=k, max_n=max_n, seed=0)
                table[sched][f"{kind}/{algo}"] = res
                emit(f"table3/{sched}/{kind}/{algo}", res["time_ms"] * 1e3,
                     f"acc={res['accuracy']*100:.1f};pre={res['precision']*100:.1f};"
                     f"rec={res['recall']*100:.1f};err={res['error']*100:.1f}")
    save_json("table3_predictors", table)
    return table


if __name__ == "__main__":
    run()
