"""Kernel micro-benchmarks: wall time of the XLA reference path on CPU (the
compiled-TPU numbers come from the roofline; interpret-mode timing is meaningless)
plus allclose re-verification of the Pallas kernels at benchmark shapes."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref


def _bench(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    key = jax.random.PRNGKey(0)
    B, S, H, Hkv, D = 2, 1024, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(key, (B, S, Hkv, D), jnp.bfloat16)
    v = jax.random.normal(key, (B, S, Hkv, D), jnp.bfloat16)

    flash = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, impl="xla"))
    us = _bench(flash, q, k, v)
    emit("kernel/flash_attention_xla_1k", us, f"B{B}S{S}H{H}")

    kv_len = jnp.full((B,), S, jnp.int32)
    dec = jax.jit(lambda q1, k, v: ops.decode_attention(q1, k, v, kv_len,
                                                        impl="xla"))
    us = _bench(dec, q[:, :1], k, v)
    emit("kernel/decode_attention_xla_1k", us, f"B{B}S{S}")

    Hr, Dh = 8, 64
    r = jax.random.normal(key, (B, 256, Hr, Dh), jnp.float32)
    w = jax.nn.sigmoid(jax.random.normal(key, (B, 256, Hr, Dh)))
    u = jax.random.normal(key, (Hr, Dh)) * 0.1
    s0 = jnp.zeros((B, Hr, Dh, Dh))
    rw = jax.jit(lambda r, w: ops.rwkv6_scan(r, r, r, w, u, s0, impl="xla"))
    us = _bench(rw, r, w)
    emit("kernel/rwkv6_scan_xla_256", us, f"B{B}H{Hr}")

    x = jax.random.normal(key, (B, 256, Hr, Dh), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (B, 256, Hr)))
    A = -jnp.exp(jax.random.normal(key, (Hr,)) * 0.3)
    Bm = jax.random.normal(key, (B, 256, 16))
    ssd_s0 = jnp.zeros((B, Hr, Dh, 16))
    ssd = jax.jit(lambda x, dt: ops.mamba2_ssd(x, dt, A, Bm, Bm, ssd_s0,
                                               impl="xla"))
    us = _bench(ssd, x, dt)
    emit("kernel/mamba2_ssd_xla_256", us, f"B{B}H{Hr}")

    # forest: the ATLAS hot path — batch of 4096 pending decisions
    rs = np.random.RandomState(0)
    Xf = jnp.asarray(rs.randn(4096, 22), jnp.float32)
    fi = jnp.asarray(rs.randint(0, 22, (64, 6)), jnp.int32)
    th = jnp.asarray(rs.randn(64, 6), jnp.float32)
    lv = jnp.asarray(rs.rand(64, 64), jnp.float32)
    fr = jax.jit(lambda X: ops.forest_infer(X, fi, th, lv, impl="xla"))
    us = _bench(fr, Xf)
    emit("kernel/forest_infer_xla_4096x64trees", us,
         f"{us/4096:.3f}us_per_decision")

    # interpret-mode correctness spot-checks at bench shapes
    got = ops.forest_infer(Xf, fi, th, lv, impl="interpret")
    want = ref.forest_infer_ref(Xf, fi, th, lv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)
    emit("kernel/forest_interpret_allclose", 0.0, "ok")


if __name__ == "__main__":
    run()
