"""Shared benchmark plumbing: CSV emission + experiment configs."""

from __future__ import annotations

import json
import os
import pathlib
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT = ROOT / "experiments"
OUT.mkdir(exist_ok=True)

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.3f},{derived}")


def save_json(name: str, obj):
    p = OUT / f"{name}.json"
    p.write_text(json.dumps(obj, indent=2, default=str))
    return p


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
        self.us = self.s * 1e6
