"""Chaos gate: the serving path must absorb faults without changing results.

``make chaos-smoke`` runs this.  Gates, all required:

1. **Fault parity** — the smoke sweep on ``--executor async`` under a seeded
   retriable FaultPlan (drops, delays, duplicates, one scheduled broker
   restart) emits SWEEP.json byte-identical to the fault-free control, with
   nonzero client-retry / broker-replay counters and *zero* fallbacks (every
   fault was absorbed by retry + idempotent replay, never by degradation).
2. **Retry-machinery overhead** — arming the full fault-tolerance path
   (request ids, per-attempt timeouts, replay slots, injector wrapping) via
   a zero-probability plan on a fault-free sweep costs within ``--budget``
   (default 10%) of the plain run in at least one of ``--attempts`` paired
   runs.  (Injected faults are excluded by construction: a dropped reply
   necessarily costs its detection timeout — that cost is the plan's, not
   the machinery's.)
3. **Outage degradation** — under a heavy early fault burst with a tight
   client deadline the sweep still completes every cell (the paper's
   graceful degradation: schedule anyway), with nonzero fallback counters.
4. **Kill-and-resume** — a ``fleet --resume`` CLI sweep SIGKILLed mid-run
   restarts from its cell ledger to byte-identical SWEEP.json.

Chaos stats land in ``experiments/CHAOS_SMOKE.json`` and are stamped into
``experiments/BENCH_<pr>.json`` under ``"chaos"``.  Non-zero exit on any
gate failure.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import re
import signal
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from common import save_json  # noqa: E402

import repro  # noqa: E402
from repro.cluster.fleet import SweepSpec, run_sweep, sweep_json  # noqa: E402
from repro.online.faults import FaultPlan  # noqa: E402

_quiet = lambda *a, **k: None

_SPEC = SweepSpec(schedulers=("fifo", "atlas-fifo"), seeds=2,
                  scenarios=("baseline",), workloads=("smoke",),
                  min_samples=40, max_train=40)

# The overhead gate runs a wider matrix so the measured fraction isn't noise
# on a sub-second baseline.
_OVERHEAD_SPEC = SweepSpec(schedulers=("fifo", "atlas-fifo"), seeds=10,
                           scenarios=("baseline",), workloads=("smoke",),
                           min_samples=40, max_train=40)

# Retriable chaos: drops + delays + duplicates + one scheduled broker
# restart, every one survivable inside the client's generous deadline, so
# the sweep must come out byte-identical.  This gate is about *correctness*
# under faults — a dropped reply necessarily costs its detection timeout,
# so wall clock is not gated here.
_PARITY_PLAN = FaultPlan(seed=7, drop=0.12, delay=0.2,
                         delay_s=(0.0005, 0.002), duplicate=0.08,
                         restart_after=(40,), max_events=24,
                         request_timeout_s=0.25, deadline_s=120.0)

# Zero-probability plan: the full fault-tolerance machinery (request ids,
# per-attempt timeouts, replay slots, injector wrapping) armed on a
# fault-free run — what the ≤10% retry-overhead budget actually measures.
# The timeout is deliberately above any barrier round's tail so no spurious
# retry pollutes the measurement.
_OVERHEAD_PLAN = FaultPlan(seed=7, max_events=0,
                           request_timeout_s=1.0, deadline_s=120.0)

# Outage chaos: a dense early burst of dropped/severed replies against a
# deadline barely above one attempt — clients exhaust their retry budget,
# predictors degrade to schedule-anyway, and the budget cap ends the outage
# so the tail of the sweep (and every done/ack) runs clean.
_OUTAGE_PLAN = FaultPlan(seed=13, drop=0.5, abrupt_close=0.2, max_events=48,
                         request_timeout_s=0.05, deadline_s=0.2)


def _fail(msg: str) -> int:
    print(f"[chaos] FAIL: {msg}", file=sys.stderr)
    return 1


def _timed_sweep(plan=None, spec=_SPEC):
    stats = {} if plan is not None else None
    t0 = time.perf_counter()
    result = run_sweep(spec, executor="async", fault_plan=plan,
                       fault_stats=stats, log=_quiet)
    return sweep_json(result), time.perf_counter() - t0, stats, result


def _cli_env():
    env = dict(os.environ)
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    return env


def _fleet_cmd(out_dir, *extra):
    return [sys.executable, "-m", "repro.cluster.fleet",
            "--schedulers", "fifo,atlas-fifo", "--seeds", "2",
            "--scenarios", "baseline", "--workloads", "smoke",
            "--min-samples", "40", "--executor", "async",
            "--out", str(out_dir), *extra]


def _gate_kill_and_resume(td: pathlib.Path) -> tuple[int, dict]:
    env = _cli_env()
    control = td / "control"
    victim = td / "victim"

    subprocess.run(_fleet_cmd(control), env=env, check=True,
                   stdout=subprocess.DEVNULL)
    control_bytes = (control / "SWEEP.json").read_text()

    # start the victim with --resume, kill it as soon as its ledger shows
    # the first finished cell — a genuinely mid-sweep SIGKILL
    proc = subprocess.Popen(_fleet_cmd(victim, "--resume"), env=env,
                            stdout=subprocess.DEVNULL)
    cells = victim / "cells"
    deadline = time.time() + 120
    while time.time() < deadline and proc.poll() is None \
            and not list(cells.glob("w1__*.json")):
        time.sleep(0.01)
    if proc.poll() is not None:
        return _fail("victim sweep finished before it could be killed "
                     "(widen the spec)"), {}
    proc.send_signal(signal.SIGKILL)
    proc.wait(timeout=30)
    n_ledger = len(list(cells.glob("w1__*.json")))
    if n_ledger == 0:
        return _fail("no ledger cells survived the kill"), {}

    # resume: finished cells come from the ledger, the rest re-run
    subprocess.run(_fleet_cmd(victim, "--resume"), env=env, check=True,
                   stdout=subprocess.DEVNULL)
    resumed_bytes = (victim / "SWEEP.json").read_text()
    if resumed_bytes != control_bytes:
        return _fail("resumed SWEEP.json differs from the uninterrupted "
                     "control"), {}
    print(f"[chaos] kill-and-resume OK: killed with {n_ledger} ledger "
          f"cells, resumed to byte-identical SWEEP.json")
    return 0, {"ledger_cells_at_kill": n_ledger}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--budget", type=float, default=0.10,
                    help="max fractional wall-clock overhead of the faulted "
                         "sweep vs the clean control")
    ap.add_argument("--attempts", type=int, default=3,
                    help="paired overhead attempts; any within budget passes")
    args = ap.parse_args(argv)

    rc = 0
    t0 = time.perf_counter()

    # -------------------------------------------------- gate 1: fault parity
    clean_bytes, t_clean, _, small_clean = _timed_sweep()
    fault_bytes, t_fault, parity_stats, _ = _timed_sweep(_PARITY_PLAN)
    if fault_bytes != clean_bytes:
        rc |= _fail("faulted SWEEP.json differs from the clean control")
    inj = parity_stats["injected"]
    if inj["drops"] == 0 or inj["delays"] == 0 or inj["restarts"] == 0:
        rc |= _fail(f"fault mix incomplete for the acceptance claim: {inj}")
    if parity_stats["client_retries"] == 0:
        rc |= _fail("no client retries — the faults never reached the "
                    "request path")
    if parity_stats["fallbacks"] != 0:
        rc |= _fail(f"{parity_stats['fallbacks']} fallbacks under retriable "
                    "chaos: parity held by luck, not retries")
    if rc == 0:
        print(f"[chaos] parity OK: {inj['events']} injected events "
              f"({inj['drops']} drops, {inj['delays']} delays, "
              f"{inj['restarts']} restart) absorbed by "
              f"{parity_stats['client_retries']} retries / "
              f"{parity_stats['replays']} replays, bytes identical "
              f"({t_fault:.2f}s vs {t_clean:.2f}s clean)")

    # ------------------------- gate 2: resilience-machinery overhead (clean)
    overhead_frac = None
    for attempt in range(args.attempts):
        _, t_off, _, _ = _timed_sweep(spec=_OVERHEAD_SPEC)
        on_bytes, t_on, on_stats, _ = _timed_sweep(_OVERHEAD_PLAN,
                                                   spec=_OVERHEAD_SPEC)
        frac = max(t_on - t_off, 0.0) / t_off
        overhead_frac = frac if overhead_frac is None \
            else min(overhead_frac, frac)
        print(f"[chaos] overhead attempt {attempt + 1}: plain {t_off:.2f}s "
              f"vs armed {t_on:.2f}s (+{frac * 100:.1f}%)")
        if frac <= args.budget:
            break
    else:
        rc |= _fail(f"retry-machinery overhead {overhead_frac * 100:.1f}% "
                    f"above {args.budget * 100:.0f}% budget in all "
                    f"{args.attempts} attempts")
    if on_stats["injected"]["events"] != 0:
        rc |= _fail("zero-probability plan injected faults — the overhead "
                    "measurement is contaminated")

    # -------------------------------------------- gate 3: outage degradation
    _, t_outage, outage_stats, outage = _timed_sweep(_OUTAGE_PLAN)
    if len(outage["cells"]) != len(small_clean["cells"]):
        rc |= _fail(f"outage sweep lost cells: {len(outage['cells'])} of "
                    f"{len(small_clean['cells'])}")
    if outage_stats["fallbacks"] == 0:
        rc |= _fail("outage never degraded the predictor — deadline too "
                    "generous for the gate to mean anything")
    else:
        print(f"[chaos] outage OK: all {len(outage['cells'])} cells "
              f"completed with {outage_stats['fallbacks']} fallbacks "
              f"({outage_stats['fallback_rows']} rows, "
              f"{t_outage:.2f}s)")

    # -------------------------------------------- gate 4: kill-and-resume
    with tempfile.TemporaryDirectory() as td:
        rc4, resume_info = _gate_kill_and_resume(pathlib.Path(td))
        rc |= rc4

    # ------------------------------------------------- artifacts + stamp
    result = {
        "ok": rc == 0,
        "parity": parity_stats is not None,
        "overhead_frac": (round(overhead_frac, 4)
                          if overhead_frac is not None else None),
        "retries": parity_stats["client_retries"] if parity_stats else None,
        "reconnects": (parity_stats["client_reconnects"]
                       if parity_stats else None),
        "replays": parity_stats["replays"] if parity_stats else None,
        "dup_requests": (parity_stats["dup_requests"]
                         if parity_stats else None),
        "injected": parity_stats["injected"] if parity_stats else None,
        "outage_fallbacks": outage_stats["fallbacks"],
        "outage_fallback_rows": outage_stats["fallback_rows"],
        "outage_cells": len(outage["cells"]),
        **resume_info,
    }
    path = save_json("CHAOS_SMOKE", result)
    print(f"[chaos] -> {path}")

    m = re.match(r"PR(\d+)", repro.PR_TAG)
    if m:
        bench_path = (pathlib.Path(__file__).resolve().parents[1]
                      / "experiments" / f"BENCH_{m.group(1)}.json")
        art = (json.loads(bench_path.read_text()) if bench_path.exists()
               else {"pr": repro.PR_TAG})
        art["chaos"] = {k: result[k] for k in
                        ("parity", "overhead_frac", "retries", "replays",
                         "dup_requests", "outage_fallbacks")}
        art["chaos"]["injected_events"] = (result["injected"] or
                                           {}).get("events")
        bench_path.write_text(json.dumps(art, indent=2, sort_keys=True)
                              + "\n")
        print(f"[chaos] stamped chaos stats into {bench_path}")

    print(f"[chaos] {'PASS' if rc == 0 else 'FAIL'} "
          f"({time.perf_counter() - t0:.1f}s total)")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
