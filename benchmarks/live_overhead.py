"""Live-telemetry gate: the wire path must observe without perturbing.

``make live-smoke`` runs this.  One TelemetryCollector (behind an
``AsyncBroker`` on ``tcp://127.0.0.1`` plus the ``/snapshot`` / ``/delta``
HTTP server) receives the smoke fleet matrix streamed live while a poller
thread curls ``/delta?since=<seq>`` mid-run.  Gates, all required:

1. **Byte parity** — the ``--obs-live`` sweep's SWEEP.json equals the
   no-telemetry run's bytes exactly (live path observes, never perturbs).
2. **Nonzero snapshot** — ``/snapshot`` reports every cell as a source with
   a nonzero frame count.
3. **Gapless deltas** — the seqs collected by the mid-run poller chain
   contiguously 1..seq with no resync.
4. **Replay equality** — folding the polled delta entries through a fresh
   collector reproduces the live aggregates bit-for-bit, and so does
   replaying the post-hoc NDJSON file of a cell run with *both* sinks
   attached (wire view == file view).
5. **Overhead** — the paired-median CPU estimator from
   ``benchmarks/obs_overhead.py``, with the on-side streaming to the live
   collector instead of a file, stays within ``--budget`` (default 5%) on
   the bench-smoke cell.  The consumer stack for this gate runs as a
   separate ``python -m repro.obs.live`` process — the way a deployment
   runs it — so ``time.process_time`` charges only the producer side
   (TransportSink thread, serialization, tcp send); collector fold CPU
   belongs to the service, not the simulator.

Live-path stats (frames/s ingested, max collector lag observed mid-run,
delta sizes) are stamped into ``experiments/BENCH_<pr>.json`` under
``"live"`` via the existing PR_TAG mechanism; the full result lands in
``experiments/LIVE_SMOKE.json``.  Non-zero exit on any gate failure.
"""

from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import os
import pathlib
import re
import statistics
import subprocess
import sys
import tempfile
import threading
import time
import urllib.request

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import obs_overhead  # noqa: E402
from common import save_json  # noqa: E402

import repro  # noqa: E402
from repro.cluster.experiment import run_scheduler  # noqa: E402
from repro.cluster.fleet import SweepSpec, run_sweep, sweep_json  # noqa: E402
from repro.obs import (LiveServer, TelemetryCollector,  # noqa: E402
                       read_ndjson)
from repro.online.server import AsyncBroker  # noqa: E402

_counter = itertools.count()

# the obs-smoke matrix: 2 schedulers x 1 seed on the bursty_tt/smoke cell
_SPEC = SweepSpec(schedulers=("fifo", "atlas-fifo"), seeds=1,
                  scenarios=("bursty_tt",), workloads=("smoke",))

_quiet = lambda *a, **k: None


def _get_json(url):
    with urllib.request.urlopen(url, timeout=30) as r:
        return json.load(r)


class _Poller(threading.Thread):
    """Mid-run ``/delta`` chain poller: collects every entry exactly once
    and tracks delta sizes + the max collector lag seen on ``/snapshot``."""

    def __init__(self, base_url: str):
        super().__init__(daemon=True, name="delta-poller")
        self.base = base_url
        self.stop_evt = threading.Event()
        self.entries: list[dict] = []
        self.delta_sizes: list[int] = []
        self.max_lag_s = 0.0
        self.resyncs = 0
        self.error: Exception | None = None

    def _poll_once(self):
        since = self.entries[-1]["seq"] if self.entries else 0
        r = _get_json(f"{self.base}/delta?since={since}")
        if r.get("resync"):
            self.resyncs += 1
        if r["frames"]:
            self.entries.extend(r["frames"])
            self.delta_sizes.append(len(r["frames"]))

    def run(self):
        n = 0
        try:
            while not self.stop_evt.is_set():
                self._poll_once()
                if n % 5 == 0:
                    h = _get_json(f"{self.base}/snapshot")["health"]
                    self.max_lag_s = max(self.max_lag_s, h["lag_max_s"])
                n += 1
                time.sleep(0.05)
            self._poll_once()            # final drain after the run ends
        except Exception as e:          # surfaced by the main thread
            self.error = e


def _fail(msg: str) -> int:
    print(f"[live] FAIL: {msg}", file=sys.stderr)
    return 1


def _live_smoke_cfg(addr: str):
    """obs_overhead-style cfg factory: the ``obs_dir`` slot becomes the
    on/off toggle for the live wire (None = off, anything = stream)."""
    def make_cfg(obs_dir, frame_every):
        cfg = obs_overhead._smoke_cfg(None, frame_every)
        if obs_dir is not None:
            cfg = dataclasses.replace(
                cfg, obs_live_addr=addr,
                obs_source=f"overhead_{next(_counter)}")
        return cfg
    return make_cfg


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pairs", type=int, default=9,
                    help="off/on pairs per overhead attempt")
    ap.add_argument("--attempts", type=int, default=3,
                    help="overhead attempts; any within budget passes")
    ap.add_argument("--budget", type=float, default=0.05,
                    help="max fractional slowdown with the live wire on")
    ap.add_argument("--frame-every", type=float, default=60.0)
    args = ap.parse_args(argv)

    rc = 0
    t0 = time.perf_counter()

    # -------------------------------------------- baseline (no telemetry)
    off_bytes = sweep_json(run_sweep(_SPEC, executor="serial", log=_quiet))
    print(f"[live] baseline sweep done ({time.perf_counter() - t0:.1f}s)")

    # ------------------------------------- live stack: broker + collector
    collector = TelemetryCollector()
    broker = AsyncBroker().start()
    broker.collector = collector
    addr = broker.serve("tcp://127.0.0.1:0")
    http = LiveServer(collector).start()
    print(f"[live] collector listening on {addr}, http {http.address}")

    poller = _Poller(http.address)
    poller.start()
    on_bytes = sweep_json(run_sweep(_SPEC, executor="serial",
                                    obs_live=addr, log=_quiet))
    # cell sinks are closed by now (SimObserver.finish), so every frame is
    # on the wire; give the broker loop a moment to drain into the collector
    deadline = time.time() + 30
    while time.time() < deadline:
        seq = collector.seq
        time.sleep(0.2)
        if collector.seq == seq:
            break
    poller.stop_evt.set()
    poller.join(timeout=30)

    # gate 1: byte parity
    if on_bytes != off_bytes:
        rc |= _fail("SWEEP.json bytes differ with --obs-live on")
    else:
        print("[live] parity OK: SWEEP.json byte-identical with the wire on")

    # gate 2: nonzero snapshot over HTTP, one source per cell
    snap = _get_json(f"{http.address}/snapshot")
    n_sources = len(snap["aggregates"])
    n_frames = snap["health"]["frames"]
    if n_frames == 0 or n_sources == 0:
        rc |= _fail("collector snapshot is empty")
    bad = [s for s, a in snap["aggregates"].items() if a["frames"] == 0]
    if bad:
        rc |= _fail(f"zero-frame sources in snapshot: {bad}")
    print(f"[live] snapshot OK: {n_sources} sources, {n_frames} frames, "
          f"{snap['health']['frames_per_s']} frames/s")

    # gate 3: gapless mid-run deltas
    if poller.error is not None:
        rc |= _fail(f"delta poller died: {poller.error!r}")
    seqs = [e["seq"] for e in poller.entries]
    if poller.resyncs or seqs != list(range(1, snap["seq"] + 1)):
        rc |= _fail(f"delta chain not gapless: {len(seqs)} entries, "
                    f"{poller.resyncs} resyncs, final seq {snap['seq']}")
    else:
        print(f"[live] deltas OK: {len(seqs)} entries gapless over "
              f"{len(poller.delta_sizes)} polls, max lag "
              f"{poller.max_lag_s:.3f}s")

    # gate 4a: polled deltas replay to the live aggregates
    replayed = TelemetryCollector()
    for e in poller.entries:
        replayed.ingest(e["frame"], source=e["source"])
    if replayed.aggregates() != collector.aggregates():
        rc |= _fail("replaying polled deltas diverges from live aggregates")
    else:
        print("[live] replay OK: polled deltas reproduce the aggregates")

    # gate 4b: wire view == post-hoc NDJSON view for a dual-sink cell
    with tempfile.TemporaryDirectory() as td:
        dual = TelemetryCollector()
        broker2 = AsyncBroker().start()
        broker2.collector = dual
        addr2 = broker2.serve("tcp://127.0.0.1:0")
        path = f"{td}/dual.ndjson"
        cfg = obs_overhead._smoke_cfg(None, args.frame_every)
        cfg = dataclasses.replace(cfg, obs_path=path, obs_live_addr=addr2,
                                  obs_source="dual")
        run_scheduler("fifo", cfg)
        deadline = time.time() + 30
        while time.time() < deadline:
            seq = dual.seq
            time.sleep(0.2)
            if dual.seq == seq:
                break
        broker2.stop()
        from_file = TelemetryCollector()
        for frame in read_ndjson(path):
            from_file.ingest(frame, source="dual")
        if from_file.aggregates() != dual.aggregates():
            rc |= _fail("NDJSON replay diverges from the wire aggregates")
        else:
            print("[live] replay OK: post-hoc NDJSON matches the wire view")

        # tear the in-process stack down before measuring: gate 5 streams
        # to its own subprocess consumer, and an idle broker loop + HTTP
        # poll thread in the measured process only add CPU noise
        final_health = collector.health()
        http.stop()
        broker.stop()

        # gate 5: live-wire overhead on the bench-smoke cell.  The
        # consumer runs as a separate process so process_time charges
        # only the producer side (sink thread + serialization + send) —
        # in deployment the collector is a service, not a thread of the
        # simulator.
        env = dict(os.environ)
        src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
        env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                             if env.get("PYTHONPATH") else src)
        consumer = subprocess.Popen(
            [sys.executable, "-m", "repro.obs.live",
             "--listen", "tcp://127.0.0.1:0", "--http", "127.0.0.1:0"],
            stdout=subprocess.PIPE, text=True, env=env)
        try:
            addr5 = json.loads(consumer.stdout.readline())["listen"]
            overhead = obs_overhead._gate(
                "live smoke", _live_smoke_cfg(addr5), td, args,
                schedulers=("fifo", "atlas-fifo"))
        finally:
            consumer.terminate()
            consumer.wait(timeout=10)
        if not overhead["ok"]:
            rc |= _fail(f"live overhead {overhead['overhead_frac'] * 100:.2f}"
                        f"% exceeds {args.budget * 100:.0f}% budget in all "
                        f"{len(overhead['attempts'])} attempts")

    # ------------------------------------------------- artifacts + stamp
    result = {
        "ok": rc == 0,
        "listen": addr,
        "sources": n_sources,
        "frames": n_frames,
        "frames_per_s": final_health["frames_per_s"],
        "max_lag_s": round(poller.max_lag_s, 3),
        "delta_polls": len(poller.delta_sizes),
        "delta_size_p50": (statistics.median(poller.delta_sizes)
                           if poller.delta_sizes else 0),
        "delta_size_max": max(poller.delta_sizes, default=0),
        "resyncs": poller.resyncs,
        "parity": on_bytes == off_bytes,
        "overhead": overhead,
    }
    path = save_json("LIVE_SMOKE", result)
    print(f"[live] -> {path}")

    m = re.match(r"PR(\d+)", repro.PR_TAG)
    if m:
        bench_path = (pathlib.Path(__file__).resolve().parents[1]
                      / "experiments" / f"BENCH_{m.group(1)}.json")
        art = (json.loads(bench_path.read_text()) if bench_path.exists()
               else {"pr": repro.PR_TAG})
        art["live"] = {k: result[k] for k in
                       ("frames", "frames_per_s", "max_lag_s",
                        "delta_size_p50", "delta_size_max", "parity")}
        art["live"]["overhead_frac"] = overhead["overhead_frac"]
        bench_path.write_text(json.dumps(art, indent=2, sort_keys=True)
                              + "\n")
        print(f"[live] stamped live stats into {bench_path}")

    print(f"[live] {'PASS' if rc == 0 else 'FAIL'} "
          f"({time.perf_counter() - t0:.1f}s total)")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
