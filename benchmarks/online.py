"""Online broker benchmark: replay a fleet decision stream through the
prediction broker (scalar vs closed-loop broker vs saturated flushes).

Fast mode replays a smoke-cell stream; REPRO_BENCH_FULL=1 replays a default
workload stream at fleet scale."""

from __future__ import annotations

from benchmarks.common import FULL, Timer, emit, save_json
from repro.online.bench import run_bench


def run() -> dict:
    if FULL:
        kw = dict(rows=40000, clients=16, workload="default",
                  scenario="bursty_tt")
    else:
        kw = dict(rows=4000, clients=12, workload="smoke",
                  scenario="bursty_tt")
    with Timer() as t:
        summary = run_bench(**kw)
    s, b, f = summary["scalar"], summary["broker"], summary["saturated"]
    emit("online/scalar", 1e6 / max(s["rows_per_s"], 1e-9),
         f"rows_s={s['rows_per_s']:.0f};dispatches={s['dispatches']}")
    emit("online/broker", 1e6 / max(b["rows_per_s"], 1e-9),
         f"rows_s={b['rows_per_s']:.0f};dispatches={b['dispatches']};"
         f"p50_ms={b['latency_ms']['p50']:.2f};"
         f"p99_ms={b['latency_ms']['p99']:.2f}")
    emit("online/saturated", 1e6 / max(f["rows_per_s"], 1e-9),
         f"rows_s={f['rows_per_s']:.0f};speedup={summary['speedup']:.1f}x;"
         f"dispatch_reduction={summary['dispatch_reduction']:.1f}x;"
         f"parity={summary['parity']};total_s={t.s:.1f}")
    save_json("online_broker", summary)
    return summary


if __name__ == "__main__":
    run()
