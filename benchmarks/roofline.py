"""§Roofline: the three roofline terms per (arch x shape x mesh) from the dry-run
artifacts, with dominant-bottleneck attribution and MODEL_FLOPS/HLO_FLOPs ratio.

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
per ICI link.  All dry-run numbers are per-device, so each term is simply
per-device-quantity / per-chip-rate (equivalent to the global/(chips*rate) form)."""

from __future__ import annotations

import json

from benchmarks.common import OUT, emit, save_json
from repro.configs import SHAPES, get_arch
from repro.models.registry import active_param_count

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN = OUT / "dryrun"


def model_flops_per_device(arch_id: str, shape_id: str, n_devices: int) -> float:
    """6*N*D for training, 2*N*D for prefill, 2*N*B per decoded token
    (N = activated params for MoE)."""
    arch = get_arch(arch_id)
    shape = SHAPES[shape_id]
    n = active_param_count(arch)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        total = 2.0 * n * tokens
    else:  # decode: one new token per sequence
        total = 2.0 * n * shape.global_batch
    return total / n_devices


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    flops = rec["cost"]["flops"]
    traffic = rec["cost"]["bytes_accessed"]
    coll = rec["collectives"].get("total", 0.0)
    t_compute = flops / PEAK_FLOPS
    t_memory = traffic / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["n_devices"])
    useful = mf / max(flops, 1.0)
    # roofline fraction: useful-FLOPs time over the bound term (how close the
    # useful work runs to the limiting resource)
    frac = (mf / PEAK_FLOPS) / max(bound, 1e-12)
    suggestions = {
        "compute": "cut non-useful FLOPs (remat recompute, causal-block waste, "
                   "padded heads) or raise arithmetic intensity per chip",
        "memory": "fuse/shrink HBM traffic: larger kernel blocks, bf16 "
                  "accumulators where safe, avoid re-materialised activations",
        "collective": "re-shard to cut cross-chip bytes: fewer FSDP regathers "
                      "(lower accum), shard_map all-to-all MoE dispatch, "
                      "hierarchical/int8-compressed gradient reduction",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_dev": mf, "hlo_flops_per_dev": flops,
        "useful_flop_ratio": useful, "roofline_fraction": frac,
        "hbm_temp_gib": rec["memory"]["temp_bytes"] / 2 ** 30,
        "hbm_args_gib": rec["memory"]["argument_bytes"] / 2 ** 30,
        "fix": suggestions[dominant],
    }


def run() -> list[dict]:
    rows = []
    for f in sorted(DRYRUN.glob("*.json")):
        rec = json.loads(f.read_text())
        row = analyze_cell(rec)
        if row is None:
            continue
        rows.append(row)
        emit(f"roofline/{row['arch']}/{row['shape']}/{row['mesh']}",
             row["t_compute_s"] * 1e6,
             f"dom={row['dominant']};frac={row['roofline_fraction']:.3f};"
             f"useful={row['useful_flop_ratio']:.2f};"
             f"tmem_us={row['t_memory_s']*1e6:.1f};"
             f"tcoll_us={row['t_collective_s']*1e6:.1f}")
    save_json("roofline", rows)
    _write_markdown(rows)
    return rows


def _write_markdown(rows):
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | dominant "
        "| 6ND/HLO | roofline frac | HBM temp GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3g} | {r['t_memory_s']:.3g} "
            f"| {r['t_collective_s']:.3g} | **{r['dominant']}** "
            f"| {r['useful_flop_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {r['hbm_temp_gib']:.1f} |")
    (OUT / "roofline.md").write_text("\n".join(lines) + "\n")


if __name__ == "__main__":
    run()
