"""Benchmark entry point — one section per paper table/figure + the assignment's
roofline/fault-tolerance benches.  Prints ``name,us_per_call,derived`` CSV.

  table3      predictor CV (acc/pre/rec/err/time x 6 algos x 3 scheds x map/reduce)
  fig4-9      finished/failed jobs+tasks, ATLAS vs base
  fig10-12    execution times
  table4      resource usage
  heartbeat   §4.2 adaptive-interval behaviour
  kernel      kernel micro-benches + interpret-mode allclose
  runtime_ft  elastic-trainer fault tolerance (ATLAS vs baseline)
  roofline    three-term roofline per dry-run cell (reads experiments/dryrun)
  sweep       fleet scenario sweep (schedulers x seeds x chaos scenarios)
  online      prediction-broker serving bench (scalar vs batched flushes)

Env: REPRO_BENCH_FULL=1 for full-size runs; default is CI-sized.
Select sections: python -m benchmarks.run [section ...]
"""

from __future__ import annotations

import subprocess
import sys

SECTIONS = ("table3", "schedulers", "sweep", "online", "heartbeat", "kernels",
            "runtime_ft", "roofline")


def _run_section(name: str) -> None:
    from benchmarks import (heartbeat, kernels, online, predictors, roofline,
                            runtime_ft, schedulers, sweep)
    {
        "table3": predictors.run,
        "schedulers": schedulers.run,
        "sweep": sweep.run,
        "online": online.run,
        "heartbeat": heartbeat.run,
        "kernels": kernels.run,
        "runtime_ft": runtime_ft.run,
        "roofline": roofline.run,
    }[name]()


def main() -> None:
    want = sys.argv[1:] or list(SECTIONS)
    if len(want) == 1:
        print(f"# === {want[0]} ===", flush=True)
        _run_section(want[0])
        return
    # one SUBPROCESS per section: the heavy sections compile hundreds of
    # distinct-shape jit programs and the accumulated JIT/LLVM state eventually
    # fails allocation in a single long-lived process
    failed = []
    for name in want:
        ret = subprocess.run([sys.executable, "-m", "benchmarks.run", name])
        if ret.returncode != 0:
            failed.append(name)
    if failed:
        print(f"# FAILED sections: {failed}")
        raise SystemExit(1)
    print("# all benchmark sections completed")


if __name__ == "__main__":
    main()
