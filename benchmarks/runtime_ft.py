"""Fault-tolerant training benchmark: the ATLAS elastic trainer vs the same loop
without prediction/duplication, same chaos seed — lost steps, rollbacks, wasted
compute, and end loss."""

from __future__ import annotations

import dataclasses
import tempfile

from benchmarks.common import FULL, emit, save_json
from repro.configs import get_arch, smoke_reduce
from repro.data import DataConfig
from repro.runtime import ElasticTrainer, RuntimeConfig


def run():
    arch = smoke_reduce(get_arch("stablelm-1.6b"))
    arch = dataclasses.replace(arch, n_layers=2, d_model=64, d_ff=128,
                               vocab_size=256, n_heads=2, n_kv_heads=2,
                               head_dim=32)
    dc = DataConfig(vocab_size=arch.vocab_size, seq_len=32, global_batch=8)
    steps = 40 if FULL else 20
    out = {}
    for atlas in (False, True):
        rcfg = RuntimeConfig(n_hosts=6, steps=steps, fail_rate=0.04,
                             degrade_rate=0.18, checkpoint_every=4,
                             atlas=atlas, seed=11)
        with tempfile.TemporaryDirectory() as d:
            res = ElasticTrainer(arch, rcfg, d, data_cfg=dc).run()
        out["atlas" if atlas else "baseline"] = res
        emit(f"runtime_ft/{'atlas' if atlas else 'baseline'}",
             res["wall_s"] * 1e6 / max(res["committed"], 1),
             f"lost={res['lost_steps']};rollbacks={res['rollbacks']};"
             f"dups={res['duplicated_shards']};ckpts={res['checkpoints']};"
             f"loss={res['final_loss']:.3f}")
    save_json("runtime_ft", out)
    return out


if __name__ == "__main__":
    run()
