"""Paper Figures 4-9 + Table 4: finished/failed jobs and tasks, execution times
and resource usage for FIFO/Fair/Capacity vs ATLAS-<base>, aggregated over seeds."""

from __future__ import annotations

import numpy as np

from benchmarks.common import FULL, emit, save_json
from repro.cluster.chaos import ChaosConfig
from repro.cluster.experiment import ExperimentConfig, compare
from repro.cluster.workload import WorkloadConfig


def run() -> dict:
    seeds = (0, 1, 2) if FULL else (0, 1)
    out: dict = {}
    for sched in ("fifo", "fair", "capacity"):
        runs = []
        for seed in seeds:
            cfg = ExperimentConfig(
                workload=WorkloadConfig(seed=7 + seed),
                chaos=ChaosConfig(seed=3 + seed),
                seed=seed)
            runs.append(compare(sched, cfg))
        agg: dict = {"base": {}, "atlas": {}, "deltas": {}}
        for part in ("base", "atlas"):
            keys = [k for k, v in runs[0][part].items()
                    if isinstance(v, (int, float))]
            agg[part] = {k: float(np.mean([r[part][k] for r in runs]))
                         for k in keys}
        agg["deltas"] = {k: float(np.mean([r["deltas"][k] for r in runs]))
                         for k in runs[0]["deltas"]}
        agg["atlas"]["stats"] = runs[0]["atlas"]["atlas"]
        out[sched] = agg
        d = agg["deltas"]
        emit(f"fig4-9/{sched}", 0.0,
             f"failed_jobs_drop={d['failed_jobs_drop_pct']:.1f}%;"
             f"failed_tasks_drop={d['failed_tasks_drop_pct']:.1f}%;"
             f"finished_jobs_gain={d['finished_jobs_gain_pct']:.1f}%;"
             f"finished_tasks_gain={d['finished_tasks_gain_pct']:.1f}%")
        emit(f"fig10-12/{sched}", agg["base"]["job_exec_time"] * 1e6,
             f"job_time_drop={d['job_time_drop_pct']:.1f}%;"
             f"matched_drop={d['job_time_matched_drop_pct']:.1f}%;"
             f"map_time={agg['base']['map_exec_time']:.0f}s->"
             f"{agg['atlas']['map_exec_time']:.0f}s")
        for res in ("cpu_ms_per_job", "mem_per_job", "hdfs_read_per_job",
                    "hdfs_write_per_job", "cpu_ms_per_task", "mem_per_task"):
            emit(f"table4/{sched}/{res}", agg["base"][res],
                 f"atlas={agg['atlas'][res]:.0f};"
                 f"drop={100*(1-agg['atlas'][res]/max(agg['base'][res],1e-9)):.1f}%")
    save_json("fig4_12_table4_schedulers", out)
    return out


if __name__ == "__main__":
    run()
