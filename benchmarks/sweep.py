"""Fleet sweep benchmark: the paper's cross-scheduler, cross-failure-regime
matrix (Figures 4-12 metrics per scenario) through the fleet engine.

Fast mode (default) runs a CI-sized smoke matrix; REPRO_BENCH_FULL=1 runs all
three baselines + their ATLAS variants over every scenario on the paper mix."""

from __future__ import annotations

from benchmarks.common import FULL, Timer, emit, save_json
from repro.cluster.fleet import SweepSpec, run_sweep, sweep_markdown
from repro.cluster.scenarios import SCENARIOS


def run() -> dict:
    if FULL:
        spec = SweepSpec(
            schedulers=("fifo", "fair", "capacity",
                        "atlas-fifo", "atlas-fair", "atlas-capacity"),
            seeds=3, scenarios=tuple(sorted(SCENARIOS)),
            workloads=("default",))
    else:
        spec = SweepSpec(schedulers=("fifo", "atlas-fifo"), seeds=2,
                         scenarios=("baseline", "bursty_tt"),
                         workloads=("smoke",))
    with Timer() as t:
        result = run_sweep(spec)
    n_cells = len(result["cells"])
    emit("fleet/sweep", t.us / max(n_cells, 1),
         f"cells={n_cells};total_s={t.s:.1f}")
    for row in result["rankings"]["overall"]:
        emit(f"fleet/overall/{row['scheduler']}", 0.0,
             f"failed_tasks={row['pct_tasks_failed']:.2f}%;"
             f"job_time={row['job_exec_time']:.1f}s")
    save_json("fleet_sweep", result)
    print(sweep_markdown(result))
    return result


if __name__ == "__main__":
    run()
