"""Deterministic synthetic data pipeline with host-sharded loading, prefetch and
elastic re-sharding.

Tokens are generated from a seeded per-shard PRNG stream (a Zipf-ish unigram mix so
losses are non-trivial), keyed by (epoch, step, shard) — any host can regenerate any
shard, which is what makes failover/elastic re-sharding trivial: after a fleet
change the new shard count just re-partitions the same global stream."""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    media_shape: tuple | None = None   # (M, D) stub frontend embeddings
    media_dtype: str = "float32"


class SyntheticStream:
    """Stateless shard generator: batch(step, shard_idx, n_shards)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rs = np.random.RandomState(cfg.seed)
        # fixed unigram distribution (Zipf-ish) + per-sequence markov-ish repeats
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks ** 1.1
        self.probs = (probs / probs.sum()).astype(np.float64)

    def batch(self, step: int, shard: int, n_shards: int) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0, (cfg.global_batch, n_shards)
        b_local = cfg.global_batch // n_shards
        rs = np.random.RandomState(
            ((cfg.seed * 1_000_003 + step) * 4096 + shard * 17 + 11) % (2 ** 32))
        toks = rs.choice(cfg.vocab_size, size=(b_local, cfg.seq_len),
                         p=self.probs).astype(np.int32)
        # inject local structure: repeat previous token with prob .25
        rep = rs.rand(b_local, cfg.seq_len) < 0.25
        for i in range(1, cfg.seq_len):
            toks[:, i] = np.where(rep[:, i], toks[:, i - 1], toks[:, i])
        out = {"tokens": toks}
        if cfg.media_shape is not None:
            M, D = cfg.media_shape
            out["media"] = rs.randn(b_local, M, D).astype(cfg.media_dtype) * 0.02
        return out


class Prefetcher:
    """Background-thread prefetch of the next `depth` host batches."""

    def __init__(self, stream: SyntheticStream, shard: int, n_shards: int,
                 depth: int = 2, start_step: int = 0):
        self.stream = stream
        self.shard, self.n_shards = shard, n_shards
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop:
            b = self.stream.batch(self._step, self.shard, self.n_shards)
            self.q.put((self._step, b))
            self._step += 1

    def next(self):
        return self.q.get()

    def stop(self):
        self._stop = True
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
