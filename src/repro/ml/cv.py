"""10-fold random cross-validation harness (paper §4.1.3): accuracy, precision,
recall, error + wall time, per algorithm."""

from __future__ import annotations

import time

import numpy as np

from repro.ml.models import ALL_MODELS


def metrics(y_true: np.ndarray, y_pred: np.ndarray) -> dict:
    tp = float(((y_pred == 1) & (y_true == 1)).sum())
    tn = float(((y_pred == 0) & (y_true == 0)).sum())
    fp = float(((y_pred == 1) & (y_true == 0)).sum())
    fn = float(((y_pred == 0) & (y_true == 1)).sum())
    tot = max(tp + tn + fp + fn, 1.0)
    return {
        "accuracy": (tp + tn) / tot,
        "precision": tp / max(tp + fp, 1.0),
        "recall": tp / max(tp + fn, 1.0),
        "error": (fp + fn) / tot,
    }


def cross_validate(model_name: str, X: np.ndarray, y: np.ndarray, *,
                   k: int = 10, seed: int = 0, max_n: int | None = 12000) -> dict:
    """Random k-fold CV.  Returns mean metrics + total wall time (ms)."""
    rng = np.random.RandomState(seed)
    if max_n is not None and X.shape[0] > max_n:
        idx = rng.choice(X.shape[0], max_n, replace=False)
        X, y = X[idx], y[idx]
    N = X.shape[0]
    perm = rng.permutation(N)
    folds = np.array_split(perm, k)
    agg = {"accuracy": [], "precision": [], "recall": [], "error": []}
    t0 = time.perf_counter()
    for i in range(k):
        test = folds[i]
        train = np.concatenate([folds[j] for j in range(k) if j != i])
        model = ALL_MODELS[model_name]()
        model.fit(X[train], y[train])
        pred = model.predict(X[test])
        m = metrics(y[test], pred)
        for kk in agg:
            agg[kk].append(m[kk])
    elapsed_ms = (time.perf_counter() - t0) * 1000.0
    out = {kk: float(np.mean(v)) for kk, v in agg.items()}
    out["time_ms"] = elapsed_ms
    out["n"] = N
    return out
