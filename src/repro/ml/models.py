"""The paper's six predictive-learning algorithms (§4.1.3), in JAX:

  GLM     logistic regression (Newton-damped Adam)
  Tree    single oblivious decision tree (variance/Gini criterion)
  CTree   conditional-inference-style tree (t-statistic-normalised gain)
  RF      random forest of oblivious trees (bagging + feature subsampling
          via per-tree bins), majority/mean vote
  Boost   gradient boosting (logistic loss, depth-3 oblivious trees)
  NN      one-hidden-layer MLP

All expose fit(X, y) / predict_proba(X) with numpy in/out; training math runs in
JAX.  Standardisation is folded into fit."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.ml.forest import ForestParams, fit_oblivious_forest, forest_predict


def _standardize_fit(X):
    mu = X.mean(0)
    sd = X.std(0) + 1e-6
    return mu, sd


class BaseModel:
    name = "base"

    def fit(self, X, y):
        raise NotImplementedError

    def predict_proba(self, X):
        raise NotImplementedError

    def predict(self, X, threshold=0.5):
        return (self.predict_proba(X) >= threshold).astype(np.float32)


# ---------------------------------------------------------------------------
# GLM
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("steps",))
def _glm_fit(X, y, steps: int = 200, lr: float = 0.3):
    N, F = X.shape
    wb = jnp.zeros((F + 1,))
    Xb = jnp.concatenate([X, jnp.ones((N, 1))], axis=1)

    def loss(wb):
        z = Xb @ wb
        return jnp.mean(jnp.logaddexp(0.0, z) - y * z) + 1e-4 * jnp.sum(wb * wb)

    g = jax.grad(loss)

    def step(carry, _):
        wb, m, v, t = carry
        gr = g(wb)
        t = t + 1
        m = 0.9 * m + 0.1 * gr
        v = 0.999 * v + 0.001 * gr * gr
        mh = m / (1 - 0.9 ** t)
        vh = v / (1 - 0.999 ** t)
        wb = wb - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return (wb, m, v, t), None

    (wb, _, _, _), _ = jax.lax.scan(step, (wb, jnp.zeros_like(wb),
                                           jnp.zeros_like(wb), 0.0),
                                    length=steps)
    return wb


class GLM(BaseModel):
    name = "Glm"

    def fit(self, X, y):
        self.mu, self.sd = _standardize_fit(X)
        Xs = jnp.asarray((X - self.mu) / self.sd)
        self.wb = _glm_fit(Xs, jnp.asarray(y))
        return self

    def predict_proba(self, X):
        Xs = (X - self.mu) / self.sd
        z = Xs @ np.asarray(self.wb[:-1]) + float(self.wb[-1])
        return 1.0 / (1.0 + np.exp(-z))


# ---------------------------------------------------------------------------
# Trees / forest
# ---------------------------------------------------------------------------

class Tree(BaseModel):
    name = "Tree"
    criterion = "var"
    depth = 6

    def fit(self, X, y):
        self.params = fit_oblivious_forest(
            X, y, n_trees=1, depth=self.depth, n_bins=16, bootstrap=False,
            criterion=self.criterion)
        return self

    def predict_proba(self, X):
        return np.clip(forest_predict(self.params, X), 0.0, 1.0)


class CTree(Tree):
    name = "CTree"
    criterion = "ctree"


class RandomForest(BaseModel):
    name = "R.F."

    def __init__(self, n_trees=24, depth=5, n_bins=8, seed=0):
        self.n_trees, self.depth, self.n_bins, self.seed = \
            n_trees, depth, n_bins, seed

    def fit(self, X, y):
        self.params = fit_oblivious_forest(
            X, y, n_trees=self.n_trees, depth=self.depth, n_bins=self.n_bins,
            bootstrap=True, seed=self.seed)
        return self

    def predict_proba(self, X):
        return np.clip(forest_predict(self.params, X), 0.0, 1.0)


class Boost(BaseModel):
    """Gradient boosting with logistic loss and shallow oblivious trees."""
    name = "Boost"

    def __init__(self, rounds=20, depth=3, lr=0.3, n_bins=8):
        self.rounds, self.depth, self.lr, self.n_bins = rounds, depth, lr, n_bins

    def fit(self, X, y):
        N = X.shape[0]
        score = np.zeros(N, np.float32)
        self.stages: list[ForestParams] = []
        prior = float(np.clip(y.mean(), 1e-3, 1 - 1e-3))
        self.bias = float(np.log(prior / (1 - prior)))
        score += self.bias
        for r in range(self.rounds):
            p = 1.0 / (1.0 + np.exp(-score))
            resid = (y - p).astype(np.float32)       # negative gradient
            hess = np.maximum(p * (1 - p), 1e-3).astype(np.float32)
            # weighted least squares on resid/hess with weight hess:
            stage = fit_oblivious_forest(
                X, resid / hess, n_trees=1, depth=self.depth, n_bins=self.n_bins,
                bootstrap=False, sample_weight=hess, seed=r)
            self.stages.append(stage)
            score += self.lr * forest_predict(stage, X)
        return self

    def predict_proba(self, X):
        score = np.full(X.shape[0], self.bias, np.float32)
        for stage in self.stages:
            score += self.lr * forest_predict(stage, X)
        return 1.0 / (1.0 + np.exp(-score))


# ---------------------------------------------------------------------------
# Neural network
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("steps", "hidden"))
def _nn_fit(X, y, key, steps: int = 400, hidden: int = 32, lr: float = 3e-3):
    N, F = X.shape
    k1, k2 = jax.random.split(key)
    params = {
        "w1": jax.random.normal(k1, (F, hidden)) / jnp.sqrt(F),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, 1)) / jnp.sqrt(hidden),
        "b2": jnp.zeros((1,)),
    }

    def fwd(p, X):
        h = jnp.tanh(X @ p["w1"] + p["b1"])
        return (h @ p["w2"] + p["b2"])[:, 0]

    def loss(p):
        z = fwd(p, X)
        return jnp.mean(jnp.logaddexp(0.0, z) - y * z)

    g = jax.grad(loss)

    def step(carry, _):
        p, m, v, t = carry
        gr = g(p)
        t = t + 1
        m = jax.tree.map(lambda m, g: 0.9 * m + 0.1 * g, m, gr)
        v = jax.tree.map(lambda v, g: 0.999 * v + 0.001 * g * g, v, gr)
        p = jax.tree.map(
            lambda p, m, v: p - lr * (m / (1 - 0.9 ** t))
            / (jnp.sqrt(v / (1 - 0.999 ** t)) + 1e-8), p, m, v)
        return (p, m, v, t), None

    zeros = jax.tree.map(jnp.zeros_like, params)
    (params, _, _, _), _ = jax.lax.scan(step, (params, zeros, zeros, 0.0),
                                        length=steps)
    return params


class NeuralNet(BaseModel):
    name = "N.N."

    def fit(self, X, y):
        self.mu, self.sd = _standardize_fit(X)
        Xs = jnp.asarray((X - self.mu) / self.sd)
        self.params = _nn_fit(Xs, jnp.asarray(y), jax.random.PRNGKey(0))
        return self

    def predict_proba(self, X):
        Xs = (X - self.mu) / self.sd
        p = self.params
        h = np.tanh(Xs @ np.asarray(p["w1"]) + np.asarray(p["b1"]))
        z = (h @ np.asarray(p["w2"]) + np.asarray(p["b2"]))[:, 0]
        return 1.0 / (1.0 + np.exp(-z))


ALL_MODELS = {
    "Tree": Tree, "Boost": Boost, "Glm": GLM, "CTree": CTree,
    "R.F.": RandomForest, "N.N.": NeuralNet,
}
