from repro.ml.cv import cross_validate, metrics
from repro.ml.forest import ForestParams, fit_oblivious_forest, forest_predict
from repro.ml.models import ALL_MODELS

__all__ = ["ALL_MODELS", "ForestParams", "cross_validate", "fit_oblivious_forest",
           "forest_predict", "metrics"]
