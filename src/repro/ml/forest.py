"""Oblivious decision trees / forests in JAX — the training side of the ATLAS
failure predictors.

Oblivious trees (one (feature, threshold) test per level, CatBoost-style) were chosen
deliberately: inference is gather-free and maps onto the MXU (see
repro/kernels/forest.py).  Training is histogram-based and fully vectorised: all
trees (and, for cross-validation, all folds) are fitted simultaneously as a batch of
per-sample weight vectors — bootstrap resampling and fold masking are both just
weights.

The split criterion is weighted variance reduction, which for {0,1} targets is
equivalent to Gini impurity up to a monotone transform; "ctree" mode normalises the
gain by pooled variance (a t-statistic-like score), approximating conditional
inference trees' test-based selection.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ForestParams:
    feat_idx: np.ndarray    # (T, D) int32
    thresholds: np.ndarray  # (T, D) float32
    leaves: np.ndarray      # (T, 2^D) float32  (mean target per leaf)


def make_bins(X: np.ndarray, n_bins: int) -> np.ndarray:
    """Per-feature candidate thresholds from quantiles: (F, Q)."""
    qs = np.linspace(0.05, 0.95, n_bins)
    thr = np.quantile(X, qs, axis=0).T.astype(np.float32)      # (F, Q)
    # de-duplicate constant features (identical quantiles give zero-gain splits)
    return thr


@functools.partial(jax.jit, static_argnames=("n_leaves", "criterion"))
def _best_split(bits, w, wy, wyy, leaf, *, n_leaves: int, criterion: str):
    """One oblivious level for a batch of trees.

    bits: (N, FQ) f32 — precomputed X[:,f] > thr[f,q] indicators.
    w/wy/wyy: (T, N) — per-tree sample weights, weight*target, weight*target^2.
    leaf: (T, N) int32 current leaf of each sample.
    Returns (gain (T, FQ), best flat candidate per tree (T,)).
    """
    L = n_leaves

    def per_tree(args):
        wt, wyt, wyyt, lt = args
        oh = jax.nn.one_hot(lt, L, dtype=jnp.float32)          # (N, L)
        stacked = jnp.stack([wt, wyt, wyyt], axis=1)           # (N, 3)
        tot = oh.T @ stacked                                   # (L, 3)
        lw = (oh * wt[:, None]).T @ bits                       # (L, FQ)
        ly = (oh * wyt[:, None]).T @ bits
        lyy = (oh * wyyt[:, None]).T @ bits
        rw = tot[:, 0:1] - lw
        ry = tot[:, 1:2] - ly
        ryy = tot[:, 2:3] - lyy
        eps = 1e-9

        def sse(s_w, s_y, s_yy):
            return s_yy - s_y * s_y / jnp.maximum(s_w, eps)

        parent = sse(tot[:, 0:1], tot[:, 1:2], tot[:, 2:3])
        child = sse(lw, ly, lyy) + sse(rw, ry, ryy)
        gain_l = parent - child                                # (L, FQ)
        gain = gain_l.sum(axis=0)                              # (FQ,)
        if criterion == "ctree":
            pooled = child.sum(axis=0) / jnp.maximum(tot[:, 0].sum(), eps)
            gain = gain / jnp.sqrt(pooled + eps)
        # degenerate splits (all left / all right) get zero gain naturally
        return gain

    gains = jax.lax.map(per_tree, (w, wy, wyy, leaf))          # (T, FQ)
    best = jnp.argmax(gains, axis=1)
    return gains, best


@functools.partial(jax.jit, static_argnames=("n_leaves",))
def _leaf_values(w, wy, leaf, *, n_leaves: int):
    def per_tree(args):
        wt, wyt, lt = args
        oh = jax.nn.one_hot(lt, n_leaves, dtype=jnp.float32)
        sw = oh.T @ wt
        sy = oh.T @ wyt
        return sy / jnp.maximum(sw, 1e-9)
    return jax.lax.map(per_tree, (w, wy, leaf))


def fit_oblivious_forest(X: np.ndarray, y: np.ndarray, *, n_trees: int = 24,
                         depth: int = 5, n_bins: int = 8, bootstrap: bool = True,
                         criterion: str = "var", seed: int = 0,
                         sample_weight: np.ndarray | None = None,
                         fold_masks: np.ndarray | None = None) -> ForestParams:
    """Fit T oblivious trees of given depth.

    fold_masks: optional (K, N) {0,1} — trains T trees *per fold* in one batch
    (weights zeroed on the fold's test samples); returns K*T trees ordered
    fold-major.  This is how the 10-fold CV trains all folds in one shot.
    """
    N, F = X.shape
    thr = make_bins(X, n_bins)                                 # (F, Q)
    Q = thr.shape[1]
    bits_np = (X[:, :, None] > thr[None]).astype(np.float32).reshape(N, F * Q)
    bits = jnp.asarray(bits_np)

    rng = np.random.RandomState(seed)
    if fold_masks is None:
        fold_masks = np.ones((1, N), np.float32)
    K = fold_masks.shape[0]
    T = n_trees * K
    if bootstrap:
        w0 = rng.poisson(1.0, size=(T, N)).astype(np.float32)
    else:
        w0 = np.ones((T, N), np.float32)
    mask = np.repeat(fold_masks, n_trees, axis=0)              # (T, N) fold-major
    w_np = w0 * mask
    if sample_weight is not None:
        w_np = w_np * sample_weight[None, :]

    w = jnp.asarray(w_np)
    yj = jnp.asarray(y, jnp.float32)
    wy = w * yj[None]
    wyy = wy * yj[None]
    leaf = jnp.zeros((T, N), jnp.int32)

    feat_idx = np.zeros((T, depth), np.int32)
    thresholds = np.zeros((T, depth), np.float32)
    thr_flat = thr.reshape(-1)
    for d in range(depth):
        _, best = _best_split(bits, w, wy, wyy, leaf,
                              n_leaves=1 << d, criterion=criterion)
        best = np.asarray(best)
        feat_idx[:, d] = best // Q
        thresholds[:, d] = thr_flat[best]
        chosen_bits = jnp.take(bits, jnp.asarray(best), axis=1).T  # (T, N)
        leaf = leaf * 2 + chosen_bits.astype(jnp.int32)

    leaves = np.asarray(_leaf_values(w, wy, leaf, n_leaves=1 << depth))
    # empty leaves fall back to the tree prior
    prior = float(np.average(y, weights=np.maximum(w_np.sum(0), 1e-9)))
    counts = np.asarray(
        jax.vmap(lambda lt, wt: jax.ops.segment_sum(wt, lt, 1 << depth))(
            leaf, w))
    leaves = np.where(counts > 0, leaves, prior).astype(np.float32)
    return ForestParams(feat_idx=feat_idx, thresholds=thresholds, leaves=leaves)


# Below this batch size the per-call dispatch overhead of the XLA/Pallas path
# dwarfs the arithmetic; the scheduler's per-decision scoring (1-13 rows per
# call) sits firmly in this regime, so it routes to the numpy mirror.
SMALL_BATCH = 64


def forest_predict_np(params: ForestParams, X: np.ndarray,
                      tree_slice: slice | None = None) -> np.ndarray:
    """Pure-numpy mirror of ``kernels.ref.forest_infer_ref`` for tiny batches."""
    x = np.asarray(X, np.float32)
    fi, th, lv = params.feat_idx, params.thresholds, params.leaves
    if tree_slice is not None:
        fi, th, lv = fi[tree_slice], th[tree_slice], lv[tree_slice]
    B = x.shape[0]
    T, D = fi.shape
    gathered = x[:, fi.reshape(-1)].reshape(B, T, D)
    bits = (gathered > th[None].astype(np.float32)).astype(np.int64)
    weights = 2 ** np.arange(D - 1, -1, -1)
    leaf_idx = (bits * weights[None, None, :]).sum(-1)          # (B, T)
    vals = lv.astype(np.float32)[np.arange(T)[None, :], leaf_idx]  # (B, T)
    return vals.mean(axis=1)


def forest_predict(params: ForestParams, X: np.ndarray, *, impl: str | None = None,
                   tree_slice: slice | None = None) -> np.ndarray:
    """Mean leaf value over trees — a probability for {0,1} targets.

    impl=None auto-routes: numpy mirror for small batches, the kernel path
    otherwise.  Pass impl="numpy"/"xla"/... to force a specific path."""
    if impl == "numpy" or (impl is None and X.shape[0] <= SMALL_BATCH):
        return forest_predict_np(params, X, tree_slice)
    from repro.kernels import ops
    fi, th, lv = params.feat_idx, params.thresholds, params.leaves
    if tree_slice is not None:
        fi, th, lv = fi[tree_slice], th[tree_slice], lv[tree_slice]
    out = ops.forest_infer(jnp.asarray(X, jnp.float32), jnp.asarray(fi),
                           jnp.asarray(th), jnp.asarray(lv), impl=impl)
    return np.asarray(out)
