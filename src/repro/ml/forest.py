"""Oblivious decision trees / forests in JAX — the training side of the ATLAS
failure predictors.

Oblivious trees (one (feature, threshold) test per level, CatBoost-style) were chosen
deliberately: inference is gather-free and maps onto the MXU (see
repro/kernels/forest.py).  Training is histogram-based and fully vectorised: all
trees (and, for cross-validation, all folds) are fitted simultaneously as a batch of
per-sample weight vectors — bootstrap resampling and fold masking are both just
weights.

The split criterion is weighted variance reduction, which for {0,1} targets is
equivalent to Gini impurity up to a monotone transform; "ctree" mode normalises the
gain by pooled variance (a t-statistic-like score), approximating conditional
inference trees' test-based selection.
"""

from __future__ import annotations

import dataclasses
import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ForestParams:
    feat_idx: np.ndarray    # (T, D) int32
    thresholds: np.ndarray  # (T, D) float32
    leaves: np.ndarray      # (T, 2^D) float32  (mean target per leaf)


def make_bins(X: np.ndarray, n_bins: int) -> np.ndarray:
    """Per-feature candidate thresholds from quantiles: (F, Q).

    Quantiles of constant / low-cardinality features repeat, and every repeat
    is the same zero-information candidate split occupying a slot in the
    (feature, quantile) candidate grid.  Each feature row keeps only its
    distinct thresholds (ascending); the tail is padded with +inf sentinels
    whose ``x > thr`` bits are identically False — a degenerate all-right
    split with exactly zero gain, so argmax never prefers one over a real
    candidate (ties resolve to the lowest flat index, which is finite)."""
    qs = np.linspace(0.05, 0.95, n_bins)
    thr = np.quantile(X, qs, axis=0).T.astype(np.float32)      # (F, Q)
    out = np.full_like(thr, np.inf)
    for f in range(thr.shape[0]):
        uniq = np.unique(thr[f])                               # sorted, distinct
        out[f, :uniq.size] = uniq
    return out


@functools.partial(jax.jit, static_argnames=("n_leaves", "criterion"))
def _best_split(bits, w, wy, wyy, leaf, *, n_leaves: int, criterion: str):
    """One oblivious level for a batch of trees.

    bits: (N, FQ) f32 — precomputed X[:,f] > thr[f,q] indicators.
    w/wy/wyy: (T, N) — per-tree sample weights, weight*target, weight*target^2.
    leaf: (T, N) int32 current leaf of each sample.
    Returns (gain (T, FQ), best flat candidate per tree (T,)).
    """
    L = n_leaves

    def per_tree(args):
        wt, wyt, wyyt, lt = args
        oh = jax.nn.one_hot(lt, L, dtype=jnp.float32)          # (N, L)
        stacked = jnp.stack([wt, wyt, wyyt], axis=1)           # (N, 3)
        tot = oh.T @ stacked                                   # (L, 3)
        lw = (oh * wt[:, None]).T @ bits                       # (L, FQ)
        ly = (oh * wyt[:, None]).T @ bits
        lyy = (oh * wyyt[:, None]).T @ bits
        rw = tot[:, 0:1] - lw
        ry = tot[:, 1:2] - ly
        ryy = tot[:, 2:3] - lyy
        eps = 1e-9

        def sse(s_w, s_y, s_yy):
            return s_yy - s_y * s_y / jnp.maximum(s_w, eps)

        parent = sse(tot[:, 0:1], tot[:, 1:2], tot[:, 2:3])
        child = sse(lw, ly, lyy) + sse(rw, ry, ryy)
        gain_l = parent - child                                # (L, FQ)
        gain = gain_l.sum(axis=0)                              # (FQ,)
        if criterion == "ctree":
            pooled = child.sum(axis=0) / jnp.maximum(tot[:, 0].sum(), eps)
            gain = gain / jnp.sqrt(pooled + eps)
        # degenerate splits (all left / all right) get zero gain naturally
        return gain

    gains = jax.lax.map(per_tree, (w, wy, wyy, leaf))          # (T, FQ)
    best = jnp.argmax(gains, axis=1)
    return gains, best


@functools.partial(jax.jit, static_argnames=("n_leaves",))
def _leaf_values(w, wy, leaf, *, n_leaves: int):
    def per_tree(args):
        wt, wyt, lt = args
        oh = jax.nn.one_hot(lt, n_leaves, dtype=jnp.float32)
        sw = oh.T @ wt
        sy = oh.T @ wyt
        return sy / jnp.maximum(sw, 1e-9)
    return jax.lax.map(per_tree, (w, wy, leaf))


def fit_oblivious_forest(X: np.ndarray, y: np.ndarray, *, n_trees: int = 24,
                         depth: int = 5, n_bins: int = 8, bootstrap: bool = True,
                         criterion: str = "var", seed: int = 0,
                         sample_weight: np.ndarray | None = None,
                         fold_masks: np.ndarray | None = None) -> ForestParams:
    """Fit T oblivious trees of given depth.

    fold_masks: optional (K, N) {0,1} — trains T trees *per fold* in one batch
    (weights zeroed on the fold's test samples); returns K*T trees ordered
    fold-major.  This is how the 10-fold CV trains all folds in one shot.
    """
    N, F = X.shape
    thr = make_bins(X, n_bins)                                 # (F, Q)
    Q = thr.shape[1]
    bits_np = (X[:, :, None] > thr[None]).astype(np.float32).reshape(N, F * Q)
    bits = jnp.asarray(bits_np)

    rng = np.random.RandomState(seed)
    if fold_masks is None:
        fold_masks = np.ones((1, N), np.float32)
    K = fold_masks.shape[0]
    T = n_trees * K
    if bootstrap:
        w0 = rng.poisson(1.0, size=(T, N)).astype(np.float32)
    else:
        w0 = np.ones((T, N), np.float32)
    mask = np.repeat(fold_masks, n_trees, axis=0)              # (T, N) fold-major
    w_np = w0 * mask
    if sample_weight is not None:
        w_np = w_np * sample_weight[None, :]

    w = jnp.asarray(w_np)
    yj = jnp.asarray(y, jnp.float32)
    wy = w * yj[None]
    wyy = wy * yj[None]
    leaf = jnp.zeros((T, N), jnp.int32)

    feat_idx = np.zeros((T, depth), np.int32)
    thresholds = np.zeros((T, depth), np.float32)
    thr_flat = thr.reshape(-1)
    for d in range(depth):
        _, best = _best_split(bits, w, wy, wyy, leaf,
                              n_leaves=1 << d, criterion=criterion)
        best = np.asarray(best)
        feat_idx[:, d] = best // Q
        thresholds[:, d] = thr_flat[best]
        chosen_bits = jnp.take(bits, jnp.asarray(best), axis=1).T  # (T, N)
        leaf = leaf * 2 + chosen_bits.astype(jnp.int32)

    leaves = np.asarray(_leaf_values(w, wy, leaf, n_leaves=1 << depth))
    # empty leaves fall back to the tree prior
    prior = float(np.average(y, weights=np.maximum(w_np.sum(0), 1e-9)))
    counts = np.asarray(
        jax.vmap(lambda lt, wt: jax.ops.segment_sum(wt, lt, 1 << depth))(
            leaf, w))
    leaves = np.where(counts > 0, leaves, prior).astype(np.float32)
    return ForestParams(feat_idx=feat_idx, thresholds=thresholds, leaves=leaves)


# Below this batch size the per-call dispatch overhead of the XLA/Pallas path
# dwarfs the arithmetic; the scheduler's per-decision scoring (1-13 rows per
# call) sits firmly in this regime, so it routes to the numpy mirror.
SMALL_BATCH = 64


def _mean_over_trees(vals: np.ndarray) -> np.ndarray:
    """Mean over axis 1 with a fixed, batch-shape-independent accumulation order.

    ``np.mean`` re-associates its pairwise reduction depending on the array
    shape, so the same row can round differently inside different batches
    (observed 1-2 ulp).  The online broker memoises probabilities and must
    return bit-identical values however requests are batched, so the tree sum
    is accumulated strictly in tree order — per-row arithmetic that cannot see
    the batch it rides in."""
    acc = vals[:, 0].astype(np.float32)                        # always a copy
    for t in range(1, vals.shape[1]):
        acc += vals[:, t]
    return acc / np.float32(vals.shape[1])


def _leaf_votes_np(fi, th, lv, x: np.ndarray) -> np.ndarray:
    """Per-(row, tree) leaf values for an oblivious forest: (B, T) float32.

    Bit patterns -> leaf indices go through a float32 dot with the power-of-two
    weights (exact for 0/1 bits and D <= 24), which is a single BLAS call
    instead of an int64 broadcast-multiply-reduce — this is the broker's
    saturated-flush floor, so per-row constants matter."""
    B = x.shape[0]
    T, D = fi.shape
    g = np.take(x, fi.reshape(-1), axis=1)                      # (B, T*D)
    bits = (g > th.reshape(1, T * D).astype(np.float32))
    weights = (1 << np.arange(D - 1, -1, -1)).astype(np.float32)
    leaf_idx = (bits.reshape(B * T, D).astype(np.float32) @ weights) \
        .astype(np.intp).reshape(B, T)
    flat_idx = leaf_idx + (np.arange(T) * lv.shape[1])[None, :]
    return np.take(lv.astype(np.float32).reshape(-1), flat_idx)


def forest_predict_np(params: ForestParams, X: np.ndarray,
                      tree_slice: slice | None = None) -> np.ndarray:
    """Pure-numpy mirror of ``kernels.ref.forest_infer_ref`` for tiny batches."""
    x = np.asarray(X, np.float32)
    fi, th, lv = params.feat_idx, params.thresholds, params.leaves
    if tree_slice is not None:
        fi, th, lv = fi[tree_slice], th[tree_slice], lv[tree_slice]
    return _mean_over_trees(_leaf_votes_np(fi, th, lv, x))


# ---------------------------------------------------------------------------
# Block-diagonal grouped inference: the serving-path hot loop
# ---------------------------------------------------------------------------

# Below this many total rows a fused flush stays on the numpy block-diagonal
# pass under impl="auto"; above it the packed layout ships to the XLA/Pallas
# grouped kernel (one device pass for the whole flush).
GROUPED_KERNEL_ROWS = 512


@dataclasses.dataclass
class PackedForests:
    """Many forests packed into one padded block-diagonal tensor layout.

    All models of a flush are padded to a common (T, D): padded levels test
    feature 0 against +inf (bits identically False), padded trees have all-zero
    leaves.  A model of true depth d stores leaf ``l`` at index ``l << (D-d)``
    so the padded bit/weight arithmetic lands on exactly the original leaf
    value — votes for real trees are bit-identical to the unpadded model.

    The same layout feeds both the numpy pass (``_leaf_votes_blockdiag``) and
    the grouped Pallas kernel (``kernels.forest.forest_infer_grouped``)."""
    feat_idx: np.ndarray    # (M, T, D) int32, zero-padded
    thresholds: np.ndarray  # (M, T, D) float32, +inf-padded
    leaves: np.ndarray      # (M, T, 2^D) float32, zero-padded / shifted
    n_trees: np.ndarray     # (M,) int32 true per-model tree counts


def pack_forests(params_list) -> PackedForests:
    """Pack per-model (T_m, D_m) forests into one padded (M, T, D) block."""
    M = len(params_list)
    T = max(p.feat_idx.shape[0] for p in params_list)
    D = max(p.feat_idx.shape[1] for p in params_list)
    if D > 24:
        raise ValueError(f"depth {D} > 24 breaks exact float32 leaf indexing")
    fi = np.zeros((M, T, D), np.int32)
    th = np.full((M, T, D), np.inf, np.float32)
    lv = np.zeros((M, T, 1 << D), np.float32)
    n_trees = np.empty(M, np.int32)
    for m, p in enumerate(params_list):
        t, d = p.feat_idx.shape
        fi[m, :t, :d] = p.feat_idx
        th[m, :t, :d] = p.thresholds
        lv[m, :t][:, np.arange(1 << d) << (D - d)] = p.leaves
        n_trees[m] = t
    return PackedForests(fi, th, lv, n_trees)


# Flush-to-flush the broker scores the same model set, so the padded blocks
# are cached by model identity (strong refs in the value keep the id()s from
# being recycled while an entry is alive).  Flushes can run concurrently from
# independent brokers, so mutation is locked.
_PACK_CACHE: dict[tuple, tuple[list, PackedForests]] = {}
_PACK_CACHE_MAX = 32
_PACK_LOCK = threading.Lock()


def _packed_for(params_list) -> PackedForests:
    key = tuple(id(p) for p in params_list)
    with _PACK_LOCK:
        hit = _PACK_CACHE.get(key)
        if hit is not None and all(a is b for a, b in
                                   zip(hit[0], params_list)):
            return hit[1]
    packed = pack_forests(params_list)
    with _PACK_LOCK:
        if len(_PACK_CACHE) >= _PACK_CACHE_MAX:
            _PACK_CACHE.pop(next(iter(_PACK_CACHE)), None)
        _PACK_CACHE[key] = (list(params_list), packed)
    return packed


def _leaf_votes_blockdiag(packed: PackedForests, x: np.ndarray,
                          seg_ids: np.ndarray) -> np.ndarray:
    """Per-(row, tree) leaf values where row r reads ONLY model seg_ids[r]'s
    block: (R, T) float32.  Every step is per-row (gather, compare, exact
    power-of-two dot, gather), so votes for row r are bit-identical to
    ``_leaf_votes_np`` on r's own model — no row is scored against trees it
    doesn't belong to, which is what makes the pass O(Σ B_m x T) instead of
    O(ΣB x ΣT)."""
    M, T, D = packed.feat_idx.shape
    L = packed.leaves.shape[2]
    R = x.shape[0]
    fi = packed.feat_idx.reshape(M, T * D)
    th = packed.thresholds.reshape(M, T * D)
    g = np.take_along_axis(x, fi[seg_ids], axis=1)              # (R, T*D)
    bits = g > th[seg_ids]
    weights = (1 << np.arange(D - 1, -1, -1)).astype(np.float32)
    leaf_idx = (bits.reshape(R * T, D).astype(np.float32) @ weights) \
        .astype(np.intp).reshape(R, T)
    flat = (seg_ids[:, None] * T + np.arange(T)[None, :]) * L + leaf_idx
    return packed.leaves.reshape(-1).take(flat)


def forest_predict_grouped(groups, *, impl: str = "numpy") -> tuple[list, int]:
    """One block-diagonal inference pass over many (ForestParams, X) groups.

    The serving broker flushes every queued prediction request — possibly from
    many independently trained predictors — as a single pass: rows are stacked
    segment-by-segment (one segment per distinct model), the models' tree
    blocks are packed into one padded tensor (``pack_forests``), and each row
    is gathered / compared / leaf-indexed against ONLY its own segment's
    block.  Because the tree mean accumulates in a fixed order
    (``_mean_over_trees``) over each model's true tree count and every other
    step is per-row, each row's probability is bit-identical to
    ``forest_predict_np(its_params, its_rows)`` regardless of which other
    groups share the flush — and regardless of the padded tail.

    Returns ``(outs, n_passes)``: one score array per group and the number of
    fused passes issued — one for the whole flush (heterogeneous model shapes
    included; they pad into the same block).  Groups that reference the *same*
    ForestParams object share one segment, so a saturated flush of many
    requests against one model costs one model's worth of trees.

    impl: "numpy" (default — strict bit-parity), "auto" (numpy below
    ``GROUPED_KERNEL_ROWS`` total rows, the XLA/Pallas grouped kernel above),
    or an explicit kernel impl ("xla"/"pallas"/"interpret") to force the
    packed device pass (kernel tree means round differently at the last ulp).
    """
    outs: list = [None] * len(groups)
    by_params: dict[int, list[int]] = {}      # id(params) -> group indices
    params_of: dict[int, ForestParams] = {}
    counts: dict[int, int] = {}
    order: list[int] = []                     # pids in first-appearance order
    total = 0
    for i, (params, X) in enumerate(groups):
        if X.shape[0] == 0:
            outs[i] = np.zeros(0, np.float32)
            continue
        pid = id(params)
        if pid not in by_params:
            by_params[pid] = []
            params_of[pid] = params
            counts[pid] = 0
            order.append(pid)
        by_params[pid].append(i)
        counts[pid] += X.shape[0]
        total += X.shape[0]
    if not total:
        return outs, 0

    # columnar row assembly: one preallocated block, segments contiguous
    first = groups[by_params[order[0]][0]][1]
    group_span: list = [None] * len(groups)
    seg_start: dict[int, int] = {}
    if len(by_params) == 1 and len(by_params[order[0]]) == 1:
        # one model, one row block (e.g. a broker column view): use it as-is
        i = by_params[order[0]][0]
        x = np.ascontiguousarray(first, np.float32)
        group_span[i] = (0, total)
        seg_start[order[0]] = 0
    else:
        x = np.empty((total, first.shape[1]), np.float32)
        pos = 0
        for pid in order:
            seg_start[pid] = pos
            for i in by_params[pid]:
                b = groups[i][1].shape[0]
                x[pos:pos + b] = groups[i][1]
                group_span[i] = (pos, pos + b)
                pos += b

    use_kernel = impl not in ("numpy", "auto") or (
        impl == "auto" and total > GROUPED_KERNEL_ROWS)
    if use_kernel:
        from repro.kernels import ops
        packed = _packed_for([params_of[p] for p in order])
        seg_sizes = np.asarray([counts[p] for p in order], np.int32)
        kernel_impl = None if impl == "auto" else impl
        scores = np.asarray(ops.forest_infer_grouped(
            x, seg_sizes, packed.feat_idx, packed.thresholds, packed.leaves,
            packed.n_trees, impl=kernel_impl), np.float32)
        for i, span in enumerate(group_span):
            if span is not None:
                outs[i] = scores[span[0]:span[1]]
        return outs, 1

    if len(order) == 1:
        # single model: the existing numpy mirror (shared tree block over the
        # stacked rows) — same arithmetic, no per-row index plumbing
        p = params_of[order[0]]
        votes = _leaf_votes_np(p.feat_idx, p.thresholds, p.leaves, x)
        means = {order[0]: _mean_over_trees(votes)}
    else:
        packed = _packed_for([params_of[p] for p in order])
        seg_ids = np.repeat(np.arange(len(order), dtype=np.intp),
                            [counts[p] for p in order])
        votes = _leaf_votes_blockdiag(packed, x, seg_ids)      # (R, T_pad)
        means = {}
        for m, pid in enumerate(order):
            s = seg_start[pid]
            t = params_of[pid].feat_idx.shape[0]
            # fixed-order mean over the model's TRUE tree count: the padded
            # tail never enters the accumulation
            means[pid] = _mean_over_trees(votes[s:s + counts[pid], :t])
    for pid in order:
        s = seg_start[pid]
        block = means[pid]
        for i in by_params[pid]:
            gs, ge = group_span[i]
            outs[i] = block[gs - s:ge - s]
    return outs, 1


def forest_predict(params: ForestParams, X: np.ndarray, *, impl: str | None = None,
                   tree_slice: slice | None = None) -> np.ndarray:
    """Mean leaf value over trees — a probability for {0,1} targets.

    impl=None auto-routes: numpy mirror for small batches, the kernel path
    otherwise.  Pass impl="numpy"/"xla"/... to force a specific path."""
    if impl == "numpy" or (impl is None and X.shape[0] <= SMALL_BATCH):
        return forest_predict_np(params, X, tree_slice)
    from repro.kernels import ops
    fi, th, lv = params.feat_idx, params.thresholds, params.leaves
    if tree_slice is not None:
        fi, th, lv = fi[tree_slice], th[tree_slice], lv[tree_slice]
    out = ops.forest_infer(jnp.asarray(X, jnp.float32), jnp.asarray(fi),
                           jnp.asarray(th), jnp.asarray(lv), impl=impl)
    return np.asarray(out)
