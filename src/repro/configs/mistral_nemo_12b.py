"""mistral-nemo-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
128k context. [hf:mistralai/Mistral-Nemo-Base-2407; hf]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,              # nemo uses 128 (d_model/40 != head_dim; explicit)
    max_position=131072,       # 128k context
    rope_theta=1000000.0,
    fsdp=True,
    shard_kv_heads=False,
    accum_steps=8,
    opt_dtype="fp32",
    source="hf:mistralai/Mistral-Nemo-Base-2407; hf",
)
