"""yi-34b [dense] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
llama-arch GQA. [arXiv:2403.04652; hf]

Note: 56 query heads do not divide the 16-way model axis (and explicit pjit arg
shardings must divide evenly).  The shipped config PADS the head count to 64 —
8 zero-initialised heads whose wo rows are zero keep the math equal to 56-head
Yi — so attention shards 16-way.  EXPERIMENTS §Perf: this took the train_4k cell
from 24.5s compute / 455s memory (replicated attention) to 6.7s / 116s and from
18.6 GiB/dev to 12.9 GiB/dev.  `n_heads_logical` records the true count."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=64,               # 56 logical + 8 padding (see note above)
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    rope_theta=5000000.0,
    fsdp=True,
    shard_kv_heads=False,
    sharding_overrides={"kv_heads": None},
    accum_steps=16,
    opt_dtype="bf16",          # 34B moments in fp32 leave no activation headroom
    source="arXiv:2403.04652; hf",
)
