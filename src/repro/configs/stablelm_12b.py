"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b; hf]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    head_dim=160,              # 5120 / 32
    rope_theta=10000.0,
    fsdp=True,                 # 12B params: shard over data for v5e HBM headroom
    shard_kv_heads=False,      # 8 kv heads on a 16-way model axis -> replicate KV
    accum_steps=8,
    opt_dtype="fp32",
    source="hf:stabilityai/stablelm-2-1_6b; hf",
)
