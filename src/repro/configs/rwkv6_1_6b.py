"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536.
Finch — data-dependent decay. [arXiv:2404.05892; unverified]"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,               # 2048 / head_dim 64 time-mix heads
    n_kv_heads=32,
    d_ff=7168,                # channel-mix hidden
    vocab_size=65536,
    head_dim=64,
    ssm=SSMConfig(kind="rwkv6", state_dim=64, head_dim=64,
                  lora_decay=64, lora_mix=32, chunk=128),
    fsdp=False,
    accum_steps=2,
    opt_dtype="fp32",
    source="arXiv:2404.05892; unverified",
)
