"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128e top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,                # per-expert hidden
    vocab_size=151936,
    head_dim=128,
    rope_theta=1000000.0,
    moe=MoEConfig(n_experts=128, top_k=8, expert_ff=1536,
                  n_shared_experts=0, capacity_factor=1.25, first_dense=0,
                  chunk_tokens=8192),  # bounds the (T*k, D) dispatch buffers
    fsdp=True,                # 235B total: must shard everything everywhere
    shard_kv_heads=False,     # 4 kv heads on 16-way model axis -> replicate
    accum_steps=32,
    opt_dtype="bf16",         # fp32 moments = 7.3 GB/chip on 256 chips; bf16 fits
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
