"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (kv=16) d_ff=1408 vocab=102400,
MoE 64e top-6, 2 shared + 64 routed, fine-grained, first layer dense.
[arXiv:2401.06066; hf]"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,            # MHA
    d_ff=1408,                # per-expert hidden (fine-grained)
    vocab_size=102400,
    head_dim=128,
    rope_theta=10000.0,
    moe=MoEConfig(n_experts=64, top_k=6, expert_ff=1408,
                  n_shared_experts=2, capacity_factor=1.25, first_dense=1),
    fsdp=True,
    shard_kv_heads=True,      # 16 kv heads / 16 = 1 per shard
    accum_steps=8,
    opt_dtype="bf16",    # fp32 moments alone are 8 GB/chip
    source="arXiv:2401.06066; hf",
)
