"""Registry of assigned architectures (``--arch <id>``) + shapes."""

from __future__ import annotations

import importlib

from repro.configs.base import (
    ArchConfig, MoEConfig, SSMConfig, HybridConfig, CrossAttnConfig, EncDecConfig,
    ShapeConfig, SHAPES, SMOKE_SHAPE, cell_supported, smoke_reduce,
)

_MODULES = {
    "stablelm-12b": "repro.configs.stablelm_12b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "yi-34b": "repro.configs.yi_34b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "llama-3.2-vision-90b": "repro.configs.llama_3_2_vision_90b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
}

ARCH_IDS = tuple(_MODULES)


def get_arch(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells():
    """Yield every assigned (arch, shape, supported, skip_reason) cell — 40 total."""
    for aid in ARCH_IDS:
        arch = get_arch(aid)
        for shape in SHAPES.values():
            ok, why = cell_supported(arch, shape)
            yield arch, shape, ok, why


__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "HybridConfig", "CrossAttnConfig",
    "EncDecConfig", "ShapeConfig", "SHAPES", "SMOKE_SHAPE", "ARCH_IDS",
    "get_arch", "get_shape", "all_cells", "cell_supported", "smoke_reduce",
]
