"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256. Cross-attn image layers every 5th layer; patch-embedding frontend is
a STUB (input_specs supplies precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""

from repro.configs.base import ArchConfig, CrossAttnConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,             # 80 self-attn + 20 cross-attn (period 5)
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
    cross_attn=CrossAttnConfig(period=5, n_media_tokens=1024),
    fsdp=True,
    shard_kv_heads=False,
    accum_steps=16,
    opt_dtype="bf16",         # 90B: fp32 moments alone would be 8.4 GB/chip
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
