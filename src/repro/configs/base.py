"""Architecture + shape configuration system.

Every assigned architecture is an ``ArchConfig``; every assigned input shape is a
``ShapeConfig``.  The (arch x shape) grid drives smoke tests, the multi-pod dry-run
and the roofline table.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_ff: int            # per-expert hidden dim
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    first_dense: int = 0      # leading layers that use a dense FFN instead of MoE
    router_aux_weight: float = 0.01
    chunk_tokens: int = 0     # >0: serialise dispatch over token chunks of this
                              # size per group (bounds the (T*k, D) gather buffers)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"      # mamba2 | rwkv6
    state_dim: int = 64       # N for mamba2; head_dim implies state for rwkv6
    head_dim: int = 64
    expand: int = 2           # d_inner = expand * d_model  (mamba2)
    conv_width: int = 4       # causal conv kernel (mamba2)
    lora_decay: int = 64      # rwkv6 data-dependent decay LoRA rank
    lora_mix: int = 32        # rwkv6 token-shift mix LoRA rank
    chunk: int = 128          # scan chunk length


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: a single shared attention block applied every `period` layers."""
    period: int = 6
    shared_attn_heads: int = 32
    shared_attn_ff: int = 8192


@dataclasses.dataclass(frozen=True)
class CrossAttnConfig:
    """Llama-3.2-vision style: every `period`-th layer cross-attends to vision tokens."""
    period: int = 5
    n_media_tokens: int = 1024


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder; the modality frontend is a stub — inputs are
    precomputed frame embeddings."""
    n_enc_layers: int = 32
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    max_position: int = 131072
    rope_theta: float = 500000.0
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    hybrid: HybridConfig | None = None
    cross_attn: CrossAttnConfig | None = None
    enc_dec: EncDecConfig | None = None
    tie_embeddings: bool = False
    # attention structure
    causal: bool = True
    sliding_window: int = 0   # 0 = full attention; >0 = window (used by hybrid @500k)
    # distribution knobs (per-arch defaults; the perf loop edits these)
    fsdp: bool = False
    decode_fsdp: bool | None = None   # None -> same as fsdp; decode-only override
    shard_kv_heads: bool = True
    sharding_overrides: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    remat: str = "full"       # full | dots | none
    accum_steps: int = 1      # gradient-accumulation microbatches (train memory knob)
    dtype: Any = jnp.bfloat16
    # optimizer memory policy (fp32 | bf16 moments); big archs need bf16 to fit v5e
    opt_dtype: str = "fp32"
    source: str = ""          # provenance tag from the assignment

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True if long-context (500k) decode is supported."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs accounting)."""
        from repro.models import registry  # lazy; avoids import cycle
        return registry.param_count(self)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_supported(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) dry-run cell runs, and why not if skipped.

    Skips follow the assignment: long_500k needs sub-quadratic attention; pure
    full-attention archs skip it (recorded in DESIGN.md §4)."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, (
            "long_500k skipped: pure full-attention arch (O(S) KV read per decoded "
            "token at S=524288 exceeds the model's published context; see DESIGN.md §4)")
    return True, ""


def smoke_reduce(arch: ArchConfig) -> ArchConfig:
    """A reduced same-family config for CPU smoke tests: tiny widths/layers/experts,
    same structural wiring (GQA ratios, MoE top-k, hybrid period, enc-dec...)."""
    kw: dict[str, Any] = dict(
        name=arch.name + "-smoke",
        n_layers=min(arch.n_layers, 4 if arch.hybrid is None else 6),
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, min(arch.n_kv_heads, 4 if arch.n_kv_heads >= arch.n_heads else 2)),
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        max_position=512,
        fsdp=False,
        remat="none",
        accum_steps=1,
        dtype=jnp.float32,
    )
    if arch.moe is not None:
        # capacity_factor 8 >= E/K makes the smoke config drop-free, so the
        # decode-vs-forward consistency test is exact; drop behaviour at tight
        # capacity is covered separately in tests/test_moe.py
        kw["moe"] = dataclasses.replace(
            arch.moe, n_experts=8, top_k=2, expert_ff=64, capacity_factor=8.0,
            n_shared_experts=min(arch.moe.n_shared_experts, 1),
            first_dense=min(arch.moe.first_dense, 1))
    if arch.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            arch.ssm, state_dim=16, head_dim=16, lora_decay=8, lora_mix=4, chunk=16)
    if arch.hybrid is not None:
        kw["hybrid"] = dataclasses.replace(
            arch.hybrid, period=3, shared_attn_heads=4, shared_attn_ff=256)
    if arch.cross_attn is not None:
        kw["cross_attn"] = dataclasses.replace(arch.cross_attn, period=2, n_media_tokens=16)
        kw["n_layers"] = 4
    if arch.enc_dec is not None:
        kw["enc_dec"] = dataclasses.replace(arch.enc_dec, n_enc_layers=2, n_frames=24)
        kw["n_layers"] = 2
    return dataclasses.replace(arch, **kw)


SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
