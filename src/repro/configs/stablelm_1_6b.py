"""stablelm-1.6b [dense] — 24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b; unverified]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,            # MHA (kv == heads)
    d_ff=5632,
    vocab_size=100352,
    head_dim=64,
    rope_theta=10000.0,
    fsdp=False,               # small enough to replicate over data
    shard_kv_heads=True,      # 32 kv heads / 16 = 2 per shard
    accum_steps=2,
    opt_dtype="fp32",
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
