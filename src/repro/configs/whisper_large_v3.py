"""whisper-large-v3 [audio] — 32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.
Encoder-decoder; conv/mel frontend is a STUB (input_specs supplies precomputed
frame embeddings). [arXiv:2212.04356; unverified]"""

from repro.configs.base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,              # decoder layers; encoder in enc_dec
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,            # MHA
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    causal=True,
    enc_dec=EncDecConfig(n_enc_layers=32, n_frames=1500),
    fsdp=True,
    shard_kv_heads=False,     # 20 heads don't divide 16; replicate KV, pad Q via d_ff shard
    sharding_overrides={"heads": None,   # 20 % 16 != 0: heads replicated
                        "vocab": None},  # 51866 % 16 != 0: embedding replicated
                                          # (133 MB bf16 — cheap); ff=5120/16 shards
    accum_steps=8,
    opt_dtype="fp32",
    source="arXiv:2212.04356; unverified",
)
