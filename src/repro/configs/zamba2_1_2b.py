"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64. Mamba2 backbone + shared attention block. [arXiv:2411.15242; hf]

At long_500k the shared attention block uses a sliding window (4096) so the KV cache
stays O(window); the Mamba2 state is O(1) — this is the hybrid path the assignment
says to run at 500k."""

from repro.configs.base import ArchConfig, HybridConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,                # shared-block MLP hidden
    vocab_size=32000,
    head_dim=64,
    ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2,
                  conv_width=4, chunk=128),
    hybrid=HybridConfig(period=6, shared_attn_heads=32, shared_attn_ff=8192),
    sliding_window=4096,
    fsdp=False,
    accum_steps=8,   # d_inner=2x width: per-token state memory is 2x a dense arch
    opt_dtype="fp32",
    source="arXiv:2411.15242; hf",
)
