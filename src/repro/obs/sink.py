"""Telemetry sinks: where per-tick frames go.

A frame is one JSON-able dict (see ``instrument.SimObserver.frame``).  The
sink protocol is deliberately tiny — ``emit(frame)`` + ``close()`` — so the
file sink here and the future async-transport sink (ROADMAP:
broker-as-a-service) are interchangeable: the instrumentation layer never
knows whether frames land on disk, in memory, or on a wire.
"""

from __future__ import annotations

import json
import pathlib


class Sink:
    """Protocol: accepts frames one at a time.  Subclasses override both."""

    def emit(self, frame: dict):
        raise NotImplementedError

    def close(self):
        pass

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
        return False


class MemorySink(Sink):
    """Collects frames in a list (tests, in-process dashboard rendering)."""

    def __init__(self):
        self.frames: list[dict] = []

    def emit(self, frame: dict):
        self.frames.append(frame)


class NDJSONSink(Sink):
    """One JSON object per line, append-only.  ``emit`` only appends the
    frame to a buffer; serialization AND the write happen together every
    ``flush_every`` frames (and on close).  Batching matters twice over: a
    live reader (``tail -f`` or the dashboard) stays at most ``flush_every``
    frames behind while the sim loop avoids a write syscall per frame, and
    encoding frames back-to-back at flush time runs warm instead of paying
    cold-cache json costs in the middle of the event loop (the overhead
    budget in ``benchmarks/obs_overhead.py`` is the forcing function).
    Pass ``flush_every=1`` for strict frame-at-a-time streaming.  Emitted
    dicts are serialized at flush time, so callers must hand over ownership
    (never mutate a frame after emit)."""

    def __init__(self, path, flush_every: int = 32):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._f = self.path.open("w")
        self.flush_every = max(int(flush_every), 1)
        self.n_frames = 0
        self._buf: list[dict] = []

    def emit(self, frame: dict):
        self._buf.append(frame)
        self.n_frames += 1
        if len(self._buf) >= self.flush_every:
            self._flush()

    def _flush(self):
        # compact separators, insertion order: frames are built with a fixed
        # deterministic key order already, and skipping sort_keys + padding
        # spaces keeps the per-frame encode inside the telemetry budget
        dumps = json.dumps
        self._f.write("".join(
            [dumps(f, separators=(",", ":")) + "\n" for f in self._buf]))
        self._buf.clear()
        self._f.flush()

    def close(self):
        if self._f is not None:
            if self._buf:
                self._flush()
            self._f.close()
            self._f = None


class TeeSink(Sink):
    """Fan one frame stream out to several sinks (file + memory, say)."""

    def __init__(self, *sinks: Sink):
        self.sinks = sinks

    def emit(self, frame: dict):
        for s in self.sinks:
            s.emit(frame)

    def close(self):
        for s in self.sinks:
            s.close()


class TransportSink(Sink):
    """Streams frames over a ``repro.online.transport`` comm to a serving
    ``AsyncBroker`` (``{"op": "telemetry", "frame": …}``), which forwards
    them to whatever Sink it was configured with — the live-dashboard wire
    the ROADMAP asks for, on the same transport the prediction traffic uses.

    ``emit`` blocks until the frame is on the channel, so a slow or wedged
    collector applies backpressure here instead of growing an unbounded
    buffer (inproc: bounded channel; tcp: kernel socket buffer).  Pass the
    broker's own ``loop`` for ``inproc://`` addresses (inproc channels are
    loop-local); tcp addresses may instead let the sink run a private loop
    thread."""

    def __init__(self, address: str, loop=None, **connect_kw):
        import asyncio
        import threading

        from repro.online.transport import SyncComm
        self.address = address
        self._own_loop = loop is None
        if self._own_loop:
            loop = asyncio.new_event_loop()
            t = threading.Thread(target=loop.run_forever, daemon=True,
                                 name="transport-sink")
            t.start()
        self._loop = loop
        self._comm = SyncComm.connect(address, loop, **connect_kw)
        self.n_frames = 0

    def emit(self, frame: dict):
        self._comm.send({"op": "telemetry", "frame": frame})
        self.n_frames += 1

    def close(self):
        if self._comm is not None:
            self._comm.close()
            self._comm = None
            if self._own_loop:
                self._loop.call_soon_threadsafe(self._loop.stop)


def read_ndjson(path) -> list[dict]:
    """Load a frame stream back (skips blank lines)."""
    p = pathlib.Path(path)
    if not p.exists():
        return []
    return [json.loads(line) for line in p.read_text().splitlines() if line]
