"""Telemetry sinks: where per-tick frames go.

A frame is one JSON-able dict (see ``instrument.SimObserver.frame``).  The
sink protocol is deliberately tiny — ``emit(frame)`` + ``close()`` — so the
file sink here and the future async-transport sink (ROADMAP:
broker-as-a-service) are interchangeable: the instrumentation layer never
knows whether frames land on disk, in memory, or on a wire.
"""

from __future__ import annotations

import json
import pathlib
import threading
import time

# process-wide event loop shared by TransportSinks (see telemetry_loop())
_shared_loop = None
_shared_loop_lock = threading.Lock()


def telemetry_loop():
    """The process-wide daemon event loop for ``tcp://`` TransportSinks.

    Spawning a loop thread per sink is measurable against a short fleet
    cell (thread + selector setup and teardown land inside the telemetry
    overhead budget), so producers that open one sink per run — the fleet's
    ``--obs-live`` path, ``bench --obs-live`` — share one lazily-started
    loop per process instead.  Sinks given a loop never own it, so
    ``TransportSink.close()`` leaves this one running for the next run.
    Not for ``inproc://`` addresses: inproc channels are loop-local, pass
    the broker's own loop for those."""
    global _shared_loop
    import asyncio
    with _shared_loop_lock:
        if _shared_loop is None or _shared_loop.is_closed():
            loop = asyncio.new_event_loop()
            threading.Thread(target=loop.run_forever, daemon=True,
                             name="telemetry-loop").start()
            _shared_loop = loop
        return _shared_loop


class Sink:
    """Protocol: accepts frames one at a time.  Subclasses override both."""

    def emit(self, frame: dict):
        raise NotImplementedError

    def close(self):
        pass

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
        return False


class MemorySink(Sink):
    """Collects frames in a list (tests, in-process dashboard rendering)."""

    def __init__(self):
        self.frames: list[dict] = []

    def emit(self, frame: dict):
        self.frames.append(frame)


class NDJSONSink(Sink):
    """One JSON object per line, append-only.  ``emit`` only appends the
    frame to a buffer; serialization AND the write happen together every
    ``flush_every`` frames (and on close).  Batching matters twice over: a
    live reader (``tail -f`` or the dashboard) stays at most ``flush_every``
    frames behind while the sim loop avoids a write syscall per frame, and
    encoding frames back-to-back at flush time runs warm instead of paying
    cold-cache json costs in the middle of the event loop (the overhead
    budget in ``benchmarks/obs_overhead.py`` is the forcing function).
    Pass ``flush_every=1`` for strict frame-at-a-time streaming.  Emitted
    dicts are serialized at flush time, so callers must hand over ownership
    (never mutate a frame after emit)."""

    def __init__(self, path, flush_every: int = 32):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._f = self.path.open("w")
        self.flush_every = max(int(flush_every), 1)
        self.n_frames = 0
        self._buf: list[dict] = []

    def emit(self, frame: dict):
        self._buf.append(frame)
        self.n_frames += 1
        if len(self._buf) >= self.flush_every:
            self._flush()

    def _flush(self):
        # compact separators, insertion order: frames are built with a fixed
        # deterministic key order already, and skipping sort_keys + padding
        # spaces keeps the per-frame encode inside the telemetry budget
        dumps = json.dumps
        self._f.write("".join(
            [dumps(f, separators=(",", ":")) + "\n" for f in self._buf]))
        self._buf.clear()
        self._f.flush()

    def close(self):
        if self._f is not None:
            if self._buf:
                self._flush()
            self._f.close()
            self._f = None


class TeeSink(Sink):
    """Fan one frame stream out to several sinks (file + memory, say)."""

    def __init__(self, *sinks: Sink):
        self.sinks = sinks

    def emit(self, frame: dict):
        for s in self.sinks:
            s.emit(frame)

    def close(self):
        for s in self.sinks:
            s.close()


class TransportSink(Sink):
    """Streams frames over a ``repro.online.transport`` comm to a serving
    ``AsyncBroker`` (``{"op": "telemetry", "frame": …}``), which forwards
    them to whatever Sink it was configured with — the live-dashboard wire
    the ROADMAP asks for, on the same transport the prediction traffic uses.

    ``emit`` blocks until the frame is on the channel, so a slow or wedged
    collector applies backpressure here instead of growing an unbounded
    buffer (inproc: bounded channel; tcp: kernel socket buffer).  Pass the
    broker's own ``loop`` for ``inproc://`` addresses (inproc channels are
    loop-local); tcp addresses may instead let the sink run a private loop
    thread, or share the process-wide :func:`telemetry_loop`.

    ``source`` names this producer on the wire: the message then carries
    ``source`` plus a 1-based per-sink sequence ``n``, which the collector
    side uses to spot gaps and reconnects across cells.  Without a source
    the message is the bare two-key form earlier PRs shipped.

    Like :class:`NDJSONSink`, frames can batch: with ``flush_every > 1``
    emit buffers and every flush ships one ``{"op": "telemetry", "frames":
    [{"frame": …, "n": …}, …]}`` message (a cross-thread send round-trip
    per *batch* instead of per frame — the wire's version of the overhead
    budget).  ``flush_interval_s`` bounds liveness: a flush also triggers
    when that much wall time passed since the last one, so a slow real-time
    producer still reaches the live dashboard promptly.  Per-frame ``n`` is
    assigned at emit time, so gap/reconnect accounting is batch-blind.

    Telemetry must never take the sim down with it: when the consumer dies
    (broker crash, restart window) a failed send marks the comm down,
    frames keep buffering up to ``max_buffer`` (oldest dropped beyond that
    — ``n_dropped`` counts them, and the per-frame ``n`` lets the collector
    see the gap), and each later flush retries the connection behind a
    deterministic capped backoff (``faults.backoff_delay``).  On reconnect
    the whole surviving buffer ships at once and the collector's wire
    accounting records one reconnect.  Set ``reconnect=False`` to restore
    the old raise-on-failure behavior."""

    def __init__(self, address: str, loop=None, source: str | None = None,
                 flush_every: int = 1, flush_interval_s: float = 0.25,
                 reconnect: bool = True, max_buffer: int = 4096,
                 backoff_base_s: float = 0.05, backoff_cap_s: float = 1.0,
                 **connect_kw):
        import asyncio

        from repro.online.transport import SyncComm
        self.address = address
        self.source = source
        self.flush_every = max(int(flush_every), 1)
        self.flush_interval_s = flush_interval_s
        self.reconnect = reconnect
        self.max_buffer = max(int(max_buffer), 1)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self._own_loop = loop is None
        self._thread = None
        if self._own_loop:
            loop = asyncio.new_event_loop()
            self._thread = threading.Thread(
                target=loop.run_forever, daemon=True, name="transport-sink")
            self._thread.start()
        self._loop = loop
        self._comm = SyncComm.connect(address, loop, **connect_kw)
        self._connect_kw = connect_kw
        self._closed = False
        self.n_frames = 0
        self.n_reconnects = 0
        self.n_dropped = 0
        self.n_send_errors = 0
        self._retry_attempt = 0
        self._retry_at = 0.0
        self._buf: list[tuple[dict, int]] = []
        self._last_flush = time.monotonic()

    def emit(self, frame: dict):
        if self._closed:
            raise RuntimeError(
                f"TransportSink({self.address!r}) is closed")
        n = self.n_frames + 1
        self._buf.append((frame, n))
        self.n_frames = n
        if (len(self._buf) >= self.flush_every
                or time.monotonic() - self._last_flush
                >= self.flush_interval_s):
            self._flush()

    def _build(self, batch) -> dict:
        if len(batch) == 1:
            frame, n = batch[0]
            msg = {"op": "telemetry", "frame": frame}
            if self.source is not None:
                msg["source"] = self.source
                msg["n"] = n
        else:
            msg = {"op": "telemetry",
                   "frames": [{"frame": f, "n": n} for f, n in batch]}
            if self.source is not None:
                msg["source"] = self.source
        return msg

    def _flush(self):
        if not self._buf:
            return
        if self._comm is None and not self._reconnect_now():
            self._trim()
            return
        try:
            self._comm.send(self._build(self._buf))
        except Exception:
            if not self.reconnect:
                raise
            self._mark_down()
            self._trim()
            return
        self._buf = []
        self._last_flush = time.monotonic()
        self._retry_attempt = 0

    # ---------------------------------------------------------- reconnection
    def _mark_down(self):
        self.n_send_errors += 1
        if self._comm is not None:
            try:
                self._comm.close(timeout=1.0)
            except Exception:
                pass
            self._comm = None
        self._arm_backoff()

    def _arm_backoff(self):
        from repro.online.faults import backoff_delay
        self._retry_at = time.monotonic() + backoff_delay(
            min(self._retry_attempt, 16), base=self.backoff_base_s,
            cap=self.backoff_cap_s)
        self._retry_attempt += 1

    def _reconnect_now(self) -> bool:
        """One reconnect attempt, rate-limited by the backoff clock (the
        sim path must never spin on a dead consumer)."""
        if time.monotonic() < self._retry_at:
            return False
        from repro.online.transport import SyncComm
        try:
            self._comm = SyncComm.connect(self.address, self._loop,
                                          timeout=self.backoff_cap_s,
                                          **self._connect_kw)
        except Exception:
            self._arm_backoff()
            return False
        self.n_reconnects += 1
        return True

    def _trim(self):
        n_over = len(self._buf) - self.max_buffer
        if n_over > 0:
            del self._buf[:n_over]
            self.n_dropped += n_over

    def close(self):
        if not self._closed:
            self._closed = True
            if self._buf and (self._comm is not None
                              or self._reconnect_now()):
                try:
                    self._comm.send(self._build(self._buf))
                    self._buf = []
                except Exception:
                    pass                 # consumer already gone: best effort
            if self._comm is not None:
                self._comm.close()
                self._comm = None
            if self._own_loop:
                # stop AND join the private loop thread, then close the
                # loop: a daemon thread left spinning here outlives the
                # sink and leaks an fd + selector per closed sink
                self._loop.call_soon_threadsafe(self._loop.stop)
                if self._thread is not None:
                    self._thread.join(timeout=10.0)
                    self._thread = None
                if not self._loop.is_running():
                    self._loop.close()


def read_ndjson(path, *, return_partial: bool = False):
    """Load a frame stream back (skips blank lines).

    A truncated *trailing* line is tolerated: ``NDJSONSink`` batches its
    flushes, so a tail-follow reader (the live view, ``dashboard.py`` mid-
    run) can catch the file between ``write`` and the newline landing.
    Complete frames are returned and the partial tail is counted; corruption
    anywhere *else* in the file still raises.  With ``return_partial=True``
    returns ``(frames, n_partial)`` where ``n_partial`` is 0 or 1."""
    p = pathlib.Path(path)
    if not p.exists():
        return ([], 0) if return_partial else []
    lines = p.read_text().splitlines()
    last = len(lines) - 1
    frames: list[dict] = []
    n_partial = 0
    for i, line in enumerate(lines):
        if not line:
            continue
        try:
            frames.append(json.loads(line))
        except json.JSONDecodeError:
            if i == last:
                n_partial = 1
            else:
                raise
    return (frames, n_partial) if return_partial else frames
