"""Chart core shared by the static dashboard and the live view.

Everything that turns a frame stream into inline-SVG HTML lives here:
``repro.obs.dashboard`` (the static CLI) and ``repro.obs.live`` (the
incremental HTTP view) both call :func:`render_html` — one render path, two
consumers, so a chart added here shows up in both.  The live server passes
``refresh=`` to get a self-refreshing document re-rendered from the
collector's in-memory frame window (incremental re-render: no file reads).

Color/spec discipline follows the repo's viz rules: categorical slots in
fixed order, a single-hue sequential ramp for the heatmap, text in ink
tokens (never series colors), hairline gridlines, light/dark via CSS custom
properties, and a table twin under every chart.
"""

from __future__ import annotations

import html

# reference palette (validated): categorical slots, sequential blue ramp,
# status steps, chrome ink.  Light / dark pairs swap via CSS custom props.
_CSS = """
:root { color-scheme: light dark; }
body {
  margin: 0; padding: 24px;
  font-family: system-ui, -apple-system, "Segoe UI", sans-serif;
  background: var(--page); color: var(--text-1);
}
.viz-root {
  --page: #f9f9f7; --surface-1: #fcfcfb;
  --text-1: #0b0b0b; --text-2: #52514e; --muted: #898781;
  --grid: #e1e0d9; --axis: #c3c2b7;
  --border: rgba(11,11,11,0.10);
  --series-1: #2a78d6; --series-2: #eb6834; --series-3: #1baf7a;
  --status-good: #0ca30c; --status-critical: #d03b3b;
}
@media (prefers-color-scheme: dark) {
  :root:where(:not([data-theme="light"])) .viz-root {
    --page: #0d0d0d; --surface-1: #1a1a19;
    --text-1: #ffffff; --text-2: #c3c2b7; --muted: #898781;
    --grid: #2c2c2a; --axis: #383835;
    --border: rgba(255,255,255,0.10);
    --series-1: #3987e5; --series-2: #d95926; --series-3: #199e70;
    --status-good: #0ca30c; --status-critical: #d03b3b;
  }
}
h1 { font-size: 20px; margin: 0 0 4px; }
.sub { color: var(--text-2); font-size: 13px; margin-bottom: 20px; }
.tiles { display: flex; flex-wrap: wrap; gap: 12px; margin-bottom: 20px; }
.tile {
  background: var(--surface-1); border: 1px solid var(--border);
  border-radius: 8px; padding: 12px 16px; min-width: 120px;
}
.tile .v { font-size: 28px; font-weight: 600; }
.tile .k { font-size: 12px; color: var(--text-2); margin-top: 2px; }
.card {
  background: var(--surface-1); border: 1px solid var(--border);
  border-radius: 8px; padding: 16px; margin-bottom: 20px;
}
.card h2 { font-size: 14px; margin: 0 0 2px; }
.card .note { font-size: 12px; color: var(--text-2); margin: 0 0 10px; }
.legend { font-size: 12px; color: var(--text-2); margin: 6px 0 0;
          display: flex; gap: 16px; flex-wrap: wrap; }
.legend .sw { display: inline-block; width: 10px; height: 10px;
              border-radius: 2px; margin-right: 5px;
              vertical-align: baseline; }
svg { display: block; max-width: 100%; }
svg text { font-family: inherit; font-size: 11px; fill: var(--muted); }
details { margin-top: 10px; font-size: 12px; }
details summary { color: var(--text-2); cursor: pointer; }
table { border-collapse: collapse; margin-top: 8px; font-size: 12px; }
th, td { text-align: right; padding: 3px 10px;
         border-bottom: 1px solid var(--grid);
         font-variant-numeric: tabular-nums; }
th { color: var(--text-2); font-weight: 600; }
td:first-child, th:first-child { text-align: left; }
a { color: var(--series-1); }
"""

# sequential blue ramp, light -> dark = low -> high (steps 100..700)
_SEQ = ("#cde2fb", "#9ec5f4", "#6da7ec", "#3987e5",
        "#256abf", "#1c5cab", "#104281", "#0d366b")


def _fmt(v, nd=2) -> str:
    """Compact number label: trims trailing zeros, SI-suffixes thousands."""
    if v is None:
        return "—"
    a = abs(v)
    if a >= 1e6:
        return f"{v / 1e6:.1f}M".replace(".0M", "M")
    if a >= 1e4:
        return f"{v / 1e3:.1f}k".replace(".0k", "k")
    s = f"{v:.{nd}f}".rstrip("0").rstrip(".")
    return s if s not in ("", "-") else "0"


def _ticks(lo: float, hi: float, n: int = 5) -> list[float]:
    """~n 'nice' tick positions covering [lo, hi]."""
    if hi <= lo:
        return [lo]
    raw = (hi - lo) / max(n, 1)
    mag = 10 ** len(str(int(raw))) / 10 if raw >= 1 else 1.0
    while mag > raw:
        mag /= 10
    step = next(s * mag for s in (1, 2, 5, 10) if s * mag >= raw)
    t, out = (int(lo / step)) * step, []
    while t <= hi + 1e-9:
        if t >= lo - 1e-9:
            out.append(round(t, 10))
        t += step
    return out or [lo]


class _Plot:
    """Shared frame: margins, linear scales, gridlines, axis labels."""

    def __init__(self, w=680, h=220, ml=48, mr=12, mt=10, mb=26):
        self.w, self.h = w, h
        self.ml, self.mr, self.mt, self.mb = ml, mr, mt, mb
        self.pw, self.ph = w - ml - mr, h - mt - mb
        self.parts: list[str] = []

    def scales(self, x0, x1, y0, y1):
        x0, x1 = (x0, x1 + 1) if x1 <= x0 else (x0, x1)
        y0, y1 = (y0, y1 + 1) if y1 <= y0 else (y0, y1)
        self.sx = lambda v: self.ml + (v - x0) / (x1 - x0) * self.pw
        self.sy = lambda v: self.mt + (1 - (v - y0) / (y1 - y0)) * self.ph
        self.xlim, self.ylim = (x0, x1), (y0, y1)

    def grid(self, x_unit="", y_fmt=_fmt):
        for ty in _ticks(*self.ylim, 4):
            y = self.sy(ty)
            self.parts.append(
                f'<line x1="{self.ml}" y1="{y:.1f}" x2="{self.ml + self.pw}"'
                f' y2="{y:.1f}" stroke="var(--grid)" stroke-width="1"/>'
                f'<text x="{self.ml - 6}" y="{y + 3.5:.1f}"'
                f' text-anchor="end">{y_fmt(ty)}</text>')
        for tx in _ticks(*self.xlim, 6):
            x = self.sx(tx)
            self.parts.append(
                f'<text x="{x:.1f}" y="{self.h - 8}" text-anchor="middle">'
                f'{_fmt(tx)}{x_unit}</text>')
        base = self.mt + self.ph
        self.parts.append(
            f'<line x1="{self.ml}" y1="{base}" x2="{self.ml + self.pw}"'
            f' y2="{base}" stroke="var(--axis)" stroke-width="1"/>')

    def line(self, xs, ys, color, *, width=2, title=None):
        pts = " ".join(f"{self.sx(x):.1f},{self.sy(y):.1f}"
                       for x, y in zip(xs, ys))
        t = f"<title>{html.escape(title)}</title>" if title else ""
        self.parts.append(
            f'<polyline points="{pts}" fill="none" stroke="{color}"'
            f' stroke-width="{width}" stroke-linejoin="round"'
            f' stroke-linecap="round">{t}</polyline>')

    def vmarker(self, x, color, label):
        px = self.sx(x)
        self.parts.append(
            f'<line x1="{px:.1f}" y1="{self.mt}" x2="{px:.1f}"'
            f' y2="{self.mt + self.ph}" stroke="{color}" stroke-width="1.5"'
            f' stroke-dasharray="3 3"><title>{html.escape(label)}</title>'
            f'</line>')

    def svg(self) -> str:
        return (f'<svg viewBox="0 0 {self.w} {self.h}" role="img">'
                + "".join(self.parts) + "</svg>")


def _legend(items) -> str:
    rows = "".join(
        f'<span><span class="sw" style="background:{c}"></span>'
        f'{html.escape(n)}</span>' for n, c in items)
    return f'<div class="legend">{rows}</div>'


def _table(headers, rows, cap=None) -> str:
    head = "".join(f"<th>{html.escape(str(h))}</th>" for h in headers)
    body = "".join(
        "<tr>" + "".join(f"<td>{html.escape(str(c))}</td>" for c in r)
        + "</tr>" for r in rows)
    note = (f'<div class="note">showing first {cap} rows</div>'
            if cap else "")
    return (f'<details><summary>table view</summary>{note}'
            f"<table><tr>{head}</tr>{body}</table></details>")


def _card(title, note, body) -> str:
    return (f'<div class="card"><h2>{html.escape(title)}</h2>'
            f'<p class="note">{html.escape(note)}</p>{body}</div>')


def render_page(title: str, body: str, *, refresh: float | None = None) -> str:
    """The document shell: CSS, light/dark tokens, optional auto-refresh.

    ``refresh`` (seconds) adds a ``<meta http-equiv="refresh">`` — the live
    server's self-refreshing view; the static dashboard omits it."""
    meta = (f'<meta http-equiv="refresh" content="{refresh:g}">'
            if refresh else "")
    return ("<!DOCTYPE html><html><head><meta charset='utf-8'>"
            f"{meta}<title>{html.escape(title)}</title>"
            f"<style>{_CSS}</style></head>"
            '<body><div class="viz-root">' + body
            + "</div></body></html>")


# --------------------------------------------------------------- charts
def _occupancy_chart(frames) -> str:
    ts = [f["t"] for f in frames]
    occ = [f["occ"] for f in frames]
    p = _Plot()
    p.scales(min(ts), max(ts), 0.0, max(1.0, max(occ)))
    p.grid(x_unit="s")
    p.line(ts, occ, "var(--series-1)", title="fleet occupancy")
    rows = [(_fmt(t), _fmt(o, 4), f["running"], f["pending"],
             f["running_jobs"]) for t, o, f in zip(ts, occ, frames)][:200]
    table = _table(["t (s)", "occupancy", "running", "pending", "jobs"],
                   rows, cap=200 if len(frames) > 200 else None)
    return _card("Fleet occupancy", "fraction of task slots busy, per frame",
                 p.svg() + table)


def _queue_chart(frames) -> str:
    ts = [f["t"] for f in frames]
    pend = [f["pending"] for f in frames]
    pen = [f["penalty_box"] for f in frames]
    p = _Plot()
    p.scales(min(ts), max(ts), 0.0, max(max(pend), max(pen), 1))
    p.grid(x_unit="s")
    p.line(ts, pend, "var(--series-1)", title="pending tasks")
    p.line(ts, pen, "var(--series-2)", title="penalty box")
    legend = _legend([("pending tasks", "var(--series-1)"),
                      ("penalty box", "var(--series-2)")])
    rows = [(_fmt(t), a, b) for t, a, b in zip(ts, pend, pen)][:200]
    table = _table(["t (s)", "pending", "penalty box"], rows,
                   cap=200 if len(frames) > 200 else None)
    return _card("Scheduler queues", "pending task backlog and penalty-box "
                 "size over time", p.svg() + legend + table)


def _ramp(v: float, vmax: float) -> str:
    if v <= 0:
        return "var(--surface-1)"
    i = min(int(v / vmax * len(_SEQ)), len(_SEQ) - 1)
    return _SEQ[i]


def _heatmap(frames, meta) -> str:
    """Per-node failure heatmap: frame bins x nodes, darker = more fails."""
    n_nodes = len(frames[0]["node_fail"])
    max_cols, max_rows = 120, 48
    col_bin = max(1, -(-len(frames) // max_cols))
    row_bin = max(1, -(-n_nodes // max_rows))
    cols = -(-len(frames) // col_bin)
    rows = -(-n_nodes // row_bin)
    grid = [[0.0] * cols for _ in range(rows)]
    for fi, f in enumerate(frames):
        c = fi // col_bin
        for ni, v in enumerate(f["node_fail"]):
            grid[ni // row_bin][c] += v
    vmax = max(max(r) for r in grid) or 1.0
    cw, ch = 680 // max(cols, 1), max(4, min(12, 480 // rows))
    ml, mt = 48, 8
    w, h = ml + cols * cw + 12, mt + rows * ch + 26
    cells = []
    for r in range(rows):
        for c in range(cols):
            v = grid[r][c]
            t0 = frames[min(c * col_bin, len(frames) - 1)]["t"]
            hi_node = min((r + 1) * row_bin, n_nodes) - 1
            node = (f"node {r * row_bin}" if row_bin == 1 else
                    f"nodes {r * row_bin}-{hi_node}")
            cells.append(
                f'<rect x="{ml + c * cw}" y="{mt + r * ch}" width="{cw}"'
                f' height="{ch}" fill="{_ramp(v, vmax)}"'
                f' stroke="var(--surface-1)" stroke-width="1">'
                f'<title>{node}, t={_fmt(t0)}s: {_fmt(v, 0)} failures'
                f'</title></rect>')
    for r in range(0, rows, max(1, rows // 8)):
        lbl = (f"n{r * row_bin}" if row_bin == 1 else f"n{r * row_bin}+")
        cells.append(f'<text x="{ml - 6}" y="{mt + r * ch + ch / 2 + 3:.0f}"'
                     f' text-anchor="end">{lbl}</text>')
    for c in range(0, cols, max(1, cols // 6)):
        t0 = frames[min(c * col_bin, len(frames) - 1)]["t"]
        cells.append(f'<text x="{ml + c * cw}" y="{h - 8}"'
                     f' text-anchor="middle">{_fmt(t0)}s</text>')
    sw = "".join(f'<span class="sw" style="background:{c}"></span>'
                 for c in _SEQ)
    legend = (f'<div class="legend"><span>0</span><span>{sw}</span>'
              f'<span>{_fmt(vmax, 0)} failures / cell</span></div>')
    totals = [0.0] * rows
    for r in range(rows):
        totals[r] = sum(grid[r])
    top = sorted(range(rows), key=lambda r: -totals[r])[:20]
    table = _table(["node (row)", "failures"],
                   [(f"n{r * row_bin}" + ("" if row_bin == 1 else "+"),
                     _fmt(totals[r], 0)) for r in top if totals[r] > 0]
                   or [("—", 0)])
    note = "failures per node per frame bin"
    if col_bin > 1 or row_bin > 1:
        note += f" (binned {col_bin} frames × {row_bin} nodes)"
    body = (f'<svg viewBox="0 0 {w} {h}" role="img">'
            + "".join(cells) + "</svg>" + legend + table)
    return _card("Per-node failures", note, body)


def _drift_chart(frames, markers) -> str:
    pts = {"map": [], "reduce": []}
    for f in frames:
        for kind, sig in f.get("drift", {}).items():
            if sig and sig.get("psi") is not None:
                pts[kind].append((f["t"], sig["psi"]))
    series = [(k, v) for k, v in pts.items() if v]
    if not series and not markers:
        return ""
    ts = [t for _, v in series for t, _ in v] or [f["t"] for f in frames]
    ys = [y for _, v in series for _, y in v] or [0.0]
    p = _Plot()
    p.scales(min(ts), max(max(ts), min(ts) + 1), 0.0, max(max(ys), 0.1))
    p.grid(x_unit="s", y_fmt=lambda v: _fmt(v, 3))
    colors = {"map": "var(--series-1)", "reduce": "var(--series-2)"}
    for kind, v in series:
        p.line([t for t, _ in v], [y for _, y in v], colors[kind],
               title=f"{kind} PSI")
    for t, ev, label in markers:
        color = ("var(--status-good)" if ev == "promote"
                 else "var(--status-critical)" if ev == "rollback"
                 else "var(--muted)")
        p.vmarker(t, color, label)
    legend = _legend(
        [(f"{k} PSI", colors[k]) for k, _ in series]
        + [("▲ promote", "var(--status-good)"),
           ("▼ rollback", "var(--status-critical)")])
    rows = ([(_fmt(t), ev, label) for t, ev, label in markers]
            or [("—", "—", "no lifecycle events")])
    table = _table(["t (s)", "event", "detail"], rows)
    return _card("Model drift & lifecycle",
                 "population-stability index per task kind; dashed markers "
                 "are registry promote/rollback events", p.svg() + legend
                 + table)


def _flush_hist_chart(edges, counts, title, note, unit="") -> str:
    p = _Plot(h=200, mb=30)
    n = len(counts)
    p.scales(0, n, 0, max(max(counts), 1))
    for ty in _ticks(0, max(max(counts), 1), 4):
        y = p.sy(ty)
        p.parts.append(
            f'<line x1="{p.ml}" y1="{y:.1f}" x2="{p.ml + p.pw}" y2="{y:.1f}"'
            f' stroke="var(--grid)" stroke-width="1"/>'
            f'<text x="{p.ml - 6}" y="{y + 3.5:.1f}" text-anchor="end">'
            f'{_fmt(ty)}</text>')
    bw = p.pw / max(n, 1)
    base = p.mt + p.ph
    labels = [f"≤{_fmt(e)}" for e in edges] + [f">{_fmt(edges[-1])}"]
    for i, c in enumerate(counts):
        if c <= 0:
            continue
        x, y = p.ml + i * bw + 1, p.sy(c)
        hh = max(base - y, 1)
        p.parts.append(
            f'<rect x="{x:.1f}" y="{y:.1f}" width="{bw - 2:.1f}"'
            f' height="{hh:.1f}" rx="2" fill="var(--series-1)">'
            f'<title>{labels[i]}{unit}: {_fmt(c, 0)} flushes</title></rect>')
    step = max(1, n // 8)
    for i in range(0, n, step):
        p.parts.append(
            f'<text x="{p.ml + (i + .5) * bw:.1f}" y="{p.h - 8}"'
            f' text-anchor="middle">{labels[i]}</text>')
    p.parts.append(
        f'<line x1="{p.ml}" y1="{base}" x2="{p.ml + p.pw}" y2="{base}"'
        f' stroke="var(--axis)" stroke-width="1"/>')
    table = _table(["bucket", "count"],
                   [(labels[i] + unit, int(c))
                    for i, c in enumerate(counts) if c > 0] or [("—", 0)])
    return _card(title, note, p.svg() + table)


def _broker_cards(broker_frames) -> str:
    flushes = [f for f in broker_frames if f.get("type") == "flush"]
    if not flushes:
        return ""
    out = []
    xs = list(range(len(flushes)))
    depth = [f["requests"] for f in flushes]
    p = _Plot(h=200)
    p.scales(0, max(xs[-1], 1), 0, max(max(depth), 1))
    p.grid()
    p.line(xs, depth, "var(--series-1)", title="queue depth at flush")
    rows = [(i, f["requests"], f["rows"], f["dispatches"],
             f.get("latency_ms", "—")) for i, f in enumerate(flushes)][:200]
    table = _table(["flush #", "requests", "rows", "dispatches", "ms"],
                   rows, cap=200 if len(flushes) > 200 else None)
    out.append(_card("Broker queue depth",
                     "requests coalesced per flush, in flush order",
                     p.svg() + table))
    # rows-per-flush histogram, rebuilt from the flush stream
    from repro.obs.instrument import FLUSH_ROW_EDGES
    counts = [0] * (len(FLUSH_ROW_EDGES) + 1)
    for f in flushes:
        r, b = f["rows"], 0
        while b < len(FLUSH_ROW_EDGES) and r > FLUSH_ROW_EDGES[b]:
            b += 1
        counts[b] += 1
    out.append(_flush_hist_chart(
        list(FLUSH_ROW_EDGES), counts, "Broker flush size",
        "rows scored per flush (batching efficiency)", unit=" rows"))
    return "".join(out)


def _jobs_chart(final) -> str:
    jobs = (final or {}).get("jobs") or []
    done = [j for j in jobs if j.get("end") is not None]
    if not done:
        return ""
    done.sort(key=lambda j: (j["submit"], str(j.get("job", ""))))
    show = done[:60]
    t0 = min(j["submit"] for j in show)
    t1 = max(j["end"] for j in show)
    p = _Plot(h=max(120, 14 * len(show) + 40), ml=60)
    p.ph = p.h - p.mt - p.mb
    p.scales(t0, t1, 0, 1)
    for tx in _ticks(t0, t1, 6):
        x = p.sx(tx)
        p.parts.append(
            f'<line x1="{x:.1f}" y1="{p.mt}" x2="{x:.1f}"'
            f' y2="{p.mt + p.ph}" stroke="var(--grid)" stroke-width="1"/>'
            f'<text x="{x:.1f}" y="{p.h - 8}" text-anchor="middle">'
            f'{_fmt(tx)}s</text>')
    bh = min(10, max(4, (p.ph - 8) // max(len(show), 1) - 2))
    for i, j in enumerate(show):
        y = p.mt + 4 + i * (p.ph - 8) / max(len(show), 1)
        x0, x1 = p.sx(j["submit"]), p.sx(j["end"])
        dur = j["end"] - j["submit"]
        p.parts.append(
            f'<rect x="{x0:.1f}" y="{y:.1f}" width="{max(x1 - x0, 2):.1f}"'
            f' height="{bh}" rx="2" fill="var(--series-1)">'
            f'<title>{html.escape(str(j.get("job", i)))}: '
            f'{_fmt(j["submit"])}s → {_fmt(j["end"])}s '
            f'({_fmt(dur)}s, {j.get("tasks", "?")} tasks)</title></rect>')
    note = f"{len(done)} completed jobs"
    if len(done) > len(show):
        note += f", first {len(show)} shown"
    rows = [(str(j.get("job", "")), _fmt(j["submit"]), _fmt(j["end"]),
             _fmt(j["end"] - j["submit"]), j.get("tasks", "—"),
             j.get("failed_attempts", 0)) for j in done[:200]]
    table = _table(["job", "submit (s)", "end (s)", "duration (s)", "tasks",
                    "failed attempts"], rows,
                   cap=200 if len(done) > 200 else None)
    return _card("Job timeline", note, p.svg() + table)


def _tiles(frames, final, meta) -> str:
    summary = (final or {}).get("summary") or {}
    last = frames[-1]
    items = [
        (_fmt(last["t"]) + "s", "simulated time"),
        (str(meta.get("n_nodes", len(last["node_occ"]))), "nodes"),
        (_fmt(summary.get("occupancy_mean", 0), 3), "mean occupancy"),
        (_fmt(summary.get("failures", sum(sum(f["node_fail"])
                                          for f in frames)), 0),
         "task failures"),
        (str(len((final or {}).get("jobs") or []) or "—"), "jobs traced"),
    ]
    rate = summary.get("memo_hit_rate")
    if rate:
        items.append((_fmt(rate * 100, 1) + "%", "memo hit rate"))
    # fault-tolerance tiles: nonzero only when the serving path actually
    # degraded/retried (the summary omits the keys on clean runs, and the
    # live path carries them in the last frame's pred block)
    pred = last.get("pred") or {}
    for key, label in (("fallbacks", "predictor fallbacks"),
                       ("retries", "broker retries"),
                       ("reconnects", "broker reconnects")):
        v = summary.get(key, pred.get(key, 0))
        if v:
            items.append((_fmt(v, 0), label))
    tiles = "".join(f'<div class="tile"><div class="v">{html.escape(v)}'
                    f'</div><div class="k">{html.escape(k)}</div></div>'
                    for v, k in items)
    return f'<div class="tiles">{tiles}</div>'


def _lifecycle_markers(frames, registry_events) -> list[tuple]:
    """(t, event, label) from in-frame events + registry events.jsonl."""
    markers = []
    for f in frames:
        for ev in f.get("events", ()):
            markers.append((ev["t"], ev["event"],
                            f"{ev['event']} @ {_fmt(ev['t'])}s "
                            + str({k: v for k, v in ev.items()
                                   if k not in ("t", "event")} or "")))
    for ev in registry_events or ():
        kind = ev.get("event")
        if kind not in ("promote", "rollback"):
            continue
        t = (ev.get("meta") or {}).get("sim_now")
        if t is None:
            continue
        markers.append(
            (t, kind,
             f"{kind} {ev.get('family', '')} v{ev.get('version', '?')} "
             f"@ {_fmt(t)}s"))
    seen, out = set(), []
    for m in sorted(markers):
        key = (round(m[0], 2), m[1])
        if key not in seen:
            seen.add(key)
            out.append(m)
    return out


def render_html(frames: list[dict], *, broker_frames=None,
                registry_events=None, title="repro ops dashboard",
                refresh: float | None = None) -> str:
    """Render a frame stream (plus optional broker flush stream and model
    registry event ledger) into one self-contained HTML document.

    ``refresh`` (seconds) makes the document self-refreshing — the live
    server re-renders from its in-memory window on every reload."""
    meta = next((f for f in frames if f.get("type") == "meta"), {})
    final = next((f for f in frames if f.get("type") == "final"), None)
    data = [f for f in frames if f.get("type") == "frame"]
    if not data:
        raise ValueError("no telemetry frames in input")
    markers = _lifecycle_markers(data, registry_events)
    sub = (f"scheduler={meta.get('scheduler', '?')} · "
           f"{meta.get('n_nodes', '?')} nodes · {len(data)} frames · "
           f"frame_every={meta.get('frame_every', '?')}s")
    if refresh:
        sub += f" · refreshing every {refresh:g}s"
    body = [
        f"<h1>{html.escape(title)}</h1>",
        f'<div class="sub">{html.escape(sub)}</div>',
        _tiles(data, final, meta),
        _occupancy_chart(data),
        _heatmap(data, meta),
        _queue_chart(data),
        _drift_chart(data, markers),
        _broker_cards(broker_frames or []),
        _jobs_chart(final),
    ]
    return render_page(title, "".join(body), refresh=refresh)


def render_broker_html(flush_frames: list[dict], *,
                       title="repro broker telemetry",
                       refresh: float | None = None) -> str:
    """A broker-only document for sources that stream flush frames without
    sim frames (``online/bench --obs-live``)."""
    body = [f"<h1>{html.escape(title)}</h1>",
            f'<div class="sub">{len(flush_frames)} flush frames'
            + (f" · refreshing every {refresh:g}s" if refresh else "")
            + "</div>",
            _broker_cards(flush_frames)
            or _card("Broker", "no flush frames yet", "")]
    return render_page(title, "".join(body), refresh=refresh)
