"""Live telemetry HTTP server: ``/snapshot``, ``/delta``, HTML views.

The serving half of the live wire (the fold half is
:mod:`repro.obs.collector`).  Stdlib ``http.server`` only — no new deps:

* ``GET /snapshot``          full state (seq + aggregates + health), JSON
* ``GET /delta?since=<seq>`` gapless monotonic increments after ``seq``
* ``GET /``                  HTML source index (links per cell)
* ``GET /view?source=<id>``  self-refreshing dashboard for one source,
                             re-rendered from the collector's in-memory
                             frame window (no file reads) through the same
                             chart core the static CLI uses

``python -m repro.obs.live --listen tcp://0.0.0.0:9500 --http :8787``
stands up a telemetry-only :class:`~repro.online.server.AsyncBroker` with a
collector attached plus this HTTP server — point fleet cells at it with
``fleet --obs-live tcp://<host>:9500`` and watch the run arrive.
"""

from __future__ import annotations

import argparse
import json
import sys
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

from repro.obs.collector import TelemetryCollector
from repro.obs.render import render_broker_html, render_html

__all__ = ["LiveServer", "TelemetryCollector", "main"]


def _index_html(collector: TelemetryCollector, refresh: float) -> str:
    from repro.obs.render import render_page
    snap = collector.snapshot()
    rows = []
    for name in sorted(snap["aggregates"]):
        agg = snap["aggregates"][name]
        h = snap["health"]["sources"].get(name, {})
        sim = agg.get("sim") or {}
        rows.append(
            f'<tr><td><a href="/view?source={name}">{name}</a></td>'
            f'<td>{agg["frames"]}</td>'
            f'<td>{sim.get("occupancy", {}).get("last", "—")}</td>'
            f'<td>{sim.get("failures", "—")}</td>'
            f'<td>{h.get("lag_s", "—")}</td>'
            f'<td>{"done" if agg.get("done") else "live"}</td></tr>')
    body = (
        "<h1>repro live telemetry</h1>"
        f'<div class="sub">{len(rows)} sources · seq {snap["seq"]} · '
        f'{snap["health"]["frames_per_s"]} frames/s · '
        f'<a href="/snapshot">/snapshot</a> · '
        f'<a href="/delta?since=0">/delta</a></div>'
        '<div class="card"><h2>Sources</h2>'
        '<p class="note">one row per producing cell</p>'
        "<table><tr><th>source</th><th>frames</th><th>occ</th>"
        "<th>failures</th><th>lag (s)</th><th>state</th></tr>"
        + "".join(rows) + "</table></div>")
    return render_page("repro live telemetry", body, refresh=refresh)


def _make_handler(collector: TelemetryCollector, refresh: float,
                  handler_timeout: float = 10.0):
    class Handler(BaseHTTPRequestHandler):
        # ThreadingHTTPServer spawns a thread per request; the collector
        # lock is the only shared state these handlers touch.

        # socketserver applies this to the connection in setup(): a client
        # that connects and then stalls (half-open socket, wedged poller)
        # hits socket.timeout instead of parking this handler thread —
        # and its keep-alive connection — forever
        timeout = handler_timeout

        def _send(self, code: int, body: bytes, ctype: str):
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.send_header("Cache-Control", "no-store")
            self.end_headers()
            self.wfile.write(body)

        def _json(self, obj, code=200):
            self._send(code, json.dumps(obj).encode("utf-8"),
                       "application/json")

        def _html(self, doc: str, code=200):
            self._send(code, doc.encode("utf-8"), "text/html; charset=utf-8")

        def do_GET(self):  # noqa: N802 (http.server API)
            u = urlparse(self.path)
            try:
                if u.path == "/snapshot":
                    self._json(collector.snapshot())
                elif u.path == "/delta":
                    q = parse_qs(u.query)
                    try:
                        since = int(q.get("since", ["0"])[0])
                    except ValueError:
                        self._json({"error": "since must be an int"}, 400)
                        return
                    self._json(collector.delta(since))
                elif u.path == "/":
                    self._html(_index_html(collector, refresh))
                elif u.path == "/view":
                    q = parse_qs(u.query)
                    name = q.get("source", [""])[0]
                    frames = collector.frames_for(name)
                    data = [f for f in frames if f.get("type") == "frame"]
                    flushes = [f for f in frames if f.get("type") == "flush"]
                    if data:
                        self._html(render_html(
                            frames, broker_frames=flushes or None,
                            title=f"live · {name}", refresh=refresh))
                    elif flushes:
                        self._html(render_broker_html(
                            flushes, title=f"live · {name}",
                            refresh=refresh))
                    else:
                        self._json({"error": f"unknown source {name!r}",
                                    "sources": collector.source_names()},
                                   404)
                else:
                    self._json({"error": "not found",
                                "endpoints": ["/", "/snapshot",
                                              "/delta?since=N",
                                              "/view?source=NAME"]}, 404)
            except (BrokenPipeError, TimeoutError):
                # client went away mid-write, or stalled past the socket
                # timeout mid-response: drop the connection
                self.close_connection = True

        def log_message(self, *a):     # quiet by default
            pass

    return Handler


class LiveServer:
    """Threaded HTTP front-end over a :class:`TelemetryCollector`.

    ``port=0`` binds an ephemeral port; the resolved base URL is in
    ``.address`` after construction.  ``start()``/``stop()`` manage the
    ``serve_forever`` thread; usable as a context manager."""

    def __init__(self, collector: TelemetryCollector, *,
                 host: str = "127.0.0.1", port: int = 0,
                 refresh: float = 2.0, handler_timeout: float = 10.0):
        self.collector = collector
        self.httpd = ThreadingHTTPServer(
            (host, port), _make_handler(collector, refresh,
                                        handler_timeout))
        self.address = f"http://{host}:{self.httpd.server_address[1]}"
        self._thread: threading.Thread | None = None

    def start(self) -> "LiveServer":
        self._thread = threading.Thread(
            target=self.httpd.serve_forever, kwargs={"poll_interval": 0.05},
            daemon=True, name="obs-live-http")
        self._thread.start()
        return self

    def stop(self):
        if self._thread is not None:
            self.httpd.shutdown()
            self._thread.join(timeout=10.0)
            self._thread = None
        self.httpd.server_close()

    def __enter__(self):
        return self.start()

    def __exit__(self, *a):
        self.stop()
        return False


def main(argv=None) -> int:
    from repro.online.server import AsyncBroker

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.live",
        description="Stand up a live telemetry collector: a telemetry-only "
                    "AsyncBroker on --listen plus an HTTP dashboard on "
                    "--http.")
    ap.add_argument("--listen", default="tcp://127.0.0.1:0",
                    help="transport address cells stream frames to "
                         "(default tcp://127.0.0.1:0)")
    ap.add_argument("--http", default="127.0.0.1:0", metavar="HOST:PORT",
                    help="HTTP bind for /snapshot, /delta and the views")
    ap.add_argument("--refresh", type=float, default=2.0,
                    help="HTML view auto-refresh seconds (default 2)")
    args = ap.parse_args(argv)

    host, _, port = args.http.rpartition(":")
    collector = TelemetryCollector()
    broker = AsyncBroker().start()
    broker.collector = collector
    addr = broker.serve(args.listen)
    http = LiveServer(collector, host=host or "127.0.0.1",
                      port=int(port or 0), refresh=args.refresh).start()
    print(json.dumps({"listen": addr, "http": http.address}), flush=True)
    try:
        threading.Event().wait()
    except KeyboardInterrupt:
        pass
    finally:
        http.stop()
        broker.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
