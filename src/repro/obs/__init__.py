"""repro.obs — live fleet observability.

A numpy-backed columnar metrics core (``metrics``), streaming per-tick frame
sinks (``sink``), instrumentation observers for the simulator / broker /
drift loop (``instrument``), a shared chart core (``render``), a
self-contained HTML ops dashboard (``dashboard``, also
``python -m repro.obs.dashboard``), and the live wire consumer: a
``TelemetryCollector`` folding multi-cell telemetry into rolling aggregates
plus an HTTP ``/snapshot`` / ``/delta`` server with self-refreshing views
(``collector`` / ``live``, also ``python -m repro.obs.live``).

See docs/OBSERVABILITY.md for the metric catalog, sink protocol, live-mode
topology and the overhead budget that keeps this layer always-on.
"""

from repro.obs.collector import TelemetryCollector
from repro.obs.instrument import BrokerObserver, SimObserver
from repro.obs.metrics import MetricsRegistry, percentile_from_hist
from repro.obs.render import render_html
from repro.obs.sink import (MemorySink, NDJSONSink, Sink, TeeSink,
                            TransportSink, read_ndjson)


def __getattr__(name):
    # lazy: importing repro.obs.live eagerly here makes
    # ``python -m repro.obs.live`` warn about double execution (runpy)
    if name == "LiveServer":
        from repro.obs.live import LiveServer
        return LiveServer
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "BrokerObserver", "SimObserver", "MetricsRegistry",
    "percentile_from_hist", "MemorySink", "NDJSONSink", "Sink", "TeeSink",
    "TransportSink", "read_ndjson", "TelemetryCollector", "LiveServer",
    "render_html",
]
