"""repro.obs — live fleet observability.

A numpy-backed columnar metrics core (``metrics``), streaming per-tick frame
sinks (``sink``), instrumentation observers for the simulator / broker /
drift loop (``instrument``), and a self-contained HTML ops dashboard
(``dashboard``, also ``python -m repro.obs.dashboard``).

See docs/OBSERVABILITY.md for the metric catalog, sink protocol and the
overhead budget that keeps this layer always-on.
"""

from repro.obs.instrument import BrokerObserver, SimObserver
from repro.obs.metrics import MetricsRegistry, percentile_from_hist
from repro.obs.sink import (MemorySink, NDJSONSink, Sink, TeeSink,
                            TransportSink, read_ndjson)

__all__ = [
    "BrokerObserver", "SimObserver", "MetricsRegistry",
    "percentile_from_hist", "MemorySink", "NDJSONSink", "Sink", "TeeSink",
    "TransportSink", "read_ndjson",
]
