"""Columnar metrics core: counters, gauges and fixed-bucket histograms in
preallocated storage, keyed by a *static* registry.  Gauge columns, histogram
banks and the per-tick ring are numpy; the counter column is a plain python
list because a ``list[int] += 1`` beats a numpy scalar add ~5x at hot-path
granularity.

Design constraints (the reason this exists instead of a dict of floats):

* **Integer-index hot path.**  Instruments are registered up front; each
  registration returns a plain ``int`` handle.  Recording is one in-place
  array write (``counters[h] += n``) — no string hashing, no attribute
  lookups, no allocation — cheap enough that the simulator leaves telemetry
  on by default (the overhead budget in ``benchmarks/obs_overhead.py`` is
  the forcing function).
* **Preallocated ring buffers.**  ``tick(t)`` copies the current counter and
  gauge columns into a fixed-capacity ring, so the last K per-tick snapshots
  are always available for windowed queries (rates, deltas) without growing
  memory over arbitrarily long runs.
* **Deterministic snapshots.**  ``snapshot()`` is a pure function of the
  recorded values — no wall-clock, no iteration-order hazards (names are
  sorted at registration) — so frames built from it are reproducible and the
  SWEEP parity guarantee (telemetry on == telemetry off, byte-for-byte)
  reduces to "the obs layer never writes back into the simulation".
"""

from __future__ import annotations

from bisect import bisect_left

import numpy as np

COUNTER, GAUGE, HISTOGRAM = "counter", "gauge", "histogram"


class MetricsRegistry:
    """Static instrument registry + columnar storage.

    Usage::

        reg = MetricsRegistry()
        h_fail = reg.counter("sim.failures")
        h_occ = reg.gauge("sim.occupancy")
        h_flush = reg.histogram("broker.flush_rows", (1, 8, 64, 512, 4096))
        reg.freeze()
        reg.inc(h_fail)                 # hot path: one in-place int add
        reg.set(h_occ, 0.7)
        reg.observe(h_flush, 130.0)

    ``freeze()`` allocates the backing arrays; registering after freeze
    raises (the registry is static by design — a dynamic key set would put a
    dict probe back on the hot path).
    """

    def __init__(self, ring_capacity: int = 1024):
        self.ring_capacity = int(ring_capacity)
        self._counter_names: list[str] = []
        self._gauge_names: list[str] = []
        self._hist_names: list[str] = []
        self._hist_edges: list[np.ndarray] = []
        self._hist_edges_l: list[list[float]] = []
        self._frozen = False
        # counter/gauge columns and histogram bucket banks are plain python
        # lists: a list `+= 1` or store is ~5x cheaper than a numpy scalar
        # indexed write, and scalar writes are all the hot path does.
        # Columnar numpy enters at tick() (ring rows) and in observe_many(),
        # where vectorised aggregation actually pays.
        self.counters: list[int] | None = None
        self.gauges: list[float] | None = None
        self.hist_counts: list[list[int]] | None = None

    # ------------------------------------------------------------ registration
    def _register(self, names: list[str], name: str) -> int:
        if self._frozen:
            raise RuntimeError(
                f"registry is frozen; cannot register {name!r}")
        if name in names:
            raise ValueError(f"duplicate instrument name {name!r}")
        names.append(name)
        return len(names) - 1

    def counter(self, name: str) -> int:
        """Monotonic int64 counter; returns its integer handle."""
        return self._register(self._counter_names, name)

    def gauge(self, name: str) -> int:
        """Last-value float64 gauge; returns its integer handle."""
        return self._register(self._gauge_names, name)

    def histogram(self, name: str, edges) -> int:
        """Fixed-bucket histogram.  ``edges`` are the (sorted) upper bucket
        bounds; values land in the first bucket whose edge is >= value, with
        one implicit overflow bucket at the end (``len(edges) + 1`` buckets
        total)."""
        e = np.asarray(edges, np.float64)
        if e.ndim != 1 or e.size == 0 or np.any(np.diff(e) <= 0):
            raise ValueError(f"histogram {name!r}: edges must be a sorted "
                             "1-D sequence")
        h = self._register(self._hist_names, name)
        self._hist_edges.append(e)
        self._hist_edges_l.append(e.tolist())    # bisect wants a list
        return h

    def freeze(self) -> "MetricsRegistry":
        """Allocate backing storage; no further registration."""
        self._frozen = True
        self.counters = [0] * len(self._counter_names)
        self.gauges = [0.0] * len(self._gauge_names)
        self.hist_counts = [[0] * (e.size + 1) for e in self._hist_edges]
        self._ring_t = np.zeros(self.ring_capacity, np.float64)
        self._ring_counters = np.zeros(
            (self.ring_capacity, len(self._counter_names)), np.int64)
        self._ring_gauges = np.zeros(
            (self.ring_capacity, len(self._gauge_names)), np.float64)
        self._ring_head = 0          # next write slot
        self._ring_len = 0
        self.n_ticks = 0
        return self

    def clone(self) -> "MetricsRegistry":
        """Fresh zeroed storage sharing this frozen registry's schema.

        Observers created per simulation run pay registration (name checks,
        f-strings, edge validation) only once for a module-level template;
        every run then clones it — the clone allocates the mutable columns
        and rings but shares the immutable name lists and histogram edges.
        Handles are schema-relative, so they transfer unchanged."""
        if not self._frozen:
            raise RuntimeError("clone() requires a frozen registry")
        c = object.__new__(MetricsRegistry)
        c.ring_capacity = self.ring_capacity
        c._counter_names = self._counter_names      # shared, immutable-by-
        c._gauge_names = self._gauge_names          # convention after freeze
        c._hist_names = self._hist_names
        c._hist_edges = self._hist_edges
        c._hist_edges_l = self._hist_edges_l
        c._frozen = True
        return c.freeze()

    # ------------------------------------------------------------ hot path
    def inc(self, handle: int, n: int = 1):
        self.counters[handle] += n

    def set(self, handle: int, value: float):
        self.gauges[handle] = value

    def observe(self, handle: int, value: float):
        # pure-python bisect: a scalar numpy searchsorted costs ~an order of
        # magnitude more than C bisect + a list add on the frame path
        b = bisect_left(self._hist_edges_l[handle], value)
        self.hist_counts[handle][b] += 1

    def observe_many(self, handle: int, values):
        """Vectorised multi-observation (one searchsorted + bincount)."""
        v = np.asarray(values, np.float64)
        if v.size == 0:
            return
        idx = np.searchsorted(self._hist_edges[handle], v, side="left")
        row = self.hist_counts[handle]
        for b, c in enumerate(np.bincount(idx)):
            row[b] += int(c)

    # ------------------------------------------------------------ ring buffer
    def tick(self, t: float):
        """Snapshot the counter/gauge columns into the ring at time ``t``."""
        i = self._ring_head
        self._ring_t[i] = t
        self._ring_counters[i] = self.counters
        self._ring_gauges[i] = self.gauges
        self._ring_head = (i + 1) % self.ring_capacity
        self._ring_len = min(self._ring_len + 1, self.ring_capacity)
        self.n_ticks += 1

    def ring(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(times, counter rows, gauge rows), oldest first — the retained
        window after any wraparound."""
        n, cap, head = self._ring_len, self.ring_capacity, self._ring_head
        if n < cap:
            sl = slice(0, n)
            return (self._ring_t[sl].copy(), self._ring_counters[sl].copy(),
                    self._ring_gauges[sl].copy())
        order = np.concatenate([np.arange(head, cap), np.arange(0, head)])
        return (self._ring_t[order], self._ring_counters[order],
                self._ring_gauges[order])

    def deltas(self, handle: int) -> np.ndarray:
        """Per-tick increments of one counter over the retained ring window."""
        _, c, _ = self.ring()
        col = c[:, handle]
        return np.diff(col, prepend=col[:1]) if col.size else col

    # ------------------------------------------------------- window views
    # Rolling read-side views over the ring for live consumers (the
    # TelemetryCollector): pure functions of ticked state, no mutation.

    def window(self, n: int | None = None):
        """Last-``n`` ring rows (times, counter rows, gauge rows), oldest
        first; the whole retained window when ``n`` is None."""
        t, c, g = self.ring()
        if n is not None and t.size > n:
            t, c, g = t[-n:], c[-n:], g[-n:]
        return t, c, g

    def counter_rate(self, handle: int, n: int | None = None) -> float:
        """Mean increment of one counter per unit of ring time over the
        last ``n`` ticks (0.0 with fewer than two ticks or zero span)."""
        t, c, _ = self.window(n)
        if t.size < 2:
            return 0.0
        span = float(t[-1] - t[0])
        if span <= 0.0:
            return 0.0
        return float(c[-1, handle] - c[0, handle]) / span

    def gauge_window(self, handle: int, n: int | None = None) -> dict:
        """min/mean/max/last of one gauge over the last ``n`` ring ticks."""
        _, _, g = self.window(n)
        col = g[:, handle]
        if col.size == 0:
            return {"min": 0.0, "mean": 0.0, "max": 0.0, "last": 0.0}
        return {"min": float(col.min()), "mean": float(col.mean()),
                "max": float(col.max()), "last": float(col[-1])}

    # ------------------------------------------------------------ export
    def names(self, kind: str) -> tuple[str, ...]:
        return tuple({COUNTER: self._counter_names, GAUGE: self._gauge_names,
                      HISTOGRAM: self._hist_names}[kind])

    def hist_edges(self, handle: int) -> np.ndarray:
        return self._hist_edges[handle]

    def snapshot(self) -> dict:
        """Current values as plain JSON-able python (deterministic order)."""
        hists = {}
        for i, name in enumerate(self._hist_names):
            hists[name] = {"edges": list(self._hist_edges_l[i]),
                           "counts": list(self.hist_counts[i])}
        return {
            "counters": {n: int(self.counters[i])
                         for i, n in enumerate(self._counter_names)},
            "gauges": {n: float(self.gauges[i])
                       for i, n in enumerate(self._gauge_names)},
            "histograms": hists,
        }


def percentile_from_hist(edges: np.ndarray, counts: np.ndarray,
                         q: float) -> float:
    """Approximate quantile from fixed-bucket counts (upper-edge estimate;
    the overflow bucket reports the last finite edge)."""
    total = int(counts.sum())
    if total == 0:
        return 0.0
    target = q * total
    acc = 0
    for b, c in enumerate(counts):
        acc += int(c)
        if acc >= target:
            return float(edges[min(b, len(edges) - 1)])
    return float(edges[-1])
