"""Static HTML ops dashboard CLI over an NDJSON frame stream.

``python -m repro.obs.dashboard frames.ndjson -o dashboard.html`` turns the
telemetry a :class:`repro.obs.SimObserver` streamed during a run into one
static HTML file: fleet occupancy timeline, per-node failure heatmap, broker
queue-depth / flush-size histograms, drift timeline annotated with
promote/rollback markers, and the job ledger.  Inline SVG only — no JS
libraries, no network — so the artifact ships anywhere a browser opens.

The chart core lives in :mod:`repro.obs.render` and is shared with the live
server (:mod:`repro.obs.live`); this module is just the post-hoc file-reading
entry point.  Tail-follow safe: a trailing line truncated mid-write by
``NDJSONSink``'s batched flush is skipped, not fatal.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs.render import render_html
from repro.obs.sink import read_ndjson

__all__ = ["render_html", "main"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.dashboard",
        description="Render an NDJSON telemetry stream into a "
                    "self-contained HTML ops dashboard.")
    ap.add_argument("frames", help="frames .ndjson from SimObserver")
    ap.add_argument("--broker", help="flush .ndjson from BrokerObserver")
    ap.add_argument("--events", help="model registry events.jsonl")
    ap.add_argument("-o", "--out", default="dashboard.html")
    ap.add_argument("--title", default="repro ops dashboard")
    args = ap.parse_args(argv)

    frames, n_partial = read_ndjson(args.frames, return_partial=True)
    if n_partial:
        print(f"note: skipped {n_partial} truncated trailing line in "
              f"{args.frames}", file=sys.stderr)
    if not any(f.get("type") == "frame" for f in frames):
        print(f"error: no telemetry frames in {args.frames}",
              file=sys.stderr)
        return 2
    broker = read_ndjson(args.broker) if args.broker else None
    events = read_ndjson(args.events) if args.events else None
    doc = render_html(frames, broker_frames=broker, registry_events=events,
                      title=args.title)
    with open(args.out, "w") as f:
        f.write(doc)
    n = sum(1 for f in frames if f.get("type") == "frame")
    print(json.dumps({"frames": n, "out": args.out,
                      "bytes": len(doc.encode("utf-8"))}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
