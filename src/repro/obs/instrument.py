"""Instrumentation hooks: observers that turn live simulator / broker state
into metrics-core records and per-tick NDJSON frames.

The contract that keeps SWEEP byte-stability trivial: observers only *read*
simulation state (node counters, queue lengths, predictor accounting) and
never touch the RNG, the event heap, or any decision input — telemetry on
vs off cannot change a single scheduling decision.

``SimObserver`` rides the simulator event loop.  The per-event hot path is
*inlined into the loop itself*: the simulator increments a plain list the
observer owns (``event_counts``) and compares ``now`` against one float
(``next_frame_t``) — no python method call per event, which measures ~10x
cheaper than even a minimal callback.  Everything heavier (per-node
occupancy gather, failure deltas, JSON encoding) runs behind
``maybe_frame()``, reached only when simulated time crosses a frame
boundary (``frame_every`` simulated seconds), and a density gate inside it
skips the frame until at least ``min_events_per_frame`` events accumulated
since the last one.  The gate bounds telemetry to a fixed fraction of
event-processing work even on event-sparse cells (long simulated stretches,
few decisions), so the cost scales with events actually handled, never with
simulated time.  ``benchmarks/obs_overhead.py`` holds this to the <=5%
budget that lets the layer stay always-on.

``BrokerObserver`` hangs off ``PredictionBroker``: per-flush rows, queue
depth and wall latency land in fixed-bucket histograms + a latency ring for
exact p50/p99.  Flush-size/row counts are deterministic under the barrier
policy; wall latencies are not, and stay out of byte-stable artifacts.
"""

from __future__ import annotations

import math

import numpy as np

from repro.obs.metrics import MetricsRegistry, percentile_from_hist

# mirrors simulator's event-kind order (EV_SUBMIT..EV_RETRAIN)
EVENT_NAMES = ("submit", "attempt_end", "heartbeat", "chaos", "timeout",
               "node_recover", "retrain")

_OCC_EDGES = tuple(i / 10 for i in range(1, 11))                # 0.1 .. 1.0
FLUSH_ROW_EDGES = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096,
                   8192)
FLUSH_LATENCY_EDGES = tuple(s / 1e3 for s in                    # seconds
                            (0.05, 0.1, 0.25, 0.5, 1, 2.5, 5, 10, 25, 50,
                             100, 250))


def _round(x: float, nd: int = 4) -> float:
    return round(float(x), nd)


class SimObserver:
    """Streams per-tick fleet telemetry from one Simulator run.

    Frames go to ``sink`` (NDJSON file, memory, or a future transport); a
    deterministic roll-up is available from ``summary()`` after the run.
    """

    # (template registry, handle attribute dict) per ring capacity — built
    # on first use, cloned per observer so per-run init skips registration
    _templates: dict = {}

    @classmethod
    def _template(cls, ring_capacity: int):
        cached = cls._templates.get(ring_capacity)
        if cached is not None:
            return cached
        m = MetricsRegistry(ring_capacity=ring_capacity)
        handles = {"_ev0": m.counter(f"sim.events.{EVENT_NAMES[0]}")}
        for name in EVENT_NAMES[1:]:
            m.counter(f"sim.events.{name}")
        handles["h_frames"] = m.counter("sim.frames")
        handles["h_failures"] = m.counter("sim.failures")
        handles["h_occ"] = m.gauge("sim.occupancy")
        handles["h_pending"] = m.gauge("sim.pending")
        handles["h_penalty"] = m.gauge("sim.penalty_box")
        handles["h_running_jobs"] = m.gauge("sim.running_jobs")
        handles["h_alive"] = m.gauge("sim.nodes_alive")
        handles["h_stale_max"] = m.gauge("sim.hb_stale_max")
        handles["h_stale_mean"] = m.gauge("sim.hb_stale_mean")
        handles["h_memo_rate"] = m.gauge("pred.memo_hit_rate")
        handles["h_memo_size"] = m.gauge("pred.memo_size")
        handles["h_memo_evict"] = m.gauge("pred.memo_evictions")
        handles["h_fallbacks"] = m.gauge("pred.fallbacks")
        handles["h_retries"] = m.gauge("pred.retries")
        handles["h_reconnects"] = m.gauge("pred.reconnects")
        handles["_h_drift"] = {kind: (m.gauge(f"drift.{kind}.psi"),
                                      m.gauge(f"drift.{kind}.brier"))
                               for kind in ("map", "reduce")}
        handles["h_occ_hist"] = m.histogram("sim.occupancy_dist", _OCC_EDGES)
        m.freeze()
        cls._templates[ring_capacity] = (m, handles)
        return m, handles

    def __init__(self, sink=None, frame_every: float = 60.0,
                 min_events_per_frame: int = 192, ring_capacity: int = 256):
        self.sink = sink
        self.frame_every = float(frame_every)
        self.min_events_per_frame = int(min_events_per_frame)
        template, handles = self._template(int(ring_capacity))
        self.__dict__.update(handles)
        self.metrics = template.clone()
        self._drift = {}                 # kind -> latest signal dict
        self._events_pending: list[dict] = []
        # the simulator's inlined hot path: it bumps event_counts[kind] and
        # calls maybe_frame() only once `now` passes next_frame_t.  These
        # are cumulative per-kind counts, folded into the registry's
        # counter column at frame/summary time.
        self.event_counts = [0] * len(EVENT_NAMES)
        self.next_frame_t = self.frame_every
        self._ev_at_frame = 0            # total events at the last frame
        self._n_frames = 0
        self._occ_sum = 0.0
        self._finished = False
        self._summary_cache: dict | None = None

    # ------------------------------------------------------------ lifecycle
    def bind(self, sim):
        n = len(sim.nodes)
        # plain python lists on purpose: the frame path iterates nodes in
        # python anyway, and small-array numpy dispatch would dominate it
        self._slots = [float(s.spec.map_slots + s.spec.reduce_slots)
                       for s in sim.nodes]
        self._total_slots = max(sum(self._slots), 1.0)
        self._prev_fail = [0] * n
        if self.sink is not None:
            self.sink.emit({
                "type": "meta", "t": 0.0, "frame_every": self.frame_every,
                "n_nodes": n,
                "node_types": [s.spec.name for s in sim.nodes],
                "node_slots": [int(s) for s in self._slots],
                "scheduler": getattr(sim.scheduler, "name", "?"),
            })

    # ------------------------------------------------------------ hot path
    def after_event(self, sim, kind: int):
        """One simulator event: counter bump + boundary check.  The
        simulator's loop inlines this body directly (a list add + one float
        compare against ``next_frame_t``); this method is the same contract
        for tests and alternative drivers."""
        self.event_counts[kind] += 1
        if sim.now >= self.next_frame_t:
            self.maybe_frame(sim)

    def maybe_frame(self, sim):
        """Boundary reached: emit a frame unless the density gate says the
        stretch since the last frame was too event-sparse to be worth one
        (the gate keeps telemetry cost a bounded fraction of event work).
        On a sparse stretch the check defers to the *next* grid boundary —
        re-testing the gate on every subsequent event would itself become
        a per-event cost."""
        total = sum(self.event_counts)
        if total - self._ev_at_frame >= self.min_events_per_frame:
            self._emit_frame(sim)
        else:
            self.next_frame_t = (math.floor(sim.now / self.frame_every) + 1) \
                * self.frame_every

    # ------------------------------------------------------------ drift/registry
    def record_drift(self, t: float, kind: str, psi: float,
                     brier: float | None, score_drift: float):
        h_psi, h_brier = self._h_drift[kind]
        self.metrics.set(h_psi, psi)
        if brier is not None:
            self.metrics.set(h_brier, brier)
        self._drift[kind] = {"t": _round(t, 2), "psi": _round(psi),
                             "brier": (None if brier is None
                                       else _round(brier)),
                             "score_drift": _round(score_drift)}

    def record_event(self, event: str, t: float, **kw):
        """Promote / rollback / retrain-skip markers (drained into frames)."""
        row = {"event": event, "t": _round(t, 2)}
        row.update({k: v for k, v in kw.items() if v is not None})
        self._events_pending.append(row)

    # ------------------------------------------------------------ frames
    def _emit_frame(self, sim):
        # stamp at the boundary grid, then advance past `now` (several quiet
        # frame periods collapse into one frame — no busywork on idle gaps)
        t = self.next_frame_t
        self.next_frame_t = (math.floor(sim.now / self.frame_every) + 1) \
            * self.frame_every
        m = self.metrics
        self._fold_events()
        self._ev_at_frame = sum(self.event_counts)
        # one plain-python pass over the nodes: at fleet scale the loop
        # dominates either way, and below it numpy dispatch would
        now = sim.now
        inv_hb = 1.0 / max(sim.heartbeat_interval, 1e-9)
        slots, prev = self._slots, self._prev_fail
        running_sum, d_fail_sum, hb_max, hb_sum = 0, 0, 0.0, 0.0
        node_occ: list[float] = []
        node_fail: list[int] = []
        for i, node in enumerate(sim.nodes):
            r = node.running_maps + node.running_reduces
            running_sum += r
            node_occ.append(round(r / slots[i], 3))
            hb = (now - node.last_heartbeat) * inv_hb
            if hb > hb_max:
                hb_max = hb
            hb_sum += hb
            f = node.failed_count
            node_fail.append(f - prev[i])
            d_fail_sum += f - prev[i]
            prev[i] = f
        n = max(len(slots), 1)
        occ = running_sum / self._total_slots

        # direct column writes (the registry hands out plain int handles so
        # exactly this is possible: ~9 method calls per frame add up)
        c, g = m.counters, m.gauges
        c[self.h_frames] += 1
        c[self.h_failures] += d_fail_sum
        g[self.h_occ] = occ
        g[self.h_pending] = float(len(sim.pending))
        # typed scheduler snapshot (PR 8): the one sanctioned window into
        # scheduler state — no more getattr-ing scheduler internals here
        sched_fs = sim.scheduler.frame_stats()
        pb_len = sched_fs["penalty_box"]
        g[self.h_penalty] = float(pb_len)
        g[self.h_running_jobs] = float(sim.n_running_jobs)
        g[self.h_alive] = float(len(sim._known_alive))
        g[self.h_stale_max] = hb_max
        g[self.h_stale_mean] = hb_sum / n
        m.observe(self.h_occ_hist, occ)
        pred = sched_fs["pred"]
        if pred is not None and pred["demand_rows"]:
            g[self.h_memo_rate] = pred["memo_hits"] / pred["demand_rows"]
        if pred is not None and "memo_size" in pred:
            g[self.h_memo_size] = float(pred["memo_size"])
            g[self.h_memo_evict] = float(pred["memo_evictions"])
        if pred is not None and "fallbacks" in pred:
            g[self.h_fallbacks] = float(pred["fallbacks"])
            g[self.h_retries] = float(pred.get("retries", 0))
            g[self.h_reconnects] = float(pred.get("reconnects", 0))
        m.tick(t)
        self._n_frames += 1
        self._occ_sum += occ

        if self.sink is not None:
            frame = {
                "type": "frame", "i": self._n_frames - 1, "t": _round(t, 2),
                "occ": _round(occ),
                "running": running_sum,
                "pending": len(sim.pending),
                "penalty_box": pb_len,
                "running_jobs": sim.n_running_jobs,
                "alive": len(sim._known_alive),
                "hb_stale_max": _round(hb_max),
                "node_occ": node_occ,
                "node_fail": node_fail,
            }
            if pred is not None:
                frame["pred"] = pred
            if self._drift:
                frame["drift"] = dict(self._drift)
            if self._events_pending:
                frame["events"] = self._events_pending
                self._events_pending = []
            self.sink.emit(frame)

    def _fold_events(self):
        """Copy the sim-maintained cumulative event counts into the registry
        counter column (so ring ticks / snapshots see current values)."""
        c, e0 = self.metrics.counters, self._ev0
        for i, v in enumerate(self.event_counts):
            c[e0 + i] = v

    def finish(self, sim):
        """Final frame + job ledger + close — called once at end of run."""
        if self._finished:
            return
        self._finished = True
        self.next_frame_t = sim.now      # stamp the closing frame at run end
        self._emit_frame(sim)
        self._summary_cache = self.summary()
        if self.sink is not None:
            final = {"type": "final", "t": _round(sim.now, 2),
                     "summary": self.summary()}
            trace = getattr(sim, "trace", None)
            jobs = getattr(trace, "jobs", None)
            if jobs:
                final["jobs"] = [jobs[j] for j in sorted(jobs)]
            self.sink.emit(final)
            self.sink.close()

    # ------------------------------------------------------------ roll-up
    def summary(self) -> dict:
        """Deterministic per-run roll-up (no wall-clock, stable key order) —
        safe to stamp into byte-stable artifacts like SWEEP.json.  Computed
        once at ``finish()``; later calls return the cached roll-up."""
        if self._summary_cache is not None:
            return self._summary_cache
        self._fold_events()
        snap = self.metrics.snapshot()
        c, g = snap["counters"], snap["gauges"]
        nf = max(self._n_frames, 1)
        out = {
            "frames": self._n_frames,
            "frame_every": self.frame_every,
            "events": {name: c[f"sim.events.{name}"]
                       for name in EVENT_NAMES},
            "failures": c["sim.failures"],
            "occupancy_mean": _round(self._occ_sum / nf),
            "occupancy_last": _round(g["sim.occupancy"]),
            "memo_hit_rate": _round(g["pred.memo_hit_rate"]),
            "memo_evictions": int(g["pred.memo_evictions"]),
        }
        # fault-tolerance counters appear only when something actually
        # happened, so a clean run's summary (and the byte-stable SWEEP
        # perf.obs block built from it) is unchanged
        for name in ("fallbacks", "retries", "reconnects"):
            v = g[f"pred.{name}"]
            if v:
                out[name] = int(v)
        if self._drift:
            out["drift_last"] = dict(sorted(self._drift.items()))
        return out


class BrokerObserver:
    """Per-flush accounting for a PredictionBroker: queue depth / flush size
    histograms (deterministic under the barrier policy) plus a wall-latency
    ring for p50/p99 (reporting only — never stamped into stable artifacts).
    """

    def __init__(self, sink=None, latency_ring: int = 4096):
        m = MetricsRegistry(ring_capacity=64)
        self.h_flushes = m.counter("broker.flushes")
        self.h_requests = m.counter("broker.requests")
        self.h_rows = m.counter("broker.rows")
        self.h_dispatches = m.counter("broker.dispatches")
        self.h_flush_rows = m.histogram("broker.flush_rows", FLUSH_ROW_EDGES)
        self.h_flush_latency = m.histogram("broker.flush_latency_s",
                                           FLUSH_LATENCY_EDGES)
        self.metrics = m.freeze()
        self.sink = sink
        self._lat = np.zeros(latency_ring, np.float64)
        self._lat_n = 0

    def record_flush(self, rows: int, n_requests: int, n_dispatches: int,
                     latency_s: float):
        m = self.metrics
        m.inc(self.h_flushes)
        m.inc(self.h_requests, n_requests)
        m.inc(self.h_rows, rows)
        m.inc(self.h_dispatches, n_dispatches)
        m.observe(self.h_flush_rows, rows)
        m.observe(self.h_flush_latency, latency_s)
        self._lat[self._lat_n % self._lat.size] = latency_s
        self._lat_n += 1
        if self.sink is not None:
            self.sink.emit({"type": "flush", "i": self._lat_n - 1,
                            "rows": rows, "requests": n_requests,
                            "dispatches": n_dispatches,
                            "latency_ms": _round(latency_s * 1e3)})

    def latency_ms(self) -> dict:
        """Exact percentiles over the retained latency ring."""
        n = min(self._lat_n, self._lat.size)
        if n == 0:
            return {"p50": 0.0, "p99": 0.0}
        lat = np.sort(self._lat[:n]) * 1e3
        return {"p50": _round(lat[int(0.50 * (n - 1))], 3),
                "p99": _round(lat[int(0.99 * (n - 1))], 3)}

    def summary(self, *, deterministic_only: bool = False) -> dict:
        snap = self.metrics.snapshot()
        hist = snap["histograms"]["broker.flush_rows"]
        out = {
            **snap["counters"],
            "flush_rows_hist": {"edges": [int(e) for e in hist["edges"]],
                                "counts": hist["counts"]},
            "flush_rows_p50": percentile_from_hist(
                np.asarray(hist["edges"]), np.asarray(hist["counts"]), 0.5),
        }
        if not deterministic_only:
            lat = snap["histograms"]["broker.flush_latency_s"]
            out["flush_latency_hist_ms"] = {
                "edges": [_round(e * 1e3, 3) for e in lat["edges"]],
                "counts": lat["counts"]}
            out["flush_latency_ms"] = self.latency_ms()
        return out

    def close(self):
        if self.sink is not None:
            self.sink.close()
