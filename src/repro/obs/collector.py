"""TelemetryCollector: the live consumer half of the telemetry wire.

``SimObserver → TransportSink → AsyncBroker → TelemetryCollector``: fleet
cells stream ``{"op": "telemetry"}`` frames over ``inproc://``/``tcp://``
(the PR 7 comm layer), the broker routes them here, and the collector folds
each frame into rolling columnar aggregates — per-source
:class:`~repro.obs.metrics.MetricsRegistry` clones (counters, gauges,
histograms + windowed ring views) plus a bounded retained-frame window the
live view re-renders from.  The HTTP side lives in :mod:`repro.obs.live`.

Design rules:

* **Observe, never perturb.**  The collector sits strictly downstream of
  the simulation: it holds no locks the sim path touches, and backpressure
  from a slow ``ingest`` propagates only through the transport's bounded
  channels — SWEEP.json stays byte-identical with the live path on.
* **Deterministic aggregates, wall-clock health.**  ``snapshot()`` splits
  ``"aggregates"`` (a pure fold over the ingested ``(source, frame)``
  sequence — replaying the ``/delta`` log or the post-hoc NDJSON files
  through a fresh collector reproduces it exactly) from ``"health"``
  (wall-clock lag, wire gaps/reconnects, ingest rate — reporting only).
* **Monotonic sequencing.**  Every ingested frame gets one global ``seq``
  from a single counter; ``delta(since)`` returns the contiguous suffix of
  the bounded log after ``since``, or flags ``resync`` when the log has
  evicted past it — a poller that chains ``since = last seq`` sees every
  frame exactly once, gaplessly, or learns it must re-snapshot.

Thread-safety: ``ingest`` runs on the broker's loop thread; ``snapshot`` /
``delta`` / ``frames_for`` run on HTTP handler threads.  One mutex guards
all state — folds are cheap (list appends + a few float stores), so the
critical section stays far below frame interarrival even under load.
"""

from __future__ import annotations

import threading
import time
from collections import deque

import numpy as np

from repro.obs.instrument import FLUSH_ROW_EDGES, _OCC_EDGES
from repro.obs.metrics import MetricsRegistry, percentile_from_hist

# queue depth (requests coalesced per broker flush) buckets
_FLUSH_REQ_EDGES = (1, 2, 4, 8, 16, 32, 64, 128, 256)

# rolling-window length (ring ticks) used for windowed rates/stats
_WINDOW = 128


def _template(ring_capacity: int) -> tuple[MetricsRegistry, dict]:
    m = MetricsRegistry(ring_capacity=ring_capacity)
    h = {
        "frames": m.counter("live.frames"),
        "sim_frames": m.counter("live.sim_frames"),
        "failures": m.counter("live.failures"),
        "flushes": m.counter("live.broker_flushes"),
        "rows": m.counter("live.broker_rows"),
        "occ": m.gauge("live.occ"),
        "pending": m.gauge("live.pending"),
        "penalty_box": m.gauge("live.penalty_box"),
        "running_jobs": m.gauge("live.running_jobs"),
        "alive": m.gauge("live.alive"),
        "hb_stale_max": m.gauge("live.hb_stale_max"),
        "drift_map_psi": m.gauge("live.drift.map.psi"),
        "drift_reduce_psi": m.gauge("live.drift.reduce.psi"),
        "pred_fallbacks": m.gauge("live.pred_fallbacks"),
        "pred_retries": m.gauge("live.pred_retries"),
        "pred_reconnects": m.gauge("live.pred_reconnects"),
        "occ_hist": m.histogram("live.occupancy_dist", _OCC_EDGES),
        "flush_rows": m.histogram("live.flush_rows", FLUSH_ROW_EDGES),
        "flush_reqs": m.histogram("live.flush_requests", _FLUSH_REQ_EDGES),
    }
    return m.freeze(), h


class _Source:
    """Per-producer fold state: metrics clone + retained frame window."""

    __slots__ = ("metrics", "frames", "meta", "final", "n_frames", "last_t",
                 "last_seq", "last_n", "gaps", "reconnects", "last_wall")

    def __init__(self, metrics: MetricsRegistry, frame_window: int):
        self.metrics = metrics
        self.frames: deque = deque(maxlen=frame_window)
        self.meta: dict | None = None
        self.final: dict | None = None
        self.n_frames = 0          # deterministic: frames folded
        self.last_t = 0.0          # deterministic: sim time of last frame
        self.last_seq = 0          # deterministic: global seq of last frame
        self.last_n = 0            # wire: producer's 1-based emit counter
        self.gaps = 0              # wire: frames the producer emitted
        #                            that never arrived (n jumped)
        self.reconnects = 0        # wire: producer counter restarted
        self.last_wall: float | None = None


class TelemetryCollector:
    """Folds a multi-producer telemetry stream into live aggregates.

    Parameters
    ----------
    delta_capacity:
        Bounded ``/delta`` log length (global, across sources).  A poller
        further behind than this gets ``resync: True``.
    frame_window:
        Retained frames per source for live rendering (plus meta/final).
    ring_capacity:
        Per-source metrics ring length (windowed rates/stats).
    """

    def __init__(self, *, delta_capacity: int = 8192,
                 frame_window: int = 512, ring_capacity: int = 256):
        self._lock = threading.Lock()
        self._template, self._h = _template(ring_capacity)
        self._frame_window = frame_window
        self._seq = 0
        self._log: deque = deque(maxlen=delta_capacity)
        self._evicted = 0          # delta-log entries dropped so far
        self.sources: dict[str, _Source] = {}
        self._wall_first: float | None = None
        self._wall_last: float | None = None

    # ------------------------------------------------------------- ingest
    def ingest(self, frame: dict, *, source: str = "default",
               n: int | None = None) -> int:
        """Fold one frame; returns its global sequence number.

        ``n`` is the producer's own 1-based emit counter (from
        ``TransportSink(source=...)``): jumps count as wire gaps, resets as
        reconnects.  Both are health-side only — the deterministic
        aggregates depend on nothing but the frame sequence itself."""
        now = time.time()
        with self._lock:
            self._seq += 1
            seq = self._seq
            src = self.sources.get(source)
            if src is None:
                src = self.sources[source] = _Source(
                    self._template.clone(), self._frame_window)
            if len(self._log) == self._log.maxlen:
                self._evicted += 1
            self._log.append({"seq": seq, "source": source, "frame": frame})
            if n is not None:
                if n <= src.last_n:
                    src.reconnects += 1
                elif n > src.last_n + 1:
                    src.gaps += n - src.last_n - 1
                src.last_n = n
            self._fold(src, frame)
            src.n_frames += 1
            src.last_seq = seq
            src.last_wall = now
            if self._wall_first is None:
                self._wall_first = now
            self._wall_last = now
            return seq

    def _fold(self, src: _Source, frame: dict):
        m, h = src.metrics, self._h
        m.inc(h["frames"])
        kind = frame.get("type")
        if kind == "frame":
            m.inc(h["sim_frames"])
            fails = sum(frame.get("node_fail", ()))
            if fails:
                m.inc(h["failures"], fails)
            m.set(h["occ"], frame["occ"])
            m.set(h["pending"], frame["pending"])
            m.set(h["penalty_box"], frame["penalty_box"])
            m.set(h["running_jobs"], frame["running_jobs"])
            m.set(h["alive"], frame["alive"])
            m.set(h["hb_stale_max"], frame["hb_stale_max"])
            m.observe(h["occ_hist"], frame["occ"])
            for dkind, sig in (frame.get("drift") or {}).items():
                key = f"drift_{dkind}_psi"
                if key in h and sig and sig.get("psi") is not None:
                    m.set(h[key], sig["psi"])
            pred = frame.get("pred")
            if pred and "fallbacks" in pred:
                m.set(h["pred_fallbacks"], pred["fallbacks"])
                m.set(h["pred_retries"], pred.get("retries", 0))
                m.set(h["pred_reconnects"], pred.get("reconnects", 0))
            src.last_t = float(frame["t"])
            m.tick(src.last_t)
            src.frames.append(frame)
        elif kind == "flush":
            m.inc(h["flushes"])
            rows = int(frame.get("rows", 0))
            m.inc(h["rows"], rows)
            m.observe(h["flush_rows"], rows)
            m.observe(h["flush_reqs"], int(frame.get("requests", 0)))
            src.frames.append(frame)
        elif kind == "meta":
            src.meta = frame
        elif kind == "final":
            src.final = frame

    # -------------------------------------------------------------- reads
    def _aggregate(self, src: _Source) -> dict:
        m, h = src.metrics, self._h
        snap = m.snapshot()
        c, g = snap["counters"], snap["gauges"]
        hists = snap["histograms"]

        def _q(name, q):
            hh = hists[name]
            return percentile_from_hist(np.asarray(hh["edges"]),
                                        np.asarray(hh["counts"]), q)

        agg = {
            "frames": src.n_frames,
            "t_last": src.last_t,
            "last_seq": src.last_seq,
        }
        if c["live.sim_frames"]:
            agg["sim"] = {
                "frames": c["live.sim_frames"],
                "failures": c["live.failures"],
                "failure_rate_w": round(
                    m.counter_rate(h["failures"], _WINDOW), 6),
                "occupancy": {k: round(v, 6) for k, v in
                              m.gauge_window(h["occ"], _WINDOW).items()},
                "occupancy_p50": _q("live.occupancy_dist", 0.50),
                "pending_last": g["live.pending"],
                "penalty_box_last": g["live.penalty_box"],
                "running_jobs_last": g["live.running_jobs"],
                "alive_last": g["live.alive"],
                "hb_stale_max": g["live.hb_stale_max"],
            }
            drift = {k: g[f"live.drift.{k}.psi"] for k in ("map", "reduce")
                     if g[f"live.drift.{k}.psi"]}
            if drift:
                agg["sim"]["drift_psi"] = drift
            # degradation counters surface only when nonzero, so clean-run
            # aggregates (and their replay comparisons) are unchanged
            for name in ("fallbacks", "retries", "reconnects"):
                v = g[f"live.pred_{name}"]
                if v:
                    agg["sim"][f"pred_{name}"] = int(v)
        if c["live.broker_flushes"]:
            agg["broker"] = {
                "flushes": c["live.broker_flushes"],
                "rows": c["live.broker_rows"],
                "flush_rows_p50": _q("live.flush_rows", 0.50),
                "flush_rows_p99": _q("live.flush_rows", 0.99),
                "queue_depth_p50": _q("live.flush_requests", 0.50),
                "queue_depth_p99": _q("live.flush_requests", 0.99),
            }
        if src.meta is not None:
            agg["meta"] = {k: src.meta[k] for k in
                           ("scheduler", "n_nodes", "frame_every")
                           if k in src.meta}
        if src.final is not None:
            agg["done"] = True
        return agg

    def _aggregates_locked(self) -> dict:
        return {name: self._aggregate(self.sources[name])
                for name in sorted(self.sources)}

    def _health_locked(self, now: float) -> dict:
        per = {}
        lag_max = 0.0
        for name in sorted(self.sources):
            src = self.sources[name]
            lag = (now - src.last_wall) if src.last_wall else 0.0
            lag_max = max(lag_max, lag)
            per[name] = {"lag_s": round(lag, 3), "wire_gaps": src.gaps,
                         "reconnects": src.reconnects,
                         "last_n": src.last_n}
        wall = ((self._wall_last - self._wall_first)
                if self._wall_first is not None else 0.0)
        return {
            "sources": per,
            "lag_max_s": round(lag_max, 3),
            "frames": self._seq,
            "wall_s": round(wall, 3),
            "frames_per_s": round(self._seq / wall, 1) if wall > 0 else 0.0,
            "delta_log_evicted": self._evicted,
        }

    def aggregates(self) -> dict:
        """Deterministic per-source roll-up — a pure function of the
        ingested ``(source, frame)`` sequence (replay-stable)."""
        with self._lock:
            return self._aggregates_locked()

    def health(self) -> dict:
        """Wall-clock reporting: per-source lag + wire accounting, global
        ingest rate.  Excluded from replay comparisons by design."""
        now = time.time()
        with self._lock:
            return self._health_locked(now)

    def snapshot(self) -> dict:
        """Full state: global seq + deterministic aggregates + health —
        one consistent cut (single lock acquisition)."""
        now = time.time()
        with self._lock:
            return {"seq": self._seq,
                    "aggregates": self._aggregates_locked(),
                    "health": self._health_locked(now)}

    def delta(self, since: int) -> dict:
        """Entries with ``seq > since``, oldest first, gapless.

        Pollers chain ``since = reply["seq"]``.  If the bounded log has
        already evicted ``since + 1`` the reply carries ``resync: True``
        plus ``dropped`` (count lost to this poller) and everything still
        retained — the client should re-pull ``/snapshot``.  A ``since``
        *ahead* of the current seq gets the same resync treatment: it means
        the poller's chain came from a previous collector incarnation (the
        consumer restarted underneath it), not from this counter — silently
        returning "no news" would wedge the poller forever."""
        with self._lock:
            if since > self._seq:
                return {"seq": self._seq, "resync": True, "dropped": 0,
                        "frames": list(self._log)}
            if since == self._seq:
                return {"seq": self._seq, "frames": []}
            oldest = self._log[0]["seq"] if self._log else self._seq + 1
            if since + 1 < oldest:
                return {"seq": self._seq, "resync": True,
                        "dropped": oldest - since - 1,
                        "frames": list(self._log)}
            out = [e for e in self._log if e["seq"] > since]
            return {"seq": self._seq, "frames": out}

    def frames_for(self, source: str) -> list[dict]:
        """Retained window for one source (meta + frames + final), for the
        live view's incremental re-render."""
        with self._lock:
            src = self.sources.get(source)
            if src is None:
                return []
            out = []
            if src.meta is not None:
                out.append(src.meta)
            out.extend(src.frames)
            if src.final is not None:
                out.append(src.final)
            return out

    @property
    def seq(self) -> int:
        with self._lock:
            return self._seq

    def source_names(self) -> list[str]:
        with self._lock:
            return sorted(self.sources)
