"""Per-(arch x shape x mesh) lowering inputs: abstract values (ShapeDtypeStruct,
zero allocation) + NamedShardings for the multi-pod dry-run and the roofline."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch.mesh import data_shards
from repro.models.layers import ShardCtx
from repro.models.registry import get_model
from repro.models.steps import (
    abstract_train_state, make_decode_step, make_prefill_step, make_train_step,
    train_state_axes,
)
from repro.optim import adamw
from repro.parallel.axes import logical_to_spec, make_rules, tree_spec


def arch_rules(arch: ArchConfig, shape: ShapeConfig, mesh):
    """Sharding rule table for this cell.

    Inference KV caches shard along `kv_seq` over the model axis (GQA kv-heads are
    usually too few for it; the full-cache einsum decode attention lets GSPMD do
    the distributed partial-softmax merge).  Long-context decode with batch too
    small for the data axes additionally spreads kv_seq over them."""
    seq_par = shape.kind == "decode" and shape.global_batch < data_shards(mesh)
    overrides = dict(arch.sharding_overrides)
    if shape.kind in ("decode", "prefill"):
        overrides.setdefault("kv_seq",
                             ("data", "model") if seq_par else "model")
    fsdp = arch.fsdp
    if shape.kind == "decode" and arch.decode_fsdp is not None:
        fsdp = arch.decode_fsdp  # e.g. vision-90b: per-layer FSDP regathers under
        # the decode scan hoist the whole stacked weights; model-only sharding fits
    return make_rules(fsdp=fsdp, shard_kv_heads=arch.shard_kv_heads,
                      sequence_parallel=seq_par, overrides=overrides)


def shard_ctx(arch: ArchConfig, shape: ShapeConfig, mesh) -> ShardCtx:
    return ShardCtx(mesh=mesh, rules=arch_rules(arch, shape, mesh),
                    n_groups=data_shards(mesh), impl="xla")


def batch_specs(arch: ArchConfig, shape: ShapeConfig):
    """Abstract input batch for this cell."""
    model = get_model(arch)
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": sds((B, S), jnp.int32)}
        if model.needs_media():
            batch["media"] = model.media_struct(B)
        return batch
    # decode: one new token against a cache of S
    return {"tokens": sds((B, 1), jnp.int32), "pos": sds((B,), jnp.int32)}


def batch_shardings(arch: ArchConfig, shape: ShapeConfig, mesh):
    rules = arch_rules(arch, shape, mesh)
    tok = NamedSharding(mesh, logical_to_spec(("batch", "seq"), rules, mesh))
    if shape.kind in ("train", "prefill"):
        out = {"tokens": tok}
        if get_model(arch).needs_media():
            out["media"] = NamedSharding(
                mesh, logical_to_spec(("batch", "frames", None), rules, mesh))
        return out
    return {"tokens": NamedSharding(mesh, logical_to_spec(("batch", None),
                                                          rules, mesh)),
            "pos": NamedSharding(mesh, logical_to_spec(("batch",), rules, mesh))}


def _sharding_tree(axes_tree, rules, mesh):
    specs = tree_spec(axes_tree, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: ArchConfig, shape: ShapeConfig, mesh):
    """Returns (fn, example_args_abstract, in_shardings, out_shardings, donate)
    ready for jax.jit(...).lower()."""
    model = get_model(arch)
    rules = arch_rules(arch, shape, mesh)
    ctx = shard_ctx(arch, shape, mesh)
    opt_cfg = adamw.AdamWConfig(
        moment_dtype="bf16" if arch.opt_dtype == "bf16" else "fp32")

    if shape.kind == "train":
        step, _ = make_train_step(arch, opt_cfg, ctx)
        state = abstract_train_state(arch, opt_cfg)
        state_shard = _sharding_tree(train_state_axes(arch), rules, mesh)
        bshard = batch_shardings(arch, shape, mesh)
        batch = batch_specs(arch, shape)
        out_shard = (state_shard, None)  # metrics replicated
        return dict(fn=step, args=(state, batch),
                    in_shardings=(state_shard, bshard),
                    out_shardings=out_shard, donate_argnums=(0,))

    params = model.abstract_params()
    params_shard = _sharding_tree(model.params_axes(), rules, mesh)

    if shape.kind == "prefill":
        step = make_prefill_step(arch, ctx)
        batch = batch_specs(arch, shape)
        bshard = batch_shardings(arch, shape, mesh)
        cache_shard = _sharding_tree(model.cache_axes(), rules, mesh)
        logits_shard = NamedSharding(
            mesh, logical_to_spec(("batch", "vocab"), rules, mesh))
        return dict(fn=step, args=(params, batch),
                    in_shardings=(params_shard, bshard),
                    out_shardings=(logits_shard, cache_shard),
                    donate_argnums=())

    # decode
    step = make_decode_step(arch, ctx)
    cache = model.cache_struct(shape.global_batch, shape.seq_len)
    cache_shard = _sharding_tree(model.cache_axes(), rules, mesh)
    b = batch_specs(arch, shape)
    bshard = batch_shardings(arch, shape, mesh)
    logits_shard = NamedSharding(mesh,
                                 logical_to_spec(("batch", "vocab"), rules, mesh))
    next_shard = NamedSharding(mesh, logical_to_spec(("batch",), rules, mesh))
    return dict(fn=step, args=(params, cache, b["tokens"], b["pos"]),
                in_shardings=(params_shard, cache_shard, bshard["tokens"],
                              bshard["pos"]),
                out_shardings=(next_shard, logits_shard, cache_shard),
                donate_argnums=(1,))
