from repro.launch.mesh import data_shards, make_production_mesh, model_shards

__all__ = ["data_shards", "make_production_mesh", "model_shards"]
