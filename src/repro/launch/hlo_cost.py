"""Loop-aware cost analysis over optimized (post-SPMD, per-device) HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count — with scan-over-layers models that undercounts FLOPs/bytes/collectives by a
factor of n_layers.  This module re-derives the three roofline inputs from the HLO
text itself, multiplying through ``known_trip_count``:

  flops             dot ops: 2 * prod(output dims) * prod(contracted dims)
  traffic_bytes     per top-level op: operand bytes + output bytes (fusions are
                    opaque — their internals never touch HBM)
  collectives       per-kind bytes: max(input, output) per op (link-traffic proxy)

Tested against analytic expectations in tests/test_hlo_cost.py.
"""

from __future__ import annotations

import dataclasses
import math
import re

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
               "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
               "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8,
               "c128": 16, "token": 0, "opaque": 0}

_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^=]*?\))|(?:[\w\[\]{},\/ ]+?))\s+"
    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_B = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "all-reduce-start", "all-gather-start",
               "collective-permute-start", "ragged-all-to-all"}
_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "after-all", "partition-id", "replica-id",
                 "while", "conditional", "call"}


def type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        b = DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * b
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


@dataclasses.dataclass
class Op:
    var: str
    type_str: str
    opcode: str
    rest: str          # raw text after the opening paren (operands + attrs)
    operands: list


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    types: dict        # var -> type string


def parse(hlo: str) -> tuple[dict[str, "Computation"], str | None]:
    comps: dict[str, Computation] = {}
    entry_name: str | None = None
    cur: Computation | None = None
    for line in hlo.splitlines():
        if cur is None:
            stripped = line.strip()
            m = _COMP_START.match(stripped)
            if m:
                cur = Computation(m.group(1), [], {})
                if stripped.startswith("ENTRY"):
                    entry_name = cur.name
            continue
        s = line.strip()
        if s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        # long tuple types carry /*index=N*/ comments whose '=' breaks the regex
        if "/*" in line:
            line = re.sub(r"/\*.*?\*/", "", line)
        m = _OP_LINE.match(line)
        if not m:
            continue
        var, type_str, opcode, rest = m.groups()
        # operand refs appear before attrs; attrs also contain %comp refs for
        # calls/body/condition — those are excluded via the parsed attrs below
        paren_depth = 1
        cut = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                paren_depth += 1
            elif ch == ")":
                paren_depth -= 1
                if paren_depth == 0:
                    cut = i
                    break
        operand_text = rest[:cut]
        operands = _OPERAND.findall(operand_text)
        op = Op(var, type_str.strip(), opcode, rest, operands)
        cur.ops.append(op)
        cur.types[var] = op.type_str
    return comps, entry_name


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = max(1, math.prod(_shape_dims(op.type_str)))
    lhs_type = comp.types.get(op.operands[0], "") if op.operands else ""
    lhs_dims = _shape_dims(lhs_type)
    m = _LHS_C.search(op.rest)
    contracted = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx:
                contracted *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contracted


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    traffic: float = 0.0
    transcendentals: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.traffic += other.traffic * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v * mult
        self.unknown_trip_loops += other.unknown_trip_loops


def _comp_cost(comp: Computation, comps: dict, memo: dict,
               flops_only: bool = False) -> Cost:
    key = (comp.name, flops_only)
    if key in memo:
        return memo[key]
    c = Cost()
    for op in comp.ops:
        oc = op.opcode
        if oc == "while":
            m = _TRIP.search(op.rest)
            trip = int(m.group(1)) if m else 1
            if not m:
                c.unknown_trip_loops += 1
            b = _BODY.search(op.rest)
            cond = _COND.search(op.rest)
            if b and b.group(1) in comps:
                c.add(_comp_cost(comps[b.group(1)], comps, memo, flops_only),
                      trip)
            if cond and cond.group(1) in comps:
                c.add(_comp_cost(comps[cond.group(1)], comps, memo, flops_only),
                      trip)
            continue
        if oc in ("call", "async-start"):
            m = _CALLS.search(op.rest)
            if m and m.group(1) in comps:
                c.add(_comp_cost(comps[m.group(1)], comps, memo, flops_only))
            continue
        if oc == "conditional":
            # branches: branch_computations={%a, %b}; take the max-cost branch
            branches = re.findall(r"branch_computations=\{([^}]*)\}", op.rest)
            if branches:
                subs = [_comp_cost(comps[n.strip().lstrip("%")], comps, memo,
                                   flops_only)
                        for n in branches[0].split(",")
                        if n.strip().lstrip("%") in comps]
                if subs:
                    best = max(subs, key=lambda s: s.flops + s.traffic)
                    c.add(best)
            continue
        if oc == "fusion":
            m = _CALLS.search(op.rest)
            if m and m.group(1) in comps:
                sub = _comp_cost(comps[m.group(1)], comps, memo, True)
                c.flops += sub.flops
                c.transcendentals += sub.transcendentals
            if not flops_only:
                out_b = type_bytes(op.type_str)
                in_b = sum(type_bytes(comp.types.get(o, "")) for o in op.operands)
                c.traffic += out_b + in_b
            continue
        if oc in ("dot", "convolution"):
            c.flops += _dot_flops(op, comp)
        elif oc in ("exponential", "tanh", "logistic", "log", "rsqrt", "sqrt",
                    "power", "sine", "cosine", "erf", "log-plus-one",
                    "exponential-minus-one"):
            c.transcendentals += max(1, math.prod(_shape_dims(op.type_str)))
        if oc in COLLECTIVES and not flops_only:
            out_b = type_bytes(op.type_str)
            in_b = sum(type_bytes(comp.types.get(o, "")) for o in op.operands)
            kind = oc.replace("-start", "")
            c.collectives[kind] = c.collectives.get(kind, 0.0) + max(out_b, in_b)
        if not flops_only and oc not in _SKIP_TRAFFIC:
            out_b = type_bytes(op.type_str)
            in_b = sum(type_bytes(comp.types.get(o, "")) for o in op.operands)
            c.traffic += out_b + in_b
    memo[key] = c
    return c


def analyze(hlo: str) -> dict:
    """Loop-aware roofline inputs from optimized HLO text (per-device numbers)."""
    comps, entry_name = parse(hlo)
    if entry_name and entry_name in comps:
        entry = comps[entry_name]
    else:  # fall back: largest computation
        entry = max(comps.values(), key=lambda c: len(c.ops))
    memo: dict = {}
    c = _comp_cost(entry, comps, memo)
    total_coll = sum(c.collectives.values())
    return {
        "flops": c.flops,
        "traffic_bytes": c.traffic,
        "transcendentals": c.transcendentals,
        "collectives": dict(c.collectives, total=total_coll),
        "unknown_trip_loops": c.unknown_trip_loops,
    }
