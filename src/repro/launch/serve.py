"""Serving launcher: `python -m repro.launch.serve --arch <id> [options]`.

Batched prefill + KV-cache decode with ATLAS-style replica routing (requests go
to the replica with the best predicted health; failover re-prefills on a
survivor).  Reduced configs on CPU; full configs on real fleets."""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch, smoke_reduce
from repro.models import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if not args.full_config:
        arch = smoke_reduce(arch)
    model = get_model(arch)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.tokens

    media = None
    if model.needs_media():
        ms = model.media_struct(args.batch)
        media = jnp.ones(ms.shape, ms.dtype) * 0.02

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 arch.vocab_size, jnp.int32)
    decode = jax.jit(lambda p, c, t, pos: model.decode(p, c, t, pos))

    t0 = time.time()
    logits, cache = model.prefill(params, prompts, media=media, max_len=max_len)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((args.batch,), args.prompt_len, jnp.int32)
    out = [np.asarray(tok[:, 0])]
    t0 = time.time()
    for _ in range(args.tokens - 1):
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        pos = pos + 1
        out.append(np.asarray(tok[:, 0]))
    dt = time.time() - t0
    n = args.batch * len(out)
    print(f"[serve] {arch.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill:.2f}s; decoded {n} tokens in {dt:.2f}s "
          f"({n / max(dt, 1e-9):.1f} tok/s)")
    print("[serve] sample:", np.stack(out, 1)[0][:16])


if __name__ == "__main__":
    main()
