import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the device count
# on first initialisation).  Everything below is ordinary.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell on the
production meshes (16x16 single-pod and 2x16x16 multi-pod), recording
memory_analysis / cost_analysis / collective-traffic for EXPERIMENTS.md §Dry-run and
the §Roofline table.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]

Results cache to experiments/dryrun/<arch>__<shape>__<mesh>.json; --force recomputes.
"""

import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import SHAPES, get_arch, cell_supported, ARCH_IDS
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch_id: str, shape_id: str, *, multi_pod: bool = False,
             force: bool = False, verbose: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out_path = OUT_DIR / f"{arch_id}__{shape_id}__{mesh_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    arch = get_arch(arch_id)
    shape = SHAPES[shape_id]
    ok, why = cell_supported(arch, shape)
    if not ok:
        rec = {"arch": arch_id, "shape": shape_id, "mesh": mesh_name,
               "status": "skipped", "reason": why}
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    t0 = time.time()
    rec = {"arch": arch_id, "shape": shape_id, "mesh": mesh_name}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh:
            cell = build_cell(arch, shape, mesh)
            jitted = jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                             out_shardings=cell["out_shardings"],
                             donate_argnums=cell["donate_argnums"])
            lowered = jitted.lower(*cell["args"])
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, list):  # older jaxlib: [dict] per partition
                cost = cost[0]
            hlo = compiled.as_text()
            # loop-aware accounting: XLA's cost_analysis counts while bodies once,
            # which undercounts scan-over-layers models by ~n_layers (see
            # repro.launch.hlo_cost + tests/test_hlo_cost.py)
            la = hlo_cost.analyze(hlo)

            rec.update({
                "status": "ok",
                "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1),
                "memory": {
                    "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                    "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                    "generated_code_bytes":
                        getattr(mem, "generated_code_size_in_bytes", 0),
                    "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
                },
                "cost_xla_raw": {
                    "flops": float(cost.get("flops", 0.0)),
                    "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
                },
                "cost": {
                    "flops": la["flops"],
                    "bytes_accessed": la["traffic_bytes"],
                    "transcendentals": la["transcendentals"],
                    "unknown_trip_loops": la["unknown_trip_loops"],
                },
                "collectives": la["collectives"],
                "n_devices": mesh.devices.size,
            })
            if verbose:
                print(f"[dryrun] {arch_id} x {shape_id} x {mesh_name}: OK "
                      f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s, "
                      f"temp {rec['memory']['temp_bytes']/2**30:.2f} GiB/dev, "
                      f"flops/dev {rec['cost']['flops']:.3g}, "
                      f"coll {la['collectives'].get('total', 0)/2**30:.2f} GiB/dev)")
    except Exception as e:  # noqa: BLE001 — a failed cell is a recorded result
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        if verbose:
            print(f"[dryrun] {arch_id} x {shape_id} x {mesh_name}: "
                  f"FAILED {type(e).__name__}: {e}")
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]

    n_ok = n_skip = n_err = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, multi_pod=mp, force=args.force)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
