"""Training launcher: `python -m repro.launch.train --arch <id> [options]`.

On a real TPU fleet this builds the production mesh and runs the sharded train
step under the ATLAS elastic runtime; on the CPU host it runs the reduced config
(the full configs are exercised via the dry-run).  Either way the control loop is
the same ElasticTrainer (checkpoint/restart, ATLAS placement, speculative shard
duplication, adaptive heartbeats)."""

from __future__ import annotations

import argparse
import pathlib

from repro.configs import ARCH_IDS, get_arch, smoke_reduce
from repro.data import DataConfig
from repro.runtime import ElasticTrainer, RuntimeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--hosts", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture (TPU fleets only)")
    ap.add_argument("--fail-rate", type=float, default=0.01)
    ap.add_argument("--atlas", dest="atlas", action="store_true", default=True)
    ap.add_argument("--no-atlas", dest="atlas", action="store_false")
    ap.add_argument("--checkpoint-dir", default="checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if not args.full_config:
        arch = smoke_reduce(arch)
    print(f"[train] arch={arch.name} layers={arch.n_layers} "
          f"d_model={arch.d_model} atlas={args.atlas}")

    rcfg = RuntimeConfig(n_hosts=args.hosts, steps=args.steps,
                         checkpoint_every=args.checkpoint_every,
                         atlas=args.atlas, fail_rate=args.fail_rate,
                         seed=args.seed)
    ckpt = pathlib.Path(args.checkpoint_dir) / arch.name
    trainer = ElasticTrainer(
        arch, rcfg, ckpt,
        data_cfg=DataConfig(vocab_size=arch.vocab_size, seq_len=args.seq_len,
                            global_batch=args.global_batch, seed=args.seed))
    out = trainer.run()
    for k, v in out.items():
        print(f"[train] {k}: {v}")


if __name__ == "__main__":
    main()
