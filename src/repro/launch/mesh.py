"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module never
touches JAX device state — the dry-run sets XLA_FLAGS before any jax import."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the pod axis extends data
    parallelism across pods (hierarchical gradient reduction)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_shards(mesh) -> int:
    """Number of data-parallel shards (pod x data axes).  Uses mesh.shape so it
    also works on AbstractMesh (no devices)."""
    sizes = dict(mesh.shape)
    return sizes.get("pod", 1) * sizes.get("data", 1)


def model_shards(mesh) -> int:
    return dict(mesh.shape).get("model", 1)
