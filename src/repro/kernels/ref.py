"""Pure-jnp oracles for every Pallas kernel.

These are the single source of numerical truth: each Pallas kernel's test asserts
allclose against the function here, and the XLA (non-Pallas) model path calls these
directly (they are written flash-style — chunked, online-softmax, fp32 accumulators —
so they are also the dry-run lowering path on the CPU host).

Conventions: q (B, S, H, D); k/v (B, S_kv, Hkv, D); GQA via H = G * Hkv.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_naive(q, k, v, *, causal=True, window=0):
    """O(S^2)-memory reference; only for small test shapes."""
    B, S, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, S, Hkv, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) / jnp.sqrt(D).astype(jnp.float32)
    qpos = jnp.arange(S)[:, None] + (Skv - S)  # right-aligned query positions
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(B, S, H, D).astype(q.dtype)


def _divisor_chunk(n: int, want: int) -> int:
    """Largest divisor of n that is <= want (whisper's 1500 frames etc.)."""
    c = min(want, n)
    while n % c:
        c -= 1
    return c


def flash_attention_ref(q, k, v, *, causal=True, window=0,
                        q_chunk=512, kv_chunk=512):
    """Chunked online-softmax attention (pure jnp, fp32 accumulators).

    Causal chunk *skipping* is done with a mask (the Pallas kernel skips blocks for
    real); the compute-term consequence is analysed in EXPERIMENTS.md §Roofline."""
    B, S, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    q_chunk = _divisor_chunk(S, q_chunk)
    kv_chunk = _divisor_chunk(Skv, kv_chunk)
    nq, nkv = S // q_chunk, Skv // kv_chunk
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    offset = Skv - S  # right-aligned queries (prefill with history)

    qf = q.reshape(B, nq, q_chunk, Hkv, G, D)
    kf = k.reshape(B, nkv, kv_chunk, Hkv, D)
    vf = v.reshape(B, nkv, kv_chunk, Hkv, D)

    # flash-style memory under autodiff: every (q-chunk x kv-chunk) block is
    # rematerialised in the backward pass (otherwise scan would store the full
    # S x S attention matrix as residuals)
    @jax.checkpoint
    def q_block(qi, qblk):
        qblk = qblk.astype(jnp.float32) * scale  # (B, qc, Hkv, G, D)
        qpos = qi * q_chunk + jnp.arange(q_chunk) + offset

        @jax.checkpoint
        def kv_step(carry, inputs):
            acc, m, l = carry
            ki, kblk, vblk = inputs
            kblk = kblk.astype(jnp.float32)
            vblk = vblk.astype(jnp.float32)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qblk, kblk)  # (B,qc,Hkv,G,kc)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((q_chunk, kv_chunk), dtype=bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum("bqhgk,bkhd->bqhgd", p, vblk)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, q_chunk, Hkv, G, D), jnp.float32)
        m0 = jnp.full((B, q_chunk, Hkv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, Hkv, G), jnp.float32)
        ks = jnp.arange(nkv)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (ks, jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0)))
        return acc / jnp.maximum(l[..., None], 1e-37)

    out = jax.lax.map(lambda args: q_block(*args),
                      (jnp.arange(nq), jnp.moveaxis(qf, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, D)
    return out.astype(q.dtype)


def decode_attention_xla(q, k, v, kv_len, *, window=0):
    """GSPMD-friendly one-token decode attention: full-cache masked softmax with
    einsum reductions over the KV sequence dim.  When the cache is sharded along
    kv_seq, XLA turns the max/sum/contraction reductions into the partial-softmax
    merge collectives automatically (the distributed flash-decode pattern) — this
    is the model-path implementation; the chunked version below is the Pallas
    kernel's oracle."""
    B, _, H, D = q.shape
    Smax, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k.astype(jnp.float32))  # (B,Hkv,G,S)
    kpos = jnp.arange(Smax)[None, :]
    mask = kpos < kv_len[:, None]
    if window:
        mask &= kpos > (kv_len[:, None] - 1 - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    out = out / jnp.maximum(l[..., None], 1e-37)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def decode_attention_ref(q, k, v, kv_len, *, window=0, kv_chunk=1024):
    """One-token decode attention: q (B, 1, H, D) against a (B, S_max, Hkv, D) cache.

    `kv_len` (B,) int32 gives the live prefix length per sequence; positions past it
    are masked.  Online softmax over kv chunks, fp32 accumulators."""
    B, _, H, D = q.shape
    Smax, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    kv_chunk = min(kv_chunk, Smax)
    nkv = Smax // kv_chunk
    assert Smax % kv_chunk == 0
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, G, D)

    def kv_step(carry, ki):
        acc, m, l = carry
        kblk = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
        vblk = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
        kblk = kblk.astype(jnp.float32)
        vblk = vblk.astype(jnp.float32)
        s = jnp.einsum("bhgd,bkhd->bhgk", qf, kblk)  # (B,Hkv,G,kc)
        kpos = ki * kv_chunk + jnp.arange(kv_chunk)
        mask = kpos[None, :] < kv_len[:, None]
        if window:
            mask &= kpos[None, :] > (kv_len[:, None] - 1 - window)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhgk,bkhd->bhgd", p, vblk)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Hkv, G, D), jnp.float32)
    m0 = jnp.full((B, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), jnp.arange(nkv))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# RWKV6 (Finch) WKV recurrence
# ---------------------------------------------------------------------------
#
# The recurrence is linear in the state, so instead of letting scan-AD store the
# (B,H,Dh,Dh) state at EVERY timestep (34 GB/device at 4k tokens — see
# EXPERIMENTS.md §Perf), we give it a custom VJP: the backward pass is the
# analytic adjoint recurrence run in reverse, with forward states recomputed
# chunk-wise from stored chunk boundaries.  Memory: O(T/c + c) states.

_RWKV_CHUNK = 128


def _rwkv6_fwd_scan(r, k, v, w, u, state0):
    B, S, H, Dh = r.shape
    uf = u.astype(jnp.float32)

    def step(state, xs):
        rt, kt, vt, wt = xs  # each (B,H,Dh)
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,Dh,Dh)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, state + uf[None, :, :, None] * kv)
        state = wt[..., :, None] * state + kv
        return state, yt

    xs = tuple(jnp.moveaxis(x.astype(jnp.float32), 1, 0) for x in (r, k, v, w))
    state, ys = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), state


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def rwkv6_scan_ref(r, k, v, w, u, state0):
    """Sequential WKV6: per head, S_t = diag(w_t) S_{t-1} + k_t^T v_t,
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t).

    r,k,v,w: (B, S, H, Dh); u: (H, Dh); state0: (B, H, Dh, Dh) [key x value dims].
    Returns y (B,S,H,Dh) and final state.  Linear-memory backward (custom VJP)."""
    y, state = _rwkv6_fwd_scan(r, k, v, w, u, state0)
    return y.astype(r.dtype), state


def _rwkv6_fwd(r, k, v, w, u, state0):
    B, S, H, Dh = r.shape
    c = _divisor_chunk(S, _RWKV_CHUNK)
    n = S // c
    split = lambda x: jnp.moveaxis(
        x.astype(jnp.float32).reshape(B, n, c, H, Dh), 1, 0)  # (n,B,c,H,Dh)

    def chunk_step(state, xs):
        rc, kc, vc, wc = xs
        yc, new_state = _rwkv6_fwd_scan(rc, kc, vc, wc, u, state)
        return new_state, (yc, state)  # emit chunk output + INITIAL state

    state_f, (ys, boundaries) = jax.lax.scan(
        chunk_step, state0.astype(jnp.float32),
        (split(r), split(k), split(v), split(w)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, Dh)
    return (y.astype(r.dtype), state_f), (r, k, v, w, u, boundaries, c)


def _rwkv6_bwd(res, cts):
    r, k, v, w, u, boundaries, c = res
    ybar, state_f_bar = cts
    B, S, H, Dh = r.shape
    n = S // c
    uf = u.astype(jnp.float32)
    split = lambda x: jnp.moveaxis(
        x.astype(jnp.float32).reshape(B, n, c, H, Dh), 1, 0)
    rs, ks, vs, ws, ybs = split(r), split(k), split(v), split(w), split(ybar)

    def chunk_bwd(sbar, xs):
        rc, kc, vc, wc, ybc, s_boundary = xs

        def fwd_step(state, t):
            kt, wt = kc[:, t], wc[:, t]
            kv = kt[..., :, None] * vc[:, t][..., None, :]
            return wt[..., :, None] * state + kv, state      # emit S_{t-1}

        _, s_prevs = jax.lax.scan(fwd_step, s_boundary, jnp.arange(c))

        def bwd_step(carry, t):
            sbar, ubar = carry
            ti = c - 1 - t
            rt, kt, vt, wt = rc[:, ti], kc[:, ti], vc[:, ti], wc[:, ti]
            yb = ybc[:, ti]
            s_prev = s_prevs[ti]
            kv = kt[..., :, None] * vt[..., None, :]
            M = s_prev + uf[None, :, :, None] * kv
            rbar = jnp.einsum("bhkv,bhv->bhk", M, yb)
            yv = jnp.einsum("bhv,bhv->bh", yb, vt)           # (ybar . v)
            kbar = jnp.einsum("bhkv,bhv->bhk", sbar, vt) \
                + uf[None] * rt * yv[..., None]
            vbar = jnp.einsum("bhkv,bhk->bhv", sbar, kt) \
                + yb * jnp.einsum("bhk,bhk->bh", rt * uf[None], kt)[..., None]
            wbar = jnp.einsum("bhkv,bhkv->bhk", sbar, s_prev)
            ubar = ubar + jnp.einsum("bhk,bh->hk", rt * kt, yv)
            sbar_prev = wt[..., :, None] * sbar \
                + rt[..., :, None] * yb[..., None, :]        # output-path term
            return (sbar_prev, ubar), (rbar, kbar, vbar, wbar)

        (sbar, ubar_c), grads = jax.lax.scan(
            bwd_step, (sbar, jnp.zeros((H, Dh), jnp.float32)), jnp.arange(c))
        # grads are stacked in REVERSE time order -> flip to chunk order
        grads = tuple(jnp.moveaxis(g[::-1], 0, 1) for g in grads)  # (B,c,H,Dh)
        return sbar, (grads, ubar_c)

    sbar0 = state_f_bar.astype(jnp.float32)
    xs_rev = tuple(x[::-1] for x in (rs, ks, vs, ws, ybs, boundaries))
    sbar_final, ((rb, kb, vb, wb), ubs) = jax.lax.scan(chunk_bwd, sbar0, xs_rev)
    join = lambda x: jnp.moveaxis(x[::-1], 0, 1).reshape(B, S, H, Dh)
    return (join(rb).astype(r.dtype), join(kb).astype(k.dtype),
            join(vb).astype(v.dtype), join(wb).astype(w.dtype),
            ubs.sum(axis=0).astype(u.dtype), sbar_final)


rwkv6_scan_ref.defvjp(_rwkv6_fwd, _rwkv6_bwd)


def rwkv6_step_ref(r, k, v, w, u, state):
    """Single decode step: r,k,v,w (B,H,Dh)."""
    kv = k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :]
    sf = state.astype(jnp.float32)
    y = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32),
                   sf + u.astype(jnp.float32)[None, :, :, None] * kv)
    state = w.astype(jnp.float32)[..., :, None] * sf + kv
    return y.astype(r.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 SSD — custom VJP for the same reason as WKV6 above (linear recurrence;
# scan-AD would store the (B,H,P,N) state per timestep)
# ---------------------------------------------------------------------------

_SSD_CHUNK = 128


def _ssd_fwd_scan(x, dt, A, Bmat, Cmat, state0):
    Af = A.astype(jnp.float32)

    def step(state, xs):
        xt, dtt, bt, ct = xs  # (B,H,P) (B,H) (B,N) (B,N)
        decay = jnp.exp(dtt * Af[None, :])                      # (B,H)
        inject = (dtt[..., None] * xt)[..., :, None] * bt[:, None, None, :]
        state = decay[..., None, None] * state + inject         # (B,H,P,N)
        yt = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, yt

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (x, dt, Bmat, Cmat))
    state, ys = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), state


@jax.custom_vjp
def mamba2_ssd_ref(x, dt, A, Bmat, Cmat, state0):
    """Sequential SSD: per head h with state (P, N):
      S_t = exp(dt_t * A_h) S_{t-1} + dt_t * x_t B_t^T ;  y_t = S_t C_t.

    x: (B, S, H, P); dt: (B, S, H); A: (H,) (negative); B,C: (B, S, N);
    state0: (B, H, P, N).  Returns y (B,S,H,P), final state.
    Linear-memory backward (chunked adjoint recurrence)."""
    y, state = _ssd_fwd_scan(x, dt, A, Bmat, Cmat, state0)
    return y.astype(x.dtype), state


def _ssd_fwd(x, dt, A, Bmat, Cmat, state0):
    B, S, H, P = x.shape
    N = Bmat.shape[-1]
    c = _divisor_chunk(S, _SSD_CHUNK)
    n = S // c
    sp = lambda a, tail: jnp.moveaxis(
        a.astype(jnp.float32).reshape((B, n, c) + tail), 1, 0)

    def chunk_step(state, xs):
        xc, dtc, bc, cc = xs
        yc, new_state = _ssd_fwd_scan(xc, dtc, A, bc, cc, state)
        return new_state, (yc, state)

    state_f, (ys, boundaries) = jax.lax.scan(
        chunk_step, state0.astype(jnp.float32),
        (sp(x, (H, P)), sp(dt, (H,)), sp(Bmat, (N,)), sp(Cmat, (N,))))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    return (y.astype(x.dtype), state_f), (x, dt, A, Bmat, Cmat, boundaries, c)


def _ssd_bwd(res, cts):
    x, dt, A, Bmat, Cmat, boundaries, c = res
    ybar, state_f_bar = cts
    B, S, H, P = x.shape
    N = Bmat.shape[-1]
    n = S // c
    Af = A.astype(jnp.float32)
    sp = lambda a, tail: jnp.moveaxis(
        a.astype(jnp.float32).reshape((B, n, c) + tail), 1, 0)
    xs_, dts, bs, cs, ybs = (sp(x, (H, P)), sp(dt, (H,)), sp(Bmat, (N,)),
                             sp(Cmat, (N,)), sp(ybar, (H, P)))

    def chunk_bwd(sbar, xs):
        xc, dtc, bc, cc, ybc, s_boundary = xs

        def fwd_step(state, t):
            decay = jnp.exp(dtc[:, t] * Af[None])
            inject = (dtc[:, t][..., None] * xc[:, t])[..., :, None] \
                * bc[:, t][:, None, None, :]
            return decay[..., None, None] * state + inject, state  # emit S_{t-1}

        _, s_prevs = jax.lax.scan(fwd_step, s_boundary, jnp.arange(c))

        def bwd_step(carry, t):
            sbar, abar_acc = carry
            ti = c - 1 - t
            xt, dtt, bt, ct, yb = (xc[:, ti], dtc[:, ti], bc[:, ti], cc[:, ti],
                                   ybc[:, ti])
            s_prev = s_prevs[ti]
            decay = jnp.exp(dtt * Af[None])                      # (B,H)
            inject = (dtt[..., None] * xt)[..., :, None] * bt[:, None, None, :]
            s_t = decay[..., None, None] * s_prev + inject
            sbar_t = sbar + yb[..., :, None] * ct[:, None, None, :]
            cbar = jnp.einsum("bhpn,bhp->bn", s_t, yb)
            abar = jnp.einsum("bhpn,bhpn->bh", sbar_t, s_prev)   # d/d decay
            dtbar = abar * decay * Af[None] \
                + jnp.einsum("bhpn,bhp,bn->bh", sbar_t, xt, bt)
            xbar = dtt[..., None] * jnp.einsum("bhpn,bn->bhp", sbar_t, bt)
            bbar = jnp.einsum("bhpn,bhp->bn", sbar_t, dtt[..., None] * xt)
            Abar = jnp.einsum("bh,bh->h", abar * decay, dtt)
            sbar_prev = decay[..., None, None] * sbar_t
            return (sbar_prev, abar_acc + Abar), (xbar, dtbar, bbar, cbar)

        (sbar, Abar_c), grads = jax.lax.scan(
            bwd_step, (sbar, jnp.zeros((H,), jnp.float32)), jnp.arange(c))
        grads = tuple(jnp.moveaxis(g[::-1], 0, 1) for g in grads)
        return sbar, (grads, Abar_c)

    sbar0 = state_f_bar.astype(jnp.float32)
    xs_rev = tuple(a[::-1] for a in (xs_, dts, bs, cs, ybs, boundaries))
    sbar_final, ((xb, dtb, bb, cb), Abars) = jax.lax.scan(chunk_bwd, sbar0,
                                                          xs_rev)
    join = lambda g, tail: jnp.moveaxis(g[::-1], 0, 1).reshape((B, S) + tail)
    return (join(xb, (H, P)).astype(x.dtype), join(dtb, (H,)).astype(dt.dtype),
            Abars.sum(axis=0).astype(A.dtype),
            join(bb, (N,)).astype(Bmat.dtype), join(cb, (N,)).astype(Cmat.dtype),
            sbar_final)


mamba2_ssd_ref.defvjp(_ssd_fwd, _ssd_bwd)


def mamba2_step_ref(x, dt, A, Bvec, Cvec, state):
    """Single decode step: x (B,H,P); dt (B,H); B,C (B,N); state (B,H,P,N)."""
    decay = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32)[None, :])
    inject = (dt.astype(jnp.float32)[..., None] * x.astype(jnp.float32))[..., :, None] \
        * Bvec.astype(jnp.float32)[:, None, None, :]
    state = decay[..., None, None] * state.astype(jnp.float32) + inject
    y = jnp.einsum("bhpn,bn->bhp", state, Cvec.astype(jnp.float32))
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# Oblivious-forest inference (the ATLAS scheduling hot path)
# ---------------------------------------------------------------------------

def forest_infer_ref(x, feat_idx, thresholds, leaves):
    """Gather-based oracle for oblivious-tree forest inference.

    x: (B, F) features; feat_idx: (T, D) int32; thresholds: (T, D); leaves: (T, 2^D).
    Tree t at level d tests  x[:, feat_idx[t, d]] > thresholds[t, d]; the D bits form
    the leaf index (level 0 = MSB).  Output: (B,) mean leaf value over trees (a margin
    score; sigmoid of it is P(task succeeds))."""
    B, F = x.shape
    T, D = feat_idx.shape
    xf = x.astype(jnp.float32)
    gathered = xf[:, feat_idx.reshape(-1)].reshape(B, T, D)
    bits = (gathered > thresholds[None].astype(jnp.float32)).astype(jnp.int32)
    weights = (2 ** jnp.arange(D - 1, -1, -1, dtype=jnp.int32))
    leaf_idx = (bits * weights[None, None, :]).sum(-1)          # (B, T)
    vals = jnp.take_along_axis(leaves.astype(jnp.float32)[None].repeat(B, 0),
                               leaf_idx[..., None], axis=2)[..., 0]
    return vals.mean(axis=1)


def forest_infer_grouped_ref(x, seg_ids, feat_idx, thresholds, leaves,
                             n_trees):
    """Block-diagonal grouped oracle: row r reads only model seg_ids[r]'s block.

    x: (R, F) rows stacked segment-by-segment; seg_ids: (R,) int32 model index
    per row; feat_idx/thresholds: (M, T, D) padded blocks (+inf thresholds on
    padded levels -> bits False); leaves: (M, T, 2^D) zero-padded (padded
    trees contribute exactly 0 to the sum); n_trees: (M,) true per-model tree
    counts.  Output: (R,) per-row mean leaf value over the row's own model."""
    R, F = x.shape
    M, T, D = feat_idx.shape
    L = leaves.shape[2]
    xf = x.astype(jnp.float32)
    fi = feat_idx.reshape(M, T * D)[seg_ids]                    # (R, T*D)
    th = thresholds.reshape(M, T * D)[seg_ids].astype(jnp.float32)
    g = jnp.take_along_axis(xf, fi, axis=1)
    bits = (g > th).astype(jnp.int32).reshape(R, T, D)
    weights = (2 ** jnp.arange(D - 1, -1, -1, dtype=jnp.int32))
    leaf_idx = (bits * weights[None, None, :]).sum(-1)          # (R, T)
    flat = (seg_ids[:, None] * T + jnp.arange(T)[None, :]) * L + leaf_idx
    vals = leaves.astype(jnp.float32).reshape(-1)[flat]         # (R, T)
    return vals.sum(axis=1) / n_trees[seg_ids].astype(jnp.float32)
