"""Flash attention as a Pallas TPU kernel.

TPU-native adaptation (not a CUDA port): the kernel is organised around the MXU and
VMEM tiling — q/k/v blocks live in VMEM via BlockSpecs, the score matmul runs on the
MXU with fp32 accumulation (preferred_element_type), online-softmax running stats are
VMEM scratch persisted across the sequential last grid dimension (TPU grids iterate
the trailing axis sequentially on a core, which replaces the CUDA notion of a kv-loop
inside one block).  Causal/window block *skipping* uses pl.when on whole blocks.

Layout: q (B, S, H, D) is viewed as (B, Hkv, G, S, D) so one kernel instance computes
all G grouped query heads for its kv head — the GQA K/V block is loaded once per
group, the TPU analogue of shared-memory KV reuse.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, window: int, q_block: int, kv_block: int,
            n_kv: int, offset: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level causal/window skip
    q_lo = qi * q_block + offset          # first absolute query position
    q_hi = q_lo + q_block - 1
    k_lo = ki * kv_block
    k_hi = k_lo + kv_block - 1
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_hi
    if window:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0]                   # (G, qb, D)
        k = k_ref[0, 0]                   # (kb, D)
        v = v_ref[0, 0]                   # (kb, D)
        G, qb, D = q.shape
        s = jax.lax.dot_general(
            q.reshape(G * qb, D), k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)  # (G*qb, kb)
        s = s * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (G, qb, kv_block), 1)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (G, qb, kv_block), 2)
        s = s.reshape(G, qb, kv_block)
        mask = jnp.ones_like(qpos, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]               # (G, qb)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_ref[...] = l_prev * alpha + p.sum(axis=-1)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p.reshape(G * qb, kv_block).astype(v.dtype), v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(G, qb, D)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + pv

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "kv_block", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_block=256, kv_block=256,
                    interpret=False):
    """q: (B, S, H, D); k/v: (B, Skv, Hkv, D).  Returns (B, S, H, D)."""
    B, S, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    q_block = min(q_block, S)
    kv_block = min(kv_block, Skv)
    assert S % q_block == 0 and Skv % kv_block == 0
    nq, nkv = S // q_block, Skv // kv_block
    offset = Skv - S

    qg = jnp.moveaxis(q.reshape(B, S, Hkv, G, D), 1, 3)   # (B, Hkv, G, S, D)
    kg = jnp.moveaxis(k, 1, 2)                            # (B, Hkv, Skv, D)
    vg = jnp.moveaxis(v, 1, 2)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, q_block=q_block,
        kv_block=kv_block, n_kv=nkv, offset=offset,
        scale=1.0 / float(D) ** 0.5)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, G, q_block, D), lambda b, h, qi, ki: (b, h, 0, qi, 0)),
            pl.BlockSpec((1, 1, kv_block, D), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, kv_block, D), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, q_block, D),
                               lambda b, h, qi, ki: (b, h, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, q_block, D), jnp.float32),   # acc
            pltpu.VMEM((G, q_block), jnp.float32),      # running max
            pltpu.VMEM((G, q_block), jnp.float32),      # running denom
        ],
        interpret=interpret,
    )(qg, kg, vg)
    return jnp.moveaxis(out, 3, 1).reshape(B, S, H, D)
