"""Flash-decode as a Pallas TPU kernel: one query token per sequence against a long
KV cache, online softmax over kv blocks.

TPU adaptation: the KV cache is streamed HBM->VMEM in (kv_block, D) tiles via
BlockSpecs; all H query heads for a kv head are processed together (the GQA group is
the MXU M dimension, so the score computation is a real matmul instead of H matvecs).
The live-length mask comes from a scalar per sequence (kv_len) placed in SMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            kv_block: int, n_kv: int, window: int, scale: float):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[0]
    k_lo = ki * kv_block
    live = k_lo < kv_len
    if window:
        live &= (k_lo + kv_block) > kv_len - 1 - window

    @pl.when(live)
    def _compute():
        q = q_ref[0]                      # (Hkv, G, D)
        k = k_ref[0]                      # (Hkv, kb, D)
        v = v_ref[0]
        Hkv, G, D = q.shape
        s = jax.lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale    # (Hkv, G, kb)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        mask = kpos < kv_len
        if window:
            mask &= kpos > kv_len - 1 - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)            # (Hkv, G, D)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + pv

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "kv_block", "interpret"))
def decode_attention(q, k, v, kv_len, *, window=0, kv_block=512, interpret=False):
    """q: (B, 1, H, D); k/v: (B, Smax, Hkv, D); kv_len: (B,) live prefix lengths."""
    B, _, H, D = q.shape
    Smax, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    kv_block = min(kv_block, Smax)
    assert Smax % kv_block == 0
    nkv = Smax // kv_block

    qg = q.reshape(B, Hkv, G, D)
    kg = jnp.moveaxis(k, 1, 2)            # (B, Hkv, Smax, D)
    vg = jnp.moveaxis(v, 1, 2)

    kernel = functools.partial(_kernel, kv_block=kv_block, n_kv=nkv,
                               window=window, scale=1.0 / float(D) ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nkv),
        in_specs=[
            pl.BlockSpec((1, Hkv, G, D), lambda b, ki, lens: (b, 0, 0, 0)),
            pl.BlockSpec((1, Hkv, kv_block, D), lambda b, ki, lens: (b, 0, ki, 0)),
            pl.BlockSpec((1, Hkv, kv_block, D), lambda b, ki, lens: (b, 0, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hkv, G, D), lambda b, ki, lens: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, G, D), jnp.float32),
            pltpu.VMEM((Hkv, G), jnp.float32),
            pltpu.VMEM((Hkv, G), jnp.float32),
        ],
    )

    def idx_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, acc, m, l):
        b = pl.program_id(0)
        _kernel(lens_ref.at[pl.ds(b, 1)], q_ref, k_ref, v_ref, o_ref, acc, m, l,
                kv_block=kv_block, n_kv=nkv, window=window,
                scale=1.0 / float(D) ** 0.5)

    out = pl.pallas_call(
        idx_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qg, kg, vg)
    return out.reshape(B, 1, H, D)
