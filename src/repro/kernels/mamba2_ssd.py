"""Mamba2 SSD recurrence as a Pallas TPU kernel.

TPU adaptation: the (H, P, N) state is VMEM scratch persisted across sequential
time-chunk grid steps; all heads are processed per kernel instance (head is a
batched VPU dimension — the per-step update is an outer-product FMA of shape
(H, P, N), which vectorises over lanes).  x/dt/B/C stream in chunk tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, s0_ref, y_ref, sf_ref, state, *,
            chunk: int, n_chunks: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        state[...] = s0_ref[0].astype(jnp.float32)

    A = a_ref[...].astype(jnp.float32)            # (H,)

    def step(t, carry):
        xt = x_ref[0, t].astype(jnp.float32)      # (H, P)
        dtt = dt_ref[0, t].astype(jnp.float32)    # (H,)
        bt = b_ref[0, t].astype(jnp.float32)      # (N,)
        ct = c_ref[0, t].astype(jnp.float32)      # (N,)
        decay = jnp.exp(dtt * A)                  # (H,)
        inject = (dtt[:, None] * xt)[:, :, None] * bt[None, None, :]
        state[...] = decay[:, None, None] * state[...] + inject
        yt = (state[...] * ct[None, None, :]).sum(axis=-1)   # (H, P)
        y_ref[0, t] = yt.astype(y_ref.dtype)
        return carry

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ti == n_chunks - 1)
    def _finish():
        sf_ref[0] = state[...].astype(sf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_ssd(x, dt, A, B, C, state0, *, chunk=128, interpret=False):
    """x: (B, S, H, P); dt: (B, S, H); A: (H,); B/C: (B, S, N);
    state0: (B, H, P, N) fp32.  Returns (y (B,S,H,P), final state)."""
    Bb, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    y, sf = pl.pallas_call(
        kernel,
        grid=(Bb, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, t: (b, t, 0)),
            pl.BlockSpec((H,), lambda b, t: (0,)),
            pl.BlockSpec((1, chunk, N), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, t: (b, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, t: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, state0)
    return y, sf
