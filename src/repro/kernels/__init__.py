"""Pallas TPU kernels (pl.pallas_call + BlockSpec VMEM tiling) with jnp oracles.

  flash_attention.py   blockwise causal/window GQA attention (MXU, online softmax)
  decode_attention.py  flash-decode vs long KV caches (scalar-prefetch lengths)
  rwkv6_scan.py        WKV6 recurrence, state resident in VMEM across time chunks
  mamba2_ssd.py        SSD recurrence, (H,P,N) state in VMEM scratch
  forest.py            oblivious-forest inference — the ATLAS scheduling hot path,
                       reformulated gather-free as two MXU matmuls

  ops.py               jit dispatch: "xla" (ref path: CPU smoke + dry-run),
                       "pallas" (TPU), "interpret" (kernel body on CPU for tests)
  ref.py               pure-jnp oracles; also the XLA lowering path — includes the
                       custom VJPs for both linear recurrences
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
