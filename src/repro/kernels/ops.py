"""Jit-ready dispatch wrappers around the Pallas kernels.

Every op takes ``impl``:
  "xla"        pure-jnp flash-style path (ref.py) — CPU smoke tests + the multi-pod
               dry-run (Pallas TPU kernels don't lower on the CPU host backend).
  "pallas"     compiled Pallas TPU kernel — the production path on real hardware.
  "interpret"  Pallas kernel body interpreted on CPU — correctness tests.

The default comes from ``repro.kernels.ops.DEFAULT_IMPL`` (env: REPRO_KERNEL_IMPL)
so tests can flip the whole model zoo onto interpret-mode kernels.
"""

from __future__ import annotations

import os


from repro.kernels import ref

DEFAULT_IMPL = os.environ.get("REPRO_KERNEL_IMPL", "xla")
_VALID = ("xla", "pallas", "interpret")


def _resolve(impl: str | None) -> str:
    impl = impl or DEFAULT_IMPL
    if impl not in _VALID:
        raise ValueError(f"impl must be one of {_VALID}, got {impl!r}")
    return impl


def flash_attention(q, k, v, *, causal=True, window=0, impl=None,
                    q_chunk=512, kv_chunk=512):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       q_chunk=q_chunk, kv_chunk=kv_chunk)
    from repro.kernels import flash_attention as fk
    return fk.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=(impl == "interpret"))


def decode_attention(q, k, v, kv_len, *, window=0, impl=None, kv_chunk=1024):
    impl = _resolve(impl)
    if impl == "xla":
        # full-cache einsum form: GSPMD shards it over kv_seq with automatic
        # partial-softmax merge collectives (see ref.decode_attention_xla)
        return ref.decode_attention_xla(q, k, v, kv_len, window=window)
    from repro.kernels import decode_attention as dk
    return dk.decode_attention(q, k, v, kv_len, window=window,
                               interpret=(impl == "interpret"))


def rwkv6_scan(r, k, v, w, u, state0, *, impl=None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.rwkv6_scan_ref(r, k, v, w, u, state0)
    from repro.kernels import rwkv6_scan as rk
    return rk.rwkv6_scan(r, k, v, w, u, state0, interpret=(impl == "interpret"))


def mamba2_ssd(x, dt, A, B, C, state0, *, impl=None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.mamba2_ssd_ref(x, dt, A, B, C, state0)
    from repro.kernels import mamba2_ssd as mk
    return mk.mamba2_ssd(x, dt, A, B, C, state0, interpret=(impl == "interpret"))


def forest_infer(x, feat_idx, thresholds, leaves, *, impl=None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.forest_infer_ref(x, feat_idx, thresholds, leaves)
    from repro.kernels import forest as fk
    return fk.forest_infer(x, feat_idx, thresholds, leaves,
                           interpret=(impl == "interpret"))


def forest_infer_grouped(x, seg_sizes, feat_idx, thresholds, leaves, n_trees,
                         *, impl=None):
    """Block-diagonal grouped forest inference over the packed multi-model
    layout (see ml.forest.pack_forests); rows stacked segment-by-segment."""
    import numpy as np

    impl = _resolve(impl)
    if impl == "xla":
        import jax.numpy as jnp
        seg_ids = np.repeat(np.arange(len(seg_sizes), dtype=np.int32),
                            np.asarray(seg_sizes))
        return ref.forest_infer_grouped_ref(
            jnp.asarray(x, jnp.float32), jnp.asarray(seg_ids),
            jnp.asarray(feat_idx), jnp.asarray(thresholds),
            jnp.asarray(leaves), jnp.asarray(n_trees))
    from repro.kernels import forest as fk
    return fk.forest_infer_grouped(x, seg_sizes, feat_idx, thresholds,
                                   leaves, n_trees,
                                   interpret=(impl == "interpret"))
