"""RWKV6 (Finch) WKV recurrence as a Pallas TPU kernel.

TPU adaptation: the (Dh x Dh) per-head state lives in VMEM scratch and persists
across the sequential time-chunk grid axis; r/k/v/w stream HBM->VMEM in
(chunk, Dh) tiles.  The recurrence is evaluated stepwise inside the chunk with a
fori_loop over VREG-resident rank-1 updates — RWKV's per-channel data-dependent
decay prevents the exp-factored chunked-matmul form from being numerically safe
for unbounded decays (see ref.py for the oracle; EXPERIMENTS.md discusses the
trade-off), so the kernel optimises memory traffic (state never leaves VMEM)
rather than MXU occupancy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sf_ref, state, *,
            chunk: int, n_chunks: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)          # (Dh,)

    def step(t, carry):
        rt = r_ref[0, 0, t].astype(jnp.float32)   # (Dh,)
        kt = k_ref[0, 0, t].astype(jnp.float32)
        vt = v_ref[0, 0, t].astype(jnp.float32)
        wt = w_ref[0, 0, t].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]            # (Dh, Dh)
        yt = (rt[:, None] * (state[...] + u[:, None] * kv)).sum(axis=0)
        y_ref[0, 0, t] = yt.astype(y_ref.dtype)
        state[...] = wt[:, None] * state[...] + kv
        return carry

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ti == n_chunks - 1)
    def _finish():
        sf_ref[0, 0] = state[...].astype(sf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, state0, *, chunk=128, interpret=False):
    """r,k,v,w: (B, S, H, Dh); u: (H, Dh); state0: (B, H, Dh, Dh) fp32.
    Returns (y (B,S,H,Dh), final state (B,H,Dh,Dh) fp32)."""
    B, S, H, Dh = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk

    tr = lambda x: jnp.moveaxis(x, 1, 2)      # (B, H, S, Dh)
    rt, kt, vt, wt = tr(r), tr(k), tr(v), tr(w)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    y, sf = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, Dh), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, chunk, Dh), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, chunk, Dh), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, chunk, Dh), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, Dh), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, 1, Dh, Dh), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, Dh), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, Dh, Dh), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, Dh), r.dtype),
            jax.ShapeDtypeStruct((B, H, Dh, Dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Dh, Dh), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u, state0)
    return jnp.moveaxis(y, 2, 1), sf
