"""Oblivious-forest inference as a Pallas TPU kernel — the ATLAS scheduling hot path.

The paper evaluates its Random Forest per scheduling decision (~26-36 ms in R).  Our
runtime predicts outcomes for *every pending step-shard each scheduler tick*, so
inference is batched and kernelised.

TPU adaptation (this is where the Hadoop-era algorithm is rethought for the MXU):
tree traversal is gather-heavy on CPUs/GPUs; TPUs hate gathers.  For *oblivious*
trees (one (feature, threshold) test per level, as in CatBoost) the whole forest
evaluates gather-free:

  1. feature gather  ->  one-hot matmul:  X (Bb,F) @ S (F, T*D) on the MXU, where
     S[f, t*D+d] = 1 iff tree t level d tests feature f (precomputed outside).
  2. bits            ->  compare with thresholds (VPU).
  3. leaf lookup     ->  product over levels of 2-way selects builds the implicit
     one-hot over 2^D leaves, contracted against leaf values with a second matmul
     (Bb, T*2^D) @ (T*2^D, 1).

Everything stays in VMEM for a batch tile; zero gathers, two matmuls per tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, sel_ref, thr_ref, path_ref, leaves_ref, o_ref, *,
            T: int, D: int):
    x = x_ref[...].astype(jnp.float32)            # (Bb, F)
    sel = sel_ref[...].astype(jnp.float32)        # (F, T*D)
    g = jax.lax.dot_general(x, sel, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Bb, T*D)
    thr = thr_ref[...].astype(jnp.float32).reshape(1, T * D)
    bits = (g > thr).astype(jnp.float32).reshape(-1, T, D)       # (Bb, T, D)

    n_leaves = 1 << D
    path = path_ref[...].astype(jnp.float32)      # (n_leaves, D), leaf bit patterns
    onehot = jnp.ones((bits.shape[0], T, n_leaves), jnp.float32)
    for d in range(D):
        b_d = bits[:, :, d][:, :, None]           # (Bb, T, 1)
        p_d = path[:, d][None, None, :]           # (1, 1, n_leaves)
        onehot = onehot * (b_d * p_d + (1.0 - b_d) * (1.0 - p_d))

    leaves = leaves_ref[...].astype(jnp.float32).reshape(T * n_leaves, 1)
    score = jax.lax.dot_general(
        onehot.reshape(-1, T * n_leaves), leaves, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # (Bb, 1)
    o_ref[...] = (score[:, 0] / T).astype(o_ref.dtype)


def _selector(feat_idx: jax.Array, F: int) -> jax.Array:
    """One-hot selector S (F, T*D) from feat_idx (T, D)."""
    flat = feat_idx.reshape(-1)                   # (T*D,)
    return jax.nn.one_hot(flat, F, dtype=jnp.float32).T


def _path_bits(D: int) -> jax.Array:
    idx = jnp.arange(1 << D)
    return ((idx[:, None] >> jnp.arange(D - 1, -1, -1)[None, :]) & 1).astype(
        jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def forest_infer(x, feat_idx, thresholds, leaves, *, block_b=256, interpret=False):
    """x: (B, F) fp32; feat_idx: (T, D) int32; thresholds: (T, D); leaves: (T, 2^D).
    Returns (B,) mean-leaf margin scores."""
    B, F = x.shape
    T, D = feat_idx.shape
    block_b = min(block_b, B)
    pad = (-B) % block_b
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    nB = xp.shape[0] // block_b

    sel = _selector(feat_idx, F)
    path = _path_bits(D)

    kernel = functools.partial(_kernel, T=T, D=D)
    out = pl.pallas_call(
        kernel,
        grid=(nB,),
        in_specs=[
            pl.BlockSpec((block_b, F), lambda i: (i, 0)),
            pl.BlockSpec((F, T * D), lambda i: (0, 0)),
            pl.BlockSpec((T, D), lambda i: (0, 0)),
            pl.BlockSpec((1 << D, D), lambda i: (0, 0)),
            pl.BlockSpec((T, 1 << D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0],), jnp.float32),
        interpret=interpret,
    )(xp, sel, thresholds.astype(jnp.float32), path, leaves.astype(jnp.float32))
    return out[:B]


# ---------------------------------------------------------------------------
# Grouped (block-diagonal) variant: many models, one padded block layout
# ---------------------------------------------------------------------------
#
# The serving broker flushes requests from MANY independently trained forests
# at once.  The grouped kernel takes the same packed block layout the numpy
# path uses (ml.forest.pack_forests): per-model selector / threshold / leaf
# blocks stacked into one padded (M, ...) tensor, rows stacked segment-by-
# segment.  The grid walks (model-segment, batch-tile) pairs flattened into
# tiles; a scalar-prefetched tile->segment map lets each tile's BlockSpec DMA
# exactly its own model's blocks into VMEM — no row is ever scored against
# trees it doesn't belong to, and no gather appears anywhere (the selector
# matmul + select-product trick of the single-model kernel, per segment).


def _grouped_kernel(seg_ref, x_ref, sel_ref, thr_ref, path_ref, leaves_ref,
                    invt_ref, o_ref, *, T: int, D: int):
    del seg_ref  # consumed by the BlockSpec index maps
    x = x_ref[...].astype(jnp.float32)            # (Bb, F)
    sel = sel_ref[0].astype(jnp.float32)          # (F, T*D) this tile's model
    g = jax.lax.dot_general(x, sel, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Bb, T*D)
    thr = thr_ref[...].astype(jnp.float32).reshape(1, T * D)
    bits = (g > thr).astype(jnp.float32).reshape(-1, T, D)       # (Bb, T, D)

    n_leaves = 1 << D
    path = path_ref[...].astype(jnp.float32)      # (n_leaves, D)
    onehot = jnp.ones((bits.shape[0], T, n_leaves), jnp.float32)
    for d in range(D):
        b_d = bits[:, :, d][:, :, None]
        p_d = path[:, d][None, None, :]
        onehot = onehot * (b_d * p_d + (1.0 - b_d) * (1.0 - p_d))

    leaves = leaves_ref[...].astype(jnp.float32).reshape(T * n_leaves, 1)
    score = jax.lax.dot_general(
        onehot.reshape(-1, T * n_leaves), leaves, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # (Bb, 1)
    # padded trees have all-zero leaves -> contribute exactly 0; divide by the
    # segment's TRUE tree count (scalar block per tile)
    o_ref[...] = (score[:, 0] * invt_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def _grouped_call(seg_of_tile, xp, sel, thr, path, leaves, inv_t, *,
                  block_b: int, interpret: bool):
    n_tiles = xp.shape[0] // block_b
    F = xp.shape[1]
    M, T, D = thr.shape
    n_leaves = 1 << D
    kernel = functools.partial(_grouped_kernel, T=T, D=D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((block_b, F), lambda i, seg: (i, 0)),
            pl.BlockSpec((1, F, T * D), lambda i, seg: (seg[i], 0, 0)),
            pl.BlockSpec((1, T, D), lambda i, seg: (seg[i], 0, 0)),
            pl.BlockSpec((n_leaves, D), lambda i, seg: (0, 0)),
            pl.BlockSpec((1, T, n_leaves), lambda i, seg: (seg[i], 0, 0)),
            pl.BlockSpec((1, 1), lambda i, seg: (seg[i], 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i, seg: (i,)),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((xp.shape[0],), jnp.float32),
        interpret=interpret,
    )(seg_of_tile, xp, sel, thr, path, leaves, inv_t)


def forest_infer_grouped(x, seg_sizes, feat_idx, thresholds, leaves, n_trees,
                         *, block_b: int = 128, interpret: bool = False):
    """Grouped block-diagonal forest inference.

    x: (R, F) rows stacked segment-by-segment (segment m = seg_sizes[m] rows);
    feat_idx/thresholds: (M, T, D) padded model blocks; leaves: (M, T, 2^D);
    n_trees: (M,) true tree counts.  Returns (R,) mean-leaf scores where each
    row is scored only by its own model's trees."""
    x = np.asarray(x, np.float32)
    seg_sizes = np.asarray(seg_sizes, np.int64)
    R, F = x.shape
    M, T, D = np.asarray(thresholds).shape

    # host-side tile layout: every segment padded up to a block_b multiple so
    # a tile never straddles two models; tile->segment map is scalar-prefetched
    tiles_per_seg = np.maximum(1, -(-seg_sizes // block_b))
    n_tiles = int(tiles_per_seg.sum())
    xp = np.zeros((n_tiles * block_b, F), np.float32)
    seg_of_tile = np.empty(n_tiles, np.int32)
    src = dst = tile = 0
    spans = []
    for m, b in enumerate(seg_sizes):
        b = int(b)
        spans.append((dst, dst + b, src, src + b))
        xp[dst:dst + b] = x[src:src + b]
        nt = int(tiles_per_seg[m])
        seg_of_tile[tile:tile + nt] = m
        src += b
        dst += nt * block_b
        tile += nt

    sel = jax.vmap(lambda f: _selector(f, F))(
        jnp.asarray(feat_idx).reshape(M, T * D))               # (M, F, T*D)
    path = _path_bits(D)
    inv_t = (1.0 / np.asarray(n_trees, np.float32))[:, None]   # (M, 1)
    out = np.asarray(_grouped_call(
        jnp.asarray(seg_of_tile), jnp.asarray(xp), sel,
        jnp.asarray(thresholds, jnp.float32), path,
        jnp.asarray(leaves, jnp.float32), jnp.asarray(inv_t),
        block_b=block_b, interpret=interpret))
    scores = np.empty(R, np.float32)
    for ds, de, ss, se in spans:
        scores[ss:se] = out[ds:de]
    return scores
