"""Oblivious-forest inference as a Pallas TPU kernel — the ATLAS scheduling hot path.

The paper evaluates its Random Forest per scheduling decision (~26-36 ms in R).  Our
runtime predicts outcomes for *every pending step-shard each scheduler tick*, so
inference is batched and kernelised.

TPU adaptation (this is where the Hadoop-era algorithm is rethought for the MXU):
tree traversal is gather-heavy on CPUs/GPUs; TPUs hate gathers.  For *oblivious*
trees (one (feature, threshold) test per level, as in CatBoost) the whole forest
evaluates gather-free:

  1. feature gather  ->  one-hot matmul:  X (Bb,F) @ S (F, T*D) on the MXU, where
     S[f, t*D+d] = 1 iff tree t level d tests feature f (precomputed outside).
  2. bits            ->  compare with thresholds (VPU).
  3. leaf lookup     ->  product over levels of 2-way selects builds the implicit
     one-hot over 2^D leaves, contracted against leaf values with a second matmul
     (Bb, T*2^D) @ (T*2^D, 1).

Everything stays in VMEM for a batch tile; zero gathers, two matmuls per tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, sel_ref, thr_ref, path_ref, leaves_ref, o_ref, *,
            T: int, D: int):
    x = x_ref[...].astype(jnp.float32)            # (Bb, F)
    sel = sel_ref[...].astype(jnp.float32)        # (F, T*D)
    g = jax.lax.dot_general(x, sel, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Bb, T*D)
    thr = thr_ref[...].astype(jnp.float32).reshape(1, T * D)
    bits = (g > thr).astype(jnp.float32).reshape(-1, T, D)       # (Bb, T, D)

    n_leaves = 1 << D
    path = path_ref[...].astype(jnp.float32)      # (n_leaves, D), leaf bit patterns
    onehot = jnp.ones((bits.shape[0], T, n_leaves), jnp.float32)
    for d in range(D):
        b_d = bits[:, :, d][:, :, None]           # (Bb, T, 1)
        p_d = path[:, d][None, None, :]           # (1, 1, n_leaves)
        onehot = onehot * (b_d * p_d + (1.0 - b_d) * (1.0 - p_d))

    leaves = leaves_ref[...].astype(jnp.float32).reshape(T * n_leaves, 1)
    score = jax.lax.dot_general(
        onehot.reshape(-1, T * n_leaves), leaves, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # (Bb, 1)
    o_ref[...] = (score[:, 0] / T).astype(o_ref.dtype)


def _selector(feat_idx: jax.Array, F: int) -> jax.Array:
    """One-hot selector S (F, T*D) from feat_idx (T, D)."""
    flat = feat_idx.reshape(-1)                   # (T*D,)
    return jax.nn.one_hot(flat, F, dtype=jnp.float32).T


def _path_bits(D: int) -> jax.Array:
    idx = jnp.arange(1 << D)
    return ((idx[:, None] >> jnp.arange(D - 1, -1, -1)[None, :]) & 1).astype(
        jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def forest_infer(x, feat_idx, thresholds, leaves, *, block_b=256, interpret=False):
    """x: (B, F) fp32; feat_idx: (T, D) int32; thresholds: (T, D); leaves: (T, 2^D).
    Returns (B,) mean-leaf margin scores."""
    B, F = x.shape
    T, D = feat_idx.shape
    block_b = min(block_b, B)
    pad = (-B) % block_b
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    nB = xp.shape[0] // block_b

    sel = _selector(feat_idx, F)
    path = _path_bits(D)

    kernel = functools.partial(_kernel, T=T, D=D)
    out = pl.pallas_call(
        kernel,
        grid=(nB,),
        in_specs=[
            pl.BlockSpec((block_b, F), lambda i: (i, 0)),
            pl.BlockSpec((F, T * D), lambda i: (0, 0)),
            pl.BlockSpec((T, D), lambda i: (0, 0)),
            pl.BlockSpec((1 << D, D), lambda i: (0, 0)),
            pl.BlockSpec((T, 1 << D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0],), jnp.float32),
        interpret=interpret,
    )(xp, sel, thresholds.astype(jnp.float32), path, leaves.astype(jnp.float32))
    return out[:B]
