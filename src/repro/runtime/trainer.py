"""ATLAS-driven elastic training runtime — the paper's scheduler operating a real
JAX training job on a (simulated) TPU fleet.

Mapping (DESIGN.md §2): TPU hosts = TaskTrackers; the schedulable task = a
*step-shard* (one data-parallel group's microbatch for one step).  Per step:

  1. heartbeat tick: liveness the coordinator *believes*; the adaptive controller
     (paper §4.2) shortens the interval under failure bursts.
  2. ATLAS placement: per-host failure prediction (same Table-1-style features:
     recent co-located failures, heartbeat RTT, restarts, load).  Suspect hosts get
     their shard *speculatively duplicated* onto the healthiest spare host —
     first-success-wins becomes grad-quorum: the step commits as long as every
     shard has at least one surviving copy.
  3. the jitted train step runs on the mesh of live hosts; a host dying mid-step
     with an un-duplicated shard loses the step -> rollback to the last checkpoint
     and elastic re-mesh (the fleet shrinks; state re-shards via CheckpointManager).
  4. hazard-driven checkpointing (beyond-paper): when predicted fleet hazard
     exceeds a threshold, snapshot immediately — insurance gets cheaper than replay.

The same loop runs unchanged on real hardware (the chaos process is replaced by
actual failure notifications); on CPU it runs a reduced model over N fake hosts."""

from __future__ import annotations

import dataclasses
import random
import time
from collections import deque

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data import DataConfig, SyntheticStream
from repro.ml.models import ALL_MODELS
from repro.models.steps import init_train_state, make_train_step
from repro.optim import AdamWConfig


@dataclasses.dataclass
class HostState:
    hid: int
    alive: bool = True
    known_alive: bool = True
    health: float = 1.0
    down_until: int = -1
    restarts: int = 0
    recent_failures: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=16))
    shards_done: int = 0

    def rtt(self) -> float:
        return 1.0 + 0.8 * (1.0 - self.health)


@dataclasses.dataclass
class RuntimeConfig:
    n_hosts: int = 8
    steps: int = 60
    checkpoint_every: int = 10
    heartbeat_every: int = 5          # steps between liveness sweeps (adaptive)
    hb_min: int = 1
    hb_max: int = 10
    atlas: bool = True                # predict + duplicate + hazard checkpoints
    threshold: float = 0.5
    hazard_ckpt_threshold: float = 0.35   # P(any shard lost next step)
    fail_rate: float = 0.02           # per-host per-step base kill prob
    degrade_rate: float = 0.05        # per-host per-step health-degrade prob
    outage_steps: tuple = (5, 15)
    algo: str = "Glm"                 # online model (fast to refit every few steps)
    refit_every: int = 8
    seed: int = 0


def _host_features(h: HostState, step: int, hb_interval: int) -> np.ndarray:
    return np.array([
        len([1 for s in h.recent_failures if step - s <= 20]),
        h.rtt(),
        float(h.restarts),
        float(h.shards_done % 97) / 97.0,   # benign load proxy
        1.0,
    ], dtype=np.float32)


class ElasticTrainer:
    def __init__(self, arch: ArchConfig, rcfg: RuntimeConfig, ckpt_dir,
                 data_cfg: DataConfig | None = None):
        self.arch = arch
        self.rcfg = rcfg
        self.rng = random.Random(rcfg.seed)
        self.hosts = [HostState(i) for i in range(rcfg.n_hosts)]
        self.hb_interval = rcfg.heartbeat_every
        self.ckpt = CheckpointManager(ckpt_dir, keep=2, async_write=False)
        self.data = SyntheticStream(data_cfg or DataConfig(
            vocab_size=arch.vocab_size, seq_len=128,
            global_batch=rcfg.n_hosts * 2, seed=rcfg.seed))
        self.opt_cfg = AdamWConfig(warmup_steps=5, total_steps=rcfg.steps)
        self.step_fn, _ = make_train_step(arch, self.opt_cfg)
        self.step_fn = jax.jit(self.step_fn)
        self.state = init_train_state(arch, jax.random.PRNGKey(rcfg.seed),
                                      self.opt_cfg)
        # online predictor state
        self._X: list = []
        self._y: list = []
        self.model = None
        # metrics
        self.committed = 0
        self.rollbacks = 0
        self.lost_steps = 0
        self.duplicated = 0
        self.wasted_shards = 0
        self.checkpoints = 0
        self.hazard_checkpoints = 0
        self.losses: list = []

    # ------------------------------------------------------------------ fleet
    def _alive(self):
        return [h for h in self.hosts if h.alive]

    def _known_alive(self):
        return [h for h in self.hosts if h.known_alive and h.alive or
                (h.known_alive and not h.alive)]  # what the coordinator believes

    def _chaos_tick(self, step: int):
        for h in self.hosts:
            if not h.alive:
                if step >= h.down_until:
                    h.alive = True
                    h.health = 1.0
                    h.restarts += 1
                continue
            if self.rng.random() < self.rcfg.degrade_rate:
                h.health = max(0.1, h.health - self.rng.uniform(0.2, 0.5))
            elif h.health < 1.0 and self.rng.random() < 0.3:
                h.health = min(1.0, h.health + 0.3)

    def _mid_step_failure(self, h: HostState, step: int) -> bool:
        p = self.rcfg.fail_rate + 0.12 * (1.0 - h.health)
        if self.rng.random() < p:
            h.alive = False
            h.down_until = step + self.rng.randint(*self.rcfg.outage_steps)
            h.recent_failures.append(step)  # 'step' here is the tick
            return True
        return False

    def _heartbeat(self, step: int):
        newly_dead = 0
        for h in self.hosts:
            if h.known_alive and not h.alive:
                newly_dead += 1
            h.known_alive = h.alive
        # paper §4.2 rule at fleet scale: >1/3 failed within a window -> halve
        if newly_dead > len(self.hosts) / 3:
            self.hb_interval = max(self.rcfg.hb_min, self.hb_interval // 2)
        else:
            self.hb_interval = min(self.rcfg.hb_max,
                                   int(self.hb_interval * 1.5) or 1)

    # ------------------------------------------------------------------ predictor
    def _p_success(self, hosts, step) -> np.ndarray:
        if self.model is None:
            return np.ones(len(hosts), np.float32)
        X = np.stack([_host_features(h, step, self.hb_interval) for h in hosts])
        return self.model.predict_proba(X)

    def _record(self, h: HostState, step: int, ok: bool):
        self._X.append(_host_features(h, step, self.hb_interval))
        self._y.append(1.0 if ok else 0.0)

    def _maybe_refit(self, tick):
        if not self.rcfg.atlas or tick % self.rcfg.refit_every:
            return
        if len(self._y) >= 40 and len(set(self._y)) > 1:
            X = np.stack(self._X[-2000:])
            y = np.asarray(self._y[-2000:], np.float32)
            self.model = ALL_MODELS[self.rcfg.algo]().fit(X, y)

    # ------------------------------------------------------------------ loop
    def run(self) -> dict:
        rcfg = self.rcfg
        t0 = time.time()
        step = int(self.state["step"])
        self.ckpt.save(step, self.state, block=True)
        self.checkpoints += 1
        tick = 0  # wall-time ticks: outages heal in ticks even when steps stall
        max_ticks = rcfg.steps * 20
        while step < rcfg.steps and tick < max_ticks:
            tick += 1
            self._chaos_tick(tick)
            if tick % max(self.hb_interval, 1) == 0:
                self._heartbeat(tick)

            workers = [h for h in self.hosts if h.known_alive]
            if not workers:
                self._heartbeat(tick)  # forced sweep; wait for recovery
                workers = [h for h in self.hosts if h.alive]
                if not workers:
                    self.lost_steps += 1
                    continue

            # ---- ATLAS placement: shard -> host (+ speculative duplicates)
            ps = self._p_success(workers, tick) if rcfg.atlas \
                else np.ones(len(workers), np.float32)
            assignment = {h.hid: [h] for h in workers}  # shard keyed by primary
            if rcfg.atlas:
                order = np.argsort(ps)  # most suspect first
                spares = [workers[i] for i in order[::-1]
                          if ps[i] >= rcfg.threshold]
                for i in order:
                    if ps[i] >= rcfg.threshold or not spares:
                        break
                    spare = spares.pop(0)
                    if spare.hid != workers[i].hid:
                        assignment[workers[i].hid].append(spare)
                        self.duplicated += 1

            # ---- hazard-driven checkpoint (beyond-paper)
            if rcfg.atlas:
                p_loss = 1.0
                for hid, copies in assignment.items():
                    p_all_fail = 1.0
                    for h in copies:
                        p_all_fail *= (rcfg.fail_rate + 0.12 * (1 - self._p_success(
                            [h], tick)[0]))
                    p_loss *= (1.0 - p_all_fail)
                p_any_loss = 1.0 - p_loss
                if p_any_loss > rcfg.hazard_ckpt_threshold and \
                        int(self.state["step"]) > self.ckpt.last_saved_step:
                    self.ckpt.save(int(self.state["step"]), self.state, block=True)
                    self.checkpoints += 1
                    self.hazard_checkpoints += 1

            # ---- run the step (host deaths may strike mid-step)
            batch = self.data.batch(step, 0, 1)  # full global batch on this mesh
            died = [h for h in workers if self._mid_step_failure(h, tick)]
            for h in workers:
                self._record(h, tick, h.alive)
                if h.alive:
                    h.shards_done += 1
            lost_shard = False
            for hid, copies in assignment.items():
                if all(not c.alive for c in copies):
                    lost_shard = True
                self.wasted_shards += sum(1 for c in copies[1:] if c.alive)

            if lost_shard:
                # step lost: rollback + elastic re-mesh (fleet shrank)
                self.rollbacks += 1
                self.lost_steps += 1
                last = self.ckpt.latest_step()
                self.state = self.ckpt.restore(last, self.state)
                step = int(self.state["step"])
                self._maybe_refit(tick)
                continue

            jb = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            self.state, metrics = self.step_fn(self.state, jb)
            self.losses.append(float(metrics["loss"]))
            self.committed += 1
            step = int(self.state["step"])

            if step % rcfg.checkpoint_every == 0:
                self.ckpt.save(step, self.state, block=True)
                self.checkpoints += 1
            self._maybe_refit(tick)

        return {
            "steps": rcfg.steps,
            "committed": self.committed,
            "rollbacks": self.rollbacks,
            "lost_steps": self.lost_steps,
            "duplicated_shards": self.duplicated,
            "wasted_shards": self.wasted_shards,
            "checkpoints": self.checkpoints,
            "hazard_checkpoints": self.hazard_checkpoints,
            "final_loss": self.losses[-1] if self.losses else float("nan"),
            "first_loss": self.losses[0] if self.losses else float("nan"),
            "wall_s": time.time() - t0,
        }

    def _advance_outages(self, step):
        for h in self.hosts:
            if not h.alive and step >= h.down_until:
                h.alive = True
                h.restarts += 1
