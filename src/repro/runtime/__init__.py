from repro.runtime.trainer import ElasticTrainer, HostState, RuntimeConfig

__all__ = ["ElasticTrainer", "HostState", "RuntimeConfig"]
