"""Model registry: family -> implementation module, plus input/media specs and
analytic parameter counts used by the roofline (6*N*D model FLOPs)."""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, lm
from repro.parallel.axes import abstract_params, init_params, params_axes


def _module(cfg: ArchConfig):
    return encdec if cfg.family == "audio" else lm


@dataclasses.dataclass(frozen=True)
class Model:
    """Thin functional facade over a family implementation."""
    cfg: ArchConfig

    # --- parameters
    def param_defs(self):
        return _module(self.cfg).param_defs(self.cfg)

    def abstract_params(self):
        return abstract_params(self.param_defs(), self.cfg.dtype)

    def params_axes(self):
        return params_axes(self.param_defs())

    def init(self, key):
        return init_params(self.param_defs(), key, self.cfg.dtype)

    # --- forward fns
    def apply(self, params, tokens, *, media=None, ctx=None, **kw):
        from repro.models.layers import NO_SHARD
        return _module(self.cfg).apply(params, self.cfg, tokens, media=media,
                                       ctx=ctx or NO_SHARD, **kw)

    def prefill(self, params, tokens, *, media=None, ctx=None, **kw):
        from repro.models.layers import NO_SHARD
        return _module(self.cfg).prefill(params, self.cfg, tokens, media=media,
                                         ctx=ctx or NO_SHARD, **kw)

    def decode(self, params, cache, tokens, pos, *, ctx=None):
        from repro.models.layers import NO_SHARD
        return _module(self.cfg).decode(params, self.cfg, cache, tokens, pos,
                                        ctx=ctx or NO_SHARD)

    # --- caches
    def cache_struct(self, batch: int, max_len: int):
        return _module(self.cfg).cache_struct(self.cfg, batch, max_len)

    def cache_axes(self):
        return _module(self.cfg).cache_axes(self.cfg)

    def init_cache(self, batch: int, max_len: int):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_struct(batch, max_len))

    # --- media stubs (frontends)
    def needs_media(self) -> bool:
        return self.cfg.family in ("audio", "vlm")

    def media_struct(self, batch: int):
        cfg = self.cfg
        if cfg.family == "audio":
            return jax.ShapeDtypeStruct(
                (batch, cfg.enc_dec.n_frames, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            return jax.ShapeDtypeStruct(
                (batch, cfg.cross_attn.n_media_tokens, cfg.d_model), cfg.dtype)
        return None


def get_model(cfg: ArchConfig) -> Model:
    return Model(cfg)


def param_count(cfg: ArchConfig) -> int:
    ap = Model(cfg).abstract_params()
    return sum(int(math.prod(x.shape)) for x in jax.tree.leaves(ap))


def active_param_count(cfg: ArchConfig) -> int:
    """Activated parameters per token (MoE: top_k + shared experts only) — the N in
    6*N*D for MoE archs."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.expert_ff
    n_moe_layers = cfg.n_layers - m.first_dense
    routed_total = n_moe_layers * m.n_experts * per_expert
    routed_active = n_moe_layers * m.top_k * per_expert
    return total - routed_total + routed_active
