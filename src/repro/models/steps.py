"""Step factories: train_step / prefill_step / decode_step closures for an arch.

These are the schedulable units of work in the ATLAS runtime and the functions the
multi-pod dry-run lowers."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import NO_SHARD, ShardCtx, xent_loss
from repro.models.registry import get_model
from repro.optim import adamw


def chunked_xent(hidden, embed_params, targets, ctx: ShardCtx = NO_SHARD,
                 chunk: int = 1024):
    """Next-token CE computed in sequence chunks so the (B, S, V) fp32 logits never
    materialise (each chunk is rematerialised in the backward pass).  hidden:
    (B, S, D) final-norm states aligned with `targets` (B, S)."""
    from repro.models.layers import lm_head_apply
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    Sp = S + pad
    n = Sp // chunk
    hs = jnp.moveaxis(hidden.reshape(B, n, chunk, D), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, n, chunk), 1, 0)

    @jax.checkpoint
    def one(h, t):
        logits = lm_head_apply(embed_params, h, ctx)          # (B, c, V) fp32
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(t, 0)[..., None],
                                   axis=-1)[..., 0]
        mask = (t >= 0).astype(jnp.float32)
        return ((lse - gold) * mask).sum(), mask.sum()

    def body(carry, xs):
        tot, cnt = carry
        s, c = one(*xs)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ts))
    return tot / jnp.maximum(cnt, 1.0)


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig | None = None,
                    ctx: ShardCtx = NO_SHARD, donate: bool = True):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {params, opt, step}; batch = {tokens (B,S) [, media (B,M,D)]}.
    Loss: next-token CE over tokens[1:] (sequence-chunked), plus MoE aux loss.
    cfg.accum_steps > 1 splits the global batch into microbatches with gradient
    accumulation (lax.scan) — the activation-memory knob for the big archs."""
    model = get_model(cfg)
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        moment_dtype="bf16" if cfg.opt_dtype == "bf16" else "fp32")

    def loss_fn(params, batch):
        hidden, aux = model.apply(params, batch["tokens"],
                                  media=batch.get("media"), ctx=ctx,
                                  return_hidden=True)
        loss = chunked_xent(hidden[:, :-1], params["embed"],
                            batch["tokens"][:, 1:], ctx)
        return loss + aux, (loss, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    # grad accumulator dtype: fp32 default; bf16 halves the buffer for 100B+ archs
    # (summing <=32 microbatch grads in bf16; drift bounded in tests/test_accum.py)
    acc_dtype = jnp.bfloat16 if cfg.opt_dtype == "bf16" else jnp.float32

    def train_step(state, batch):
        B = jax.tree.leaves(batch)[0].shape[0]
        # microbatches must stay shardable across the data axes
        A = max(1, min(cfg.accum_steps, B // max(ctx.n_groups, 1) or 1))
        while B % A:
            A -= 1
        if A == 1:
            (total, (loss, aux)), grads = grad_fn(state["params"], batch)
        else:
            def micro(carry, mb):
                g_acc, l_acc, a_acc = carry
                (tot, (l, a)), g = grad_fn(state["params"], mb)
                g_acc = jax.tree.map(
                    lambda ga, gi: (ga.astype(jnp.float32)
                                    + gi.astype(jnp.float32)).astype(acc_dtype),
                    g_acc, g)
                return (g_acc, l_acc + l, a_acc + a), None

            mbs = jax.tree.map(
                lambda x: x.reshape((A, x.shape[0] // A) + x.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype),
                              state["params"])
            (grads, loss, aux), _ = jax.lax.scan(
                micro, (g0, jnp.zeros(()), jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / A, grads)
            loss, aux = loss / A, aux / A
            total = loss + aux
        params, opt, om = adamw.apply_updates(state["params"], grads,
                                              state["opt"], opt_cfg)
        metrics = {"loss": loss, "aux_loss": aux, "total_loss": total, **om}
        return {"params": params, "opt": opt, "step": state["step"] + 1}, metrics

    return train_step, opt_cfg


def make_prefill_step(cfg: ArchConfig, ctx: ShardCtx = NO_SHARD):
    model = get_model(cfg)

    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch["tokens"],
                                      media=batch.get("media"), ctx=ctx)
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ArchConfig, ctx: ShardCtx = NO_SHARD):
    """decode_step(params, cache, tokens (B,1), pos (B,)) -> (next_token, logits, cache)."""
    model = get_model(cfg)

    def decode_step(params, cache, tokens, pos):
        logits, cache = model.decode(params, cache, tokens, pos, ctx=ctx)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return decode_step


def init_train_state(cfg: ArchConfig, key, opt_cfg: adamw.AdamWConfig):
    model = get_model(cfg)
    params = model.init(key)
    return {"params": params, "opt": adamw.init_opt_state(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig):
    model = get_model(cfg)
    ap = model.abstract_params()
    return {"params": ap, "opt": adamw.abstract_opt_state(ap, opt_cfg),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def train_state_axes(cfg: ArchConfig):
    model = get_model(cfg)
    pa = model.params_axes()
    return {"params": pa, "opt": adamw.opt_state_axes(pa), "step": ()}
