"""Model-zoo building blocks, pure JAX.

Every block exposes ``<block>_defs(...) -> pytree[ParamDef]`` and apply functions.
Parameters carry logical axis names (see repro.parallel.axes); activation sharding
constraints go through a ShardCtx so the same code runs unsharded on CPU and fully
sharded on the production mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.parallel.axes import ParamDef, logical_to_spec


# ---------------------------------------------------------------------------
# Sharding context
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Carries mesh + logical rules + runtime knobs into model apply functions."""
    mesh: Mesh | None = None
    rules: Mapping[str, Any] | None = None
    n_groups: int = 1          # MoE dispatch groups (== data-shard count on a mesh)
    impl: str | None = None    # kernel impl override (xla | pallas | interpret)

    def constrain(self, x, *axes):
        if self.mesh is None or self.rules is None:
            return x
        spec = logical_to_spec(axes, self.rules, self.mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


NO_SHARD = ShardCtx()


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_defs(dim: int, kind: str = "rms") -> dict:
    d = {"scale": ParamDef((dim,), ("embed",), init="ones")}
    if kind == "layer":
        d["bias"] = ParamDef((dim,), ("embed",), init="zeros")
    return d


def norm_apply(p: dict, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # LayerNorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # RMSNorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def group_norm_apply(scale, x, n_groups: int, eps: float = 1e-5):
    """Per-head group norm over the last dim reshaped to groups (RWKV6 ln_x)."""
    B, S, D = x.shape
    xf = x.astype(jnp.float32).reshape(B, S, n_groups, D // n_groups)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(B, S, D)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_apply(x, positions, theta: float):
    """x: (..., S, H, D) with positions (..., S) or (S,). Rotates pairs (d, d+D/2)."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (self / cross, full-seq / cached decode)
# ---------------------------------------------------------------------------

def attn_defs(d_model: int, n_heads: int, n_kv: int, head_dim: int) -> dict:
    return {
        "wq": ParamDef((d_model, n_heads, head_dim), ("embed", "heads", "head_dim"),
                       init="scaled"),
        "wk": ParamDef((d_model, n_kv, head_dim), ("embed", "kv_heads", "head_dim"),
                       init="scaled"),
        "wv": ParamDef((d_model, n_kv, head_dim), ("embed", "kv_heads", "head_dim"),
                       init="scaled"),
        "wo": ParamDef((n_heads, head_dim, d_model), ("heads", "head_dim", "embed"),
                       init="scaled"),
    }


def _qkv(p, x, kv_x=None):
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
    return q, k, v


def attn_apply(p, x, *, positions=None, theta=10000.0, causal=True, window=0,
               ctx: ShardCtx = NO_SHARD, kv_x=None, use_rope=True):
    """Full-sequence attention.  kv_x != None -> cross attention (no rope on kv side
    unless positions provided for it; vision/audio tokens are position-free here)."""
    q, k, v = _qkv(p, x, kv_x)
    if use_rope and positions is not None:
        q = rope_apply(q, positions, theta)
        if kv_x is None:
            k = rope_apply(k, positions, theta)
    q = ctx.constrain(q, "batch", "seq", "heads", "head_dim")
    k = ctx.constrain(k, "batch", "seq", "kv_heads", "head_dim")
    out = ops.flash_attention(q, k, v, causal=causal and kv_x is None,
                              window=window, impl=ctx.impl)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return ctx.constrain(y, "batch", "seq", "embed")


def attn_prefill(p, x, *, positions, theta, causal=True, window=0,
                 ctx: ShardCtx = NO_SHARD, cache_len: int, use_rope=True):
    """Full-seq attention that also emits a right-padded KV cache of length cache_len."""
    q, k, v = _qkv(p, x)
    if use_rope:
        q = rope_apply(q, positions, theta)
        k = rope_apply(k, positions, theta)
    out = ops.flash_attention(q, k, v, causal=causal, window=window, impl=ctx.impl)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    S = x.shape[1]
    pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
    return ctx.constrain(y, "batch", "seq", "embed"), \
        (jnp.pad(k, pad), jnp.pad(v, pad))


def attn_decode(p, x, cache_k, cache_v, pos, *, theta, window=0,
                ctx: ShardCtx = NO_SHARD, use_rope=True, cross_kv=None):
    """Single-token decode.  x: (B, 1, D); cache_k/v: (B, Smax, Hkv, Dh);
    pos: (B,) number of tokens already in the cache.  Returns y, (new_k, new_v)."""
    if cross_kv is not None:  # cross-attention: static KV, no cache update
        ck, cv = cross_kv
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        kv_len = jnp.full((x.shape[0],), ck.shape[1], jnp.int32)
        out = ops.decode_attention(q, ck, cv, kv_len, impl=ctx.impl)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        return ctx.constrain(y, "batch", "seq", "embed"), (cache_k, cache_v)

    q, k, v = _qkv(p, x)
    if use_rope:
        q = rope_apply(q, pos[:, None], theta)
        k = rope_apply(k, pos[:, None], theta)
    B = x.shape[0]
    # scatter the new row at position `pos` (per sequence)
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, pos].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, pos].set(v[:, 0].astype(cache_v.dtype))
    cache_k = ctx.constrain(cache_k, "batch", "kv_seq", "kv_heads", "head_dim")
    cache_v = ctx.constrain(cache_v, "batch", "kv_seq", "kv_heads", "head_dim")
    out = ops.decode_attention(q, cache_k, cache_v, pos + 1, window=window,
                               impl=ctx.impl)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return ctx.constrain(y, "batch", "seq", "embed"), (cache_k, cache_v)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_defs(d_model: int, d_ff: int, kind: str = "swiglu") -> dict:
    d = {
        "wu": ParamDef((d_model, d_ff), ("embed", "ff"), init="scaled"),
        "wd": ParamDef((d_ff, d_model), ("ff", "embed"), init="scaled"),
    }
    if kind == "swiglu":
        d["wg"] = ParamDef((d_model, d_ff), ("embed", "ff"), init="scaled")
    return d


def mlp_apply(p, x, ctx: ShardCtx = NO_SHARD):
    up = jnp.einsum("bsd,df->bsf", x, p["wu"])
    if "wg" in p:
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"]).astype(jnp.float32))
        h = h.astype(x.dtype) * up
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    h = ctx.constrain(h, "batch", "seq", "ff")
    return ctx.constrain(jnp.einsum("bsf,fd->bsd", h, p["wd"]),
                         "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based token dispatch, capacity-bounded)
# ---------------------------------------------------------------------------

def moe_defs(cfg: ArchConfig) -> dict:
    m = cfg.moe
    d_model = cfg.d_model
    d = {
        "router": ParamDef((d_model, m.n_experts), ("embed", "experts"),
                           init="scaled", scale=0.1),
        "wg": ParamDef((m.n_experts, d_model, m.expert_ff),
                       ("experts", "embed", "expert_ff"), init="scaled"),
        "wu": ParamDef((m.n_experts, d_model, m.expert_ff),
                       ("experts", "embed", "expert_ff"), init="scaled"),
        "wd": ParamDef((m.n_experts, m.expert_ff, d_model),
                       ("experts", "expert_ff", "embed"), init="scaled"),
    }
    if m.n_shared_experts:
        d["shared"] = mlp_defs(d_model, m.n_shared_experts * m.expert_ff)
    return d


def moe_apply(p, x, cfg: ArchConfig, ctx: ShardCtx = NO_SHARD, dropless=False):
    """x: (B, S, D).  Tokens are grouped into ctx.n_groups groups (== data shards on
    a mesh) so routing/sorting stays shard-local under GSPMD; experts are sharded
    over the model axis (EP).  Capacity-bounded: overflow tokens are dropped (they
    keep the shared-expert/residual path).  ``dropless=True`` sets capacity to the
    worst case (decode path: serving must be deterministic w.r.t. batch makeup)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    G = min(ctx.n_groups, T)
    while T % G:
        G -= 1
    Tg = T // G
    # token chunking: serialise dispatch over sub-chunks so the (Tg*K, D) gather /
    # scatter buffers stay bounded at long sequence lengths (qwen3 prefill_32k)
    if m.chunk_tokens and Tg > m.chunk_tokens:
        sub = m.chunk_tokens
        while Tg % sub:
            sub -= 1
        n_sub = Tg // sub

        xs = jnp.moveaxis(x.reshape(G, n_sub, sub, D), 1, 0)  # (n_sub,G,sub,D)

        def body(_, xc):
            # xc (G, sub, D) re-enters as batch=G x seq=sub; ctx.n_groups == G so
            # the inner call keeps the same shard-local grouping and cannot
            # re-chunk (sub <= chunk_tokens)
            y, aux = moe_apply(p, xc, cfg, ctx, dropless=dropless)
            return None, (y, aux)

        _, (ys, auxs) = jax.lax.scan(body, None, xs)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)
        return y, auxs.mean()
    E, K = m.n_experts, m.top_k
    if dropless:
        C = Tg * K
    else:
        C = min(max(1, int(m.capacity_factor * Tg * K / E)), Tg * K)

    xt = x.reshape(G, Tg, D)
    xt = ctx.constrain(xt, "batch", None, "embed")
    logits = jnp.einsum("gtd,de->gte", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)                      # (G, Tg, K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # --- load-balance auxiliary loss (Switch-style), counted pre-drop
    me = probs.mean(axis=(0, 1))                              # (E,)
    one_hot_top1 = jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=(0, 1))
    aux_loss = (me * ce).sum() * E * m.router_aux_weight

    # --- sort-based dispatch, per group
    def dispatch(xg, topi_g, topv_g):
        # xg (Tg, D); topi/topv (Tg, K)
        eid = topi_g.reshape(-1)                              # (Tg*K,)
        w = topv_g.reshape(-1)
        tok = jnp.repeat(jnp.arange(Tg), K)
        order = jnp.argsort(eid, stable=True)
        eid_s, tok_s, w_s = eid[order], tok[order], w[order]
        counts = jnp.zeros((E,), jnp.int32).at[eid].add(1)
        offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(counts)[:-1]])
        slot = jnp.arange(Tg * K) - offsets[eid_s]
        keep = slot < C
        slot_c = jnp.where(keep, slot, 0)
        gathered = xg[tok_s] * keep[:, None].astype(xg.dtype)
        xin = jnp.zeros((E, C, D), xg.dtype).at[eid_s, slot_c].add(
            gathered, mode="drop")
        return xin, (eid_s, tok_s, w_s, slot_c, keep)

    xin, route = jax.vmap(dispatch)(xt, topi, topv)           # (G, E, C, D)
    xin = ctx.constrain(xin, "batch", "experts", None, "embed")

    # --- expert FFN (EP over 'model' via the experts axis)
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["wg"]).astype(jnp.float32))
    up = jnp.einsum("gecd,edf->gecf", xin, p["wu"])
    h = (gate.astype(x.dtype) * up)
    h = ctx.constrain(h, "batch", "experts", None, "expert_ff")
    out_e = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    out_e = ctx.constrain(out_e, "batch", "experts", None, "embed")

    # --- combine back
    def combine(out_g, route_g):
        eid_s, tok_s, w_s, slot_c, keep = route_g
        vals = out_g[eid_s, slot_c] * (w_s * keep.astype(jnp.float32)).astype(
            out_g.dtype)[:, None]
        return jnp.zeros((Tg, D), out_g.dtype).at[tok_s].add(vals)

    y = jax.vmap(combine)(out_e, route).reshape(B, S, D)

    if m.n_shared_experts:
        y = y + mlp_apply(p["shared"], x, ctx)
    return ctx.constrain(y, "batch", "seq", "embed"), aux_loss


# ---------------------------------------------------------------------------
# RWKV6 (Finch)
# ---------------------------------------------------------------------------

def rwkv_heads(cfg: ArchConfig) -> int:
    """RWKV time-mix heads are d_model / head_dim (projections are D->D)."""
    return cfg.d_model // cfg.ssm.head_dim


def rwkv6_defs(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    s = cfg.ssm
    n_mix = 5  # r, k, v, w, g
    return {
        "tm": {  # time mix
            "mu": ParamDef((n_mix, D), (None, "embed"), init="zeros"),
            "mix_w1": ParamDef((D, n_mix * s.lora_mix), ("embed", None), init="scaled"),
            "mix_w2": ParamDef((n_mix, s.lora_mix, D), (None, None, "embed"),
                               init="scaled", scale=0.1),
            "decay0": ParamDef((D,), ("embed",), init="zeros"),
            "decay_w1": ParamDef((D, s.lora_decay), ("embed", None), init="scaled"),
            "decay_w2": ParamDef((s.lora_decay, D), (None, "embed"),
                                 init="scaled", scale=0.1),
            "bonus": ParamDef((rwkv_heads(cfg), s.head_dim), ("heads", "head_dim"),
                              init="zeros"),
            "wr": ParamDef((D, D), ("embed", "heads_x_dim"), init="scaled"),
            "wk": ParamDef((D, D), ("embed", "heads_x_dim"), init="scaled"),
            "wv": ParamDef((D, D), ("embed", "heads_x_dim"), init="scaled"),
            "wg": ParamDef((D, D), ("embed", "heads_x_dim"), init="scaled"),
            "wo": ParamDef((D, D), ("heads_x_dim", "embed"), init="scaled"),
            "ln_x": ParamDef((D,), ("embed",), init="ones"),
        },
        "cm": {  # channel mix
            "mu_k": ParamDef((D,), ("embed",), init="zeros"),
            "mu_r": ParamDef((D,), ("embed",), init="zeros"),
            "wk": ParamDef((D, cfg.d_ff), ("embed", "ff"), init="scaled"),
            "wv": ParamDef((cfg.d_ff, D), ("ff", "embed"), init="scaled"),
            "wr": ParamDef((D, D), ("embed", "heads_x_dim"), init="scaled"),
        },
    }


def _rwkv6_projections(p, x, x_prev, cfg: ArchConfig):
    """Shared between train scan and decode step.  x, x_prev: (B, S, D)."""
    s = cfg.ssm
    H, Dh = rwkv_heads(cfg), s.head_dim
    B, S, D = x.shape
    delta = x_prev - x
    # data-dependent token-shift amounts (5 lerp amounts via LoRA)
    mix_in = jnp.tanh(jnp.einsum("bsd,dr->bsr", x + 0.5 * delta, p["tm"]["mix_w1"])
                      .astype(jnp.float32)).astype(x.dtype)
    mix_in = mix_in.reshape(B, S, 5, s.lora_mix)
    dyn = jnp.einsum("bsnr,nrd->nbsd", mix_in, p["tm"]["mix_w2"])
    mu = p["tm"]["mu"][:, None, None, :].astype(x.dtype)
    xs = x[None] + delta[None] * (mu + dyn)                   # (5, B, S, D)
    xr, xk, xv, xw, xg = xs[0], xs[1], xs[2], xs[3], xs[4]
    r = jnp.einsum("bsd,de->bse", xr, p["tm"]["wr"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,de->bse", xk, p["tm"]["wk"]).reshape(B, S, H, Dh)
    v = jnp.einsum("bsd,de->bse", xv, p["tm"]["wv"]).reshape(B, S, H, Dh)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["tm"]["wg"])
                    .astype(jnp.float32)).astype(x.dtype)
    dec = p["tm"]["decay0"].astype(jnp.float32) + jnp.einsum(
        "bsd,dr->bsr", jnp.tanh(xw.astype(jnp.float32)),
        p["tm"]["decay_w1"].astype(jnp.float32)) @ p["tm"]["decay_w2"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec.clip(-20.0, 10.0))).reshape(B, S, H, Dh)  # in (0,1)
    return r, k, v, w, g


def rwkv6_time_mix(p, x, x_prev_row, state0, cfg: ArchConfig,
                   ctx: ShardCtx = NO_SHARD):
    """Full-seq time mix.  x: (B,S,D); x_prev_row: (B,D) last token of the previous
    segment (zeros at start); state0: (B,H,Dh,Dh).  Returns y, (last_x, state)."""
    B, S, D = x.shape
    x_prev = jnp.concatenate([x_prev_row[:, None], x[:, :-1]], axis=1)
    r, k, v, w, g = _rwkv6_projections(p, x, x_prev, cfg)
    u = p["tm"]["bonus"]
    y, state = ops.rwkv6_scan(r, k, v, w.astype(r.dtype), u, state0, impl=ctx.impl)
    y = y.reshape(B, S, D)
    y = group_norm_apply(p["tm"]["ln_x"], y, rwkv_heads(cfg))
    y = jnp.einsum("bse,ed->bsd", y * g, p["tm"]["wo"])
    return ctx.constrain(y, "batch", "seq", "embed"), (x[:, -1], state)


def rwkv6_channel_mix(p, x, x_prev_row):
    """x: (B,S,D); returns y, last_x."""
    x_prev = jnp.concatenate([x_prev_row[:, None], x[:, :-1]], axis=1)
    delta = x_prev - x
    xk = x + delta * p["cm"]["mu_k"].astype(x.dtype)
    xr = x + delta * p["cm"]["mu_r"].astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, p["cm"]["wk"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    v = jnp.einsum("bsf,fd->bsd", k, p["cm"]["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cm"]["wr"])
                       .astype(jnp.float32)).astype(x.dtype)
    return r * v, x[:, -1]


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

def mamba2_defs(cfg: ArchConfig) -> dict:
    s = cfg.ssm
    D = cfg.d_model
    d_inner = s.expand * D
    H = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.state_dim
    return {
        "in_proj": ParamDef((D, 2 * d_inner + 2 * s.state_dim + H),
                            ("embed", "heads_x_dim"), init="scaled"),
        "conv_w": ParamDef((s.conv_width, conv_ch), ("conv", "heads_x_dim"),
                           init="scaled", scale=0.5),
        "conv_b": ParamDef((conv_ch,), ("heads_x_dim",), init="zeros"),
        "a_log": ParamDef((H,), ("heads",), init="zeros"),
        "dt_bias": ParamDef((H,), ("heads",), init="zeros"),
        "d_skip": ParamDef((H,), ("heads",), init="ones"),
        "norm": ParamDef((d_inner,), ("heads_x_dim",), init="ones"),
        "out_proj": ParamDef((d_inner, D), ("heads_x_dim", "embed"), init="scaled"),
    }


def _mamba2_split(p, x, cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    N = s.state_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xbc, dt, d_inner, H, N


def mamba2_apply(p, x, conv_state0, ssd_state0, cfg: ArchConfig,
                 ctx: ShardCtx = NO_SHARD):
    """Full-seq Mamba2 block.  conv_state0: (B, conv_w-1, conv_ch) left context;
    ssd_state0: (B, H, P, N).  Returns y, (conv_state, ssd_state)."""
    s = cfg.ssm
    B, S, D = x.shape
    z, xbc, dt, d_inner, H, N = _mamba2_split(p, x, cfg)
    # causal conv over seq with carried left context
    seq = jnp.concatenate([conv_state0.astype(xbc.dtype), xbc], axis=1)
    kernel = p["conv_w"]
    conv = sum(seq[:, i:i + S] * kernel[i][None, None] for i in range(s.conv_width))
    conv = jax.nn.silu((conv + p["conv_b"][None, None]).astype(jnp.float32)
                       ).astype(x.dtype)
    x_ssm, Bmat, Cmat = jnp.split(conv, [d_inner, d_inner + N], axis=-1)
    xh = x_ssm.reshape(B, S, H, s.head_dim)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, ssd_state = ops.mamba2_ssd(xh, dtf.astype(x.dtype), A.astype(jnp.float32),
                                  Bmat, Cmat, ssd_state0, impl=ctx.impl)
    y = y + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_inner)
    y = group_norm_apply(p["norm"], y, H)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    conv_state = seq[:, S:]  # last conv_w-1 rows
    return ctx.constrain(out, "batch", "seq", "embed"), (conv_state, ssd_state)


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------

def embed_defs(cfg: ArchConfig) -> dict:
    d = {"embedding": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                               init="normal")}
    if not cfg.tie_embeddings:
        d["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                                init="scaled")
    return d


def embed_apply(p, tokens, ctx: ShardCtx = NO_SHARD):
    y = p["embedding"][tokens]
    return ctx.constrain(y, "batch", "seq", "embed")


def lm_head_apply(p, x, ctx: ShardCtx = NO_SHARD):
    if "lm_head" in p:
        logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"])
    else:
        logits = jnp.einsum("bsd,vd->bsv", x, p["embedding"])
    return ctx.constrain(logits.astype(jnp.float32), "batch", "seq", "vocab")


def xent_loss(logits, targets, mask=None):
    """Stable CE; logits (B,S,V) fp32, targets (B,S) int32."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
