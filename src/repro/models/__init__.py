from repro.models.registry import Model, active_param_count, get_model, param_count
from repro.models.layers import NO_SHARD, ShardCtx, xent_loss

__all__ = ["Model", "ShardCtx", "NO_SHARD", "active_param_count", "get_model",
           "param_count", "xent_loss"]
