"""Whisper-style encoder-decoder backbone (audio family).

The mel/conv frontend is a STUB per the assignment: inputs are precomputed frame
embeddings (B, n_frames, d_model).  LayerNorm + GELU MLP + absolute sinusoidal
positions (no RoPE), matching Whisper's transformer shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.layers import NO_SHARD, ShardCtx
from repro.models.lm import _remat, stack_defs


def _sinusoid(S: int, D: int, offset=0):
    pos = jnp.arange(S, dtype=jnp.float32) + offset
    half = D // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None] * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_block_defs(cfg: ArchConfig) -> dict:
    return {
        "ln1": L.norm_defs(cfg.d_model, "layer"),
        "attn": L.attn_defs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.resolved_head_dim),
        "ln2": L.norm_defs(cfg.d_model, "layer"),
        "mlp": L.mlp_defs(cfg.d_model, cfg.d_ff, kind="gelu"),
    }


def _dec_block_defs(cfg: ArchConfig) -> dict:
    d = _enc_block_defs(cfg)
    d["ln_x"] = L.norm_defs(cfg.d_model, "layer")
    d["xattn"] = L.attn_defs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.resolved_head_dim)
    return d


def param_defs(cfg: ArchConfig) -> dict:
    e = cfg.enc_dec
    return {
        "embed": L.embed_defs(cfg),
        "enc_blocks": stack_defs(_enc_block_defs(cfg), e.n_enc_layers),
        "enc_norm": L.norm_defs(cfg.d_model, "layer"),
        "dec_blocks": stack_defs(_dec_block_defs(cfg), cfg.n_layers),
        "final_norm": L.norm_defs(cfg.d_model, "layer"),
    }


def encode(params, cfg: ArchConfig, frames, ctx: ShardCtx = NO_SHARD):
    """frames: (B, n_frames, d_model) stub embeddings -> (B, n_frames, d_model)."""
    B, S, D = frames.shape
    x = frames + _sinusoid(S, D).astype(frames.dtype)[None]
    x = ctx.constrain(x, "batch", "frames", "embed")

    def body(x, blk):
        h = L.attn_apply(blk["attn"], L.norm_apply(blk["ln1"], x), positions=None,
                         causal=False, ctx=ctx, use_rope=False)
        x = x + h
        return x + L.mlp_apply(blk["mlp"], L.norm_apply(blk["ln2"], x), ctx), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["enc_blocks"])
    return L.norm_apply(params["enc_norm"], x)


def _dec_block(cfg, blk, x, enc_out, positions, ctx):
    h = L.attn_apply(blk["attn"], L.norm_apply(blk["ln1"], x), positions=positions,
                     causal=True, ctx=ctx, use_rope=False)
    x = x + h
    h = L.attn_apply(blk["xattn"], L.norm_apply(blk["ln_x"], x), positions=None,
                     causal=False, ctx=ctx, kv_x=enc_out, use_rope=False)
    x = x + h
    return x + L.mlp_apply(blk["mlp"], L.norm_apply(blk["ln2"], x), ctx)


def apply(params, cfg: ArchConfig, tokens, *, media=None, ctx: ShardCtx = NO_SHARD,
          pos_offset=0, return_hidden=False):
    """Full-seq teacher-forced decode over `tokens` given `media` frames.
    Returns (logits (B,S,V) fp32, aux 0.0)."""
    assert media is not None, "whisper needs frame embeddings"
    enc_out = encode(params, cfg, media, ctx)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S) + pos_offset, (B, S))
    x = L.embed_apply(params["embed"], tokens, ctx)
    x = x + _sinusoid(S, cfg.d_model, pos_offset).astype(x.dtype)[None]

    def body(x, blk):
        return _dec_block(cfg, blk, x, enc_out, positions, ctx), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["dec_blocks"])
    x = L.norm_apply(params["final_norm"], x)
    if return_hidden:
        return x, jnp.zeros((), jnp.float32)
    return L.lm_head_apply(params["embed"], x, ctx), jnp.zeros((), jnp.float32)


def cache_struct(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    hd = cfg.resolved_head_dim
    e = cfg.enc_dec
    sds = jax.ShapeDtypeStruct
    kv = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    xkv = (cfg.n_layers, batch, e.n_frames, cfg.n_kv_heads, hd)
    return {"k": sds(kv, cfg.dtype), "v": sds(kv, cfg.dtype),
            "xk": sds(xkv, cfg.dtype), "xv": sds(xkv, cfg.dtype)}


def cache_axes(cfg: ArchConfig) -> dict:
    kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    xkv = ("layers", "batch", "frames", "kv_heads", "head_dim")
    return {"k": kv, "v": kv, "xk": xkv, "xv": xkv}


def prefill(params, cfg: ArchConfig, tokens, *, media=None,
            ctx: ShardCtx = NO_SHARD, max_len: int | None = None):
    assert media is not None
    enc_out = encode(params, cfg, media, ctx)
    B, S = tokens.shape
    max_len = max_len or S
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = L.embed_apply(params["embed"], tokens, ctx)
    x = x + _sinusoid(S, cfg.d_model).astype(x.dtype)[None]

    def body(x, blk):
        h, kv = L.attn_prefill(blk["attn"], L.norm_apply(blk["ln1"], x),
                               positions=positions, theta=0.0, ctx=ctx,
                               cache_len=max_len, use_rope=False)
        x = x + h
        xk = jnp.einsum("bmd,dhk->bmhk", enc_out, blk["xattn"]["wk"])
        xv = jnp.einsum("bmd,dhk->bmhk", enc_out, blk["xattn"]["wv"])
        h = L.attn_apply(blk["xattn"], L.norm_apply(blk["ln_x"], x), positions=None,
                         causal=False, ctx=ctx, kv_x=enc_out, use_rope=False)
        x = x + h
        x = x + L.mlp_apply(blk["mlp"], L.norm_apply(blk["ln2"], x), ctx)
        return x, (kv[0], kv[1], xk, xv)

    x, (k, v, xk, xv) = jax.lax.scan(_remat(body, cfg), x, params["dec_blocks"])
    x = L.norm_apply(params["final_norm"], x)
    logits = L.lm_head_apply(params["embed"], x[:, -1:], ctx)
    return logits[:, 0], {"k": k, "v": v, "xk": xk, "xv": xv}


def decode(params, cfg: ArchConfig, cache, tokens, pos, *,
           ctx: ShardCtx = NO_SHARD):
    B = tokens.shape[0]
    x = L.embed_apply(params["embed"], tokens, ctx)
    # positions differ per sequence; add sinusoid at pos per row
    D = cfg.d_model
    half = D // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[:, None] * freqs[None]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    x = x + pe[:, None].astype(x.dtype)

    def body(x, xs):
        blk, ck, cv, xk, xv = xs
        h, (nk, nv) = L.attn_decode(blk["attn"], L.norm_apply(blk["ln1"], x),
                                    ck, cv, pos, theta=0.0, ctx=ctx, use_rope=False)
        x = x + h
        h, _ = L.attn_decode(blk["xattn"], L.norm_apply(blk["ln_x"], x), None, None,
                             pos, theta=0.0, ctx=ctx, cross_kv=(xk, xv))
        x = x + h
        x = x + L.mlp_apply(blk["mlp"], L.norm_apply(blk["ln2"], x), ctx)
        return x, (nk, nv)

    x, kvs = jax.lax.scan(body, x, (params["dec_blocks"], cache["k"], cache["v"],
                                    cache["xk"], cache["xv"]))
    x = L.norm_apply(params["final_norm"], x)
    logits = L.lm_head_apply(params["embed"], x, ctx)
    return logits[:, 0], {"k": kvs[0], "v": kvs[1],
                          "xk": cache["xk"], "xv": cache["xv"]}
