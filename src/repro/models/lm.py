"""Decoder-only LM assembly for families: dense, moe, ssm (RWKV6), hybrid
(Mamba2 + shared attention, Zamba2-style), vlm (cross-attn image layers).

All layer stacks are `lax.scan`-ed over stacked parameters (keeps HLO size
O(1) in depth — essential for 94-100 layer archs at 512 devices) with remat
per the config.  Three entry points per family:

  apply(params, batch, ctx)        full-seq forward -> (logits, aux_loss)
  prefill(params, batch, ctx)      full-seq forward -> (logits_last, cache)
  decode(params, cache, batch,ctx) one-token step   -> (logits, cache)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.layers import NO_SHARD, ShardCtx
from repro.parallel.axes import ParamDef, is_param_def


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def stack_defs(defs: Any, n: int) -> Any:
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes,
                           dtype=d.dtype, init=d.init, scale=d.scale),
        defs, is_leaf=is_param_def)


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _slice_tree(tree, lo, hi):
    return jax.tree.map(lambda p: p[lo:hi], tree)


def _positions(B, S, offset=0):
    return jnp.broadcast_to(jnp.arange(S) + offset, (B, S))


# ---------------------------------------------------------------------------
# Parameter definitions per family
# ---------------------------------------------------------------------------

def _dense_block_defs(cfg: ArchConfig) -> dict:
    return {
        "ln1": L.norm_defs(cfg.d_model),
        "attn": L.attn_defs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.resolved_head_dim),
        "ln2": L.norm_defs(cfg.d_model),
        "mlp": L.mlp_defs(cfg.d_model, cfg.d_ff),
    }


def _moe_block_defs(cfg: ArchConfig) -> dict:
    return {
        "ln1": L.norm_defs(cfg.d_model),
        "attn": L.attn_defs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.resolved_head_dim),
        "ln2": L.norm_defs(cfg.d_model),
        "moe": L.moe_defs(cfg),
    }


def _dense_fallback_ff(cfg: ArchConfig) -> int:
    # deepseek-style: the leading dense layer matches the activated expert width
    m = cfg.moe
    return (m.top_k + m.n_shared_experts) * m.expert_ff


def _rwkv_block_defs(cfg: ArchConfig) -> dict:
    d = L.rwkv6_defs(cfg)
    d["ln1"] = L.norm_defs(cfg.d_model)
    d["ln2"] = L.norm_defs(cfg.d_model)
    return d


def _mamba_block_defs(cfg: ArchConfig) -> dict:
    return {"ln": L.norm_defs(cfg.d_model), "mamba": L.mamba2_defs(cfg)}


def _shared_attn_defs(cfg: ArchConfig) -> dict:
    h = cfg.hybrid
    hd = cfg.d_model // h.shared_attn_heads
    return {
        "ln1": L.norm_defs(cfg.d_model),
        "attn": L.attn_defs(cfg.d_model, h.shared_attn_heads, h.shared_attn_heads, hd),
        "ln2": L.norm_defs(cfg.d_model),
        "mlp": L.mlp_defs(cfg.d_model, h.shared_attn_ff),
    }


def _cross_block_defs(cfg: ArchConfig) -> dict:
    d = _dense_block_defs(cfg)
    d["gate_attn"] = ParamDef((1,), (None,), init="zeros")
    d["gate_mlp"] = ParamDef((1,), (None,), init="zeros")
    return d


def param_defs(cfg: ArchConfig) -> dict:
    p: dict = {"embed": L.embed_defs(cfg), "final_norm": L.norm_defs(cfg.d_model)}
    if cfg.family == "dense":
        p["blocks"] = stack_defs(_dense_block_defs(cfg), cfg.n_layers)
    elif cfg.family == "moe":
        nd = cfg.moe.first_dense
        if nd:
            dense = dict(_moe_block_defs(cfg))
            dense.pop("moe")
            dense["mlp"] = L.mlp_defs(cfg.d_model, _dense_fallback_ff(cfg))
            p["dense0"] = stack_defs(dense, nd)
        p["blocks"] = stack_defs(_moe_block_defs(cfg), cfg.n_layers - nd)
    elif cfg.family == "ssm":
        p["ln0"] = L.norm_defs(cfg.d_model)
        p["blocks"] = stack_defs(_rwkv_block_defs(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        p["blocks"] = stack_defs(_mamba_block_defs(cfg), cfg.n_layers)
        p["shared"] = _shared_attn_defs(cfg)
    elif cfg.family == "vlm":
        period = cfg.cross_attn.period
        n_cross = cfg.n_layers // period
        n_self = cfg.n_layers - n_cross
        p["self_blocks"] = stack_defs(_dense_block_defs(cfg), n_self)
        p["cross_blocks"] = stack_defs(_cross_block_defs(cfg), n_cross)
    else:
        raise ValueError(f"family {cfg.family} not handled by lm.py")
    return p


def _hybrid_groups(cfg: ArchConfig) -> list[tuple[int, int]]:
    """Static (lo, hi) mamba-layer slices; the shared block runs before each."""
    period = cfg.hybrid.period
    return [(lo, min(lo + period, cfg.n_layers))
            for lo in range(0, cfg.n_layers, period)]


def n_shared_invocations(cfg: ArchConfig) -> int:
    return len(_hybrid_groups(cfg))


# ---------------------------------------------------------------------------
# Full-sequence forward (train / the body of prefill)
# ---------------------------------------------------------------------------

def _dense_block_apply(cfg, blk, x, positions, ctx, window=None):
    w = cfg.sliding_window if window is None else window
    h = L.attn_apply(blk["attn"], L.norm_apply(blk["ln1"], x), positions=positions,
                     theta=cfg.rope_theta, causal=cfg.causal, window=w, ctx=ctx)
    x = x + h
    x = x + L.mlp_apply(blk["mlp"], L.norm_apply(blk["ln2"], x), ctx)
    return x


def _moe_block_apply(cfg, blk, x, positions, ctx):
    h = L.attn_apply(blk["attn"], L.norm_apply(blk["ln1"], x), positions=positions,
                     theta=cfg.rope_theta, causal=cfg.causal,
                     window=cfg.sliding_window, ctx=ctx)
    x = x + h
    y, aux = L.moe_apply(blk["moe"], L.norm_apply(blk["ln2"], x), cfg, ctx)
    return x + y, aux


def _rwkv_block_apply(cfg, blk, x, tm_prev, cm_prev, state0, ctx):
    h, (tm_last, state) = L.rwkv6_time_mix(
        blk, L.norm_apply(blk["ln1"], x), tm_prev, state0, cfg, ctx)
    x = x + h
    h, cm_last = L.rwkv6_channel_mix(blk, L.norm_apply(blk["ln2"], x), cm_prev)
    return x + h, tm_last, cm_last, state


def _mamba_block_apply(cfg, blk, x, conv0, ssd0, ctx):
    h, (conv_s, ssd_s) = L.mamba2_apply(blk["mamba"], L.norm_apply(blk["ln"], x),
                                        conv0, ssd0, cfg, ctx)
    return x + h, conv_s, ssd_s


def _shared_block_apply(cfg, p, x, positions, ctx):
    h = cfg.hybrid
    blk = p["shared"]
    y = L.attn_apply(blk["attn"], L.norm_apply(blk["ln1"], x), positions=positions,
                     theta=cfg.rope_theta, causal=True,
                     window=cfg.sliding_window, ctx=ctx)
    x = x + y
    return x + L.mlp_apply(blk["mlp"], L.norm_apply(blk["ln2"], x), ctx)


def _cross_block_apply(cfg, blk, x, media, ctx):
    h = L.attn_apply(blk["attn"], L.norm_apply(blk["ln1"], x), positions=None,
                     causal=False, ctx=ctx, kv_x=media, use_rope=False)
    x = x + jnp.tanh(blk["gate_attn"].astype(jnp.float32)).astype(x.dtype) * h
    h = L.mlp_apply(blk["mlp"], L.norm_apply(blk["ln2"], x), ctx)
    return x + jnp.tanh(blk["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * h


def apply(params, cfg: ArchConfig, tokens, *, media=None, ctx: ShardCtx = NO_SHARD,
          pos_offset=0, return_hidden=False):
    """Full-sequence forward.  tokens (B, S) int32; media (B, M, D) for vlm.
    Returns (logits (B,S,V) fp32, aux_loss scalar); with return_hidden=True the
    first element is the final-norm hidden state instead (the train step computes
    the LM loss in sequence chunks so the full fp32 logits never materialise)."""
    B, S = tokens.shape
    positions = _positions(B, S, pos_offset)
    x = L.embed_apply(params["embed"], tokens, ctx)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family == "dense":
        def body(x, blk):
            return _dense_block_apply(cfg, blk, x, positions, ctx), None
        x, _ = jax.lax.scan(_remat(body, cfg), x, params["blocks"])

    elif cfg.family == "moe":
        if "dense0" in params:
            def body0(x, blk):
                return _dense_block_apply(cfg, blk, x, positions, ctx), None
            x, _ = jax.lax.scan(_remat(body0, cfg), x, params["dense0"])

        def body(x, blk):
            x, aux = _moe_block_apply(cfg, blk, x, positions, ctx)
            return x, aux
        x, auxs = jax.lax.scan(_remat(body, cfg), x, params["blocks"])
        aux_total = aux_total + auxs.sum()

    elif cfg.family == "ssm":
        x = L.norm_apply(params["ln0"], x)
        s = cfg.ssm
        H, Dh = L.rwkv_heads(cfg), s.head_dim
        zeros_prev = jnp.zeros((B, cfg.d_model), x.dtype)
        state0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)

        def body(x, blk):
            x, _, _, _ = _rwkv_block_apply(cfg, blk, x, zeros_prev, zeros_prev,
                                           state0, ctx)
            return x, None
        x, _ = jax.lax.scan(_remat(body, cfg), x, params["blocks"])

    elif cfg.family == "hybrid":
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        H = d_inner // s.head_dim
        conv_ch = d_inner + 2 * s.state_dim
        conv0 = jnp.zeros((B, s.conv_width - 1, conv_ch), x.dtype)
        ssd0 = jnp.zeros((B, H, s.head_dim, s.state_dim), jnp.float32)

        def body(x, blk):
            x, _, _ = _mamba_block_apply(cfg, blk, x, conv0, ssd0, ctx)
            return x, None

        def group_fn(x, blocks_slice):
            # one shared-attn invocation + its mamba layers, rematerialised as a
            # unit (the shared block is outside any scan, so it needs its own
            # checkpoint to avoid storing attention/MLP intermediates per group)
            x = _shared_block_apply(cfg, params, x, positions, ctx)
            x, _ = jax.lax.scan(body, x, blocks_slice)
            return x
        group_fn = _remat(group_fn, cfg)
        for lo, hi in _hybrid_groups(cfg):
            x = group_fn(x, _slice_tree(params["blocks"], lo, hi))

    elif cfg.family == "vlm":
        assert media is not None, "vlm needs media embeddings"
        period = cfg.cross_attn.period
        n_cross = cfg.n_layers // period
        n_self_per = period - 1
        self_grouped = jax.tree.map(
            lambda p: p.reshape((n_cross, n_self_per) + p.shape[1:]),
            params["self_blocks"])

        def self_body(x, blk):
            return _dense_block_apply(cfg, blk, x, positions, ctx), None

        def period_body(x, xs):
            # remat the WHOLE period (4 self layers + 1 cross layer): the cross
            # block lives outside the inner scan and must not store its
            # intermediates once per period
            self_p, cross_p = xs
            x, _ = jax.lax.scan(self_body, x, self_p)
            x = _cross_block_apply(cfg, cross_p, x, media, ctx)
            return x, None
        x, _ = jax.lax.scan(_remat(period_body, cfg), x,
                            (self_grouped, params["cross_blocks"]))

    else:
        raise ValueError(cfg.family)

    x = L.norm_apply(params["final_norm"], x)
    if return_hidden:
        return x, aux_total
    logits = L.lm_head_apply(params["embed"], x, ctx)
    return logits, aux_total


# ---------------------------------------------------------------------------
# KV/state cache — abstract structure + prefill + decode
# ---------------------------------------------------------------------------

def cache_struct(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """ShapeDtypeStructs for the decode cache (used by input_specs + init)."""
    dt = cfg.dtype
    hd = cfg.resolved_head_dim
    sds = jax.ShapeDtypeStruct
    if cfg.family in ("dense", "moe"):
        nl = cfg.n_layers
        kv = (nl, batch, max_len, cfg.n_kv_heads, hd)
        return {"k": sds(kv, dt), "v": sds(kv, dt)}
    if cfg.family == "ssm":
        s = cfg.ssm
        nl = cfg.n_layers
        return {
            "wkv": sds((nl, batch, cfg.d_model // s.head_dim, s.head_dim, s.head_dim), jnp.float32),
            "tm_prev": sds((nl, batch, cfg.d_model), dt),
            "cm_prev": sds((nl, batch, cfg.d_model), dt),
        }
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        H = d_inner // s.head_dim
        conv_ch = d_inner + 2 * s.state_dim
        ninv = n_shared_invocations(cfg)
        W = min(max_len, cfg.sliding_window or max_len)
        hh = cfg.hybrid.shared_attn_heads
        hhd = cfg.d_model // hh
        return {
            "conv": sds((cfg.n_layers, batch, s.conv_width - 1, conv_ch), dt),
            "ssd": sds((cfg.n_layers, batch, H, s.head_dim, s.state_dim), jnp.float32),
            "shared_k": sds((ninv, batch, W, hh, hhd), dt),
            "shared_v": sds((ninv, batch, W, hh, hhd), dt),
        }
    if cfg.family == "vlm":
        period = cfg.cross_attn.period
        n_cross = cfg.n_layers // period
        n_self = cfg.n_layers - n_cross
        kv = (n_self, batch, max_len, cfg.n_kv_heads, hd)
        xkv = (n_cross, batch, cfg.cross_attn.n_media_tokens, cfg.n_kv_heads, hd)
        return {"k": sds(kv, dt), "v": sds(kv, dt),
                "xk": sds(xkv, dt), "xv": sds(xkv, dt)}
    raise ValueError(cfg.family)


def cache_axes(cfg: ArchConfig) -> dict:
    """Logical axes matching cache_struct (for sharding)."""
    if cfg.family in ("dense", "moe"):
        kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        return {"k": kv, "v": kv}
    if cfg.family == "ssm":
        return {"wkv": ("layers", "batch", "heads", "head_dim", "head_dim"),
                "tm_prev": ("layers", "batch", "embed"),
                "cm_prev": ("layers", "batch", "embed")}
    if cfg.family == "hybrid":
        kv = ("layers", "batch", "kv_seq", "heads", "head_dim")
        return {"conv": ("layers", "batch", "conv", "heads_x_dim"),
                "ssd": ("layers", "batch", "heads", "head_dim", "state"),
                "shared_k": kv, "shared_v": kv}
    if cfg.family == "vlm":
        kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        xkv = ("layers", "batch", "frames", "kv_heads", "head_dim")
        return {"k": kv, "v": kv, "xk": xkv, "xv": xkv}
    raise ValueError(cfg.family)


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_struct(cfg, batch, max_len))


def prefill(params, cfg: ArchConfig, tokens, *, media=None,
            ctx: ShardCtx = NO_SHARD, max_len: int | None = None):
    """Process a prompt, return (logits_last (B,V), cache filled to S)."""
    B, S = tokens.shape
    max_len = max_len or S
    positions = _positions(B, S)
    x = L.embed_apply(params["embed"], tokens, ctx)

    if cfg.family in ("dense", "moe"):
        def body(x, blk):
            h, kv = L.attn_prefill(blk["attn"], L.norm_apply(blk["ln1"], x),
                                   positions=positions, theta=cfg.rope_theta,
                                   window=cfg.sliding_window, ctx=ctx,
                                   cache_len=max_len)
            x = x + h
            if "moe" in blk:
                y, _ = L.moe_apply(blk["moe"], L.norm_apply(blk["ln2"], x), cfg, ctx)
            else:
                y = L.mlp_apply(blk["mlp"], L.norm_apply(blk["ln2"], x), ctx)
            return x + y, kv

        stacks = []
        if "dense0" in params:
            x, kv0 = jax.lax.scan(_remat(body, cfg), x, params["dense0"])
            stacks.append(kv0)
        x, kvs = jax.lax.scan(_remat(body, cfg), x, params["blocks"])
        stacks.append(kvs)
        k = jnp.concatenate([s[0] for s in stacks]) if len(stacks) > 1 else stacks[0][0]
        v = jnp.concatenate([s[1] for s in stacks]) if len(stacks) > 1 else stacks[0][1]
        cache = {"k": k, "v": v}

    elif cfg.family == "ssm":
        x = L.norm_apply(params["ln0"], x)
        s = cfg.ssm
        H, Dh = L.rwkv_heads(cfg), s.head_dim
        zeros_prev = jnp.zeros((B, cfg.d_model), x.dtype)
        state0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)

        def body(x, blk):
            x, tm_last, cm_last, st = _rwkv_block_apply(
                cfg, blk, x, zeros_prev, zeros_prev, state0, ctx)
            return x, (st, tm_last, cm_last)
        x, (wkv, tm_prev, cm_prev) = jax.lax.scan(_remat(body, cfg), x,
                                                  params["blocks"])
        cache = {"wkv": wkv, "tm_prev": tm_prev, "cm_prev": cm_prev}

    elif cfg.family == "hybrid":
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        H = d_inner // s.head_dim
        conv_ch = d_inner + 2 * s.state_dim
        conv0 = jnp.zeros((B, s.conv_width - 1, conv_ch), x.dtype)
        ssd0 = jnp.zeros((B, H, s.head_dim, s.state_dim), jnp.float32)
        W = min(max_len, cfg.sliding_window or max_len)
        hh = cfg.hybrid.shared_attn_heads

        def body(x, blk):
            x, conv_s, ssd_s = _mamba_block_apply(cfg, blk, x, conv0, ssd0, ctx)
            return x, (conv_s, ssd_s)
        body = _remat(body, cfg)
        sk, sv, convs, ssds = [], [], [], []
        for lo, hi in _hybrid_groups(cfg):
            h, kv = L.attn_prefill(
                params["shared"]["attn"],
                L.norm_apply(params["shared"]["ln1"], x), positions=positions,
                theta=cfg.rope_theta, window=cfg.sliding_window, ctx=ctx,
                cache_len=max_len)
            x = x + h
            x = x + L.mlp_apply(params["shared"]["mlp"],
                                L.norm_apply(params["shared"]["ln2"], x), ctx)
            # keep only the trailing window of the cache (wrap-indexed at decode)
            k_w = kv[0][:, -W:] if S >= W else jnp.pad(kv[0][:, :S],
                                                       [(0, 0), (0, W - S), (0, 0), (0, 0)])
            v_w = kv[1][:, -W:] if S >= W else jnp.pad(kv[1][:, :S],
                                                       [(0, 0), (0, W - S), (0, 0), (0, 0)])
            sk.append(k_w)
            sv.append(v_w)
            x, (conv_s, ssd_s) = jax.lax.scan(body, x,
                                              _slice_tree(params["blocks"], lo, hi))
            convs.append(conv_s)
            ssds.append(ssd_s)
        cache = {"conv": jnp.concatenate(convs), "ssd": jnp.concatenate(ssds),
                 "shared_k": jnp.stack(sk), "shared_v": jnp.stack(sv)}

    elif cfg.family == "vlm":
        assert media is not None
        period = cfg.cross_attn.period
        n_cross = cfg.n_layers // period
        n_self_per = period - 1
        self_grouped = jax.tree.map(
            lambda p: p.reshape((n_cross, n_self_per) + p.shape[1:]),
            params["self_blocks"])

        def self_body(x, blk):
            h, kv = L.attn_prefill(blk["attn"], L.norm_apply(blk["ln1"], x),
                                   positions=positions, theta=cfg.rope_theta,
                                   ctx=ctx, cache_len=max_len)
            x = x + h
            x = x + L.mlp_apply(blk["mlp"], L.norm_apply(blk["ln2"], x), ctx)
            return x, kv

        def period_body(x, xs):
            self_p, cross_p = xs
            x, kvs = jax.lax.scan(_remat(self_body, cfg), x, self_p)
            xm = L.norm_apply(cross_p["ln1"], x)
            xk = jnp.einsum("bmd,dhk->bmhk", media, cross_p["attn"]["wk"])
            xv = jnp.einsum("bmd,dhk->bmhk", media, cross_p["attn"]["wv"])
            x = _cross_block_apply(cfg, cross_p, x, media, ctx)
            return x, (kvs, (xk, xv))
        x, (kvs, xkvs) = jax.lax.scan(period_body, x,
                                      (self_grouped, params["cross_blocks"]))
        k = kvs[0].reshape((-1,) + kvs[0].shape[2:])
        v = kvs[1].reshape((-1,) + kvs[1].shape[2:])
        cache = {"k": k, "v": v, "xk": xkvs[0], "xv": xkvs[1]}

    else:
        raise ValueError(cfg.family)

    x = L.norm_apply(params["final_norm"], x)
    logits = L.lm_head_apply(params["embed"], x[:, -1:], ctx)
    return logits[:, 0], cache


def decode(params, cfg: ArchConfig, cache: dict, tokens, pos, *,
           ctx: ShardCtx = NO_SHARD):
    """One decode step.  tokens (B, 1) int32; pos (B,) tokens already in cache.
    Returns (logits (B, V) fp32, new cache)."""
    B = tokens.shape[0]
    x = L.embed_apply(params["embed"], tokens, ctx)

    if cfg.family in ("dense", "moe"):
        def body(x, xs):
            blk, ck, cv = xs
            h, (nk, nv) = L.attn_decode(blk["attn"], L.norm_apply(blk["ln1"], x),
                                        ck, cv, pos, theta=cfg.rope_theta,
                                        window=cfg.sliding_window, ctx=ctx)
            x = x + h
            if "moe" in blk:
                y, _ = L.moe_apply(blk["moe"], L.norm_apply(blk["ln2"], x), cfg, ctx,
                                   dropless=True)
            else:
                y = L.mlp_apply(blk["mlp"], L.norm_apply(blk["ln2"], x), ctx)
            return x + y, (nk, nv)

        if "dense0" in params:
            nd = params["dense0"]["ln1"]["scale"].shape[0]
            x, kv0 = jax.lax.scan(body, x, (params["dense0"],
                                            cache["k"][:nd], cache["v"][:nd]))
            x, kvs = jax.lax.scan(body, x, (params["blocks"],
                                            cache["k"][nd:], cache["v"][nd:]))
            cache = {"k": jnp.concatenate([kv0[0], kvs[0]]),
                     "v": jnp.concatenate([kv0[1], kvs[1]])}
        else:
            x, kvs = jax.lax.scan(body, x, (params["blocks"],
                                            cache["k"], cache["v"]))
            cache = {"k": kvs[0], "v": kvs[1]}

    elif cfg.family == "ssm":
        x = L.norm_apply(params["ln0"], x)
        s = cfg.ssm
        H, Dh = L.rwkv_heads(cfg), s.head_dim

        from repro.kernels import ref as kref

        def body(x, xs):
            blk, wkv, tm_prev, cm_prev = xs
            xin = L.norm_apply(blk["ln1"], x)
            r, k, v, w, g = L._rwkv6_projections(blk, xin, tm_prev[:, None], cfg)
            y, wkv_new = kref.rwkv6_step_ref(
                r[:, 0], k[:, 0], v[:, 0], w[:, 0].astype(r.dtype),
                blk["tm"]["bonus"], wkv)
            y = y.reshape(B, 1, cfg.d_model)
            y = L.group_norm_apply(blk["tm"]["ln_x"], y, L.rwkv_heads(cfg))
            y = jnp.einsum("bse,ed->bsd", y * g, blk["tm"]["wo"])
            x = x + y
            xin2 = L.norm_apply(blk["ln2"], x)
            h, cm_last = L.rwkv6_channel_mix(blk, xin2, cm_prev)
            x = x + h
            return x, (wkv_new, xin[:, -1], cm_last)

        x, (wkv, tm_prev, cm_prev) = jax.lax.scan(
            body, x, (params["blocks"], cache["wkv"],
                      cache["tm_prev"], cache["cm_prev"]))
        cache = {"wkv": wkv, "tm_prev": tm_prev, "cm_prev": cm_prev}

    elif cfg.family == "hybrid":
        from repro.kernels import ref as kref
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        H = d_inner // s.head_dim
        N = s.state_dim
        W = cache["shared_k"].shape[2]

        def body(x, xs):
            blk, conv_st, ssd_st = xs
            xin = L.norm_apply(blk["ln"], x)
            z, xbc, dt, _, _, _ = L._mamba2_split(blk["mamba"], xin, cfg)
            seq = jnp.concatenate([conv_st.astype(xbc.dtype), xbc], axis=1)
            kernel = blk["mamba"]["conv_w"]
            conv = sum(seq[:, i] * kernel[i][None] for i in range(s.conv_width))
            conv = jax.nn.silu((conv + blk["mamba"]["conv_b"][None])
                               .astype(jnp.float32)).astype(x.dtype)
            x_ssm, Bv, Cv = jnp.split(conv, [d_inner, d_inner + N], axis=-1)
            xh = x_ssm.reshape(B, H, s.head_dim)
            dtf = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                                  + blk["mamba"]["dt_bias"].astype(jnp.float32))
            A = -jnp.exp(blk["mamba"]["a_log"].astype(jnp.float32))
            y, ssd_new = kref.mamba2_step_ref(xh, dtf, A, Bv, Cv, ssd_st)
            y = y + xh * blk["mamba"]["d_skip"].astype(x.dtype)[None, :, None]
            y = y.reshape(B, 1, d_inner)
            y = L.group_norm_apply(blk["mamba"]["norm"], y, H)
            y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
            out = jnp.einsum("bse,ed->bsd", y, blk["mamba"]["out_proj"])
            return x + out, (seq[:, 1:], ssd_new)

        groups = _hybrid_groups(cfg)
        convs, ssds, sks, svs = [], [], [], []
        for gi, (lo, hi) in enumerate(groups):
            # shared attention with a wrap-indexed sliding-window cache
            blk = params["shared"]
            xin = L.norm_apply(blk["ln1"], x)
            q = jnp.einsum("bsd,dhk->bshk", xin, blk["attn"]["wq"])
            k = jnp.einsum("bsd,dhk->bshk", xin, blk["attn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", xin, blk["attn"]["wv"])
            q = L.rope_apply(q, pos[:, None], cfg.rope_theta)
            k = L.rope_apply(k, pos[:, None], cfg.rope_theta)
            slot = pos % W
            bidx = jnp.arange(B)
            ck = cache["shared_k"][gi].at[bidx, slot].set(k[:, 0])
            cv = cache["shared_v"][gi].at[bidx, slot].set(v[:, 0])
            from repro.kernels import ops as kops
            kv_len = jnp.minimum(pos + 1, W)
            out = kops.decode_attention(q, ck, cv, kv_len, impl=ctx.impl)
            y = jnp.einsum("bshk,hkd->bsd", out, blk["attn"]["wo"])
            x = x + y
            x = x + L.mlp_apply(blk["mlp"], L.norm_apply(blk["ln2"], x), ctx)
            sks.append(ck)
            svs.append(cv)
            x, (conv_s, ssd_s) = jax.lax.scan(
                body, x, (_slice_tree(params["blocks"], lo, hi),
                          cache["conv"][lo:hi], cache["ssd"][lo:hi]))
            convs.append(conv_s)
            ssds.append(ssd_s)
        cache = {"conv": jnp.concatenate(convs), "ssd": jnp.concatenate(ssds),
                 "shared_k": jnp.stack(sks), "shared_v": jnp.stack(svs)}

    elif cfg.family == "vlm":
        period = cfg.cross_attn.period
        n_cross = cfg.n_layers // period
        n_self_per = period - 1
        self_grouped = jax.tree.map(
            lambda p: p.reshape((n_cross, n_self_per) + p.shape[1:]),
            params["self_blocks"])
        kc = cache["k"].reshape((n_cross, n_self_per) + cache["k"].shape[1:])
        vc = cache["v"].reshape((n_cross, n_self_per) + cache["v"].shape[1:])

        def self_body(x, xs):
            blk, ck, cv = xs
            h, (nk, nv) = L.attn_decode(blk["attn"], L.norm_apply(blk["ln1"], x),
                                        ck, cv, pos, theta=cfg.rope_theta, ctx=ctx)
            x = x + h
            x = x + L.mlp_apply(blk["mlp"], L.norm_apply(blk["ln2"], x), ctx)
            return x, (nk, nv)

        def period_fn(x, self_p, cross_p, ck, cv, xk, xv):
            x, kvs = jax.lax.scan(self_body, x, (self_p, ck, cv))
            xin = L.norm_apply(cross_p["ln1"], x)
            h, _ = L.attn_decode(cross_p["attn"], xin, None, None, pos,
                                 theta=cfg.rope_theta, ctx=ctx,
                                 cross_kv=(xk, xv))
            x = x + jnp.tanh(cross_p["gate_attn"].astype(jnp.float32)
                             ).astype(x.dtype) * h
            h = L.mlp_apply(cross_p["mlp"], L.norm_apply(cross_p["ln2"], x), ctx)
            x = x + jnp.tanh(cross_p["gate_mlp"].astype(jnp.float32)
                             ).astype(x.dtype) * h
            return x, kvs

        # python-unrolled over periods: under a scan, GSPMD reshards the WHOLE
        # stacked FSDP weights before the loop (a full-model regather in HBM);
        # unrolled, each period's weights are gathered transiently (DESIGN.md §5)
        ks_out, vs_out = [], []
        for g in range(n_cross):
            sp = jax.tree.map(lambda t: t[g], self_grouped)
            cp = jax.tree.map(lambda t: t[g], params["cross_blocks"])
            x, kvs = period_fn(x, sp, cp, kc[g], vc[g],
                               cache["xk"][g], cache["xv"][g])
            ks_out.append(kvs[0])
            vs_out.append(kvs[1])
        k_new = jnp.stack(ks_out).reshape((-1,) + ks_out[0].shape[1:])
        v_new = jnp.stack(vs_out).reshape((-1,) + vs_out[0].shape[1:])
        cache = {"k": k_new, "v": v_new,
                 "xk": cache["xk"], "xv": cache["xv"]}

    else:
        raise ValueError(cfg.family)

    x = L.norm_apply(params["final_norm"], x)
    logits = L.lm_head_apply(params["embed"], x, ctx)
    return logits[:, 0], cache
