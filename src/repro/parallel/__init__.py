from repro.parallel.axes import (
    DEFAULT_RULES, ParamDef, abstract_params, init_params, is_param_def,
    logical_to_spec, make_rules, params_axes, tree_sharding, tree_spec,
)

__all__ = [
    "DEFAULT_RULES", "ParamDef", "abstract_params", "init_params", "is_param_def",
    "logical_to_spec", "make_rules", "params_axes", "tree_sharding", "tree_spec",
]
