"""Logical-axis sharding: parameters/activations are annotated with *logical* axis
names; a per-arch rule table maps logical names onto physical mesh axes.

This is the MaxText-style indirection that lets one model definition run on any mesh
(single pod ``(data, model)`` or multi-pod ``(pod, data, model)``) and lets the perf
loop re-shard by editing rules rather than model code.

Logical axes used in the zoo:
  layers     stacked-scan layer dimension (never sharded; no PP axis in the mesh)
  batch      global batch                -> ("pod", "data")
  seq        activation sequence dim     -> None (or "data" for SP long-context)
  kv_seq     KV-cache sequence dim       -> None, or "data" for long_500k decode
  embed      d_model                     -> None, or "data" for FSDP weight shard
  ff         MLP hidden                  -> "model"
  heads      attention query heads       -> "model"
  kv_heads   attention KV heads          -> "model" iff divisible, else None
  head_dim   per-head dim                -> None
  vocab      vocabulary                  -> "model"
  experts    MoE expert dim              -> "model"   (expert parallelism)
  expert_ff  per-expert hidden           -> None (EP already covers "model")
  state      SSM/RWKV recurrent state    -> None
  conv       conv kernel width           -> None
  frames     audio/vision token dim      -> None
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Default rules for a (data, model) or (pod, data, model) mesh.  Values may be a
# mesh-axis name, a tuple of mesh-axis names, or None (replicated).
DEFAULT_RULES: dict[str, Any] = {
    "layers": None,
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
    "embed": None,
    "ff": "model",
    "heads": "model",
    "heads_x_dim": "model",  # fused (heads*head_dim) projections (rwkv/mamba d_inner)
    "kv_heads": "model",
    "head_dim": None,
    "vocab": "model",
    "experts": "model",
    "expert_ff": None,
    "state": None,
    "conv": None,
    "frames": None,
}


def make_rules(
    *,
    fsdp: bool = False,
    shard_kv_heads: bool = True,
    sequence_parallel: bool = False,
    overrides: Mapping[str, Any] | None = None,
) -> dict[str, Any]:
    """Build a rule table.

    fsdp: additionally shard the ``embed`` dim of weights over the data axes
      (ZeRO-3 / FSDP style; XLA inserts per-layer all-gathers that overlap with
      the scanned layer compute).
    shard_kv_heads: disable for archs whose kv_heads don't divide the model axis
      (GSPMD would pad; replicating KV is cheaper for GQA).
    sequence_parallel: shard kv_seq over the data axes (long-context decode where
      batch==1 cannot use the data axis).
    """
    rules = dict(DEFAULT_RULES)
    if fsdp:
        rules["embed"] = ("pod", "data")
    if not shard_kv_heads:
        rules["kv_heads"] = None
    if sequence_parallel:
        rules["kv_seq"] = ("pod", "data", "model")
        rules["batch"] = None
    if overrides:
        rules.update(overrides)
    return rules


def _mesh_axes(mesh: Mesh) -> set[str]:
    return set(mesh.axis_names)


def _resolve_entry(entry: Any, present: set[str]) -> Any:
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' on single-pod)."""
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry if entry in present else None
    kept = tuple(a for a in entry if a in present)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def logical_to_spec(axes: Sequence[str | None], rules: Mapping[str, Any], mesh: Mesh) -> P:
    """Map a tuple of logical axis names to a PartitionSpec for `mesh`."""
    present = _mesh_axes(mesh)
    used: set[str] = set()
    parts = []
    for name in axes:
        if name is None:
            parts.append(None)
            continue
        entry = _resolve_entry(rules.get(name), present)
        # A mesh axis may appear at most once in a PartitionSpec.
        if entry is None:
            parts.append(None)
        elif isinstance(entry, str):
            if entry in used:
                parts.append(None)
            else:
                used.add(entry)
                parts.append(entry)
        else:
            fresh = tuple(a for a in entry if a not in used)
            used.update(fresh)
            parts.append(fresh if fresh else None)
    return P(*parts)


def tree_spec(axes_tree: Any, rules: Mapping[str, Any], mesh: Mesh) -> Any:
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: logical_to_spec(axes, rules, mesh),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def tree_sharding(axes_tree: Any, rules: Mapping[str, Any], mesh: Mesh) -> Any:
    """Same as tree_spec but returns NamedShardings bound to `mesh`."""
    specs = tree_spec(axes_tree, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

Initializer = Any  # Callable[[jax.Array key, tuple shape, dtype], jax.Array]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Single source of truth for one parameter tensor: shape, dtype, logical axes
    and initializer.  Models build a pytree of these; everything else (abstract
    eval, sharding, init) derives from it."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: Any = None  # filled by the model's default dtype when None
    init: str = "normal"  # normal | zeros | ones | scaled (fan-in scaled normal)
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_param_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def abstract_params(defs: Any, default_dtype) -> Any:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype or default_dtype),
        defs, is_leaf=is_param_def)


def params_axes(defs: Any) -> Any:
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=is_param_def)


def init_params(defs: Any, key: jax.Array, default_dtype) -> Any:
    """Materialize parameters.  Each leaf gets a distinct fold of `key`."""
    import jax.numpy as jnp

    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_param_def)
    out = []
    for i, d in enumerate(leaves):
        dtype = d.dtype or default_dtype
        k = jax.random.fold_in(key, i)
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, dtype)
        elif d.init == "scaled":
            fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[-1], 1)
            std = d.scale / (fan_in ** 0.5)
            arr = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dtype)
        else:  # normal
            arr = (jax.random.normal(k, d.shape, jnp.float32) * 0.02 * d.scale).astype(dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)
