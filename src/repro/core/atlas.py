"""ATLAS — the paper's Algorithm 1, wrapping any base scheduler.

Control flow per (task -> node) decision the base scheduler proposes:

  predict outcome (map/reduce model, Table-1 features)
  ├─ SUCCESS ──> Check-Availability(TT, DN)  (active probe; a dead node found here
  │              is reported to the JobTracker *before* its heartbeat timeout)
  │     ├─ alive ──> Check-Availability-Slots ──> Execute
  │     │             └─ none free: wait; on time-out -> queue + PENALTY
  │     └─ dead  ──> notify JT; on time-out -> queue + PENALTY
  └─ FAIL ────> enough resources? Execute-Speculatively(Task, N) on the N nodes
                with the highest predicted success; else queue + PENALTY

plus (running alongside): the adaptive heartbeat controller (§4.2) and periodic
model retraining (every 10 simulated minutes, §5.1)."""

from __future__ import annotations

import dataclasses
from collections import deque

from repro.cluster.simulator import EV_RETRAIN, MAP
from repro.core.heartbeat import HeartbeatController
from repro.core.predictor import TaskPredictor
from repro.sched.base import Scheduler, SchedulerStats


@dataclasses.dataclass
class AtlasStats(SchedulerStats):
    """ATLAS's ``stats()`` schema: the shared counters plus Algorithm-1
    accounting.  The refresher trio is ``None`` (omitted from ``to_dict``)
    when no drift-aware refresh loop is attached, so cell stats stay
    byte-identical whichever lifecycle ran the model."""
    predictions: int = 0
    predicted_fail: int = 0
    relocations: int = 0
    speculative_launches: int = 0
    penalties: int = 0
    dead_probes: int = 0
    hb_adjustments: int = 0
    model_fits: int = 0
    refreshes: int | None = None
    promotions: int | None = None
    rollbacks: int | None = None
    # decisions taken while the serving predictor was degraded (broker
    # unreachable past its retry budget, scoring fell back to the paper's
    # schedule-anyway default); None/omitted on every healthy run so clean
    # cell stats keep their historical bytes
    degraded_decisions: int | None = None


class ATLASScheduler(Scheduler):
    """ATLAS integrates with any Hadoop base scheduler (FIFO/Fair/Capacity)."""

    def __init__(self, base: Scheduler, *, predictor: TaskPredictor | None = None,
                 threshold: float = 0.5, n_speculative: int = 2,
                 retrain_every: float = 600.0, refresher=None,
                 heartbeat: HeartbeatController | None = None,
                 max_penalty_box: int = 512, penalty_timeout: float = 150.0):
        super().__init__()
        self.base = base
        self.name = f"atlas-{base.name}"
        self.predictor = predictor or TaskPredictor()
        self.threshold = threshold
        self.n_speculative = n_speculative
        self.retrain_every = retrain_every
        # optional drift-aware refresh loop (repro.online.drift): retrains on
        # feature/score drift instead of only the fixed §5.1 clock
        self.refresher = refresher
        if refresher is not None:
            refresher.bind_predictor(self.predictor)
        self.hb = heartbeat or HeartbeatController()
        self.penalty_timeout = penalty_timeout
        self.penalty_box: deque = deque(maxlen=max_penalty_box)
        # counters (reported in EXPERIMENTS.md)
        self.n_predictions = 0
        self.n_predicted_fail = 0
        self.n_speculative_launches = 0
        self.n_relocations = 0
        self.n_penalties = 0
        self.n_dead_probes = 0
        self.n_degraded_decisions = 0

    # ------------------------------------------------------------------ binding
    def bind(self, sim):
        self.sim = sim
        self.base.bind(sim)
        self.base.launch = self._atlas_launch        # intercept Algorithm-1 gate
        if self.refresher is not None:
            sim._push(self.refresher.check_every, EV_RETRAIN, None)
        elif self.retrain_every > 0:
            sim._push(self.retrain_every, EV_RETRAIN, None)

    # ------------------------------------------------------------------ hooks
    def on_tick(self):
        # broker hook: snapshot the schedulable set so every p_success raised
        # during this tick can be served from one primed batch
        self.predictor.begin_tick(
            self.sim, extra_keys=[key for key, _ in self.penalty_box])
        self.base.schedule()
        self._drain_penalty_box()
        self.base.speculate_stragglers()

    def on_heartbeat(self, node):
        self.hb.on_heartbeat(self.sim)
        self.base.on_heartbeat(node)

    def on_retrain(self):
        if self.refresher is not None:
            # drift-aware path: check often, retrain when the monitor (or the
            # staleness clock it keeps) says the environment moved
            if self.sim.trace is not None:
                self.refresher.step(self.sim)
            self.sim._push(self.sim.now + self.refresher.check_every,
                           EV_RETRAIN, None)
            return
        if self.sim.trace is not None:
            self.predictor.fit(self.sim.trace)
        self.sim._push(self.sim.now + self.retrain_every, EV_RETRAIN, None)

    # ------------------------------------------------------------------ Algorithm 1
    def _atlas_launch(self, task, node, *, speculative=False):
        sim = self.sim
        self.n_predictions += 1
        p = self.predictor.p_success(sim, task, node, speculative)
        if getattr(self.predictor, "degraded", False):
            # graceful degradation: the serving path is answering with the
            # untrained-predictor default (p=1.0, schedule anyway) — count
            # the decision so operators can bound the outage's blast radius
            self.n_degraded_decisions += 1

        if p >= self.threshold:
            # ---- predicted SUCCESS: verify TT/DN liveness, then slots
            if not node.tt_alive or node.suspended:
                # active probe found a dead/suspended TT the JT thought alive:
                # notify the JT *now* (stranded attempts fail early and get
                # rescheduled, instead of waiting out the heartbeat)
                self.n_dead_probes += 1
                sim.detect_tt_failure(node)
                alt = self._best_alternative(task, exclude={node.nid})
                if alt is not None:
                    return self.launch(task, alt, speculative=speculative)
                return self._penalize(task)
            if task.kind == MAP and task.block_nodes and not any(
                    sim.nodes[b].dn_alive for b in task.block_nodes):
                # input block unavailable: executing now would fail (DN dead)
                self.n_dead_probes += 1
                return self._penalize(task)
            free = (node.free_map_slots() if task.kind == MAP
                    else node.free_reduce_slots())
            if free <= 0:
                alt = self._best_alternative(task, exclude={node.nid})
                if alt is not None:
                    return self.launch(task, alt, speculative=speculative)
                return self._penalize(task)
            return self.launch(task, node, speculative=speculative)

        # ---- predicted FAIL on the *proposed* node
        self.n_predicted_fail += 1
        if speculative:
            return None  # never multiply a copy that is itself predicted to fail
        # first remedy: reschedule onto a node where the model predicts success
        alt = self._best_alternative(task, exclude={node.nid})
        if alt is not None:
            self.n_relocations += 1
            return self.launch(task, alt, speculative=False)
        # predicted to fail everywhere -> multiple speculative instances, but only
        # with genuine spare capacity (never starve the normal queue)
        return self._execute_speculatively(task)

    def _execute_speculatively(self, task):
        """Launch up to N instances on the nodes with best predicted outcome."""
        sim = self.sim
        cands = self._free_alive_nodes(task)
        if len(cands) < 1 or not self._enough_resources(task, len(cands)):
            return self._penalize(task)
        ps = self.predictor.p_success_nodes(sim, task, cands)
        order = sorted(range(len(cands)), key=lambda i: -ps[i])
        picked = [cands[i] for i in order[: self.n_speculative]]
        att = None
        for j, n in enumerate(picked):
            att = self.launch(task, n, speculative=(j > 0)) or att
            self.n_speculative_launches += int(j > 0)
        return att

    def _penalize(self, task):
        task.penalty += 1
        self.n_penalties += 1
        self.penalty_box.append((task.key, self.sim.now))
        return None

    def _drain_penalty_box(self):
        """Penalised tasks wait (priority lowered) until the cluster has spare
        capacity — then they get the multi-node speculative treatment.  A bounded
        wait (the paper's scheduler time-out) force-launches stragglers on the
        best-predicted node so jobs can't stall forever."""
        sim = self.sim
        budget = 16
        while self.penalty_box and budget > 0:
            key, enq = self.penalty_box[0]
            task = sim._task_by_key(key)
            if task is None or task.status != "pending":
                self.penalty_box.popleft()
                continue
            cands = self._free_alive_nodes(task)
            timed_out = sim.now - enq >= self.penalty_timeout
            spare = len(cands) >= self.n_speculative and not sim.pending
            if not (spare or (timed_out and cands)):
                break
            self.penalty_box.popleft()
            ps = self.predictor.p_success_nodes(sim, task, cands)
            order = sorted(range(len(cands)), key=lambda i: -ps[i])
            n_copies = self.n_speculative if spare else 1
            picked = [cands[i] for i in order[:n_copies]]
            for j, n in enumerate(picked):
                self.launch(task, n, speculative=(j > 0))
                self.n_speculative_launches += int(j > 0)
            budget -= 1

    # ------------------------------------------------------------------ helpers
    def _free_alive_nodes(self, task):
        # ATLAS's active probe view: actually-up nodes with a free slot, read
        # from the simulator's incremental free-slot index (1000-node fleets
        # call this per decision)
        return self.sim.free_nodes(task.kind, liveness="actual")

    def _enough_resources(self, task, n_free: int) -> bool:
        # spare capacity beyond what the normal queue needs right now: multi-
        # speculation must never starve ordinarily-scheduled work
        backlog = len(self.sim.pending)
        return n_free >= self.n_speculative + max(1, backlog)

    def _best_alternative(self, task, exclude=()):
        cands = [n for n in self._free_alive_nodes(task) if n.nid not in exclude]
        if not cands:
            return None
        ps = self.predictor.p_success_nodes(self.sim, task, cands)
        best = max(range(len(cands)), key=lambda i: ps[i])
        if ps[best] < self.threshold:
            return None
        return cands[best]

    def stats(self) -> AtlasStats:
        return AtlasStats(
            launches=self.n_launches,
            speculative_copies=self.n_speculative_copies,
            predictions=self.n_predictions,
            predicted_fail=self.n_predicted_fail,
            relocations=self.n_relocations,
            speculative_launches=self.n_speculative_launches,
            penalties=self.n_penalties,
            dead_probes=self.n_dead_probes,
            hb_adjustments=self.hb.adjustments,
            model_fits=self.predictor.fits,
            # NOTE: dispatch counters live on the predictor/broker, not here —
            # cell stats must be identical whichever batching executor ran them
            **({"refreshes": self.refresher.refreshes,
                "promotions": self.refresher.promotions,
                "rollbacks": self.refresher.rollbacks}
               if self.refresher is not None else {}),
            **({"degraded_decisions": self.n_degraded_decisions}
               if self.n_degraded_decisions else {}),
        )

    def frame_stats(self) -> dict:
        return {"penalty_box": len(self.penalty_box),
                "pred": self.predictor.frame_stats()}
