"""Adaptive heartbeat controller (§4.2): if more than 1/3 of TaskTrackers failed
within one heartbeat window, halve the interval (floor: min_interval); otherwise
grow it back (cap: max_interval) to save JT<->TT control traffic.  Runs alongside
ATLAS, adjusting on the fly."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class HeartbeatController:
    min_interval: float = 120.0     # paper: 2 min floor
    max_interval: float = 600.0     # paper: 10 min default
    grow: float = 1.25
    fail_frac_threshold: float = 1.0 / 3.0

    window_start: float = 0.0
    adjustments: int = 0

    def on_heartbeat(self, sim):
        interval = sim.heartbeat_interval
        if sim.now - self.window_start < interval:
            return
        frac = sim.hb_failures_window / max(len(sim.nodes), 1)
        if frac > self.fail_frac_threshold:
            new = max(self.min_interval, interval / 2.0)
        else:
            new = min(self.max_interval, interval * self.grow)
        if new != interval:
            self.adjustments += 1
        sim.heartbeat_interval = new
        sim.hb_failures_window = 0
        self.window_start = sim.now
