# ATLAS — the paper's primary contribution: failure prediction + Algorithm 1
# scheduling + adaptive heartbeat + penalty/speculation mechanisms.
from repro.core.atlas import ATLASScheduler
from repro.core.heartbeat import HeartbeatController
from repro.core.predictor import TaskPredictor

__all__ = ["ATLASScheduler", "HeartbeatController", "TaskPredictor"]
