"""Task-outcome predictor used by ATLAS: two models (map / reduce, as in §4.2),
trained on TelemetryTrace logs and re-trained online every 10 simulated minutes.

The default algorithm is Random Forest (the paper's winner); every probability —
single proposal or candidate batch — flows through one choke point
(``predict_batch``) so the online broker (repro.online.broker) can interpose
batched, memoised scoring without changing a single decision.  ``n_dispatches``
counts actual model invocations: the currency the broker optimises."""

from __future__ import annotations

import numpy as np

from repro.cluster.telemetry import TelemetryTrace, attempt_features
from repro.ml.forest import ForestParams, forest_predict_np
from repro.ml.models import ALL_MODELS


def forest_family_params(model) -> ForestParams | None:
    """The ForestParams of a single-forest model (Tree/CTree/R.F.), else None.
    Boost is multi-stage and GLM/NN are dense — those score via predict_proba."""
    params = getattr(model, "params", None)
    return params if isinstance(params, ForestParams) else None


class TaskPredictor:
    def __init__(self, algo: str = "R.F.", min_samples: int = 150,
                 max_train: int = 20000, seed: int = 0):
        self.algo = algo
        self.min_samples = min_samples
        self.max_train = max_train
        self.seed = seed
        self.map_model = None
        self.reduce_model = None
        self.fits = 0
        # dispatch accounting: one dispatch == one model invocation
        self.n_dispatches = 0
        self.n_rows_scored = 0

    # ------------------------------------------------------------------ train
    def fit(self, trace: TelemetryTrace) -> bool:
        return self.fit_datasets(*trace.datasets())

    def fit_datasets(self, map_data, reduce_data) -> bool:
        """Fit from raw (X, y) arrays — the form the fleet sweep ships across
        process boundaries so one training trace serves many cells."""
        (mx, my), (rx, ry) = map_data, reduce_data
        trained = False
        rng = np.random.RandomState(self.seed + self.fits)

        def sub(X, y):
            if X.shape[0] > self.max_train:
                idx = rng.choice(X.shape[0], self.max_train, replace=False)
                return X[idx], y[idx]
            return X, y

        if mx.shape[0] >= self.min_samples and len(np.unique(my)) > 1:
            X, y = sub(mx, my)
            self.map_model = ALL_MODELS[self.algo]().fit(X, y)
            trained = True
        if rx.shape[0] >= self.min_samples and len(np.unique(ry)) > 1:
            X, y = sub(rx, ry)
            self.reduce_model = ALL_MODELS[self.algo]().fit(X, y)
            trained = True
        self.fits += int(trained)
        if trained:
            self._models_changed()
        return trained

    def adopt(self, other: "TaskPredictor"):
        """Take over another predictor's trained models (drift-refresh promote:
        the candidate was fitted off to the side, evaluated, and won)."""
        self.map_model = other.map_model
        self.reduce_model = other.reduce_model
        self.fits = other.fits
        self._models_changed()

    def _models_changed(self):
        """Hook: the broker invalidates its memo when the models swap."""

    @property
    def ready(self) -> bool:
        return self.map_model is not None or self.reduce_model is not None

    # ------------------------------------------------------------------ infer
    def model_for_kind(self, kind: str):
        return self.map_model if kind == "map" else self.reduce_model

    def _model_for(self, task):
        return self.model_for_kind(task.kind)

    def predict_batch(self, kind: str, X: np.ndarray) -> np.ndarray:
        """Score a feature batch with the map/reduce model — the single choke
        point every probability flows through (and the unit of dispatch).

        Forest-family models are pinned to the numpy mirror whatever the batch
        size: ``predict_proba`` would auto-route >SMALL_BATCH batches onto the
        XLA kernel, whose tree mean rounds differently at the last ulp, and
        scheduler decisions must not depend on candidate-set size or executor
        (the broker memoises these exact floats).  Training/CV paths keep the
        size-dispatched ``forest_predict`` route."""
        model = self.model_for_kind(kind)
        if model is None:
            return np.ones(X.shape[0], np.float32)
        self.n_dispatches += 1
        self.n_rows_scored += X.shape[0]
        params = forest_family_params(model)
        if params is not None:
            return np.clip(forest_predict_np(params, X), 0.0, 1.0) \
                .astype(np.float32)
        return np.asarray(model.predict_proba(X), np.float32)

    def begin_tick(self, sim, extra_keys=()):
        """Scheduler-tick hook (no-op here).  The online BrokerPredictor uses
        it to snapshot the pending queue and prime one batched flush."""

    def frame_stats(self) -> dict:
        """Live accounting snapshot for the obs layer (``Scheduler.
        frame_stats()["pred"]``).  The plain predictor has no memo, so the
        memo counters are structurally zero; BrokerPredictor overrides with
        its real accounting plus memo size/eviction fields."""
        return {"dispatches": self.n_dispatches, "rows": self.n_rows_scored,
                "memo_hits": 0, "memo_misses": 0, "demand_rows": 0}

    def p_success(self, sim, task, node, speculative=False) -> float:
        if self.model_for_kind(task.kind) is None:
            return 1.0                  # untrained: skip feature construction
        x = attempt_features(sim, task, node, speculative)[None]
        return float(self.predict_batch(task.kind, x)[0])

    def p_success_nodes(self, sim, task, nodes, speculative=False) -> np.ndarray:
        """Batched scoring of candidate placements (one kernel call)."""
        if self.model_for_kind(task.kind) is None or not len(nodes):
            return np.ones(len(nodes), np.float32)
        X = np.stack([attempt_features(sim, task, n, speculative)
                      for n in nodes])
        return self.predict_batch(task.kind, X)

    # ------------------------------------------------------------------ state
    def snapshot(self) -> dict:
        """Serialisable trained state for the model registry (forest-family
        algos only — their whole model is one ForestParams)."""
        models = {}
        for kind in ("map", "reduce"):
            model = self.model_for_kind(kind)
            if model is None:
                models[kind] = None
                continue
            params = forest_family_params(model)
            if params is None:
                raise ValueError(
                    f"algo {self.algo!r} is not registry-serialisable "
                    "(only single-forest models: Tree, CTree, R.F.)")
            models[kind] = params
        return {"algo": self.algo, "seed": self.seed,
                "min_samples": self.min_samples, "max_train": self.max_train,
                "fits": self.fits, "models": models}

    def load_snapshot(self, snap: dict):
        """Restore trained models from ``snapshot()`` output — bit-identical
        scoring to the predictor that published it.

        This is the broker crash-recovery path (``AsyncBroker.
        from_registry``): a snapshot damaged by the very crash being
        recovered from must fail loudly here, not as a scoring-time
        ``KeyError`` three layers down."""
        missing = [k for k in ("algo", "seed", "min_samples", "max_train",
                               "fits", "models") if k not in snap]
        if missing:
            raise ValueError("malformed predictor snapshot: missing "
                             + ", ".join(missing))
        if snap["algo"] not in ALL_MODELS:
            raise ValueError(f"snapshot algo {snap['algo']!r} unknown; "
                             f"known: {', '.join(sorted(ALL_MODELS))}")
        self.algo = snap["algo"]
        self.seed = snap["seed"]
        self.min_samples = snap["min_samples"]
        self.max_train = snap["max_train"]
        self.fits = snap["fits"]
        for kind in ("map", "reduce"):
            params = snap["models"].get(kind)
            if params is None:
                model = None
            else:
                model = ALL_MODELS[self.algo]()
                model.params = params
            if kind == "map":
                self.map_model = model
            else:
                self.reduce_model = model
        self._models_changed()
        return self
