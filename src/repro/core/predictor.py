"""Task-outcome predictor used by ATLAS: two models (map / reduce, as in §4.2),
trained on TelemetryTrace logs and re-trained online every 10 simulated minutes.

The default algorithm is Random Forest (the paper's winner); inference goes through
repro.kernels.forest on TPU (batched over every pending decision in a tick)."""

from __future__ import annotations

import numpy as np

from repro.cluster.telemetry import TelemetryTrace, attempt_features
from repro.ml.models import ALL_MODELS


class TaskPredictor:
    def __init__(self, algo: str = "R.F.", min_samples: int = 150,
                 max_train: int = 20000, seed: int = 0):
        self.algo = algo
        self.min_samples = min_samples
        self.max_train = max_train
        self.seed = seed
        self.map_model = None
        self.reduce_model = None
        self.fits = 0

    # ------------------------------------------------------------------ train
    def fit(self, trace: TelemetryTrace) -> bool:
        return self.fit_datasets(*trace.datasets())

    def fit_datasets(self, map_data, reduce_data) -> bool:
        """Fit from raw (X, y) arrays — the form the fleet sweep ships across
        process boundaries so one training trace serves many cells."""
        (mx, my), (rx, ry) = map_data, reduce_data
        trained = False
        rng = np.random.RandomState(self.seed + self.fits)

        def sub(X, y):
            if X.shape[0] > self.max_train:
                idx = rng.choice(X.shape[0], self.max_train, replace=False)
                return X[idx], y[idx]
            return X, y

        if mx.shape[0] >= self.min_samples and len(np.unique(my)) > 1:
            X, y = sub(mx, my)
            self.map_model = ALL_MODELS[self.algo]().fit(X, y)
            trained = True
        if rx.shape[0] >= self.min_samples and len(np.unique(ry)) > 1:
            X, y = sub(rx, ry)
            self.reduce_model = ALL_MODELS[self.algo]().fit(X, y)
            trained = True
        self.fits += int(trained)
        return trained

    @property
    def ready(self) -> bool:
        return self.map_model is not None or self.reduce_model is not None

    # ------------------------------------------------------------------ infer
    def _model_for(self, task):
        return self.map_model if task.kind == "map" else self.reduce_model

    def p_success(self, sim, task, node, speculative=False) -> float:
        model = self._model_for(task)
        if model is None:
            return 1.0
        x = attempt_features(sim, task, node, speculative)[None]
        return float(model.predict_proba(x)[0])

    def p_success_nodes(self, sim, task, nodes, speculative=False) -> np.ndarray:
        """Batched scoring of candidate placements (one kernel call)."""
        model = self._model_for(task)
        if model is None:
            return np.ones(len(nodes), np.float32)
        X = np.stack([attempt_features(sim, task, n, speculative)
                      for n in nodes])
        return model.predict_proba(X)
