"""AdamW from scratch (no optax), with a configurable moment dtype so 100B+ archs
fit v5e HBM (bf16 moments halve optimizer bytes; update math stays fp32)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "fp32"  # fp32 | bf16
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def _mdtype(cfg: AdamWConfig):
    return jnp.bfloat16 if cfg.moment_dtype == "bf16" else jnp.float32


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params: Any, cfg: AdamWConfig) -> dict:
    md = _mdtype(cfg)
    zeros = lambda p: jnp.zeros(p.shape, md)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def abstract_opt_state(abstract_params: Any, cfg: AdamWConfig) -> dict:
    md = _mdtype(cfg)
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, md)
    return {"m": jax.tree.map(sds, abstract_params),
            "v": jax.tree.map(sds, abstract_params),
            "count": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_state_axes(param_axes: Any) -> dict:
    """Moments shard exactly like their parameters."""
    return {"m": param_axes, "v": param_axes, "count": ()}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    lr = schedule(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    md = _mdtype(cfg)
    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # decay matrices, not norms/biases
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), m32.astype(md), v32.astype(md)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
