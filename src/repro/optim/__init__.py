from repro.optim.adamw import (
    AdamWConfig, abstract_opt_state, apply_updates, global_norm, init_opt_state,
    opt_state_axes, schedule,
)

__all__ = ["AdamWConfig", "abstract_opt_state", "apply_updates", "global_norm",
           "init_opt_state", "opt_state_axes", "schedule"]
