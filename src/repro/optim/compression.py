"""Int8 error-feedback gradient compression for cross-pod reduction.

At 512+ chips the pod-to-pod (DCN) all-reduce of bf16 gradients is the scaling
bottleneck; int8 quantisation with per-block scales cuts it 2x (vs bf16) while the
error-feedback residual keeps the *accumulated* quantisation error bounded, so
convergence is unaffected (Seide et al.; standard in production data-parallel
stacks).

Usage inside a shard_map'd gradient sync:
    g_q, new_resid = compress(g + resid)
    g_sum = jax.lax.psum(decompress(g_q), 'pod')
or locally as a drop-in quantise/dequantise pair (tested for error-feedback
contraction in tests/test_runtime.py)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    flat = x.reshape(-1)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), x.dtype)])
    return flat, n


def compress(g: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """g (any shape, float) -> (int8 values, per-block fp16 scales, residual).
    residual = g - dequantised(g): feed it back into the next step's gradient."""
    flat, n = _pad_to_block(g.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    # quantise with the SAME fp16-rounded scale the receiver will use, so the
    # residual is exact w.r.t. what actually reconstructs on the other side
    scale16 = scale.astype(jnp.float16).astype(jnp.float32)
    scale16 = jnp.maximum(scale16, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale16), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale16).reshape(-1)[:n].reshape(g.shape)
    resid = g.astype(jnp.float32) - deq
    return q, scale16.astype(jnp.float16)[:, 0], resid.astype(g.dtype)


def decompress(q: jax.Array, scale: jax.Array, shape, dtype=jnp.float32):
    deq = q.astype(jnp.float32) * scale.astype(jnp.float32)[:, None]
    n = 1
    for d in shape:
        n *= d
    return deq.reshape(-1)[:n].reshape(shape).astype(dtype)


def compressed_psum(g: jax.Array, axis_name: str, resid: jax.Array | None = None):
    """Error-feedback int8 psum over `axis_name` (use inside shard_map).
    Returns (summed gradient fp32, new residual)."""
    gin = g.astype(jnp.float32) + (resid.astype(jnp.float32)
                                   if resid is not None else 0.0)
    q, scale, new_resid = compress(gin)
    # psum over the dequantised int8 payload: on real fabric the int8+scales are
    # what moves over DCN; XLA reduces the dequantised form (bytes accounted in
    # the roofline via the int8 operand sizes)
    deq = decompress(q, scale, g.shape)
    return jax.lax.psum(deq, axis_name), new_resid
