"""Small shared utilities with no heavy dependencies."""

from __future__ import annotations

import hashlib

import numpy as np


def array_digest(arr: np.ndarray, n_hex: int = 16) -> str:
    """Short content digest of an array's raw bytes (sha256 prefix) — the
    integrity stamp used by both the model registry and checkpoint store."""
    return hashlib.sha256(np.asarray(arr).tobytes()).hexdigest()[:n_hex]
