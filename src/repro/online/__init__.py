"""repro.online — serving-scale predictor lifecycle for ATLAS.

  broker     batched prediction broker: tick-primed memo + cross-cell
             barrier-flush batching, bit-identical to per-decision scoring
  transport  connector/listener comm layer (inproc:// zero-copy channels,
             tcp:// length-prefixed msgpack/JSON frames)
  server     AsyncBroker: the broker as a service — event-loop serving with
             virtual-time flush scheduling (continuous batching)
  registry   versioned, atomic ForestParams store (publish/promote/rollback)
  drift      sliding-window drift monitor + incremental refresh control loop
  bench      load-generator CLI: python -m repro.online.bench
"""

from repro.online.broker import (BrokerPredictor, PredictionBroker,
                                 score_groups)
from repro.online.drift import DriftMonitor, OnlineRefresher
from repro.online.registry import ModelRegistry
from repro.online.server import AsyncBroker, BrokerClient
from repro.online.transport import (Comm, CommClosedError, FrameTooLargeError,
                                    Listener, SyncComm, connect, listen)

__all__ = ["BrokerPredictor", "PredictionBroker", "score_groups",
           "DriftMonitor", "OnlineRefresher", "ModelRegistry",
           "AsyncBroker", "BrokerClient", "Comm", "CommClosedError",
           "FrameTooLargeError", "Listener", "SyncComm", "connect", "listen"]
