"""repro.online — serving-scale predictor lifecycle for ATLAS.

  broker     batched prediction broker: tick-primed memo + cross-cell
             barrier-flush batching, bit-identical to per-decision scoring
  registry   versioned, atomic ForestParams store (publish/promote/rollback)
  drift      sliding-window drift monitor + incremental refresh control loop
  bench      load-generator CLI: python -m repro.online.bench
"""

from repro.online.broker import (BrokerPredictor, PredictionBroker,
                                 score_groups)
from repro.online.drift import DriftMonitor, OnlineRefresher
from repro.online.registry import ModelRegistry

__all__ = ["BrokerPredictor", "PredictionBroker", "score_groups",
           "DriftMonitor", "OnlineRefresher", "ModelRegistry"]
