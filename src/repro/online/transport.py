"""Connector/listener comm abstraction — the broker's wire layer.

The serving stack needs one request/reply surface that works both for
same-process fleet cells (zero copy, no serialization) and for independent
clients across a socket.  This module is that surface, in the style of
dask.distributed's comm core: an address string picks a backend,

    inproc://<name>     same-process channel: deque + asyncio.Event per
                        direction, payload objects pass through BY REFERENCE
                        (a numpy feature block is never copied, a model
                        object rides along untouched)
    tcp://host:port     asyncio streams; each message is one length-prefixed
                        frame, msgpack-encoded when msgpack is importable and
                        JSON otherwise (numpy arrays round-trip losslessly in
                        both — raw bytes under msgpack, base64 under JSON)

and every backend hands back the same five-method ``Comm``:

    comm = await connect("tcp://127.0.0.1:9815")
    await comm.send({"op": "predict", "kind": "map", "X": rows})
    reply = await comm.recv()
    await comm.close()

    listener = await listen("inproc://broker", handler)   # handler(comm)
    await listener.stop()

Failure semantics are explicit and tested: ``recv()`` on a peer-closed comm
raises ``CommClosedError`` (a clean EOF between frames) and a connection cut
mid-frame raises the same (the length prefix promised bytes that never came);
a frame above ``max_frame`` raises ``FrameTooLargeError`` on the *sender* for
outgoing frames and on the receiver for incoming headers, so a corrupt or
hostile prefix can never make the reader allocate unbounded memory.
Backpressure is built in: an inproc channel holds at most ``capacity``
messages and ``send`` awaits a slow consumer; TCP relies on the kernel socket
buffer via ``writer.drain()``.

Everything here is event-loop-local.  Synchronous callers (a fleet cell
thread blocking on its own prediction) wrap a comm in ``SyncComm``, which
schedules the coroutines onto the loop's thread and blocks on the result.
"""

from __future__ import annotations

import asyncio
import base64
import collections
import concurrent.futures
import json
import struct

import numpy as np

try:                                    # optional: the binary frame encoding
    import msgpack
except ImportError:                     # pragma: no cover - baked into CI image
    msgpack = None


class CommClosedError(IOError):
    """The peer closed (or the connection died) before/while a message moved."""


class FrameTooLargeError(ValueError):
    """A frame exceeded ``max_frame`` (outgoing payload or incoming header)."""


DEFAULT_MAX_FRAME = 64 * 1024 * 1024    # 64 MiB: far above any sane flush

# wire header: 1 format byte (J/M) + 4-byte big-endian payload length
_HEADER = struct.Struct("!cI")
_FMT_JSON = b"J"
_FMT_MSGPACK = b"M"
_ND_EXT = 0x4E                          # msgpack ExtType code for ndarrays


# ---------------------------------------------------------------------------
# Serialization: python structures + numpy arrays <-> one frame payload
# ---------------------------------------------------------------------------

def _nd_pack(a: np.ndarray) -> bytes:
    a = np.ascontiguousarray(a)
    head = json.dumps([a.dtype.str, list(a.shape)]).encode()
    return struct.pack("!I", len(head)) + head + a.tobytes()


def _nd_unpack(b: bytes) -> np.ndarray:
    (hlen,) = struct.unpack_from("!I", b, 0)
    dtype, shape = json.loads(b[4:4 + hlen].decode())
    return np.frombuffer(b[4 + hlen:], dtype=np.dtype(dtype)).reshape(shape)


def _msgpack_default(o):
    if isinstance(o, np.ndarray):
        return msgpack.ExtType(_ND_EXT, _nd_pack(o))
    if isinstance(o, (np.floating, np.integer)):
        return o.item()
    raise TypeError(f"unserializable message field: {type(o).__name__}")


def _msgpack_ext_hook(code, data):
    if code == _ND_EXT:
        return _nd_unpack(data)
    return msgpack.ExtType(code, data)      # pragma: no cover


class _JSONEncoder(json.JSONEncoder):
    def default(self, o):
        if isinstance(o, np.ndarray):
            a = np.ascontiguousarray(o)
            return {"__nd__": [a.dtype.str, list(a.shape),
                               base64.b64encode(a.tobytes()).decode()]}
        if isinstance(o, (np.floating, np.integer)):
            return o.item()
        return super().default(o)


def _json_object_hook(d):
    nd = d.get("__nd__")
    if nd is not None and len(d) == 1:
        dtype, shape, data = nd
        return np.frombuffer(base64.b64decode(data),
                             dtype=np.dtype(dtype)).reshape(shape)
    return d


def dumps(msg, serializer: str = "auto") -> tuple[bytes, bytes]:
    """Encode one message -> (format byte, payload bytes)."""
    if serializer == "auto":
        serializer = "msgpack" if msgpack is not None else "json"
    if serializer == "msgpack":
        if msgpack is None:
            raise RuntimeError("msgpack serializer requested but unavailable")
        return _FMT_MSGPACK, msgpack.packb(msg, default=_msgpack_default,
                                           use_bin_type=True)
    if serializer == "json":
        return _FMT_JSON, json.dumps(msg, cls=_JSONEncoder,
                                     separators=(",", ":")).encode()
    raise ValueError(f"unknown serializer {serializer!r}")


def loads(fmt: bytes, payload: bytes):
    """Decode one (format byte, payload) frame back into a message."""
    if fmt == _FMT_MSGPACK:
        if msgpack is None:
            raise RuntimeError("received a msgpack frame but msgpack is "
                               "unavailable")
        return msgpack.unpackb(payload, ext_hook=_msgpack_ext_hook, raw=False,
                               strict_map_key=False)
    if fmt == _FMT_JSON:
        return json.loads(payload.decode(), object_hook=_json_object_hook)
    raise CommClosedError(f"unknown frame format byte {fmt!r}")


# ---------------------------------------------------------------------------
# Comm protocol
# ---------------------------------------------------------------------------

class Comm:
    """One established bidirectional message channel."""

    local_addr: str = "?"
    peer_addr: str = "?"

    async def send(self, msg) -> None:
        raise NotImplementedError

    async def recv(self):
        raise NotImplementedError

    async def close(self) -> None:
        raise NotImplementedError

    @property
    def closed(self) -> bool:
        raise NotImplementedError

    def __repr__(self):
        state = "closed" if self.closed else "open"
        return (f"<{type(self).__name__} {self.local_addr} -> "
                f"{self.peer_addr} [{state}]>")


class Listener:
    """A bound endpoint invoking ``handler(comm)`` per accepted connection."""

    address: str = "?"

    async def stop(self) -> None:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# inproc backend: deque + event per direction, zero-copy payloads
# ---------------------------------------------------------------------------

class _Channel:
    """One direction of an inproc comm: a bounded deque of message objects.

    ``asyncio.Event`` pairs signal data-available / space-available; a full
    channel parks the sender until the consumer drains (bounded-queue
    backpressure with zero copies — the object itself is the payload)."""

    def __init__(self, capacity: int):
        self.q: collections.deque = collections.deque()
        self.capacity = capacity
        self.readable = asyncio.Event()
        self.writable = asyncio.Event()
        self.writable.set()
        self.closed = False

    async def put(self, msg):
        while len(self.q) >= self.capacity and not self.closed:
            self.writable.clear()
            await self.writable.wait()
        if self.closed:
            raise CommClosedError("inproc peer closed")
        self.q.append(msg)
        self.readable.set()

    async def get(self):
        while not self.q:
            if self.closed:
                raise CommClosedError("inproc peer closed")
            self.readable.clear()
            await self.readable.wait()
        msg = self.q.popleft()
        if len(self.q) < self.capacity:
            self.writable.set()
        return msg

    def close(self):
        self.closed = True
        self.readable.set()            # wake any parked reader/writer
        self.writable.set()


class InProcComm(Comm):
    def __init__(self, rx: _Channel, tx: _Channel, local: str, peer: str):
        self._rx, self._tx = rx, tx
        self.local_addr, self.peer_addr = local, peer
        self._closed = False

    async def send(self, msg):
        if self._closed:
            raise CommClosedError("comm already closed")
        await self._tx.put(msg)

    async def recv(self):
        if self._closed:
            raise CommClosedError("comm already closed")
        return await self._rx.get()

    async def close(self):
        self._closed = True
        self._rx.close()
        self._tx.close()

    @property
    def closed(self) -> bool:
        return self._closed


class _InProcListener(Listener):
    def __init__(self, name: str, handler, capacity: int):
        self.address = f"inproc://{name}"
        self._name = name
        self._handler = handler
        self._capacity = capacity
        self._tasks: set = set()

    def _connect(self) -> InProcComm:
        a, b = _Channel(self._capacity), _Channel(self._capacity)
        server_side = InProcComm(a, b, self.address, "inproc://client")
        client_side = InProcComm(b, a, "inproc://client", self.address)
        t = asyncio.ensure_future(self._handler(server_side))
        self._tasks.add(t)
        t.add_done_callback(self._tasks.discard)
        return client_side

    async def stop(self):
        _INPROC.pop(self._name, None)
        for t in list(self._tasks):
            t.cancel()
        # let cancellations unwind so handler tasks never leak across tests
        await asyncio.gather(*self._tasks, return_exceptions=True)


_INPROC: dict[str, _InProcListener] = {}


# ---------------------------------------------------------------------------
# tcp backend: asyncio streams, length-prefixed frames
# ---------------------------------------------------------------------------

class TCPComm(Comm):
    def __init__(self, reader, writer, *, serializer: str = "auto",
                 max_frame: int = DEFAULT_MAX_FRAME):
        self._reader, self._writer = reader, writer
        self.serializer = serializer
        self.max_frame = max_frame
        self._closed = False
        peer = writer.get_extra_info("peername") or ("?", "?")
        sock = writer.get_extra_info("sockname") or ("?", "?")
        self.peer_addr = f"tcp://{peer[0]}:{peer[1]}"
        self.local_addr = f"tcp://{sock[0]}:{sock[1]}"

    async def send(self, msg):
        if self._closed:
            raise CommClosedError("comm already closed")
        fmt, payload = dumps(msg, self.serializer)
        if len(payload) > self.max_frame:
            raise FrameTooLargeError(
                f"frame of {len(payload)} bytes exceeds max_frame="
                f"{self.max_frame}")
        try:
            self._writer.write(_HEADER.pack(fmt, len(payload)))
            self._writer.write(payload)
            await self._writer.drain()       # kernel-buffer backpressure
        except (OSError, RuntimeError) as e:
            # OSError covers ConnectionError plus the rest of the socket
            # failure surface (ETIMEDOUT, EPIPE via os-level writes, ...)
            self._closed = True
            raise CommClosedError(str(e)) from e

    async def recv(self):
        if self._closed:
            raise CommClosedError("comm already closed")
        try:
            head = await self._reader.readexactly(_HEADER.size)
        except (asyncio.IncompleteReadError, OSError) as e:
            self._closed = True
            if isinstance(e, asyncio.IncompleteReadError) and not e.partial:
                raise CommClosedError("peer closed") from e
            raise CommClosedError("connection lost mid-header") from e
        fmt, length = _HEADER.unpack(head)
        if length > self.max_frame:
            self._closed = True
            self._writer.close()
            raise FrameTooLargeError(
                f"incoming frame header claims {length} bytes "
                f"(max_frame={self.max_frame})")
        try:
            payload = await self._reader.readexactly(length)
        except (asyncio.IncompleteReadError, OSError) as e:
            self._closed = True
            raise CommClosedError("connection lost mid-frame") from e
        try:
            return loads(fmt, payload)
        except CommClosedError:
            self._closed = True
            raise
        except Exception as e:
            # an abrupt peer death can hand us a length-complete but garbage
            # payload (e.g. RST after a partial kernel buffer flush); decode
            # failures from any codec (struct/json/base64/msgpack) are a dead
            # connection to the caller, never a bare parser exception
            self._closed = True
            raise CommClosedError(f"undecodable frame: {e!r}") from e

    async def close(self):
        if self._closed:
            return
        self._closed = True
        try:
            self._writer.close()
            await self._writer.wait_closed()
        except (OSError, RuntimeError):   # peer already gone
            pass

    @property
    def closed(self) -> bool:
        return self._closed


class _TCPListener(Listener):
    def __init__(self, server, address: str):
        self._server = server
        self.address = address

    async def stop(self):
        self._server.close()
        await self._server.wait_closed()


# ---------------------------------------------------------------------------
# Address routing
# ---------------------------------------------------------------------------

def parse_address(address: str) -> tuple[str, str]:
    scheme, sep, rest = address.partition("://")
    if not sep or scheme not in ("inproc", "tcp"):
        raise ValueError(f"bad address {address!r} "
                         "(want inproc://<name> or tcp://host:port)")
    return scheme, rest


async def connect(address: str, *, serializer: str = "auto",
                  max_frame: int = DEFAULT_MAX_FRAME,
                  capacity: int = 1024) -> Comm:
    """Open a client comm to a listening address."""
    scheme, rest = parse_address(address)
    if scheme == "inproc":
        listener = _INPROC.get(rest)
        if listener is None:
            raise CommClosedError(f"no inproc listener at {address!r}")
        return listener._connect()
    host, _, port = rest.rpartition(":")
    reader, writer = await asyncio.open_connection(host, int(port))
    return TCPComm(reader, writer, serializer=serializer, max_frame=max_frame)


async def listen(address: str, handler, *, serializer: str = "auto",
                 max_frame: int = DEFAULT_MAX_FRAME,
                 capacity: int = 1024) -> Listener:
    """Bind ``address`` and invoke ``await handler(comm)`` per connection.

    ``tcp://host:0`` binds an ephemeral port; read the bound address back
    from ``listener.address``."""
    scheme, rest = parse_address(address)
    if scheme == "inproc":
        if rest in _INPROC:
            raise ValueError(f"inproc listener {address!r} already bound")
        lst = _InProcListener(rest, handler, capacity)
        _INPROC[rest] = lst
        return lst

    async def on_connect(reader, writer):
        await handler(TCPComm(reader, writer, serializer=serializer,
                              max_frame=max_frame))

    host, _, port = rest.rpartition(":")
    server = await asyncio.start_server(on_connect, host, int(port))
    bound = server.sockets[0].getsockname()
    return _TCPListener(server, f"tcp://{bound[0]}:{bound[1]}")


# ---------------------------------------------------------------------------
# Sync facade: blocking send/recv for client threads outside the loop
# ---------------------------------------------------------------------------

class SyncComm:
    """Blocking wrapper around a Comm living on another thread's event loop.

    This is how a fleet-cell thread (synchronous simulator code) talks to the
    AsyncBroker: every call schedules the coroutine onto the loop thread and
    blocks on its result, so the calling thread sees ordinary synchronous
    request/reply semantics."""

    def __init__(self, comm: Comm, loop: asyncio.AbstractEventLoop):
        self.comm = comm
        self.loop = loop

    @classmethod
    def connect(cls, address: str, loop: asyncio.AbstractEventLoop,
                timeout: float | None = 30.0, **kw) -> "SyncComm":
        fut = asyncio.run_coroutine_threadsafe(connect(address, **kw), loop)
        return cls(fut.result(timeout), loop)

    def _run(self, coro, timeout=None):
        fut = asyncio.run_coroutine_threadsafe(coro, self.loop)
        try:
            return fut.result(timeout)
        except concurrent.futures.TimeoutError:
            # .result(timeout) does NOT cancel the scheduled coroutine; an
            # orphaned recv would later consume a reply meant for the next
            # request and desync the stream.  Cancel, and let the caller
            # treat the comm as dead (retry layers reconnect).
            fut.cancel()
            raise

    def send(self, msg, timeout: float | None = None):
        return self._run(self.comm.send(msg), timeout)

    def recv(self, timeout: float | None = None):
        return self._run(self.comm.recv(), timeout)

    def close(self, timeout: float | None = 10.0):
        if not self.comm.closed and self.loop.is_running():
            self._run(self.comm.close(), timeout)
