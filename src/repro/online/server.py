"""AsyncBroker — the broker as a service: an asyncio serving loop over the
``repro.online.transport`` comm layer.

The PR-4/5 ``PredictionBroker`` batches across clients with a lock-step
barrier (every registered client parks one request per round) or a wall-clock
depth timer.  Both develop a latency tail under open-loop traffic: the
barrier makes every request wait for the slowest client's next submit, and
the timer trades tail batches for 2 ms of deliberate jitter.  BENCH_5
measured the damage at the paper fleet: p50 1.4 ms but p99 49 ms — pure
flush-policy stall, not compute.  Since ATLAS puts a prediction on every
task placement, that tail is scheduler stall time.

``AsyncBroker`` replaces the thread barrier with an event loop and a
*virtual-time* flush policy:

  policy="vt"       requests are admitted in logical arrival order; ``vnow``
                    (the admission counter) is the clock.  A flush fires when
                      - the queued rows reach ``depth``            (depth cap)
                      - the oldest queued request has seen
                        ``vt_window`` admissions since its own     (staleness
                        admission                                   cap)
                      - the loop drains the currently-ready burst  (idle
                        of arrivals                                 drain)
                    The first two are pure functions of the admission
                    sequence — no wall clock anywhere in the steady state, so
                    flush composition is keyed to logical arrival order and
                    batches stay fat exactly when arrivals are dense.  The
                    idle drain is what kills the tail: whatever accumulated
                    while the previous flush was scoring goes out as the next
                    batch immediately (continuous batching), instead of
                    waiting for a timer or a straggler.  A per-request
                    latency budget (``slo_ms``, or ``budget_ms`` on the
                    request) arms one safety-valve timer per batch that
                    force-flushes early when the oldest request is about to
                    blow its SLO — the only wall-clock path, and it only
                    fires when the policy already failed to flush in time.
  policy="barrier"  the PredictionBroker lock-step round rule (flush when
                    every registered live client has a request parked),
                    driven by the loop instead of a condition variable.
                    Rounds — and therefore every stats() counter — are a
                    pure function of each client's request sequence, which is
                    what lets ``fleet --executor async`` reproduce the
                    threaded barrier executor's SWEEP.json byte for byte.

Wire protocol (one msg dict per frame; ndarray-safe over tcp://):

  {"op": "predict",  "id": n, "kind": "map", "X": ndarray,
   "budget_ms": 5.0}                 -> {"id": n, "probs": ndarray}
  {"op": "submit",   "id": n, "groups": [(model, X), ...]}
                                     -> {"id": n, "probs": [ndarray, ...]}
                                        (inproc only: live model objects)
  {"op": "register", "n": 4}         (barrier membership, no reply)
  {"op": "done"}                     (client will not submit again)
  {"op": "telemetry", "frame": {...},
   "source": "cell", "n": 7}         (repro.obs frame -> collector +
                                      telemetry_sink; source/n optional:
                                      per-producer id + 1-based emit counter
                                      for gap/reconnect accounting)
  {"op": "telemetry", "source": "cell",
   "frames": [{"frame": {...}, "n": 7}, ...]}
                                     (batched form: TransportSink with
                                      flush_every > 1 ships one message
                                      per flush, per-frame n preserved)
  {"op": "stats"}                    -> deterministic counter dict
  {"op": "ping"}                     -> {"op": "pong"}

Row-level outputs are bit-identical to scalar scoring however requests are
batched (the ``score_groups`` invariant), so every policy serves the same
floats — the policies only move *when* a batch closes.
"""

from __future__ import annotations

import asyncio
import concurrent.futures
import itertools
import os
import threading
import time

import numpy as np

from repro.online.broker import score_groups
from repro.online.faults import (FaultInjector, PredictorUnavailableError,
                                 backoff_delay)
from repro.online.transport import (CommClosedError, SyncComm, connect,
                                    listen)

_SERVE_SEQ = itertools.count()
_CLIENT_SEQ = itertools.count()


class _Req:
    """One admitted request: where to reply + its span of the next flush."""

    __slots__ = ("comm", "req_id", "groups", "rows", "vadmit", "deadline",
                 "client")

    def __init__(self, comm, req_id, groups, rows, vadmit, deadline,
                 client=None):
        self.comm = comm
        self.req_id = req_id
        self.groups = groups
        self.rows = rows
        self.vadmit = vadmit
        self.deadline = deadline
        self.client = client


class AsyncBroker:
    """Event-loop batching server for prediction traffic.

    ``models`` maps kind names ("map"/"reduce") to scoring models for the
    named-model ``predict`` op (the only op that works across tcp://);
    in-process clients may instead ship live model objects via ``submit``.
    The loop runs on a dedicated daemon thread (``start``/``stop``);
    ``serve`` binds any number of transport addresses onto it."""

    def __init__(self, models: dict | None = None, *, impl: str = "numpy",
                 policy: str = "vt", depth: int = 2048,
                 vt_window: int | None = None, slo_ms: float | None = None,
                 slo_margin: float = 0.5, max_queue_rows: int = 65536,
                 serializer: str = "auto"):
        if policy not in ("vt", "barrier"):
            raise ValueError(f"unknown flush policy {policy!r}")
        self.models = dict(models or {})
        self.impl = impl
        self.policy = policy
        self.depth = int(depth)
        self.vt_window = vt_window
        self.slo_ms = slo_ms
        self.slo_margin = float(slo_margin)
        self.max_queue_rows = int(max_queue_rows)
        self.serializer = serializer
        # optional collaborators
        self.obs = None                  # repro.obs.BrokerObserver
        self.telemetry_sink = None       # repro.obs Sink for telemetry frames
        self.collector = None            # repro.obs.TelemetryCollector
        # per-source telemetry wire accounting (reporting only)
        self._telemetry_sources: dict[str, dict] = {}
        # idempotent-replay state: one outstanding request per client, so a
        # single slot per client id holds either the in-flight _Req (a
        # retransmit just re-aims its reply comm) or the finished reply (a
        # retransmit gets it resent verbatim — never rescored, never
        # recounted).  This is what makes client retries invisible to the
        # deterministic counters and keeps SWEEP.json byte parity under
        # fault injection.
        self._replay: dict[str, tuple] = {}
        self._done_clients: set[str] = set()
        self._injectors: list[FaultInjector] = []
        # loop state (loop-confined once started)
        self.loop: asyncio.AbstractEventLoop | None = None
        self._thread: threading.Thread | None = None
        self._listeners: list = []
        self._queue: list[_Req] = []
        self._queued_rows = 0
        self._clients = 0
        self._vnow = 0
        self._epoch = 0
        self._slo_handle: asyncio.TimerHandle | None = None
        self._slo_at = float("inf")
        self._drain = None               # asyncio.Event, lazily on the loop
        # deterministic accounting (mirrors PredictionBroker.stats())
        self.n_flushes = 0
        self.n_dispatches = 0
        self.n_rows = 0
        self.n_requests = 0
        self.max_flush_rows = 0
        # cause counters (reporting only — depend on arrival timing)
        self.n_depth_flushes = 0
        self.n_vt_flushes = 0
        self.n_idle_flushes = 0
        self.n_deadline_flushes = 0
        self.n_backpressure_waits = 0
        self.n_telemetry_frames = 0
        self.n_replays = 0               # cached replies resent to retries
        self.n_dup_requests = 0          # retransmits of in-flight requests

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "AsyncBroker":
        """Spin up the serving loop on its own daemon thread."""
        if self._thread is not None:
            return self
        ready = threading.Event()

        def run():
            self.loop = asyncio.new_event_loop()
            asyncio.set_event_loop(self.loop)
            self._drain = asyncio.Event()
            ready.set()
            self.loop.run_forever()
            # unwind whatever the stop() cancellation left behind
            pending = asyncio.all_tasks(self.loop)
            for t in pending:
                t.cancel()
            if pending:
                self.loop.run_until_complete(
                    asyncio.gather(*pending, return_exceptions=True))
            self.loop.close()

        self._thread = threading.Thread(target=run, daemon=True,
                                        name="async-broker")
        self._thread.start()
        ready.wait()
        return self

    def serve(self, address: str = "", *, fault_plan=None, **kw) -> str:
        """Bind a listener; returns the bound address (``tcp://…:0`` resolves
        its ephemeral port, no address picks a fresh inproc name).

        ``fault_plan`` (a ``repro.online.faults.FaultPlan``) wraps every
        accepted comm in the plan's seeded fault schedule and arms its
        listener-restart events: at each ``restart_after`` threshold the
        listener goes down, every established connection dies abruptly, and
        the same concrete address rebinds — clients ride it out through
        their reconnect/retry path."""
        if not address:
            address = f"inproc://broker-{next(_SERVE_SEQ)}"
        kw.setdefault("serializer", self.serializer)
        handler = self._handle
        injector = None
        if fault_plan is not None:
            injector = FaultInjector(fault_plan)
            handler = injector.wrap_handler(self._handle)
        lst = asyncio.run_coroutine_threadsafe(
            listen(address, handler, **kw), self.loop).result(30)
        self._listeners.append(lst)
        if injector is not None:
            self._injectors.append(injector)
            bound = lst.address

            def trigger():               # fires on the loop thread
                asyncio.ensure_future(
                    self._restart_listener(bound, handler, injector, kw))

            injector.on_restart = trigger
        return lst.address

    async def _restart_listener(self, address, handler, injector, kw):
        """The broker-restart fault: tear the listener down (severing every
        live connection, no clean goodbyes) and rebind the same address."""
        for i, lst in enumerate(self._listeners):
            if lst.address == address:
                await lst.stop()
                await injector.close_active()
                self._listeners[i] = await listen(address, handler, **kw)
                return

    def stop(self):
        if self._thread is None:
            return

        async def shutdown():
            for lst in self._listeners:
                await lst.stop()
            self._listeners.clear()
            if self._queue:              # never strand a parked client
                self._flush("idle")

        asyncio.run_coroutine_threadsafe(shutdown(), self.loop).result(30)
        self.loop.call_soon_threadsafe(self.loop.stop)
        self._thread.join(timeout=30)
        self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *a):
        self.stop()
        return False

    # ------------------------------------------------------------ recovery
    @classmethod
    def from_registry(cls, registry_dir, name: str, *,
                      version: int | None = None, **kw) -> "AsyncBroker":
        """Rebuild a broker's model state from a ``ModelRegistry`` snapshot.

        This is the crash-recovery path: a replacement broker process owns
        no live model objects, but the registry's versioned snapshot is the
        durable source of truth.  Scoring is a pure function of (model
        params, rows), so the rebuilt broker serves bit-identical
        probabilities to the one that died."""
        from repro.core.predictor import TaskPredictor
        from repro.online.registry import ModelRegistry
        snap = ModelRegistry(registry_dir).load(name, version)
        pred = TaskPredictor().load_snapshot(snap)
        models = {}
        for kind in ("map", "reduce"):
            model = pred.model_for_kind(kind)
            if model is not None:
                models[kind] = model
        return cls(models, **kw)

    def resume_collector(self, collector):
        """Attach a telemetry collector after a broker restart, seeding the
        per-source wire accounting from the collector's surviving state so
        producers reconnect gaplessly: the first frame after the restart is
        judged against the last ``n`` actually ingested, not against zero
        (which would count every producer as one bogus reconnect-with-gap)."""
        self.collector = collector
        for name in collector.source_names():
            src = collector.sources[name]
            self._telemetry_sources[name] = {
                "frames": src.n_frames, "last_n": src.last_n,
                "gaps": src.gaps, "reconnects": src.reconnects,
                "ingest_s": 0.0}

    def fault_stats(self) -> dict:
        """Replay/dedup counters + injected-fault totals (reporting only —
        these quantify the chaos absorbed, and stay out of ``stats()`` so
        faulted and clean runs emit identical deterministic counters)."""
        injected = {"events": 0, "drops": 0, "delays": 0, "duplicates": 0,
                    "closes": 0, "restarts": 0, "messages_in": 0}
        for inj in self._injectors:
            for k, v in inj.stats().items():
                injected[k] += v
        return {"replays": self.n_replays,
                "dup_requests": self.n_dup_requests,
                "injected": injected}

    # ------------------------------------------------------------ membership
    def add_clients(self, n: int = 1):
        """Barrier-round membership (thread-safe; PredictionBroker API)."""
        if self.loop is not None and self._thread is not None:
            self.loop.call_soon_threadsafe(self._add_clients, n)
        else:
            self._add_clients(n)

    def _add_clients(self, n: int):
        self._clients += n

    def _client_done(self):
        self._clients -= 1
        if self.policy == "barrier" and self._queue \
                and len(self._queue) >= max(self._clients, 1):
            self._flush("round")

    # ------------------------------------------------------------ serving
    async def _handle(self, comm):
        try:
            while True:
                try:
                    msg = await comm.recv()
                except CommClosedError:
                    return
                try:
                    await self._dispatch(comm, msg)
                except CommClosedError:
                    # the connection died mid-reply (peer vanished, or an
                    # injected abrupt close): the client's retry path owns
                    # recovery — this handler just winds down
                    return
        finally:
            if not comm.closed:
                await comm.close()

    async def _dispatch(self, comm, msg):
        op = msg.get("op")
        if op == "predict" or op == "submit":
            if not self._replay_hit(comm, msg):
                await self._admit(comm, msg, op)
        elif op == "done":
            cid = msg.get("client")
            if cid is None:
                self._client_done()      # legacy fire-and-forget form
            else:
                if cid not in self._done_clients:
                    self._done_clients.add(cid)
                    self._replay.pop(cid, None)
                    self._client_done()
                if msg.get("id") is not None:
                    # acked so the client can retry a lost done
                    # without double-shrinking the barrier
                    await comm.send({"id": msg["id"], "ok": True})
        elif op == "register":
            self._add_clients(int(msg.get("n", 1)))
        elif op == "telemetry":
            self._route_telemetry(msg)
        elif op == "stats":
            await comm.send(self.stats())
        elif op == "ping":
            await comm.send({"op": "pong"})
        else:
            await comm.send({"id": msg.get("id"),
                             "error": f"unknown op {op!r}"})

    def _replay_hit(self, comm, msg) -> bool:
        """Idempotent-replay check for a scoring request.

        Returns True when the message is a retransmit (same client id +
        request id as this client's one outstanding slot): a still-pending
        original just gets its reply re-aimed at the new comm, a finished
        one gets the cached reply resent.  Either way the request is never
        re-admitted — ``n_requests``/flush composition see it exactly once.
        Messages without a ``client`` field (raw-comm callers) bypass
        dedup entirely."""
        cid = msg.get("client")
        if cid is None:
            return False
        entry = self._replay.get(cid)
        if entry is None or entry[0] != msg.get("id"):
            return False
        self.n_dup_requests += 1
        _, state, val = entry
        if state == "pending":
            val.comm = comm              # reply lands on the fresh comm
        else:
            self.n_replays += 1
            self._send_cached(comm, val)
        return True

    def _send_cached(self, comm, msg: dict):
        if comm.closed:
            return
        task = asyncio.ensure_future(comm.send(msg))
        task.add_done_callback(_swallow_closed)

    async def _admit(self, comm, msg, op):
        if op == "predict":
            model = self.models.get(msg.get("kind"))
            if model is None:
                await comm.send({"id": msg.get("id"),
                                 "error": f"unknown kind {msg.get('kind')!r}"})
                return
            groups = [(model, msg["X"])]
        else:
            groups = msg["groups"]
        rows = sum(np.asarray(X).shape[0] for _, X in groups)
        # bounded-queue admission control: a full queue parks THIS comm's
        # read loop until a flush drains — over tcp the stall propagates to
        # the client through the kernel socket buffer (backpressure, not
        # load shedding: every admitted request is eventually served)
        if self.policy == "vt":
            while self._queued_rows >= self.max_queue_rows:
                self.n_backpressure_waits += 1
                self._drain.clear()
                await self._drain.wait()
        self.n_requests += 1
        budget = msg.get("budget_ms", self.slo_ms)
        deadline = (time.perf_counter() + budget * 1e-3 * self.slo_margin
                    if budget else None)
        self._vnow += 1
        req = _Req(comm, msg.get("id"), groups, rows, self._vnow, deadline,
                   msg.get("client"))
        if req.client is not None:
            self._replay[req.client] = (req.req_id, "pending", req)
        first = not self._queue
        self._queue.append(req)
        self._queued_rows += rows
        if self.policy == "barrier":
            if len(self._queue) >= max(self._clients, 1):
                self._flush("round")
            return
        # ---- virtual-time policy ----
        if self._queued_rows >= self.depth:
            self.n_depth_flushes += 1
            self._flush("depth")
            return
        if self.vt_window is not None \
                and self._vnow - self._queue[0].vadmit >= self.vt_window:
            self.n_vt_flushes += 1
            self._flush("vt")
            return
        if first:
            # idle drain: runs after the callbacks already ready this loop
            # iteration, so one dense burst of arrivals lands in one batch
            self.loop.call_soon(self._idle_flush, self._epoch)
        if deadline is not None and deadline < self._slo_at:
            self._arm_slo(deadline)

    # ------------------------------------------------------------ flush paths
    def _idle_flush(self, epoch: int):
        if epoch == self._epoch and self._queue:
            self.n_idle_flushes += 1
            self._flush("idle")

    def _arm_slo(self, deadline: float):
        if self._slo_handle is not None:
            self._slo_handle.cancel()
        self._slo_at = deadline
        delay = max(deadline - time.perf_counter(), 0.0)
        self._slo_handle = self.loop.call_later(
            delay, self._slo_flush, self._epoch)

    def _slo_flush(self, epoch: int):
        self._slo_handle = None
        self._slo_at = float("inf")
        if epoch == self._epoch and self._queue:
            self.n_deadline_flushes += 1
            self._flush("slo")

    def _flush(self, cause: str):
        batch, self._queue = self._queue, []
        rows, self._queued_rows = self._queued_rows, 0
        self._epoch += 1
        if self._slo_handle is not None:
            self._slo_handle.cancel()
            self._slo_handle = None
            self._slo_at = float("inf")
        self._drain.set()
        flat = [g for req in batch for g in req.groups]
        t0 = time.perf_counter()
        try:
            outs, n = score_groups(flat, impl=self.impl)
        except Exception as e:
            for req in batch:
                self._reply(req, {"id": req.req_id, "error": repr(e)})
            return
        self.n_flushes += 1
        self.n_dispatches += n
        self.n_rows += rows
        self.max_flush_rows = max(self.max_flush_rows, rows)
        if self.obs is not None:
            self.obs.record_flush(rows, len(batch), n,
                                  time.perf_counter() - t0)
        at = 0
        for req in batch:
            span = outs[at:at + len(req.groups)]
            at += len(req.groups)
            self._reply(req, {"id": req.req_id, "probs": span})

    def _reply(self, req: _Req, msg: dict):
        if req.client is not None:
            # cache even error replies: scoring is deterministic, so a retry
            # of a failed request deserves the same verdict, not a rescore
            self._replay[req.client] = (req.req_id, "done", msg)
        if req.comm.closed:
            return
        task = asyncio.ensure_future(req.comm.send(msg))
        task.add_done_callback(_swallow_closed)

    # ------------------------------------------------------------ telemetry
    def _route_telemetry(self, msg: dict):
        """Fan telemetry frames to the registered consumers.

        One message carries a single ``frame`` or a batched ``frames`` list
        (each entry ``{"frame": …, "n": …}`` — ``TransportSink`` batches
        like ``NDJSONSink`` does).  Runs on the loop thread inside the
        client's handler coroutine, so a slow ``collector.ingest`` parks
        exactly that producer's channel — backpressure reaches the emitting
        ``TransportSink`` through the transport's bounded buffers instead of
        growing a queue here.  The time spent is accounted per source
        (``ingest_s``) so a wedged collector is visible in
        ``telemetry_stats()``."""
        entries = msg.get("frames")
        if entries is None:
            entries = ({"frame": msg["frame"], "n": msg.get("n")},)
        source = msg.get("source", "default")
        st = self._telemetry_sources.get(source)
        if st is None:
            st = self._telemetry_sources[source] = {
                "frames": 0, "last_n": 0, "gaps": 0, "reconnects": 0,
                "ingest_s": 0.0}
        for entry in entries:
            self.n_telemetry_frames += 1
            st["frames"] += 1
            n = entry.get("n")
            if n is not None:
                if n <= st["last_n"]:
                    st["reconnects"] += 1
                elif n > st["last_n"] + 1:
                    st["gaps"] += n - st["last_n"] - 1
                st["last_n"] = n
            if self.collector is not None:
                t0 = time.perf_counter()
                self.collector.ingest(entry["frame"], source=source, n=n)
                st["ingest_s"] += time.perf_counter() - t0
            if self.telemetry_sink is not None:
                self.telemetry_sink.emit(entry["frame"])

    def telemetry_stats(self) -> dict:
        """Per-source telemetry wire accounting.  Reporting only — values
        depend on arrival order and wall clock, so this stays out of the
        deterministic ``stats()`` dict."""
        return {"frames": self.n_telemetry_frames,
                "sources": {k: {**v, "ingest_s": round(v["ingest_s"], 6)}
                            for k, v in
                            sorted(self._telemetry_sources.items())}}

    # ------------------------------------------------------------ accounting
    def stats(self) -> dict:
        """Deterministic counters, same keys/semantics as
        ``PredictionBroker.stats()`` (cause counters stay off — they depend
        on arrival timing, not on the request streams)."""
        return {"flushes": self.n_flushes, "dispatches": self.n_dispatches,
                "rows": self.n_rows, "requests": self.n_requests,
                "max_flush_rows": self.max_flush_rows,
                "policy": self.policy}


def _swallow_closed(task: asyncio.Task):
    """A reply raced a client disconnect: nothing to do, nobody to tell."""
    if not task.cancelled():
        exc = task.exception()
        if exc is not None and not isinstance(exc, CommClosedError):
            raise exc


class BrokerClient:
    """Synchronous client facade with the ``PredictionBroker`` surface
    (``submit`` / ``done``), so a ``BrokerPredictor`` can serve a fleet cell
    through an ``AsyncBroker`` unchanged.  One outstanding request per client
    (the predictor blocks on each flush), so replies need no demux.

    Every request carries a stable ``client`` id + monotone request id, and
    the request path is a retry loop: on a transport failure or a
    ``request_timeout_s`` expiry the comm is dropped (a timed-out stream can
    no longer be trusted — a late reply would answer the wrong request), the
    client sleeps a deterministic capped-exponential backoff
    (``faults.backoff_delay``), reconnects, and resends the *same* message.
    The broker's replay slot makes the retry idempotent, so transparent
    reconnect never double-scores a flush.  The budget is ``max_retries``
    attempts within ``deadline_s``; past it the client raises
    ``PredictorUnavailableError`` — the graceful-degradation signal.  With
    the default ``request_timeout_s=None`` the client blocks forever like
    the pre-fault-tolerance client (retries then only trigger on explicit
    connection failures)."""

    def __init__(self, address: str, loop: asyncio.AbstractEventLoop, *,
                 client_id: str | None = None,
                 request_timeout_s: float | None = None,
                 deadline_s: float | None = None, max_retries: int = 8,
                 backoff_base_s: float = 0.05, backoff_cap_s: float = 1.0,
                 retry_seed: int = 0, **connect_kw):
        self.address = address
        self._loop = loop
        self._connect_kw = connect_kw
        self.client_id = client_id or f"c{os.getpid()}-{next(_CLIENT_SEQ)}"
        self.request_timeout_s = request_timeout_s
        self.deadline_s = deadline_s
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.retry_seed = int(retry_seed)
        self.n_retries = 0
        self.n_reconnects = 0
        self._seq = 0
        self._done_sent = False
        self._comm = None
        self._was_connected = False
        self._comm = self._connect(self._budget_deadline())

    # ------------------------------------------------------------ plumbing
    def _budget_deadline(self) -> float | None:
        return (None if self.deadline_s is None
                else time.monotonic() + self.deadline_s)

    def _remaining(self, deadline: float | None) -> float | None:
        if deadline is None:
            return None
        return max(deadline - time.monotonic(), 0.001)

    def _attempt_timeout(self, deadline: float | None) -> float | None:
        rem = self._remaining(deadline)
        if self.request_timeout_s is None:
            return rem
        return rem if rem is not None and rem < self.request_timeout_s \
            else self.request_timeout_s

    def _backoff(self, attempt: int, deadline: float | None):
        delay = backoff_delay(attempt, base=self.backoff_base_s,
                              cap=self.backoff_cap_s, seed=self.retry_seed)
        rem = self._remaining(deadline)
        if rem is not None:
            delay = min(delay, rem)
        time.sleep(delay)

    def _connect(self, deadline: float | None) -> SyncComm:
        """Connect with retries: a listener mid-restart refuses connections
        for a moment, and that window must look like latency, not failure."""
        attempt = 0
        while True:
            try:
                comm = SyncComm.connect(
                    self.address, self._loop,
                    timeout=self._attempt_timeout(deadline) or 30.0,
                    **self._connect_kw)
                if self._was_connected:
                    self.n_reconnects += 1
                self._was_connected = True
                return comm
            except (CommClosedError, OSError,
                    concurrent.futures.TimeoutError) as e:
                attempt += 1
                out_of_time = (deadline is not None
                               and time.monotonic() >= deadline)
                if attempt > self.max_retries or out_of_time:
                    raise PredictorUnavailableError(
                        f"cannot reach broker at {self.address} "
                        f"after {attempt} attempts: {e!r}") from e
                self._backoff(attempt - 1, deadline)

    def _drop_comm(self):
        if self._comm is not None:
            try:
                self._comm.close(timeout=1.0)
            except Exception:
                pass
            self._comm = None

    def _request(self, msg: dict) -> dict:
        """Send one message and block for its reply, retrying transparently
        across timeouts, dead comms, and broker restarts."""
        deadline = self._budget_deadline()
        attempt = 0
        while True:
            try:
                if self._comm is None:
                    self._comm = self._connect(deadline)
                t = self._attempt_timeout(deadline)
                self._comm.send(msg, timeout=t)
                while True:
                    reply = self._comm.recv(timeout=t)
                    if reply.get("id") == msg["id"]:
                        return reply
                    # a stale duplicate (wire-level dup fault or a late
                    # reply to an already-retried request): discard and
                    # keep waiting for the answer to THIS request
            except (CommClosedError, OSError,
                    concurrent.futures.TimeoutError) as e:
                self._drop_comm()
                attempt += 1
                self.n_retries += 1
                out_of_time = (deadline is not None
                               and time.monotonic() >= deadline)
                if attempt > self.max_retries or out_of_time:
                    raise PredictorUnavailableError(
                        f"broker at {self.address} unreachable after "
                        f"{attempt} attempts: {e!r}") from e
                self._backoff(attempt - 1, deadline)

    # ------------------------------------------------------------ API
    def submit(self, groups) -> list:
        if not groups:
            return []
        self._seq += 1
        reply = self._request({"op": "submit", "id": self._seq,
                               "client": self.client_id, "groups": groups})
        if reply.get("error") is not None:
            # a broker-reported error is an answer, not an outage: no retry
            raise RuntimeError(f"broker error: {reply['error']}")
        return list(reply["probs"])

    def predict(self, kind: str, X, budget_ms: float | None = None):
        """Named-model scoring (the op that works across tcp://)."""
        self._seq += 1
        msg = {"op": "predict", "id": self._seq, "client": self.client_id,
               "kind": kind, "X": X}
        if budget_ms is not None:
            msg["budget_ms"] = budget_ms
        reply = self._request(msg)
        if reply.get("error") is not None:
            raise RuntimeError(f"broker error: {reply['error']}")
        (probs,) = reply["probs"]
        return probs

    def register(self, n: int = 1):
        self._comm.send({"op": "register", "n": n})

    def done(self):
        """Retract this client from the barrier (acked + idempotent: a lost
        ack is retried, the broker dedups by client id)."""
        if self._done_sent:
            return
        self._done_sent = True
        self._seq += 1
        try:
            self._request({"op": "done", "id": self._seq,
                           "client": self.client_id})
        except PredictorUnavailableError:
            pass                         # broker is gone; nothing to retract

    def close(self):
        if self._comm is not None:
            self._comm.close()
