"""AsyncBroker — the broker as a service: an asyncio serving loop over the
``repro.online.transport`` comm layer.

The PR-4/5 ``PredictionBroker`` batches across clients with a lock-step
barrier (every registered client parks one request per round) or a wall-clock
depth timer.  Both develop a latency tail under open-loop traffic: the
barrier makes every request wait for the slowest client's next submit, and
the timer trades tail batches for 2 ms of deliberate jitter.  BENCH_5
measured the damage at the paper fleet: p50 1.4 ms but p99 49 ms — pure
flush-policy stall, not compute.  Since ATLAS puts a prediction on every
task placement, that tail is scheduler stall time.

``AsyncBroker`` replaces the thread barrier with an event loop and a
*virtual-time* flush policy:

  policy="vt"       requests are admitted in logical arrival order; ``vnow``
                    (the admission counter) is the clock.  A flush fires when
                      - the queued rows reach ``depth``            (depth cap)
                      - the oldest queued request has seen
                        ``vt_window`` admissions since its own     (staleness
                        admission                                   cap)
                      - the loop drains the currently-ready burst  (idle
                        of arrivals                                 drain)
                    The first two are pure functions of the admission
                    sequence — no wall clock anywhere in the steady state, so
                    flush composition is keyed to logical arrival order and
                    batches stay fat exactly when arrivals are dense.  The
                    idle drain is what kills the tail: whatever accumulated
                    while the previous flush was scoring goes out as the next
                    batch immediately (continuous batching), instead of
                    waiting for a timer or a straggler.  A per-request
                    latency budget (``slo_ms``, or ``budget_ms`` on the
                    request) arms one safety-valve timer per batch that
                    force-flushes early when the oldest request is about to
                    blow its SLO — the only wall-clock path, and it only
                    fires when the policy already failed to flush in time.
  policy="barrier"  the PredictionBroker lock-step round rule (flush when
                    every registered live client has a request parked),
                    driven by the loop instead of a condition variable.
                    Rounds — and therefore every stats() counter — are a
                    pure function of each client's request sequence, which is
                    what lets ``fleet --executor async`` reproduce the
                    threaded barrier executor's SWEEP.json byte for byte.

Wire protocol (one msg dict per frame; ndarray-safe over tcp://):

  {"op": "predict",  "id": n, "kind": "map", "X": ndarray,
   "budget_ms": 5.0}                 -> {"id": n, "probs": ndarray}
  {"op": "submit",   "id": n, "groups": [(model, X), ...]}
                                     -> {"id": n, "probs": [ndarray, ...]}
                                        (inproc only: live model objects)
  {"op": "register", "n": 4}         (barrier membership, no reply)
  {"op": "done"}                     (client will not submit again)
  {"op": "telemetry", "frame": {...},
   "source": "cell", "n": 7}         (repro.obs frame -> collector +
                                      telemetry_sink; source/n optional:
                                      per-producer id + 1-based emit counter
                                      for gap/reconnect accounting)
  {"op": "telemetry", "source": "cell",
   "frames": [{"frame": {...}, "n": 7}, ...]}
                                     (batched form: TransportSink with
                                      flush_every > 1 ships one message
                                      per flush, per-frame n preserved)
  {"op": "stats"}                    -> deterministic counter dict
  {"op": "ping"}                     -> {"op": "pong"}

Row-level outputs are bit-identical to scalar scoring however requests are
batched (the ``score_groups`` invariant), so every policy serves the same
floats — the policies only move *when* a batch closes.
"""

from __future__ import annotations

import asyncio
import itertools
import threading
import time

import numpy as np

from repro.online.broker import score_groups
from repro.online.transport import (CommClosedError, SyncComm, connect,
                                    listen)

_SERVE_SEQ = itertools.count()


class _Req:
    """One admitted request: where to reply + its span of the next flush."""

    __slots__ = ("comm", "req_id", "groups", "rows", "vadmit", "deadline")

    def __init__(self, comm, req_id, groups, rows, vadmit, deadline):
        self.comm = comm
        self.req_id = req_id
        self.groups = groups
        self.rows = rows
        self.vadmit = vadmit
        self.deadline = deadline


class AsyncBroker:
    """Event-loop batching server for prediction traffic.

    ``models`` maps kind names ("map"/"reduce") to scoring models for the
    named-model ``predict`` op (the only op that works across tcp://);
    in-process clients may instead ship live model objects via ``submit``.
    The loop runs on a dedicated daemon thread (``start``/``stop``);
    ``serve`` binds any number of transport addresses onto it."""

    def __init__(self, models: dict | None = None, *, impl: str = "numpy",
                 policy: str = "vt", depth: int = 2048,
                 vt_window: int | None = None, slo_ms: float | None = None,
                 slo_margin: float = 0.5, max_queue_rows: int = 65536,
                 serializer: str = "auto"):
        if policy not in ("vt", "barrier"):
            raise ValueError(f"unknown flush policy {policy!r}")
        self.models = dict(models or {})
        self.impl = impl
        self.policy = policy
        self.depth = int(depth)
        self.vt_window = vt_window
        self.slo_ms = slo_ms
        self.slo_margin = float(slo_margin)
        self.max_queue_rows = int(max_queue_rows)
        self.serializer = serializer
        # optional collaborators
        self.obs = None                  # repro.obs.BrokerObserver
        self.telemetry_sink = None       # repro.obs Sink for telemetry frames
        self.collector = None            # repro.obs.TelemetryCollector
        # per-source telemetry wire accounting (reporting only)
        self._telemetry_sources: dict[str, dict] = {}
        # loop state (loop-confined once started)
        self.loop: asyncio.AbstractEventLoop | None = None
        self._thread: threading.Thread | None = None
        self._listeners: list = []
        self._queue: list[_Req] = []
        self._queued_rows = 0
        self._clients = 0
        self._vnow = 0
        self._epoch = 0
        self._slo_handle: asyncio.TimerHandle | None = None
        self._slo_at = float("inf")
        self._drain = None               # asyncio.Event, lazily on the loop
        # deterministic accounting (mirrors PredictionBroker.stats())
        self.n_flushes = 0
        self.n_dispatches = 0
        self.n_rows = 0
        self.n_requests = 0
        self.max_flush_rows = 0
        # cause counters (reporting only — depend on arrival timing)
        self.n_depth_flushes = 0
        self.n_vt_flushes = 0
        self.n_idle_flushes = 0
        self.n_deadline_flushes = 0
        self.n_backpressure_waits = 0
        self.n_telemetry_frames = 0

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "AsyncBroker":
        """Spin up the serving loop on its own daemon thread."""
        if self._thread is not None:
            return self
        ready = threading.Event()

        def run():
            self.loop = asyncio.new_event_loop()
            asyncio.set_event_loop(self.loop)
            self._drain = asyncio.Event()
            ready.set()
            self.loop.run_forever()
            # unwind whatever the stop() cancellation left behind
            pending = asyncio.all_tasks(self.loop)
            for t in pending:
                t.cancel()
            if pending:
                self.loop.run_until_complete(
                    asyncio.gather(*pending, return_exceptions=True))
            self.loop.close()

        self._thread = threading.Thread(target=run, daemon=True,
                                        name="async-broker")
        self._thread.start()
        ready.wait()
        return self

    def serve(self, address: str = "", **kw) -> str:
        """Bind a listener; returns the bound address (``tcp://…:0`` resolves
        its ephemeral port, no address picks a fresh inproc name)."""
        if not address:
            address = f"inproc://broker-{next(_SERVE_SEQ)}"
        kw.setdefault("serializer", self.serializer)
        lst = asyncio.run_coroutine_threadsafe(
            listen(address, self._handle, **kw), self.loop).result(30)
        self._listeners.append(lst)
        return lst.address

    def stop(self):
        if self._thread is None:
            return

        async def shutdown():
            for lst in self._listeners:
                await lst.stop()
            self._listeners.clear()
            if self._queue:              # never strand a parked client
                self._flush("idle")

        asyncio.run_coroutine_threadsafe(shutdown(), self.loop).result(30)
        self.loop.call_soon_threadsafe(self.loop.stop)
        self._thread.join(timeout=30)
        self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *a):
        self.stop()
        return False

    # ------------------------------------------------------------ membership
    def add_clients(self, n: int = 1):
        """Barrier-round membership (thread-safe; PredictionBroker API)."""
        if self.loop is not None and self._thread is not None:
            self.loop.call_soon_threadsafe(self._add_clients, n)
        else:
            self._add_clients(n)

    def _add_clients(self, n: int):
        self._clients += n

    def _client_done(self):
        self._clients -= 1
        if self.policy == "barrier" and self._queue \
                and len(self._queue) >= max(self._clients, 1):
            self._flush("round")

    # ------------------------------------------------------------ serving
    async def _handle(self, comm):
        try:
            while True:
                try:
                    msg = await comm.recv()
                except CommClosedError:
                    return
                op = msg.get("op")
                if op == "predict" or op == "submit":
                    await self._admit(comm, msg, op)
                elif op == "done":
                    self._client_done()
                elif op == "register":
                    self._add_clients(int(msg.get("n", 1)))
                elif op == "telemetry":
                    self._route_telemetry(msg)
                elif op == "stats":
                    await comm.send(self.stats())
                elif op == "ping":
                    await comm.send({"op": "pong"})
                else:
                    await comm.send({"id": msg.get("id"),
                                     "error": f"unknown op {op!r}"})
        finally:
            if not comm.closed:
                await comm.close()

    async def _admit(self, comm, msg, op):
        if op == "predict":
            model = self.models.get(msg.get("kind"))
            if model is None:
                await comm.send({"id": msg.get("id"),
                                 "error": f"unknown kind {msg.get('kind')!r}"})
                return
            groups = [(model, msg["X"])]
        else:
            groups = msg["groups"]
        rows = sum(np.asarray(X).shape[0] for _, X in groups)
        # bounded-queue admission control: a full queue parks THIS comm's
        # read loop until a flush drains — over tcp the stall propagates to
        # the client through the kernel socket buffer (backpressure, not
        # load shedding: every admitted request is eventually served)
        if self.policy == "vt":
            while self._queued_rows >= self.max_queue_rows:
                self.n_backpressure_waits += 1
                self._drain.clear()
                await self._drain.wait()
        self.n_requests += 1
        budget = msg.get("budget_ms", self.slo_ms)
        deadline = (time.perf_counter() + budget * 1e-3 * self.slo_margin
                    if budget else None)
        self._vnow += 1
        req = _Req(comm, msg.get("id"), groups, rows, self._vnow, deadline)
        first = not self._queue
        self._queue.append(req)
        self._queued_rows += rows
        if self.policy == "barrier":
            if len(self._queue) >= max(self._clients, 1):
                self._flush("round")
            return
        # ---- virtual-time policy ----
        if self._queued_rows >= self.depth:
            self.n_depth_flushes += 1
            self._flush("depth")
            return
        if self.vt_window is not None \
                and self._vnow - self._queue[0].vadmit >= self.vt_window:
            self.n_vt_flushes += 1
            self._flush("vt")
            return
        if first:
            # idle drain: runs after the callbacks already ready this loop
            # iteration, so one dense burst of arrivals lands in one batch
            self.loop.call_soon(self._idle_flush, self._epoch)
        if deadline is not None and deadline < self._slo_at:
            self._arm_slo(deadline)

    # ------------------------------------------------------------ flush paths
    def _idle_flush(self, epoch: int):
        if epoch == self._epoch and self._queue:
            self.n_idle_flushes += 1
            self._flush("idle")

    def _arm_slo(self, deadline: float):
        if self._slo_handle is not None:
            self._slo_handle.cancel()
        self._slo_at = deadline
        delay = max(deadline - time.perf_counter(), 0.0)
        self._slo_handle = self.loop.call_later(
            delay, self._slo_flush, self._epoch)

    def _slo_flush(self, epoch: int):
        self._slo_handle = None
        self._slo_at = float("inf")
        if epoch == self._epoch and self._queue:
            self.n_deadline_flushes += 1
            self._flush("slo")

    def _flush(self, cause: str):
        batch, self._queue = self._queue, []
        rows, self._queued_rows = self._queued_rows, 0
        self._epoch += 1
        if self._slo_handle is not None:
            self._slo_handle.cancel()
            self._slo_handle = None
            self._slo_at = float("inf")
        self._drain.set()
        flat = [g for req in batch for g in req.groups]
        t0 = time.perf_counter()
        try:
            outs, n = score_groups(flat, impl=self.impl)
        except Exception as e:
            for req in batch:
                self._reply(req, {"id": req.req_id, "error": repr(e)})
            return
        self.n_flushes += 1
        self.n_dispatches += n
        self.n_rows += rows
        self.max_flush_rows = max(self.max_flush_rows, rows)
        if self.obs is not None:
            self.obs.record_flush(rows, len(batch), n,
                                  time.perf_counter() - t0)
        at = 0
        for req in batch:
            span = outs[at:at + len(req.groups)]
            at += len(req.groups)
            self._reply(req, {"id": req.req_id, "probs": span})

    def _reply(self, req: _Req, msg: dict):
        if req.comm.closed:
            return
        task = asyncio.ensure_future(req.comm.send(msg))
        task.add_done_callback(_swallow_closed)

    # ------------------------------------------------------------ telemetry
    def _route_telemetry(self, msg: dict):
        """Fan telemetry frames to the registered consumers.

        One message carries a single ``frame`` or a batched ``frames`` list
        (each entry ``{"frame": …, "n": …}`` — ``TransportSink`` batches
        like ``NDJSONSink`` does).  Runs on the loop thread inside the
        client's handler coroutine, so a slow ``collector.ingest`` parks
        exactly that producer's channel — backpressure reaches the emitting
        ``TransportSink`` through the transport's bounded buffers instead of
        growing a queue here.  The time spent is accounted per source
        (``ingest_s``) so a wedged collector is visible in
        ``telemetry_stats()``."""
        entries = msg.get("frames")
        if entries is None:
            entries = ({"frame": msg["frame"], "n": msg.get("n")},)
        source = msg.get("source", "default")
        st = self._telemetry_sources.get(source)
        if st is None:
            st = self._telemetry_sources[source] = {
                "frames": 0, "last_n": 0, "gaps": 0, "reconnects": 0,
                "ingest_s": 0.0}
        for entry in entries:
            self.n_telemetry_frames += 1
            st["frames"] += 1
            n = entry.get("n")
            if n is not None:
                if n <= st["last_n"]:
                    st["reconnects"] += 1
                elif n > st["last_n"] + 1:
                    st["gaps"] += n - st["last_n"] - 1
                st["last_n"] = n
            if self.collector is not None:
                t0 = time.perf_counter()
                self.collector.ingest(entry["frame"], source=source, n=n)
                st["ingest_s"] += time.perf_counter() - t0
            if self.telemetry_sink is not None:
                self.telemetry_sink.emit(entry["frame"])

    def telemetry_stats(self) -> dict:
        """Per-source telemetry wire accounting.  Reporting only — values
        depend on arrival order and wall clock, so this stays out of the
        deterministic ``stats()`` dict."""
        return {"frames": self.n_telemetry_frames,
                "sources": {k: {**v, "ingest_s": round(v["ingest_s"], 6)}
                            for k, v in
                            sorted(self._telemetry_sources.items())}}

    # ------------------------------------------------------------ accounting
    def stats(self) -> dict:
        """Deterministic counters, same keys/semantics as
        ``PredictionBroker.stats()`` (cause counters stay off — they depend
        on arrival timing, not on the request streams)."""
        return {"flushes": self.n_flushes, "dispatches": self.n_dispatches,
                "rows": self.n_rows, "requests": self.n_requests,
                "max_flush_rows": self.max_flush_rows,
                "policy": self.policy}


def _swallow_closed(task: asyncio.Task):
    """A reply raced a client disconnect: nothing to do, nobody to tell."""
    if not task.cancelled():
        exc = task.exception()
        if exc is not None and not isinstance(exc, CommClosedError):
            raise exc


class BrokerClient:
    """Synchronous client facade with the ``PredictionBroker`` surface
    (``submit`` / ``done``), so a ``BrokerPredictor`` can serve a fleet cell
    through an ``AsyncBroker`` unchanged.  One outstanding request per client
    (the predictor blocks on each flush), so replies need no demux."""

    def __init__(self, address: str, loop: asyncio.AbstractEventLoop,
                 **connect_kw):
        self.address = address
        self._comm = SyncComm.connect(address, loop, **connect_kw)
        self._seq = 0
        self._done_sent = False

    def submit(self, groups) -> list:
        if not groups:
            return []
        self._seq += 1
        self._comm.send({"op": "submit", "id": self._seq, "groups": groups})
        reply = self._comm.recv()
        if reply.get("error") is not None:
            raise RuntimeError(f"broker error: {reply['error']}")
        return list(reply["probs"])

    def predict(self, kind: str, X, budget_ms: float | None = None):
        """Named-model scoring (the op that works across tcp://)."""
        self._seq += 1
        msg = {"op": "predict", "id": self._seq, "kind": kind, "X": X}
        if budget_ms is not None:
            msg["budget_ms"] = budget_ms
        self._comm.send(msg)
        reply = self._comm.recv()
        if reply.get("error") is not None:
            raise RuntimeError(f"broker error: {reply['error']}")
        (probs,) = reply["probs"]
        return probs

    def register(self, n: int = 1):
        self._comm.send({"op": "register", "n": n})

    def done(self):
        if not self._done_sent:
            self._done_sent = True
            self._comm.send({"op": "done"})

    def close(self):
        self._comm.close()
