"""Batched prediction broker — the serving hot path of the ATLAS predictors.

Two layers, composable:

* ``BrokerPredictor`` (drop-in ``TaskPredictor``): batches *within* a scheduler
  tick.  ``begin_tick`` snapshots the schedulable set; the first request of the
  tick primes one vectorised flush over (pending ∪ penalty-box) tasks x
  free-slot nodes, and every later ``p_success`` / ``p_success_nodes`` in the
  tick is served from an exact-feature memo.  Misses (state moved under the
  tick — e.g. a launch consumed a slot) are flushed as their own small batch.

* ``PredictionBroker``: batches *across* clients.  Fleet ATLAS cells run
  concurrently as broker clients; a request parks until every registered
  client has one queued (a lock-step round), then the whole round is scored as
  ONE fused pass over the stacked forests (``ml.forest.forest_predict_grouped``)
  and distributed.  Rounds are a pure function of each client's request
  sequence — no timers — so flush/dispatch counts are deterministic and a
  brokered sweep reproduces the serial sweep byte-for-byte.

Exactness: probabilities must not depend on how requests are batched, or
decisions would drift between executors.  Per-row forest arithmetic is
batch-independent by construction (fixed-order tree mean — see
``ml.forest._mean_over_trees``), and the scalar path
(``TaskPredictor.predict_batch``) pins forest-family scoring to the same
numpy mirror at every batch size, so memo hits, primed rows, fused flushes
and scalar calls all produce bit-identical floats for the forest family
(Tree / CTree / R.F.) on any fleet size.  Other algos score unfused via their
own ``predict_proba``.

``impl`` selects the flush backend: ``"numpy"`` (default — strict parity via
the small-batch fast path), ``"auto"`` (size-dispatched: big flushes route to
the XLA/Pallas forest kernel, trading last-ulp parity for MXU throughput), or
an explicit kernel impl (``"xla"`` / ``"pallas"`` / ``"interpret"``).
"""

from __future__ import annotations

import threading

import numpy as np

from repro.cluster.telemetry import attempt_features
from repro.core.predictor import TaskPredictor, forest_family_params
from repro.ml.forest import SMALL_BATCH, forest_predict, forest_predict_grouped


def score_groups(groups, impl: str = "numpy") -> tuple[list, int]:
    """Score ``[(model, X)]`` -> ``([probs], n_dispatches)``.

    Requests against the same forest model are coalesced into one row block
    (then sliced back apart — per-row arithmetic, so bit-identical to scoring
    each request alone), and distinct forest models fuse into one pass per
    forest shape.  Other models (and, under ``impl="auto"``, oversized row
    blocks bound for the XLA/Pallas kernel) each cost one dispatch."""
    outs: list = [None] * len(groups)
    arrays: list = [None] * len(groups)
    merged: dict[int, list[int]] = {}         # id(params) -> group indices
    params_of: dict[int, object] = {}
    n = 0
    for i, (model, X) in enumerate(groups):
        X = np.asarray(X, np.float32)
        arrays[i] = X
        if X.shape[0] == 0:
            outs[i] = np.zeros(0, np.float32)
            continue
        params = forest_family_params(model)
        if params is None:
            outs[i] = np.asarray(model.predict_proba(X), np.float32)
            n += 1
            continue
        merged.setdefault(id(params), []).append(i)
        params_of[id(params)] = params

    def scatter(idxs, block):
        o = 0
        for i in idxs:
            b = arrays[i].shape[0]
            outs[i] = block[o:o + b]
            o += b

    fuse: list[tuple[list, object, np.ndarray]] = []
    for pid, idxs in merged.items():
        X = (arrays[idxs[0]] if len(idxs) == 1 else
             np.concatenate([arrays[i] for i in idxs]))
        params = params_of[pid]
        if impl == "numpy" or (impl == "auto" and X.shape[0] <= SMALL_BATCH):
            fuse.append((idxs, params, X))
        else:
            kernel_impl = None if impl == "auto" else impl
            n += 1
            scatter(idxs, np.clip(
                forest_predict(params, X, impl=kernel_impl),
                0.0, 1.0).astype(np.float32))
    if fuse:
        raw, passes = forest_predict_grouped([(p, X) for _, p, X in fuse])
        n += passes
        for (idxs, _, _), scores in zip(fuse, raw):
            # same clip the forest models apply in predict_proba
            scatter(idxs, np.clip(scores, 0.0, 1.0).astype(np.float32))
    return outs, n


class _Pending:
    __slots__ = ("groups", "outs", "error", "done")

    def __init__(self, groups):
        self.groups = groups
        self.outs = None
        self.error = None
        self.done = False


class PredictionBroker:
    """Cross-client batching server with a deterministic barrier flush.

    Clients are registered up front (``add_clients``) so round membership
    never depends on thread start-up timing; each client calls ``done()``
    (in a ``finally``) when its run completes.  ``submit`` blocks until the
    round containing the request is flushed."""

    def __init__(self, impl: str = "numpy"):
        self.impl = impl
        self._cv = threading.Condition()
        self._queue: list[_Pending] = []
        self._clients = 0
        # accounting
        self.n_flushes = 0
        self.n_dispatches = 0
        self.n_rows = 0
        self.n_requests = 0
        self.max_flush_rows = 0

    # ------------------------------------------------------------ lifecycle
    def add_clients(self, n: int = 1):
        with self._cv:
            self._clients += n

    def done(self):
        """A client finished: it will never submit again, so a waiting round
        must not hold the barrier open for it."""
        with self._cv:
            self._clients -= 1
            if self._queue and len(self._queue) >= max(self._clients, 1):
                self._flush_locked()

    # ------------------------------------------------------------ serving
    def submit(self, groups) -> list:
        """Block until this request's round flushes; returns one probability
        array per (model, X) group."""
        if not groups:
            return []
        p = _Pending(groups)
        with self._cv:
            self.n_requests += 1
            self._queue.append(p)
            if len(self._queue) >= max(self._clients, 1):
                self._flush_locked()
            while not p.done:
                self._cv.wait()
        if p.error is not None:
            raise p.error
        return p.outs

    def _flush_locked(self):
        batch = self._queue
        self._queue = []
        flat = [g for p in batch for g in p.groups]
        try:
            outs, n = score_groups(flat, impl=self.impl)
            rows = sum(np.asarray(X).shape[0] for _, X in flat)
            self.n_flushes += 1
            self.n_dispatches += n
            self.n_rows += rows
            self.max_flush_rows = max(self.max_flush_rows, rows)
            at = 0
            for p in batch:
                p.outs = outs[at:at + len(p.groups)]
                at += len(p.groups)
                p.done = True
        except Exception as e:  # surface in every waiting client
            for p in batch:
                p.error = e
                p.done = True
        finally:
            self._cv.notify_all()

    def stats(self) -> dict:
        return {"flushes": self.n_flushes, "dispatches": self.n_dispatches,
                "rows": self.n_rows, "requests": self.n_requests,
                "max_flush_rows": self.max_flush_rows}


class BrokerPredictor(TaskPredictor):
    """Drop-in ``TaskPredictor`` that serves probabilities through batched
    flushes (tick-primed memo + optional shared cross-cell broker) while
    producing bit-identical decisions to the per-decision path."""

    def __init__(self, *, broker: PredictionBroker | None = None,
                 impl: str = "numpy", max_prime_rows: int = 4096, **kw):
        super().__init__(**kw)
        self.broker = broker
        self.impl = impl
        self.max_prime_rows = max_prime_rows
        self._memo: dict = {}
        self._primed = True          # no tick snapshot yet
        self._tick_sim = None
        self._tick_keys: tuple = ()
        # demand-side accounting: what the per-decision path would have cost.
        # These depend only on the decision sequence, so they are identical
        # across executors (unlike dispatch counts, which the broker shrinks).
        self.n_demand_calls = 0
        self.n_demand_rows = 0
        self.n_memo_hits = 0

    # ------------------------------------------------------------ tick hooks
    def begin_tick(self, sim, extra_keys=()):
        self._memo.clear()
        self._primed = False
        self._tick_sim = sim
        self._tick_keys = tuple(dict.fromkeys(
            tuple(sim.pending) + tuple(extra_keys)))

    def _models_changed(self):
        # retrain/promote swaps the models: memoised probabilities are stale
        memo = getattr(self, "_memo", None)
        if memo is not None:
            memo.clear()

    # ------------------------------------------------------------ flushing
    def _flush(self, groups) -> list:
        if self.broker is not None:
            return self.broker.submit(groups)
        outs, n = score_groups(groups, impl=self.impl)
        self.n_dispatches += n
        self.n_rows_scored += sum(np.asarray(X).shape[0] for _, X in groups)
        return outs

    def _memoize(self, kind: str, X: np.ndarray, probs: np.ndarray):
        for row, p in zip(X, probs):
            self._memo[(kind, row.tobytes())] = np.float32(p)

    def _prime(self, sim, extra_rows):
        """One batched flush covering the whole schedulable cross product
        (pending ∪ penalty-box tasks x nodes with a free slot of the right
        kind) plus the rows of the triggering request."""
        self._primed = True
        per_kind: dict[str, list] = {}
        for kind, x in extra_rows:
            per_kind.setdefault(kind, []).append(x)
        budget = self.max_prime_rows
        for key in self._tick_keys:
            if budget <= 0:
                break
            task = sim._task_by_key(key)
            if task is None or task.status != "pending":
                continue
            if self.model_for_kind(task.kind) is None:
                continue
            for node in sim.nodes:
                free = (node.free_map_slots() if task.kind == "map"
                        else node.free_reduce_slots())
                if free <= 0:
                    continue
                per_kind.setdefault(task.kind, []).append(
                    attempt_features(sim, task, node, False))
                budget -= 1
        kinds = [k for k, rows in per_kind.items()
                 if rows and self.model_for_kind(k) is not None]
        if not kinds:
            return
        groups = [(self.model_for_kind(k), np.stack(per_kind[k]))
                  for k in kinds]
        outs = self._flush(groups)
        for k, (_, X), probs in zip(kinds, groups, outs):
            self._memoize(k, X, probs)

    # ------------------------------------------------------------ inference
    def p_success(self, sim, task, node, speculative=False) -> float:
        model = self.model_for_kind(task.kind)
        if model is None:
            return 1.0
        self.n_demand_calls += 1
        self.n_demand_rows += 1
        x = attempt_features(sim, task, node, speculative)
        if not self._primed:
            self._prime(sim, [(task.kind, x)])
        p = self._memo.get((task.kind, x.tobytes()))
        if p is None:
            (out,) = self._flush([(model, x[None])])
            self._memoize(task.kind, x[None], out)
            p = out[0]
        else:
            self.n_memo_hits += 1
        return float(p)

    def p_success_nodes(self, sim, task, nodes, speculative=False) -> np.ndarray:
        model = self.model_for_kind(task.kind)
        if model is None or not len(nodes):
            return np.ones(len(nodes), np.float32)
        self.n_demand_calls += 1
        self.n_demand_rows += len(nodes)
        X = np.stack([attempt_features(sim, task, n, speculative)
                      for n in nodes])
        if not self._primed:
            self._prime(sim, [(task.kind, x) for x in X])
        out = np.empty(len(nodes), np.float32)
        missing = []
        for i, row in enumerate(X):
            p = self._memo.get((task.kind, row.tobytes()))
            if p is None:
                missing.append(i)
            else:
                self.n_memo_hits += 1
                out[i] = p
        if missing:
            (scored,) = self._flush([(model, X[missing])])
            self._memoize(task.kind, X[missing], scored)
            out[missing] = scored
        return out
