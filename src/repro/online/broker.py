"""Batched prediction broker — the serving hot path of the ATLAS predictors.

Two layers, composable:

* ``BrokerPredictor`` (drop-in ``TaskPredictor``): batches *within* a scheduler
  tick.  ``begin_tick`` snapshots the schedulable set; the first request of the
  tick primes one vectorised flush over (pending ∪ penalty-box) tasks x
  free-slot nodes, and every later ``p_success`` / ``p_success_nodes`` in the
  tick is served from an exact-feature memo.  Misses (state moved under the
  tick — e.g. a launch consumed a slot) are flushed as their own small batch.
  Feature rows are written into preallocated columnar buffers in place —
  the per-request plumbing is an (offset, length) pair, not a fresh array.

* ``PredictionBroker``: batches *across* clients.  Requests append their rows
  into per-model columnar buffers under the broker lock; a flush scores each
  model's filled prefix as ONE slice of ONE block-diagonal pass
  (``ml.forest.forest_predict_grouped``) and scatters spans back.  Two flush
  policies:

    policy="barrier"  (default) a request parks until every registered client
                      has one queued (a lock-step round).  Rounds are a pure
                      function of each client's request sequence — no timers —
                      so flush/dispatch counts are deterministic and a
                      brokered sweep reproduces the serial sweep byte-for-byte.
                      When a single client remains (skewed wave: one long cell
                      running solo), the round would contain exactly its own
                      request, so ``submit`` scores it inline and skips the
                      park/notify machinery entirely (identical accounting).
    policy="depth"    queue-depth flush with bounded delay: flush as soon as
                      ``depth`` rows are queued, or ``max_delay`` seconds after
                      the first request of a batch arrived — whichever comes
                      first.  Tail batches stay fat on skewed waves at the
                      price of wall-clock timers (row-level outputs are still
                      bit-identical; flush *counts* become timing-dependent,
                      so the deterministic sweeps keep the barrier).

Exactness: probabilities must not depend on how requests are batched, or
decisions would drift between executors.  Per-row forest arithmetic is
batch-independent by construction (fixed-order tree mean + block-diagonal
segmentation — see ``ml.forest``), and the scalar path
(``TaskPredictor.predict_batch``) pins forest-family scoring to the same
numpy mirror at every batch size, so memo hits, primed rows, fused flushes
and scalar calls all produce bit-identical floats for the forest family
(Tree / CTree / R.F.) on any fleet size.  Other algos score unfused via their
own ``predict_proba``.

``impl`` selects the flush backend: ``"numpy"`` (default — strict parity via
the block-diagonal numpy pass), ``"auto"`` (size-dispatched: fat flushes route
to the grouped XLA/Pallas forest kernel, trading last-ulp parity for MXU
throughput), or an explicit kernel impl (``"xla"`` / ``"pallas"`` /
``"interpret"``)."""

from __future__ import annotations

import threading
import time

import numpy as np

from repro.cluster.telemetry import N_FEATURES, attempt_features
from repro.core.predictor import TaskPredictor, forest_family_params
from repro.ml.forest import forest_predict_grouped

_EMPTY = np.zeros(0, np.float32)

# ---------------------------------------------------------------------------
# Vectorised feature hashing (the memo key).
#
# The memo used to key on row.tobytes() — a 88-byte allocation + copy per
# probe, per row.  Instead each float32 row is viewed as raw uint32 words and
# folded with TWO independent multiply-sum hashes over deterministic odd
# uint64 constants, vectorised over the whole flush.  Keys are (kind, h1, h2):
# 128 hash bits, so a collision (~2^-128 per pair) is effectively impossible
# and the forest bit-exactness guarantee still holds in practice.  Hashing is
# bit-pattern-based, exactly like tobytes(): equal keys <=> equal rows.
# ---------------------------------------------------------------------------

_HASH_CONSTS: dict[int, tuple[np.ndarray, np.ndarray]] = {}


def _hash_consts(width: int) -> tuple[np.ndarray, np.ndarray]:
    c = _HASH_CONSTS.get(width)
    if c is None:
        rng = np.random.default_rng(0xA71A5 + width)   # fixed, per width
        a = rng.integers(1, 2 ** 63, size=(2, width), dtype=np.uint64)
        a = a * np.uint64(2) + np.uint64(1)            # odd => full period
        _HASH_CONSTS[width] = c = (a[0], a[1])
    return c


def feature_hashes(X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-row (h1, h2) uint64 hash pair for a float32 feature matrix —
    one vectorised multiply-sum per hash, no per-row allocation."""
    X = np.ascontiguousarray(X, np.float32)
    u = X.view(np.uint32).astype(np.uint64)
    a1, a2 = _hash_consts(X.shape[1])
    return (u * a1).sum(axis=1), (u * a2).sum(axis=1)


class _Column:
    """Columnar row buffer for one model: a preallocated float32 feature array
    appended in place; a flush reads the filled prefix as one slice."""

    __slots__ = ("params", "buf", "fill")

    def __init__(self, params, width: int, cap: int = 256):
        self.params = params
        self.buf = np.empty((cap, width), np.float32)
        self.fill = 0

    def append(self, X: np.ndarray) -> int:
        """Copy X into the buffer; returns the start offset of the span."""
        b = X.shape[0]
        need = self.fill + b
        if need > self.buf.shape[0]:
            new = np.empty((max(need, 2 * self.buf.shape[0]),
                            self.buf.shape[1]), np.float32)
            new[:self.fill] = self.buf[:self.fill]
            self.buf = new
        self.buf[self.fill:need] = X
        start, self.fill = self.fill, need
        return start

    def view(self) -> np.ndarray:
        return self.buf[:self.fill]

    def reset(self):
        self.fill = 0


def score_groups(groups, impl: str = "numpy") -> tuple[list, int]:
    """Score ``[(model, X)]`` -> ``([probs], n_dispatches)``.

    Forest-family requests are appended into per-model columnar buffers and
    scored as ONE block-diagonal pass (then sliced back apart — per-row
    arithmetic, so bit-identical to scoring each request alone).  Other models
    each cost one dispatch via their own ``predict_proba``.

    Coalescing happens HERE even though ``forest_predict_grouped`` also
    groups by model: handing it one contiguous column per model costs one
    extra (vectorised, ~µs) row copy but lets the predict_proba clip run once
    per model *block* — clipping per request would put thousands of small
    ``np.clip`` calls right back on the saturated-flush floor this module
    exists to remove."""
    outs: list = [None] * len(groups)
    cols: dict[int, _Column] = {}
    order: list[_Column] = []
    spans: list = []                          # (group idx, column, start, stop)
    n = 0
    for i, (model, X) in enumerate(groups):
        X = np.asarray(X, np.float32)
        if X.shape[0] == 0:
            outs[i] = _EMPTY
            continue
        params = forest_family_params(model)
        if params is None:
            outs[i] = np.asarray(model.predict_proba(X), np.float32)
            n += 1
            continue
        col = cols.get(id(params))
        if col is None:
            col = cols[id(params)] = _Column(params, X.shape[1])
            order.append(col)
        start = col.append(X)
        spans.append((i, col, start, start + X.shape[0]))
    if order:
        raw, passes = forest_predict_grouped(
            [(c.params, c.view()) for c in order], impl=impl)
        n += passes
        # same clip the forest models apply in predict_proba (elementwise,
        # so clipping the block then slicing == slicing then clipping)
        blocks = {id(c): np.clip(r, 0.0, 1.0).astype(np.float32)
                  for c, r in zip(order, raw)}
        for i, col, s, e in spans:
            outs[i] = blocks[id(col)][s:e]
    return outs, n


class _Pending:
    __slots__ = ("groups", "outs", "error", "done")

    def __init__(self, groups):
        self.groups = groups
        self.outs = None
        self.error = None
        self.done = False


class PredictionBroker:
    """Cross-client batching server with barrier or queue-depth flushes.

    Clients are registered up front (``add_clients``) so barrier-round
    membership never depends on thread start-up timing; each client calls
    ``done()`` (in a ``finally``) when its run completes.  ``submit`` blocks
    until the flush containing the request completes."""

    def __init__(self, impl: str = "numpy", policy: str = "barrier",
                 depth: int = 256, max_delay: float = 0.002):
        if policy not in ("barrier", "depth"):
            raise ValueError(f"unknown flush policy {policy!r}")
        self.impl = impl
        self.policy = policy
        self.depth = depth
        self.max_delay = max_delay
        self._cv = threading.Condition()
        self._queue: list[_Pending] = []
        self._queued_rows = 0
        self._clients = 0
        self._timer: threading.Timer | None = None
        self._timer_gen = 0
        # optional repro.obs.BrokerObserver: per-flush rows/requests/latency
        self.obs = None
        # accounting
        self.n_flushes = 0
        self.n_dispatches = 0
        self.n_rows = 0
        self.n_requests = 0
        self.max_flush_rows = 0
        self.n_solo_flushes = 0
        self.n_deadline_flushes = 0

    # ------------------------------------------------------------ lifecycle
    def add_clients(self, n: int = 1):
        with self._cv:
            self._clients += n

    def done(self):
        """A client finished: it will never submit again, so a waiting round
        must not hold the barrier open for it."""
        with self._cv:
            self._clients -= 1
            if self.policy == "barrier" and self._queue \
                    and len(self._queue) >= max(self._clients, 1):
                self._flush_locked()

    # ------------------------------------------------------------ serving
    def submit(self, groups) -> list:
        """Block until this request's flush completes; returns one probability
        array per (model, X) group."""
        if not groups:
            return []
        with self._cv:
            self.n_requests += 1
            if self.policy == "barrier" and self._clients <= 1 \
                    and not self._queue:
                # solo client: a barrier round would contain exactly this one
                # request — score it inline (identical flush accounting)
                # instead of paying the park/notify machinery per request
                self.n_solo_flushes += 1
                return self._score_direct(groups)
            p = _Pending(groups)
            self._queue.append(p)
            self._queued_rows += sum(np.asarray(X).shape[0]
                                     for _, X in groups)
            if self._should_flush():
                self._flush_locked()
            elif self.policy == "depth" and self._timer is None:
                self._arm_timer()
            while not p.done:
                self._cv.wait()
        if p.error is not None:
            raise p.error
        return p.outs

    def _should_flush(self) -> bool:
        if self.policy == "barrier":
            return len(self._queue) >= max(self._clients, 1)
        return self._queued_rows >= self.depth

    # ------------------------------------------------------------ depth timer
    def _arm_timer(self):
        self._timer_gen += 1
        gen = self._timer_gen
        t = threading.Timer(self.max_delay, self._deadline_flush, args=(gen,))
        t.daemon = True
        self._timer = t
        t.start()

    def _deadline_flush(self, gen: int):
        with self._cv:
            if gen != self._timer_gen:
                return                        # a depth flush beat the clock
            self._timer = None
            if self._queue:
                self.n_deadline_flushes += 1
                self._flush_locked()

    # ------------------------------------------------------------ flushing
    def _score_direct(self, groups) -> list:
        t0 = time.perf_counter()
        outs, n = score_groups(groups, impl=self.impl)
        rows = sum(np.asarray(X).shape[0] for _, X in groups)
        self.n_flushes += 1
        self.n_dispatches += n
        self.n_rows += rows
        self.max_flush_rows = max(self.max_flush_rows, rows)
        if self.obs is not None:
            self.obs.record_flush(rows, 1, n, time.perf_counter() - t0)
        return outs

    def _flush_locked(self):
        batch = self._queue
        self._queue = []
        self._queued_rows = 0
        self._timer_gen += 1                  # invalidate any pending timer
        self._timer = None
        flat = [g for p in batch for g in p.groups]
        try:
            t0 = time.perf_counter()
            outs, n = score_groups(flat, impl=self.impl)
            rows = sum(np.asarray(X).shape[0] for _, X in flat)
            self.n_flushes += 1
            self.n_dispatches += n
            self.n_rows += rows
            self.max_flush_rows = max(self.max_flush_rows, rows)
            if self.obs is not None:
                self.obs.record_flush(rows, len(batch), n,
                                      time.perf_counter() - t0)
            at = 0
            for p in batch:
                p.outs = outs[at:at + len(p.groups)]
                at += len(p.groups)
                p.done = True
        except Exception as e:  # surface in every waiting client
            for p in batch:
                p.error = e
                p.done = True
        finally:
            self._cv.notify_all()

    def stats(self) -> dict:
        # deterministic counters only: whether a given flush fired via the
        # solo bypass or a done()-triggered round (and whether a depth flush
        # beat its deadline timer) depends on thread interleaving, so the
        # cause counters (n_solo_flushes / n_deadline_flushes) stay off the
        # byte-stable SWEEP perf block and are read as attributes instead
        return {"flushes": self.n_flushes, "dispatches": self.n_dispatches,
                "rows": self.n_rows, "requests": self.n_requests,
                "max_flush_rows": self.max_flush_rows,
                "policy": self.policy}


class BrokerPredictor(TaskPredictor):
    """Drop-in ``TaskPredictor`` that serves probabilities through batched
    flushes (tick-primed memo + optional shared cross-cell broker) while
    producing bit-identical decisions to the per-decision path."""

    def __init__(self, *, broker=None, impl: str = "numpy",
                 max_prime_rows: int = 4096, memo_cap: int = 65536,
                 fallback_probe_every: int = 64, **kw):
        super().__init__(**kw)
        self.broker = broker
        self.impl = impl
        self.max_prime_rows = max_prime_rows
        # graceful degradation (paper behavior: when the failure predictor
        # is unavailable, schedule anyway — never fail the task).  A broker
        # that stays unreachable past the client's retry budget flips
        # ``degraded``; degraded flushes answer p=1.0 for every row, which
        # is exactly the untrained-model semantics: the ATLAS gate passes
        # and the base scheduler's proposed placement goes through
        # deterministically.  Every ``fallback_probe_every``-th degraded
        # flush retries the broker for real (a logical cadence, no wall
        # clock) and a success clears the degradation.
        self.fallback_probe_every = int(fallback_probe_every)
        self.degraded = False
        self._probe_countdown = 0
        self.n_fallbacks = 0
        self.n_fallback_rows = 0
        # exact-feature memo bound: the memo clears per tick in fleet runs,
        # but a serving-mode predictor (no ticks — e.g. behind the
        # AsyncBroker on an open-loop stream) would otherwise grow it without
        # limit.  Eviction is insertion-ordered (python dicts iterate oldest
        # first), far above any tick's prime size by default so deterministic
        # sweep accounting never changes; evicted rows simply re-score
        # bit-identically on their next miss.
        self.memo_cap = int(memo_cap)
        self._memo: dict = {}
        self._primed = True          # no tick snapshot yet
        self._tick_sim = None
        self._tick_keys: tuple = ()
        # columnar scratch: per-kind prime buffers + candidate-set buffer,
        # preallocated once and appended in place tick after tick
        self._prime_bufs: dict[str, np.ndarray] = {}
        self._cand_buf = np.empty((64, N_FEATURES), np.float32)
        # demand-side accounting: what the per-decision path would have cost.
        # These depend only on the decision sequence, so they are identical
        # across executors (unlike dispatch counts, which the broker shrinks).
        self.n_demand_calls = 0
        self.n_demand_rows = 0
        self.n_memo_hits = 0
        self.n_memo_misses = 0
        self.n_memo_evictions = 0

    def frame_stats(self) -> dict:
        # field order matters: NDJSON frame bytes must match the obs layer's
        # historical per-frame pred dict exactly (new keys append at the end)
        return {"dispatches": self.n_dispatches, "rows": self.n_rows_scored,
                "memo_hits": self.n_memo_hits,
                "memo_misses": self.n_memo_misses,
                "demand_rows": self.n_demand_rows,
                "memo_size": len(self._memo),
                "memo_evictions": self.n_memo_evictions,
                "fallbacks": self.n_fallbacks,
                "retries": getattr(self.broker, "n_retries", 0),
                "reconnects": getattr(self.broker, "n_reconnects", 0)}

    # ------------------------------------------------------------ tick hooks
    def begin_tick(self, sim, extra_keys=()):
        self._memo.clear()
        self._primed = False
        self._tick_sim = sim
        self._tick_keys = tuple(dict.fromkeys(
            tuple(sim.pending) + tuple(extra_keys)))

    def _models_changed(self):
        # retrain/promote swaps the models: memoised probabilities are stale
        memo = getattr(self, "_memo", None)
        if memo is not None:
            memo.clear()

    # ------------------------------------------------------------ flushing
    def _flush(self, groups) -> list:
        if self.broker is not None:
            return self._flush_brokered(groups)
        outs, n = score_groups(groups, impl=self.impl)
        self.n_dispatches += n
        self.n_rows_scored += sum(np.asarray(X).shape[0] for _, X in groups)
        return outs

    def _flush_brokered(self, groups) -> list:
        from repro.online.faults import PredictorUnavailableError
        if not self.degraded or self._probe_countdown <= 0:
            try:
                outs = self.broker.submit(groups)
                self.degraded = False
                return outs
            except PredictorUnavailableError:
                self.degraded = True
                self._probe_countdown = self.fallback_probe_every
        else:
            self._probe_countdown -= 1
        return self._fallback(groups)

    def _fallback(self, groups) -> list:
        """Degraded-mode answer: p=1.0 per row (schedule anyway).  Fallback
        rows do land in the tick memo, but the memo clears every
        ``begin_tick``, so stale optimism is bounded to one tick after the
        broker comes back."""
        self.n_fallbacks += 1
        outs = []
        for _, X in groups:
            rows = np.asarray(X).shape[0]
            self.n_fallback_rows += rows
            outs.append(np.ones(rows, np.float32))
        return outs

    def _memoize(self, kind: str, X: np.ndarray, probs: np.ndarray,
                 hashes=None):
        """Store per-row probabilities under vectorised (h1, h2) hash keys —
        one fused hash pass per flush instead of a tobytes() per row."""
        h1, h2 = feature_hashes(X) if hashes is None else hashes
        memo = self._memo
        for a, b, p in zip(h1.tolist(), h2.tolist(), probs):
            memo[(kind, a, b)] = np.float32(p)
        self._evict_memo()

    def _evict_memo(self):
        """Hold the memo at ``memo_cap`` entries, oldest insertions first."""
        memo = self._memo
        n_over = len(memo) - self.memo_cap
        if n_over > 0:
            it = iter(memo)
            for key in [next(it) for _ in range(n_over)]:
                del memo[key]
            self.n_memo_evictions += n_over

    def _prime_rows(self, kind: str, fill: int) -> tuple[np.ndarray, int]:
        """The kind's prime buffer with space for one more row at ``fill``."""
        buf = self._prime_bufs.get(kind)
        if buf is None:
            buf = self._prime_bufs[kind] = np.empty((256, N_FEATURES),
                                                    np.float32)
        if fill >= buf.shape[0]:
            new = np.empty((2 * buf.shape[0], N_FEATURES), np.float32)
            new[:fill] = buf[:fill]
            buf = self._prime_bufs[kind] = new
        return buf, fill

    def _prime(self, sim, extra_rows):
        """One batched flush covering the whole schedulable cross product
        (pending ∪ penalty-box tasks x nodes with a free slot of the right
        kind) plus the rows of the triggering request.  Rows append in place
        into preallocated per-kind columnar buffers."""
        self._primed = True
        fills: dict[str, int] = {}
        for kind, x in extra_rows:
            buf, fill = self._prime_rows(kind, fills.get(kind, 0))
            buf[fill] = x
            fills[kind] = fill + 1
        budget = self.max_prime_rows
        for key in self._tick_keys:
            if budget <= 0:
                break
            task = sim._task_by_key(key)
            if task is None or task.status != "pending":
                continue
            if self.model_for_kind(task.kind) is None:
                continue
            for node in sim.free_nodes(task.kind, liveness="any"):
                buf, fill = self._prime_rows(task.kind,
                                             fills.get(task.kind, 0))
                attempt_features(sim, task, node, False, out=buf[fill])
                fills[task.kind] = fill + 1
                budget -= 1
                if budget <= 0:
                    break
        kinds = [k for k, fill in fills.items()
                 if fill and self.model_for_kind(k) is not None]
        if not kinds:
            return
        groups = [(self.model_for_kind(k), self._prime_bufs[k][:fills[k]])
                  for k in kinds]
        outs = self._flush(groups)
        for k, (_, X), probs in zip(kinds, groups, outs):
            self._memoize(k, X, probs)

    # ------------------------------------------------------------ inference
    def p_success(self, sim, task, node, speculative=False) -> float:
        model = self.model_for_kind(task.kind)
        if model is None:
            return 1.0
        self.n_demand_calls += 1
        self.n_demand_rows += 1
        x = attempt_features(sim, task, node, speculative)
        if not self._primed:
            self._prime(sim, [(task.kind, x)])
        h1, h2 = feature_hashes(x[None])
        key = (task.kind, int(h1[0]), int(h2[0]))
        p = self._memo.get(key)
        if p is None:
            self.n_memo_misses += 1
            (out,) = self._flush([(model, x[None])])
            self._memo[key] = p = np.float32(out[0])
            self._evict_memo()
        else:
            self.n_memo_hits += 1
        return float(p)

    def p_success_nodes(self, sim, task, nodes, speculative=False) -> np.ndarray:
        model = self.model_for_kind(task.kind)
        if model is None or not len(nodes):
            return np.ones(len(nodes), np.float32)
        self.n_demand_calls += 1
        self.n_demand_rows += len(nodes)
        if len(nodes) > self._cand_buf.shape[0]:
            self._cand_buf = np.empty((2 * len(nodes), N_FEATURES),
                                      np.float32)
        X = self._cand_buf[:len(nodes)]
        for i, n in enumerate(nodes):
            attempt_features(sim, task, n, speculative, out=X[i])
        if not self._primed:
            self._prime(sim, [(task.kind, x) for x in X])
        h1, h2 = feature_hashes(X)           # one vectorised pass, all rows
        out = np.empty(len(nodes), np.float32)
        missing = []
        kind, memo = task.kind, self._memo
        for i in range(len(nodes)):
            p = memo.get((kind, int(h1[i]), int(h2[i])))
            if p is None:
                missing.append(i)
            else:
                self.n_memo_hits += 1
                out[i] = p
        if missing:
            self.n_memo_misses += len(missing)
            (scored,) = self._flush([(model, X[missing])])
            self._memoize(kind, X[missing], scored,
                          hashes=(h1[missing], h2[missing]))
            out[missing] = scored
        return out
