"""Load generator for the prediction broker: replay fleet decision streams.

Builds a decision stream (the launch-time feature rows a fleet cell actually
raised), trains the predictor on it, then serves the stream three ways:

  scalar     the per-decision path — one model dispatch per request
  broker     closed loop: N concurrent clients through one PredictionBroker —
             lock-step rounds fused into single passes; measures per-request
             latency percentiles and the dispatch reduction
  saturated  open loop: the stream arrives faster than flushes drain, so the
             queue depth fills every flush — the broker's peak batched
             throughput (this is the ≥10x-vs-scalar number)

Row-level outputs are compared bit-for-bit across all three modes
(``impl="numpy"``), so the bench doubles as a live parity check.

  python -m repro.online.bench [--rows 6000] [--clients 12] [--workload smoke]
      [--scenario bursty_tt] [--impl numpy|auto|xla|interpret] [--rate R]
      [--fleet-sizes 0,100] [--policy barrier|depth] [--depth N]
      [--max-delay S] [--out experiments] [--stamp-sweep [PATH]] [--smoke]

``--rate`` paces each client (requests/s of wall time, 0 = flat out).
``--fleet-sizes`` is the scale axis: each size replays a decision stream from
a fleet of that many nodes (0 = the paper's 13-slave fleet; candidate-set
requests grow with the fleet), and the per-size throughput/latency sections
land in the summary, ``BENCH_<pr>.json`` and — with ``--stamp-sweep`` —
``SWEEP.json``.  ``--policy depth`` serves the broker section through the
queue-depth flush policy with bounded delay instead of the deterministic
barrier.  Exit status is non-zero when the batched run shows no throughput or
parity breaks — ``make bench-smoke`` gates CI on this."""

from __future__ import annotations

import argparse
import json
import pathlib
import re
import sys
import threading
import time

import numpy as np

import repro
from repro.core.predictor import TaskPredictor
from repro.online.broker import PredictionBroker

# deterministic request-size mix mimicking the scheduler's demand: mostly
# single-proposal p_success rows, periodically a candidate-set p_success_nodes
# (whose size tracks the fleet: every free node is a candidate placement)
REQUEST_SIZES = (1, 1, 1, 2, 1, 1, 13, 1, 1, 4)


def request_sizes(fleet_size: int = 0) -> tuple:
    if not fleet_size:
        return REQUEST_SIZES
    cand = min(fleet_size, 256)
    return tuple(cand if s == 13 else s for s in REQUEST_SIZES)


# ---------------------------------------------------------------------------
# Stream construction
# ---------------------------------------------------------------------------

def build_stream(workload: str = "smoke", scenario: str = "bursty_tt",
                 seed: int = 0, min_rows: int = 2000, fleet_size: int = 0):
    """(predictor, [(kind, X_request)]) from one base-scheduler fleet cell.

    The trace's launch-time feature rows ARE the decision stream ATLAS would
    have scored; they are tiled to ``min_rows`` and cut into requests with the
    ``request_sizes(fleet_size)`` mix.  Falls back to a synthetic stream when
    the cell's trace can't train (tiny workloads with too few outcomes of one
    class)."""
    from repro.cluster.experiment import ExperimentConfig, run_scheduler
    from repro.cluster.fleet import cell_seed
    from repro.cluster.scenarios import scenario_chaos, workload_for_seed

    env = ((scenario, workload, f"n{fleet_size}", seed) if fleet_size
           else (scenario, workload, seed))
    cfg = ExperimentConfig(
        workload=workload_for_seed(workload, cell_seed("workload", *env)),
        chaos=scenario_chaos(scenario, cell_seed("chaos", *env)),
        seed=cell_seed("sim", *env), min_samples=32, fleet_size=fleet_size)
    _, trace, _ = run_scheduler("fifo", cfg, with_trace=True)
    (mx, my), (rx, ry) = trace.datasets()
    predictor = TaskPredictor(algo="R.F.", min_samples=32, seed=0)
    predictor.fit_datasets((mx, my), (rx, ry))

    rows = [("map", x) for x in mx] + [("reduce", x) for x in rx]
    rows = [(k, x) for k, x in rows
            if predictor.model_for_kind(k) is not None]
    if not rows:  # untrained fallback: synthetic decision stream
        rng = np.random.RandomState(seed)
        X = rng.rand(512, mx.shape[1] if mx.size else 22).astype(np.float32)
        y = (rng.rand(512) < 0.4).astype(np.float32)
        predictor.fit_datasets((X, y), (X, y))
        rows = [("map", x) for x in X]

    while len(rows) < min_rows:
        rows = rows + rows
    rows = rows[:min_rows]

    sizes = request_sizes(fleet_size)
    requests, i, s = [], 0, 0
    while i < len(rows):
        size = sizes[s % len(sizes)]
        chunk = rows[i:i + size]
        i += size
        s += 1
        # a request is single-kind, like p_success_nodes
        kind = chunk[0][0]
        X = np.stack([x for k, x in chunk if k == kind])
        requests.append((kind, X))
        rest = [(k, x) for k, x in chunk if k != kind]
        if rest:
            requests.append((rest[0][0], np.stack([x for _, x in rest])))
    return predictor, requests


# ---------------------------------------------------------------------------
# Serving modes
# ---------------------------------------------------------------------------

def run_scalar(predictor: TaskPredictor, requests) -> dict:
    """The un-brokered baseline, timed at both granularities:

    * per request — today's ``p_success`` / ``p_success_nodes`` call pattern
      (one dispatch per call), and
    * per decision — one dispatch per scored row, the paper's per-decision
      evaluation (each row of a candidate set is one predicted placement).
    """
    d0, r0 = predictor.n_dispatches, predictor.n_rows_scored
    outs = []
    t0 = time.perf_counter()
    for kind, X in requests:
        outs.append(predictor.predict_batch(kind, X))
    dt = time.perf_counter() - t0
    rows = predictor.n_rows_scored - r0
    t0 = time.perf_counter()
    for kind, X in requests:
        for i in range(X.shape[0]):
            predictor.predict_batch(kind, X[i:i + 1])
    dt_rows = time.perf_counter() - t0
    return {"rows": rows, "requests": len(requests), "seconds": dt,
            "rows_per_s": rows / max(dt, 1e-9),
            "per_decision_rows_per_s": rows / max(dt_rows, 1e-9),
            "dispatches": predictor.n_dispatches - d0 - rows,
            "outputs": outs}


def run_broker(predictor: TaskPredictor, requests, *, clients: int = 12,
               impl: str = "numpy", rate: float = 0.0,
               policy: str = "barrier", depth: int = 256,
               max_delay: float = 0.002, obs=None) -> dict:
    """Concurrent clients replaying shards of the stream through one broker."""
    broker = PredictionBroker(impl=impl, policy=policy, depth=depth,
                              max_delay=max_delay)
    broker.obs = obs
    shards = [list(range(c, len(requests), clients)) for c in range(clients)]
    shards = [s for s in shards if s]
    broker.add_clients(len(shards))
    outs: list = [None] * len(requests)
    lat: list = []
    lat_lock = threading.Lock()
    errors: list = []

    def client(idxs):
        my_lat = []
        try:
            for qi in idxs:
                kind, X = requests[qi]
                if rate > 0:
                    time.sleep(1.0 / rate)
                model = predictor.model_for_kind(kind)
                t0 = time.perf_counter()
                (out,) = broker.submit([(model, X)])
                my_lat.append(time.perf_counter() - t0)
                outs[qi] = out
        except Exception as e:                       # pragma: no cover
            errors.append(e)
        finally:
            broker.done()
            with lat_lock:
                lat.extend(my_lat)

    threads = [threading.Thread(target=client, args=(sh,))
               for sh in shards]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    if errors:
        raise errors[0]
    lat.sort()

    def pct(q):
        return lat[min(int(q * len(lat)), len(lat) - 1)] * 1e3 if lat else 0.0

    s = broker.stats()
    out = {"rows": s["rows"], "requests": s["requests"], "seconds": dt,
           "rows_per_s": s["rows"] / max(dt, 1e-9),
           "dispatches": s["dispatches"], "flushes": s["flushes"],
           "max_flush_rows": s["max_flush_rows"],
           "clients": len(shards), "impl": impl, "policy": policy,
           "solo_flushes": broker.n_solo_flushes,
           "deadline_flushes": broker.n_deadline_flushes,
           "latency_ms": {"p50": pct(0.50), "p95": pct(0.95),
                          "p99": pct(0.99)},
           "outputs": outs}
    if obs is not None:
        obs.close()
        # full summary: the flush-latency section is reporting-only (wall
        # clock), which is fine here — BENCH latency numbers already are
        out["obs"] = obs.summary()
    return out


def run_saturated(predictor: TaskPredictor, requests,
                  *, impl: str = "numpy", batch_rows: int = 8192) -> dict:
    """Open-loop saturation: requests arrive faster than flushes drain, so
    every flush scores a full queue.  Replays the stream through the broker's
    flush path (``score_groups``) at that depth — peak batched throughput."""
    from repro.online.broker import score_groups
    chunks, cur, rows = [], [], 0
    for kind, X in requests:
        cur.append((predictor.model_for_kind(kind), X))
        rows += X.shape[0]
        if rows >= batch_rows:
            chunks.append(cur)
            cur, rows = [], 0
    if cur:
        chunks.append(cur)
    outs, dispatches, total = [], 0, 0
    t0 = time.perf_counter()
    for chunk in chunks:
        o, n = score_groups(chunk, impl=impl)
        outs.extend(o)
        dispatches += n
        total += sum(X.shape[0] for _, X in chunk)
    dt = time.perf_counter() - t0
    return {"rows": total, "requests": len(requests), "seconds": dt,
            "rows_per_s": total / max(dt, 1e-9), "dispatches": dispatches,
            "flushes": len(chunks), "batch_rows": batch_rows,
            "outputs": outs}


def _parity(scalar: dict, *others) -> bool:
    for mode in others:
        for a, b in zip(scalar["outputs"], mode["outputs"]):
            if b is None or not np.array_equal(a, b):
                return False
    return True


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------

def summarize(scalar: dict, broker: dict, saturated: dict,
              parity: bool | None, fleet_size: int = 0) -> dict:
    strip = lambda d: {k: v for k, v in d.items() if k != "outputs"}  # noqa: E731
    return {
        "pr": repro.PR_TAG,
        "fleet_size": fleet_size,
        "scalar": strip(scalar),
        "broker": strip(broker),
        "saturated": strip(saturated),
        "speedup": saturated["rows_per_s"] / max(scalar["rows_per_s"], 1e-9),
        "speedup_vs_per_decision": saturated["rows_per_s"]
        / max(scalar["per_decision_rows_per_s"], 1e-9),
        "dispatch_reduction": scalar["dispatches"]
        / max(broker["dispatches"], 1),
        "parity": parity,
    }


def _size_block(summary: dict) -> dict:
    """The compact per-fleet-size perf record stamped into SWEEP/BENCH."""
    return {
        "batched_rows_per_s": round(summary["saturated"]["rows_per_s"], 1),
        "broker_rows_per_s": round(summary["broker"]["rows_per_s"], 1),
        "scalar_rows_per_s": round(summary["scalar"]["rows_per_s"], 1),
        "speedup": round(summary["speedup"], 2),
        "dispatch_reduction": round(summary["dispatch_reduction"], 2),
        "latency_ms": {k: round(v, 3)
                       for k, v in summary["broker"]["latency_ms"].items()},
        "parity": summary["parity"],
    }


def stamp_sweep(summary: dict, sweep_json_path) -> bool:
    """Merge the broker numbers into SWEEP.json + SWEEP.md so the perf
    trajectory across PRs lives in one artifact."""
    jp = pathlib.Path(sweep_json_path)
    if not jp.exists():
        return False
    obj = json.loads(jp.read_text())
    perf = obj.setdefault("perf", {})
    perf["online_bench"] = {
        "pr": summary["pr"],
        **_size_block(summary),
        # the fleet-size scale axis: one throughput/latency block per size
        "per_fleet_size": {
            str(size): _size_block(s)
            for size, s in sorted(summary.get("per_fleet_size", {}).items(),
                                  key=lambda kv: int(kv[0]))
        },
    }
    jp.write_text(json.dumps(obj, indent=2, sort_keys=True) + "\n")
    mp = jp.with_name("SWEEP.md")
    if mp.exists():
        b = perf["online_bench"]
        # re-stamping replaces the previous broker section, never appends a
        # second one (the section is always the trailing block we wrote)
        md = mp.read_text()
        cut = md.find("\n## online broker (")
        if cut != -1:
            md = md[:cut]

        def row(label, blk):
            return (f"| {label} | {blk['scalar_rows_per_s']:.0f} "
                    f"| {blk['batched_rows_per_s']:.0f} "
                    f"| {blk['speedup']:.1f}x "
                    f"| {blk['dispatch_reduction']:.1f}x "
                    f"| {blk['latency_ms']['p50']:.2f} "
                    f"| {blk['latency_ms']['p99']:.2f} "
                    f"| {blk['parity']} |")

        lines = [md.rstrip("\n"), "",
                 f"## online broker ({summary['pr']})", "",
                 "| fleet | scalar rows/s | batched rows/s | speedup "
                 "| dispatch reduction | p50 ms | p99 ms | parity |",
                 "|---|---|---|---|---|---|---|---|"]
        sizes = b["per_fleet_size"] or {"0": b}
        for size, blk in sorted(sizes.items(), key=lambda kv: int(kv[0])):
            lines.append(row("paper (13)" if size == "0" else size, blk))
        mp.write_text("\n".join(lines) + "\n")
    return True


def run_bench(*, rows: int = 6000, clients: int = 12, workload: str = "smoke",
              scenario: str = "bursty_tt", impl: str = "numpy",
              rate: float = 0.0, seed: int = 0, fleet_size: int = 0,
              policy: str = "barrier", depth: int = 256,
              max_delay: float = 0.002, obs_dir=None) -> dict:
    predictor, requests = build_stream(workload=workload, scenario=scenario,
                                       seed=seed, min_rows=rows,
                                       fleet_size=fleet_size)
    obs = None
    if obs_dir is not None:
        from repro.obs import BrokerObserver, NDJSONSink
        d = pathlib.Path(obs_dir)
        d.mkdir(parents=True, exist_ok=True)
        obs = BrokerObserver(sink=NDJSONSink(d / f"bench_n{fleet_size}.ndjson"))
    scalar = run_scalar(predictor, requests)
    broker = run_broker(predictor, requests, clients=clients, impl=impl,
                        rate=rate, policy=policy, depth=depth,
                        max_delay=max_delay, obs=obs)
    saturated = run_saturated(predictor, requests, impl=impl)
    parity = (_parity(scalar, broker, saturated) if impl == "numpy"
              else None)
    return summarize(scalar, broker, saturated, parity, fleet_size)


def run_bench_sizes(fleet_sizes, **kw) -> dict:
    """The full bench at each fleet size; the first size is the primary
    summary, every size lands under ``per_fleet_size``."""
    sizes = list(fleet_sizes) or [0]
    summary = None
    per_size = {}
    for size in sizes:
        s = run_bench(fleet_size=size, **kw)
        per_size[str(size)] = s
        if summary is None:
            summary = dict(s)     # copy: the primary also sits in per_size
    summary["per_fleet_size"] = per_size
    return summary


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.online.bench",
        description="Broker load generator: replay fleet decision streams")
    ap.add_argument("--rows", type=int, default=6000)
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--workload", default="smoke")
    ap.add_argument("--scenario", default="bursty_tt")
    ap.add_argument("--impl", default="numpy",
                    choices=("numpy", "auto", "xla", "pallas", "interpret"))
    ap.add_argument("--rate", type=float, default=0.0,
                    help="per-client request rate (req/s, 0 = max)")
    ap.add_argument("--fleet-sizes", default="0",
                    help="comma list of fleet sizes to bench (0 = the "
                         "paper's 13-slave fleet); first is the primary "
                         "summary, all land in per_fleet_size")
    ap.add_argument("--policy", default="barrier",
                    choices=("barrier", "depth"),
                    help="broker flush policy (depth = queue-depth with "
                         "bounded delay; non-deterministic flush counts)")
    ap.add_argument("--depth", type=int, default=256,
                    help="queue-depth flush threshold in rows (policy=depth)")
    ap.add_argument("--max-delay", type=float, default=0.002,
                    help="bounded flush delay in seconds (policy=depth)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments",
                    help="directory for ONLINE.json")
    ap.add_argument("--stamp-sweep", nargs="?", const="experiments/SWEEP.json",
                    default=None, metavar="SWEEP_JSON",
                    help="merge the summary into an existing SWEEP.json/.md")
    ap.add_argument("--obs", action="store_true",
                    help="attach a BrokerObserver: per-flush NDJSON frames "
                         "under <out>/obs/ and an obs block in BENCH_<pr>")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run (fewer rows/clients)")
    args = ap.parse_args(argv)

    rows, clients = args.rows, args.clients
    if args.smoke:
        rows, clients = min(rows, 2000), min(clients, 12)
    fleet_sizes = [int(s) for s in args.fleet_sizes.split(",")]
    obs_dir = str(pathlib.Path(args.out) / "obs") if args.obs else None
    summary = run_bench_sizes(
        fleet_sizes, rows=rows, clients=clients, workload=args.workload,
        scenario=args.scenario, impl=args.impl, rate=args.rate,
        seed=args.seed, policy=args.policy, depth=args.depth,
        max_delay=args.max_delay, obs_dir=obs_dir)

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    (out / "ONLINE.json").write_text(
        json.dumps(summary, indent=2, sort_keys=True) + "\n")
    # per-PR perf artifact: BENCH_<n>.json accumulates the trajectory across
    # PRs (one file per PR_TAG, re-runs overwrite their own PR's file)
    m = re.match(r"PR(\d+)", repro.PR_TAG)
    if m:
        bench_art = {
            "pr": repro.PR_TAG,
            **_size_block(summary),
            "per_fleet_size": {size: _size_block(s) for size, s in
                               summary["per_fleet_size"].items()},
        }
        if args.obs:
            # per-size broker telemetry roll-up (flush hists + latency)
            bench_art["obs"] = {
                size: s_sz["broker"].get("obs")
                for size, s_sz in summary["per_fleet_size"].items()}
        (out / f"BENCH_{m.group(1)}.json").write_text(
            json.dumps(bench_art, indent=2, sort_keys=True) + "\n")
    b, s, f = summary["broker"], summary["scalar"], summary["saturated"]
    print(f"[online] scalar    : {s['rows']} rows, {s['dispatches']} "
          f"dispatches, {s['rows_per_s']:,.0f} rows/s "
          f"({s['per_decision_rows_per_s']:,.0f} rows/s per-decision)")
    print(f"[online] broker    : {b['rows']} rows, {b['dispatches']} "
          f"dispatches ({b['flushes']} flushes, max batch "
          f"{b['max_flush_rows']} rows), {b['rows_per_s']:,.0f} rows/s "
          f"[p50 {b['latency_ms']['p50']:.2f} ms, "
          f"p99 {b['latency_ms']['p99']:.2f} ms]")
    print(f"[online] saturated : {f['rows']} rows, {f['dispatches']} "
          f"dispatches ({f['flushes']} flushes), "
          f"{f['rows_per_s']:,.0f} rows/s")
    print(f"[online] batched speedup {summary['speedup']:.1f}x "
          f"({summary['speedup_vs_per_decision']:.1f}x vs per-decision), "
          f"dispatch reduction {summary['dispatch_reduction']:.1f}x, "
          f"parity={summary['parity']}")
    if len(summary["per_fleet_size"]) > 1:
        for size, s_sz in sorted(summary["per_fleet_size"].items(),
                                 key=lambda kv: int(kv[0])):
            blk = _size_block(s_sz)
            label = "paper(13)" if size == "0" else size
            print(f"[online] fleet {label:>9s}: "
                  f"{blk['batched_rows_per_s']:>10,.0f} batched rows/s, "
                  f"broker p50 {blk['latency_ms']['p50']:.2f} ms "
                  f"p99 {blk['latency_ms']['p99']:.2f} ms, "
                  f"parity={blk['parity']}")
    if args.stamp_sweep:
        if stamp_sweep(summary, args.stamp_sweep):
            print(f"[online] stamped perf into {args.stamp_sweep}")
        else:
            print(f"[online] no {args.stamp_sweep} to stamp (run the sweep "
                  "first)")

    bad = any(s_sz["broker"]["rows_per_s"] <= 0
              or s_sz["saturated"]["rows_per_s"] <= 0
              or s_sz["parity"] is False
              for s_sz in summary["per_fleet_size"].values())
    if bad:
        print("[online] FAIL: no batched throughput or parity break",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
