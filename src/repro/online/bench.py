"""Load generator for the prediction broker: replay fleet decision streams.

Builds a decision stream (the launch-time feature rows a fleet cell actually
raised), trains the predictor on it, then serves the stream three ways:

  scalar     the per-decision path — one model dispatch per request
  broker     closed loop: N concurrent clients through one PredictionBroker —
             lock-step rounds fused into single passes; measures per-request
             latency percentiles and the dispatch reduction
  saturated  open loop: the stream arrives faster than flushes drain, so the
             queue depth fills every flush — the broker's peak batched
             throughput (this is the ≥10x-vs-scalar number)
  open-loop  (PR 7) timed arrivals through the serving ``AsyncBroker`` over
             the transport layer: Poisson and bursty (two-state MMPP)
             schedules on inproc:// and tcp:// backends, latency measured
             from each request's *scheduled* arrival (no coordinated
             omission), p50/p95/p99 + SLO-violation rate per config

Row-level outputs are compared bit-for-bit across all modes
(``impl="numpy"``), so the bench doubles as a live parity check.

  python -m repro.online.bench [--rows 6000] [--clients 12] [--workload smoke]
      [--scenario bursty_tt] [--impl numpy|auto|xla|interpret] [--rate R]
      [--fleet-sizes 0,100] [--policy barrier|depth] [--depth N]
      [--max-delay S] [--no-open-loop] [--open-rate R] [--slo-ms MS]
      [--open-backends inproc,tcp] [--out experiments]
      [--stamp-sweep [PATH]] [--smoke]

``--rate`` paces each client (requests/s of wall time, 0 = flat out).
``--fleet-sizes`` is the scale axis: each size replays a decision stream from
a fleet of that many nodes (0 = the paper's 13-slave fleet; candidate-set
requests grow with the fleet), and the per-size throughput/latency sections
land in the summary, ``BENCH_<pr>.json`` and — with ``--stamp-sweep`` —
``SWEEP.json``.  ``--policy depth`` serves the broker section through the
queue-depth flush policy with bounded delay instead of the deterministic
barrier.  Exit status is non-zero when the batched run shows no throughput or
parity breaks — ``make bench-smoke`` gates CI on this."""

from __future__ import annotations

import argparse
import asyncio
import json
import pathlib
import re
import sys
import threading
import time

import numpy as np

import repro
from repro.core.predictor import TaskPredictor
from repro.online.broker import PredictionBroker

# deterministic request-size mix mimicking the scheduler's demand: mostly
# single-proposal p_success rows, periodically a candidate-set p_success_nodes
# (whose size tracks the fleet: every free node is a candidate placement)
REQUEST_SIZES = (1, 1, 1, 2, 1, 1, 13, 1, 1, 4)

# open-loop auto-rate ceilings (requests/s): past these the per-message
# event-loop hop — not forest scoring — is what saturates, and pushing an
# open-loop schedule beyond service capacity just measures queue growth
OPEN_RATE_CAP = 12000.0
TCP_RATE_CAP = 4000.0

# CI tail budget: open-loop p99 must stay under max(10x p50, this floor)
P99_FLOOR_MS = 25.0


def request_sizes(fleet_size: int = 0) -> tuple:
    if not fleet_size:
        return REQUEST_SIZES
    cand = min(fleet_size, 256)
    return tuple(cand if s == 13 else s for s in REQUEST_SIZES)


# ---------------------------------------------------------------------------
# Stream construction
# ---------------------------------------------------------------------------

def build_stream(workload: str = "smoke", scenario: str = "bursty_tt",
                 seed: int = 0, min_rows: int = 2000, fleet_size: int = 0):
    """(predictor, [(kind, X_request)]) from one base-scheduler fleet cell.

    The trace's launch-time feature rows ARE the decision stream ATLAS would
    have scored; they are tiled to ``min_rows`` and cut into requests with the
    ``request_sizes(fleet_size)`` mix.  Falls back to a synthetic stream when
    the cell's trace can't train (tiny workloads with too few outcomes of one
    class)."""
    from repro.cluster.experiment import ExperimentConfig, run_scheduler
    from repro.cluster.fleet import cell_seed
    from repro.cluster.scenarios import make_spec

    env = ((scenario, workload, f"n{fleet_size}", seed) if fleet_size
           else (scenario, workload, seed))
    point = make_spec(scenario, workload)
    cfg = ExperimentConfig(
        workload=point.workload_for_seed(cell_seed("workload", *env)),
        chaos=point.chaos_for_seed(cell_seed("chaos", *env)),
        seed=cell_seed("sim", *env), min_samples=32, fleet_size=fleet_size)
    _, trace, _ = run_scheduler("fifo", cfg, with_trace=True)
    (mx, my), (rx, ry) = trace.datasets()
    predictor = TaskPredictor(algo="R.F.", min_samples=32, seed=0)
    predictor.fit_datasets((mx, my), (rx, ry))

    rows = [("map", x) for x in mx] + [("reduce", x) for x in rx]
    rows = [(k, x) for k, x in rows
            if predictor.model_for_kind(k) is not None]
    if not rows:  # untrained fallback: synthetic decision stream
        rng = np.random.RandomState(seed)
        X = rng.rand(512, mx.shape[1] if mx.size else 22).astype(np.float32)
        y = (rng.rand(512) < 0.4).astype(np.float32)
        predictor.fit_datasets((X, y), (X, y))
        rows = [("map", x) for x in X]

    while len(rows) < min_rows:
        rows = rows + rows
    rows = rows[:min_rows]

    sizes = request_sizes(fleet_size)
    requests, i, s = [], 0, 0
    while i < len(rows):
        size = sizes[s % len(sizes)]
        chunk = rows[i:i + size]
        i += size
        s += 1
        # a request is single-kind, like p_success_nodes
        kind = chunk[0][0]
        X = np.stack([x for k, x in chunk if k == kind])
        requests.append((kind, X))
        rest = [(k, x) for k, x in chunk if k != kind]
        if rest:
            requests.append((rest[0][0], np.stack([x for _, x in rest])))
    return predictor, requests


# ---------------------------------------------------------------------------
# Serving modes
# ---------------------------------------------------------------------------

def run_scalar(predictor: TaskPredictor, requests) -> dict:
    """The un-brokered baseline, timed at both granularities:

    * per request — today's ``p_success`` / ``p_success_nodes`` call pattern
      (one dispatch per call), and
    * per decision — one dispatch per scored row, the paper's per-decision
      evaluation (each row of a candidate set is one predicted placement).
    """
    d0, r0 = predictor.n_dispatches, predictor.n_rows_scored
    outs = []
    t0 = time.perf_counter()
    for kind, X in requests:
        outs.append(predictor.predict_batch(kind, X))
    dt = time.perf_counter() - t0
    rows = predictor.n_rows_scored - r0
    t0 = time.perf_counter()
    for kind, X in requests:
        for i in range(X.shape[0]):
            predictor.predict_batch(kind, X[i:i + 1])
    dt_rows = time.perf_counter() - t0
    return {"rows": rows, "requests": len(requests), "seconds": dt,
            "rows_per_s": rows / max(dt, 1e-9),
            "per_decision_rows_per_s": rows / max(dt_rows, 1e-9),
            "dispatches": predictor.n_dispatches - d0 - rows,
            "outputs": outs}


def run_broker(predictor: TaskPredictor, requests, *, clients: int = 12,
               impl: str = "numpy", rate: float = 0.0,
               policy: str = "barrier", depth: int = 256,
               max_delay: float = 0.002, obs=None) -> dict:
    """Concurrent clients replaying shards of the stream through one broker."""
    broker = PredictionBroker(impl=impl, policy=policy, depth=depth,
                              max_delay=max_delay)
    broker.obs = obs
    shards = [list(range(c, len(requests), clients)) for c in range(clients)]
    shards = [s for s in shards if s]
    broker.add_clients(len(shards))
    outs: list = [None] * len(requests)
    lat: list = []
    lat_lock = threading.Lock()
    errors: list = []

    def client(idxs):
        my_lat = []
        try:
            for qi in idxs:
                kind, X = requests[qi]
                if rate > 0:
                    time.sleep(1.0 / rate)
                model = predictor.model_for_kind(kind)
                t0 = time.perf_counter()
                (out,) = broker.submit([(model, X)])
                my_lat.append(time.perf_counter() - t0)
                outs[qi] = out
        except Exception as e:                       # pragma: no cover
            errors.append(e)
        finally:
            broker.done()
            with lat_lock:
                lat.extend(my_lat)

    threads = [threading.Thread(target=client, args=(sh,))
               for sh in shards]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    if errors:
        raise errors[0]
    lat.sort()

    def pct(q):
        return lat[min(int(q * len(lat)), len(lat) - 1)] * 1e3 if lat else 0.0

    s = broker.stats()
    out = {"rows": s["rows"], "requests": s["requests"], "seconds": dt,
           "rows_per_s": s["rows"] / max(dt, 1e-9),
           "dispatches": s["dispatches"], "flushes": s["flushes"],
           "max_flush_rows": s["max_flush_rows"],
           "clients": len(shards), "impl": impl, "policy": policy,
           "solo_flushes": broker.n_solo_flushes,
           "deadline_flushes": broker.n_deadline_flushes,
           "latency_ms": {"p50": pct(0.50), "p95": pct(0.95),
                          "p99": pct(0.99)},
           "outputs": outs}
    if obs is not None:
        obs.close()
        # full summary: the flush-latency section is reporting-only (wall
        # clock), which is fine here — BENCH latency numbers already are
        out["obs"] = obs.summary()
    return out


def run_saturated(predictor: TaskPredictor, requests,
                  *, impl: str = "numpy", batch_rows: int = 8192) -> dict:
    """Open-loop saturation: requests arrive faster than flushes drain, so
    every flush scores a full queue.  Replays the stream through the broker's
    flush path (``score_groups``) at that depth — peak batched throughput."""
    from repro.online.broker import score_groups
    chunks, cur, rows = [], [], 0
    for kind, X in requests:
        cur.append((predictor.model_for_kind(kind), X))
        rows += X.shape[0]
        if rows >= batch_rows:
            chunks.append(cur)
            cur, rows = [], 0
    if cur:
        chunks.append(cur)
    outs, dispatches, total = [], 0, 0
    t0 = time.perf_counter()
    for chunk in chunks:
        o, n = score_groups(chunk, impl=impl)
        outs.extend(o)
        dispatches += n
        total += sum(X.shape[0] for _, X in chunk)
    dt = time.perf_counter() - t0
    return {"rows": total, "requests": len(requests), "seconds": dt,
            "rows_per_s": total / max(dt, 1e-9), "dispatches": dispatches,
            "flushes": len(chunks), "batch_rows": batch_rows,
            "outputs": outs}


def _arrival_schedule(n: int, rate_rps: float, kind: str, rng) -> np.ndarray:
    """Cumulative scheduled offsets (seconds) for ``n`` requests.

    "poisson" draws exponential gaps at ``rate_rps``; "bursty" is a two-state
    MMPP — bursts at 4x the base rate, calm stretches at 0.4x, flipping with
    probability 0.05 per arrival — so the mean rate is *approximately* the
    base and the tails come from genuine arrival clumps."""
    rate_rps = max(rate_rps, 1e-6)
    if kind == "poisson":
        gaps = rng.exponential(1.0 / rate_rps, size=n)
    elif kind == "bursty":
        gaps = np.empty(n)
        fast = True
        for i in range(n):
            r = rate_rps * (4.0 if fast else 0.4)
            gaps[i] = rng.exponential(1.0 / r)
            if rng.rand() < 0.05:
                fast = not fast
    else:
        raise ValueError(f"unknown arrival process {kind!r}")
    return np.cumsum(gaps)


async def _open_loop_client(address, requests, idxs, sched, t0, outs, lats,
                            slo_ms, reply_timeout_s: float = 120.0):
    """One open-loop client: fire requests at their scheduled offsets without
    waiting for replies; a reader task demuxes replies by id.  Latency is
    measured from the *scheduled* arrival, so a stalled broker keeps paying
    for the requests it should already have served (no coordinated omission).
    The reader is bounded by ``reply_timeout_s``: a wedged broker turns into
    a clean ``TimeoutError`` instead of hanging the bench (and CI) forever.
    """
    from repro.online.transport import connect
    comm = await connect(address)
    pending: dict = {}
    n = len(idxs)

    async def reader():
        for _ in range(n):
            reply = await comm.recv()
            t_done = time.perf_counter()
            qi, t_sched = pending.pop(reply["id"])
            if reply.get("error") is not None:
                raise RuntimeError(f"broker error: {reply['error']}")
            outs[qi] = reply["probs"][0]
            lats[qi] = max(t_done - t_sched, 0.0)

    rtask = asyncio.ensure_future(reader())
    try:
        for j, qi in enumerate(idxs):
            t_sched = t0 + sched[j]
            delay = t_sched - time.perf_counter()
            if delay > 0:
                await asyncio.sleep(delay)
            kind, X = requests[qi % len(requests)]
            msg = {"op": "predict", "id": j, "kind": kind, "X": X}
            if slo_ms:
                msg["budget_ms"] = slo_ms
            pending[j] = (qi, t_sched)
            await comm.send(msg)
        await asyncio.wait_for(rtask, reply_timeout_s)
    finally:
        rtask.cancel()
        await comm.close()


def run_open_loop(predictor, requests, *, backend: str = "inproc",
                  arrivals: str = "poisson", clients: int = 8,
                  rate_rps: float = 1000.0, n_requests: int | None = None,
                  slo_ms: float = 25.0, policy: str = "vt", depth: int = 2048,
                  vt_window: int | None = None, impl: str = "numpy",
                  seed: int = 0) -> dict:
    """Open-loop load through a serving AsyncBroker on one transport backend.

    ``rate_rps`` is the *aggregate* arrival rate across all clients; the
    request stream is replayed modulo its length when ``n_requests`` exceeds
    it (outputs stay comparable to the scalar baseline index-wise)."""
    from repro.online.server import AsyncBroker

    models = {k: predictor.model_for_kind(k) for k in ("map", "reduce")}
    models = {k: v for k, v in models.items() if v is not None}
    server = AsyncBroker(models, impl=impl, policy=policy, depth=depth,
                         vt_window=vt_window, slo_ms=slo_ms)
    server.start()
    n = n_requests or len(requests)
    shards = [list(range(c, n, clients)) for c in range(clients)]
    shards = [s for s in shards if s]
    rng = np.random.RandomState(seed)
    per_client = rate_rps / max(len(shards), 1)
    scheds = [_arrival_schedule(len(sh), per_client, arrivals, rng)
              for sh in shards]
    outs: list = [None] * n
    lats: list = [None] * n

    async def drive():
        t0 = time.perf_counter() + 0.02     # common epoch for all schedules
        await asyncio.gather(*[
            _open_loop_client(address, requests, sh, sc, t0, outs, lats,
                              slo_ms)
            for sh, sc in zip(shards, scheds)])
        return time.perf_counter() - t0

    try:
        address = server.serve("tcp://127.0.0.1:0" if backend == "tcp"
                               else "")
        if backend == "tcp":
            # tcp clients live on their own loop in this thread; frames
            # cross the real (loopback) socket stack
            dt = asyncio.run(drive())
        else:
            # inproc channels are loop-local: clients run on the server loop
            dt = asyncio.run_coroutine_threadsafe(
                drive(), server.loop).result(600)
        stats = server.stats()
        causes = {"depth": server.n_depth_flushes,
                  "vt": server.n_vt_flushes,
                  "idle": server.n_idle_flushes,
                  "slo": server.n_deadline_flushes}
    finally:
        server.stop()

    lat = sorted(1e3 * v for v in lats if v is not None)

    def pct(q):
        return lat[min(int(q * len(lat)), len(lat) - 1)] if lat else 0.0

    viol = sum(1 for v in lat if v > slo_ms) / max(len(lat), 1)
    return {"backend": backend, "arrivals": arrivals,
            "clients": len(shards), "rate_rps": round(rate_rps, 1),
            "slo_ms": slo_ms, "policy": policy,
            "rows": stats["rows"], "requests": stats["requests"],
            "seconds": dt, "rows_per_s": stats["rows"] / max(dt, 1e-9),
            "flushes": stats["flushes"], "dispatches": stats["dispatches"],
            "max_flush_rows": stats["max_flush_rows"],
            "flush_causes": causes,
            "latency_ms": {"p50": pct(0.50), "p95": pct(0.95),
                           "p99": pct(0.99)},
            "slo_violation_rate": viol,
            "outputs": outs}


def _parity(scalar: dict, *others) -> bool:
    for mode in others:
        for a, b in zip(scalar["outputs"], mode["outputs"]):
            if b is None or not np.array_equal(a, b):
                return False
    return True


def _parity_mod(scalar_outputs: list, outs: list) -> bool:
    """Open-loop replays the stream modulo its length: outs[i] must equal
    the scalar output for request i % len(stream), bit for bit."""
    m = len(scalar_outputs)
    for i, o in enumerate(outs):
        if o is None or not np.array_equal(scalar_outputs[i % m], o):
            return False
    return True


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------

def summarize(scalar: dict, broker: dict, saturated: dict,
              parity: bool | None, fleet_size: int = 0,
              open_loop: dict | None = None) -> dict:
    strip = lambda d: {k: v for k, v in d.items() if k != "outputs"}  # noqa: E731
    out = {
        "pr": repro.PR_TAG,
        "fleet_size": fleet_size,
        "scalar": strip(scalar),
        "broker": strip(broker),
        "saturated": strip(saturated),
        "speedup": saturated["rows_per_s"] / max(scalar["rows_per_s"], 1e-9),
        "speedup_vs_per_decision": saturated["rows_per_s"]
        / max(scalar["per_decision_rows_per_s"], 1e-9),
        "dispatch_reduction": scalar["dispatches"]
        / max(broker["dispatches"], 1),
        "parity": parity,
    }
    if open_loop:
        out["open_loop"] = {cfg: strip(r) for cfg, r in open_loop.items()}
    return out


def _size_block(summary: dict) -> dict:
    """The compact per-fleet-size perf record stamped into SWEEP/BENCH."""
    blk = {
        "batched_rows_per_s": round(summary["saturated"]["rows_per_s"], 1),
        "broker_rows_per_s": round(summary["broker"]["rows_per_s"], 1),
        "scalar_rows_per_s": round(summary["scalar"]["rows_per_s"], 1),
        "speedup": round(summary["speedup"], 2),
        "dispatch_reduction": round(summary["dispatch_reduction"], 2),
        "latency_ms": {k: round(v, 3)
                       for k, v in summary["broker"]["latency_ms"].items()},
        "parity": summary["parity"],
    }
    if summary.get("open_loop"):
        blk["open_loop"] = {
            cfg: {
                "rate_rps": r["rate_rps"],
                "rows_per_s": round(r["rows_per_s"], 1),
                "latency_ms": {k: round(v, 3)
                               for k, v in r["latency_ms"].items()},
                "p99_over_p50": round(
                    r["latency_ms"]["p99"]
                    / max(r["latency_ms"]["p50"], 1e-9), 2),
                "slo_ms": r["slo_ms"],
                "slo_violation_rate": round(r["slo_violation_rate"], 4),
                "flush_causes": r["flush_causes"],
                "parity": r["parity"],
            }
            for cfg, r in sorted(summary["open_loop"].items())
        }
    return blk


def stamp_sweep(summary: dict, sweep_json_path) -> bool:
    """Merge the broker numbers into SWEEP.json + SWEEP.md so the perf
    trajectory across PRs lives in one artifact."""
    jp = pathlib.Path(sweep_json_path)
    if not jp.exists():
        return False
    obj = json.loads(jp.read_text())
    perf = obj.setdefault("perf", {})
    perf["online_bench"] = {
        "pr": summary["pr"],
        **_size_block(summary),
        # the fleet-size scale axis: one throughput/latency block per size
        "per_fleet_size": {
            str(size): _size_block(s)
            for size, s in sorted(summary.get("per_fleet_size", {}).items(),
                                  key=lambda kv: int(kv[0]))
        },
    }
    jp.write_text(json.dumps(obj, indent=2, sort_keys=True) + "\n")
    mp = jp.with_name("SWEEP.md")
    if mp.exists():
        b = perf["online_bench"]
        # re-stamping replaces the previous broker section, never appends a
        # second one (the section is always the trailing block we wrote)
        md = mp.read_text()
        cut = md.find("\n## online broker (")
        if cut != -1:
            md = md[:cut]

        def row(label, blk):
            return (f"| {label} | {blk['scalar_rows_per_s']:.0f} "
                    f"| {blk['batched_rows_per_s']:.0f} "
                    f"| {blk['speedup']:.1f}x "
                    f"| {blk['dispatch_reduction']:.1f}x "
                    f"| {blk['latency_ms']['p50']:.2f} "
                    f"| {blk['latency_ms']['p99']:.2f} "
                    f"| {blk['parity']} |")

        lines = [md.rstrip("\n"), "",
                 f"## online broker ({summary['pr']})", "",
                 "| fleet | scalar rows/s | batched rows/s | speedup "
                 "| dispatch reduction | p50 ms | p99 ms | parity |",
                 "|---|---|---|---|---|---|---|---|"]
        sizes = b["per_fleet_size"] or {"0": b}
        for size, blk in sorted(sizes.items(), key=lambda kv: int(kv[0])):
            lines.append(row("paper (13)" if size == "0" else size, blk))
        mp.write_text("\n".join(lines) + "\n")
    return True


def run_bench(*, rows: int = 6000, clients: int = 12, workload: str = "smoke",
              scenario: str = "bursty_tt", impl: str = "numpy",
              rate: float = 0.0, seed: int = 0, fleet_size: int = 0,
              policy: str = "barrier", depth: int = 256,
              max_delay: float = 0.002, obs_dir=None, obs_live=None,
              open_loop: bool = True, open_rate: float = 0.0,
              open_backends: tuple = ("inproc", "tcp"),
              slo_ms: float = 25.0) -> dict:
    predictor, requests = build_stream(workload=workload, scenario=scenario,
                                       seed=seed, min_rows=rows,
                                       fleet_size=fleet_size)
    obs = None
    if obs_dir is not None or obs_live is not None:
        from repro.obs import (BrokerObserver, NDJSONSink, TeeSink,
                               TransportSink)
        sinks = []
        if obs_dir is not None:
            d = pathlib.Path(obs_dir)
            d.mkdir(parents=True, exist_ok=True)
            sinks.append(NDJSONSink(d / f"bench_n{fleet_size}.ndjson"))
        if obs_live is not None:
            from repro.obs.sink import telemetry_loop
            loop = (telemetry_loop()
                    if obs_live.startswith("tcp://") else None)
            sinks.append(TransportSink(obs_live, loop=loop,
                                       source=f"bench_n{fleet_size}",
                                       flush_every=8))
        obs = BrokerObserver(
            sink=sinks[0] if len(sinks) == 1 else TeeSink(*sinks))
    scalar = run_scalar(predictor, requests)
    broker = run_broker(predictor, requests, clients=clients, impl=impl,
                        rate=rate, policy=policy, depth=depth,
                        max_delay=max_delay, obs=obs)
    saturated = run_saturated(predictor, requests, impl=impl)
    parity = (_parity(scalar, broker, saturated) if impl == "numpy"
              else None)
    open_runs = {}
    if open_loop:
        # auto rate: half the saturated row throughput converted to
        # requests/s, capped where per-message event-loop overhead (not
        # scoring) becomes the bottleneck — the point is tail behaviour
        # under heavy-but-feasible load, not a throughput contest
        mean_rows = scalar["rows"] / max(len(requests), 1)
        auto = min(0.5 * saturated["rows_per_s"] / max(mean_rows, 1e-9),
                   OPEN_RATE_CAP)
        configs = [(b, "poisson") for b in open_backends]
        if "inproc" in open_backends:
            configs.append(("inproc", "bursty"))
        for b, arr in configs:
            r = open_rate if open_rate > 0 else (
                auto if b == "inproc" else min(auto, TCP_RATE_CAP))
            # size the run to ~1s of schedule so the tail has enough samples
            n_open = int(min(max(len(requests), r), 60000))
            run = run_open_loop(
                predictor, requests, backend=b, arrivals=arr,
                clients=min(clients, 8), rate_rps=r, n_requests=n_open,
                slo_ms=slo_ms, impl=impl, seed=seed)
            run["parity"] = (_parity_mod(scalar["outputs"], run["outputs"])
                             if impl == "numpy" else None)
            open_runs[f"{b}_{arr}"] = run
    return summarize(scalar, broker, saturated, parity, fleet_size,
                     open_runs)


def run_bench_sizes(fleet_sizes, **kw) -> dict:
    """The full bench at each fleet size; the first size is the primary
    summary, every size lands under ``per_fleet_size``."""
    sizes = list(fleet_sizes) or [0]
    summary = None
    per_size = {}
    for size in sizes:
        s = run_bench(fleet_size=size, **kw)
        per_size[str(size)] = s
        if summary is None:
            summary = dict(s)     # copy: the primary also sits in per_size
    summary["per_fleet_size"] = per_size
    return summary


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.online.bench",
        description="Broker load generator: replay fleet decision streams")
    ap.add_argument("--rows", type=int, default=6000)
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--workload", default="smoke")
    ap.add_argument("--scenario", default="bursty_tt")
    ap.add_argument("--impl", default="numpy",
                    choices=("numpy", "auto", "xla", "pallas", "interpret"))
    ap.add_argument("--rate", type=float, default=0.0,
                    help="per-client request rate (req/s, 0 = max)")
    ap.add_argument("--fleet-sizes", default="0",
                    help="comma list of fleet sizes to bench (0 = the "
                         "paper's 13-slave fleet); first is the primary "
                         "summary, all land in per_fleet_size")
    ap.add_argument("--policy", default="barrier",
                    choices=("barrier", "depth"),
                    help="broker flush policy (depth = queue-depth with "
                         "bounded delay; non-deterministic flush counts)")
    ap.add_argument("--depth", type=int, default=256,
                    help="queue-depth flush threshold in rows (policy=depth)")
    ap.add_argument("--max-delay", type=float, default=0.002,
                    help="bounded flush delay in seconds (policy=depth)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-open-loop", action="store_true",
                    help="skip the open-loop AsyncBroker section")
    ap.add_argument("--open-rate", type=float, default=0.0,
                    help="aggregate open-loop arrival rate (req/s; 0 = auto "
                         "from the saturated throughput)")
    ap.add_argument("--open-backends", default="inproc,tcp",
                    help="comma list of transport backends for the "
                         "open-loop section (inproc,tcp)")
    ap.add_argument("--slo-ms", type=float, default=25.0,
                    help="open-loop per-request latency budget (drives the "
                         "broker's early-flush safety valve + the "
                         "violation-rate metric)")
    ap.add_argument("--out", default="experiments",
                    help="directory for ONLINE.json")
    ap.add_argument("--stamp-sweep", nargs="?", const="experiments/SWEEP.json",
                    default=None, metavar="SWEEP_JSON",
                    help="merge the summary into an existing SWEEP.json/.md")
    ap.add_argument("--obs", action="store_true",
                    help="attach a BrokerObserver: per-flush NDJSON frames "
                         "under <out>/obs/ and an obs block in BENCH_<pr>")
    ap.add_argument("--obs-live", default=None, metavar="ADDR",
                    help="also stream broker flush frames to a live "
                         "TelemetryCollector at this transport address "
                         "(see python -m repro.obs.live)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run (fewer rows/clients)")
    args = ap.parse_args(argv)

    rows, clients = args.rows, args.clients
    if args.smoke:
        rows, clients = min(rows, 2000), min(clients, 12)
    fleet_sizes = [int(s) for s in args.fleet_sizes.split(",")]
    obs_dir = str(pathlib.Path(args.out) / "obs") if args.obs else None
    summary = run_bench_sizes(
        fleet_sizes, rows=rows, clients=clients, workload=args.workload,
        scenario=args.scenario, impl=args.impl, rate=args.rate,
        seed=args.seed, policy=args.policy, depth=args.depth,
        max_delay=args.max_delay, obs_dir=obs_dir, obs_live=args.obs_live,
        open_loop=not args.no_open_loop, open_rate=args.open_rate,
        open_backends=tuple(args.open_backends.split(",")),
        slo_ms=args.slo_ms)

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    (out / "ONLINE.json").write_text(
        json.dumps(summary, indent=2, sort_keys=True) + "\n")
    # per-PR perf artifact: BENCH_<n>.json accumulates the trajectory across
    # PRs (one file per PR_TAG, re-runs overwrite their own PR's file)
    m = re.match(r"PR(\d+)", repro.PR_TAG)
    if m:
        bench_art = {
            "pr": repro.PR_TAG,
            **_size_block(summary),
            "per_fleet_size": {size: _size_block(s) for size, s in
                               summary["per_fleet_size"].items()},
        }
        if args.obs:
            # per-size broker telemetry roll-up (flush hists + latency)
            bench_art["obs"] = {
                size: s_sz["broker"].get("obs")
                for size, s_sz in summary["per_fleet_size"].items()}
        (out / f"BENCH_{m.group(1)}.json").write_text(
            json.dumps(bench_art, indent=2, sort_keys=True) + "\n")
    b, s, f = summary["broker"], summary["scalar"], summary["saturated"]
    print(f"[online] scalar    : {s['rows']} rows, {s['dispatches']} "
          f"dispatches, {s['rows_per_s']:,.0f} rows/s "
          f"({s['per_decision_rows_per_s']:,.0f} rows/s per-decision)")
    print(f"[online] broker    : {b['rows']} rows, {b['dispatches']} "
          f"dispatches ({b['flushes']} flushes, max batch "
          f"{b['max_flush_rows']} rows), {b['rows_per_s']:,.0f} rows/s "
          f"[p50 {b['latency_ms']['p50']:.2f} ms, "
          f"p99 {b['latency_ms']['p99']:.2f} ms]")
    print(f"[online] saturated : {f['rows']} rows, {f['dispatches']} "
          f"dispatches ({f['flushes']} flushes), "
          f"{f['rows_per_s']:,.0f} rows/s")
    print(f"[online] batched speedup {summary['speedup']:.1f}x "
          f"({summary['speedup_vs_per_decision']:.1f}x vs per-decision), "
          f"dispatch reduction {summary['dispatch_reduction']:.1f}x, "
          f"parity={summary['parity']}")
    for cfg, r in sorted(summary.get("open_loop", {}).items()):
        lm = r["latency_ms"]
        print(f"[online] open-loop {cfg:>14s}: {r['rate_rps']:,.0f} req/s "
              f"offered, {r['rows_per_s']:,.0f} rows/s served "
              f"[p50 {lm['p50']:.2f} p95 {lm['p95']:.2f} "
              f"p99 {lm['p99']:.2f} ms, "
              f"{100 * r['slo_violation_rate']:.1f}% > {r['slo_ms']:.0f} ms "
              f"SLO], parity={r['parity']}")
    if len(summary["per_fleet_size"]) > 1:
        for size, s_sz in sorted(summary["per_fleet_size"].items(),
                                 key=lambda kv: int(kv[0])):
            blk = _size_block(s_sz)
            label = "paper(13)" if size == "0" else size
            print(f"[online] fleet {label:>9s}: "
                  f"{blk['batched_rows_per_s']:>10,.0f} batched rows/s, "
                  f"broker p50 {blk['latency_ms']['p50']:.2f} ms "
                  f"p99 {blk['latency_ms']['p99']:.2f} ms, "
                  f"parity={blk['parity']}")
    if args.stamp_sweep:
        if stamp_sweep(summary, args.stamp_sweep):
            print(f"[online] stamped perf into {args.stamp_sweep}")
        else:
            print(f"[online] no {args.stamp_sweep} to stamp (run the sweep "
                  "first)")

    bad = any(s_sz["broker"]["rows_per_s"] <= 0
              or s_sz["saturated"]["rows_per_s"] <= 0
              or s_sz["parity"] is False
              for s_sz in summary["per_fleet_size"].values())
    if bad:
        print("[online] FAIL: no batched throughput or parity break",
              file=sys.stderr)
        return 1
    # tail-latency budget: every open-loop config must hold p99 within 10x
    # of its p50 (with an absolute floor so sub-ms p50s don't gate on noise)
    # and keep its outputs bit-identical to the scalar baseline
    for s_sz in summary["per_fleet_size"].values():
        for cfg, r in s_sz.get("open_loop", {}).items():
            lm = r["latency_ms"]
            budget = max(10.0 * lm["p50"], P99_FLOOR_MS)
            if r["parity"] is False or lm["p99"] > budget:
                print(f"[online] FAIL: open-loop {cfg} p99 {lm['p99']:.2f} ms"
                      f" > budget {budget:.2f} ms or parity break"
                      f" (parity={r['parity']})", file=sys.stderr)
                return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
