"""Versioned model registry: trained ForestParams survive across processes.

Fleet cells ship *model versions* instead of raw training traces: the wave-1
worker trains once per (base, env), publishes, and every ATLAS cell on that env
loads the version — bit-identical scoring, no arrays over the process boundary.

Layout (one directory per version, ``checkpoint.store`` discipline — atomic
tmp-dir + rename, sha256 digests verified on load):

    <root>/<name>/
        v_000001/
            meta.json        algo/seed/fits, array digests+shapes, user meta
            params.npz       map__/reduce__ {feat_idx, thresholds, leaves}
        v_000002/ ...
        HEAD                 serving version (atomic os.replace)
        events.jsonl         append-only publish/promote/rollback ledger

Concurrent publishers of *different* names are safe (the fleet trains one
model per env).  Two writers racing on the same name would collide on the
version rename — by design loudly, not silently."""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import time

import numpy as np

from repro.ml.forest import ForestParams
from repro.util import array_digest

_ARRAYS = ("feat_idx", "thresholds", "leaves")


class ModelRegistry:
    def __init__(self, root):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------ paths
    def _dir(self, name: str) -> pathlib.Path:
        d = self.root / name
        if not d.resolve().is_relative_to(self.root.resolve()):
            raise ValueError(f"model name escapes the registry root: {name!r}")
        return d

    def _vdir(self, name: str, version: int) -> pathlib.Path:
        return self._dir(name) / f"v_{version:06d}"

    # ------------------------------------------------------------ queries
    def versions(self, name: str) -> list[int]:
        d = self._dir(name)
        out = []
        for p in d.glob("v_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def head(self, name: str) -> int | None:
        p = self._dir(name) / "HEAD"
        if not p.exists():
            return None
        return int(p.read_text().strip())

    def history(self, name: str) -> list[dict]:
        p = self._dir(name) / "events.jsonl"
        if not p.exists():
            return []
        return [json.loads(line) for line in p.read_text().splitlines() if line]

    # ------------------------------------------------------------ write
    def _record(self, name: str, event: dict):
        event = {"time": time.time(), **event}
        with (self._dir(name) / "events.jsonl").open("a") as f:
            f.write(json.dumps(event) + "\n")

    def publish(self, name: str, snapshot: dict, *, meta: dict | None = None,
                promote: bool = True) -> int:
        """Persist a ``TaskPredictor.snapshot()`` as the next version.
        ``promote=False`` archives a candidate without moving HEAD (the drift
        refresher records rejected candidates this way)."""
        d = self._dir(name)
        d.mkdir(parents=True, exist_ok=True)
        version = (self.versions(name) or [0])[-1] + 1
        tmp = d / f".tmp_v_{version:06d}"
        final = self._vdir(name, version)
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        arrays, digests, shapes = {}, {}, {}
        for kind in ("map", "reduce"):
            params = snapshot["models"].get(kind)
            if params is None:
                continue
            for field in _ARRAYS:
                arr = np.asarray(getattr(params, field))
                key = f"{kind}__{field}"
                arrays[key] = arr
                digests[key] = array_digest(arr)
                shapes[key] = list(arr.shape)
        np.savez(tmp / "params.npz", **arrays)
        (tmp / "meta.json").write_text(json.dumps({
            "version": version,
            "algo": snapshot["algo"], "seed": snapshot["seed"],
            "min_samples": snapshot["min_samples"],
            "max_train": snapshot["max_train"], "fits": snapshot["fits"],
            "kinds": sorted(k for k, v in snapshot["models"].items()
                            if v is not None),
            "digests": digests, "shapes": shapes,
            "meta": meta or {},
            "time": time.time(),
        }))
        tmp.rename(final)                       # atomic publish
        self._record(name, {"event": "publish", "version": version,
                            "promoted": promote, "meta": meta or {}})
        if promote:
            self._set_head(name, version, event=None)
        return version

    def _set_head(self, name: str, version: int, *, event: str | None):
        d = self._dir(name)
        tmp = d / ".HEAD.tmp"
        tmp.write_text(str(version))
        os.replace(tmp, d / "HEAD")             # atomic promote
        if event:
            self._record(name, {"event": event, "version": version})

    def promote(self, name: str, version: int):
        if version not in self.versions(name):
            raise KeyError(f"{name}: no version {version}")
        self._set_head(name, version, event="promote")

    def rollback(self, name: str) -> int:
        """Move HEAD to the newest version older than the current HEAD."""
        cur = self.head(name)
        older = [v for v in self.versions(name) if cur is None or v < cur]
        if not older:
            raise KeyError(f"{name}: nothing to roll back to")
        self._set_head(name, older[-1], event="rollback")
        return older[-1]

    # ------------------------------------------------------------ read
    def load(self, name: str, version: int | None = None,
             *, verify: bool = True) -> dict:
        """Load a version (default: HEAD) back into ``snapshot()`` form."""
        if version is None:
            version = self.head(name)
            if version is None:
                versions = self.versions(name)
                if not versions:
                    raise KeyError(f"{name}: no published versions")
                version = versions[-1]
        d = self._vdir(name, version)
        meta = json.loads((d / "meta.json").read_text())
        data = np.load(d / "params.npz")
        models: dict = {"map": None, "reduce": None}
        for kind in meta["kinds"]:
            fields = {}
            for field in _ARRAYS:
                key = f"{kind}__{field}"
                arr = data[key]
                if verify and array_digest(arr) != meta["digests"][key]:
                    raise IOError(
                        f"{name} v{version}: {key} digest mismatch (corrupt?)")
                fields[field] = arr
            models[kind] = ForestParams(**fields)
        return {"algo": meta["algo"], "seed": meta["seed"],
                "min_samples": meta["min_samples"],
                "max_train": meta["max_train"], "fits": meta["fits"],
                "models": models}
