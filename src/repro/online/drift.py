"""Drift monitor + incremental refresher: retrain when the environment moves,
not only when the §5.1 clock says so.

The companion paper (arXiv:1507.03562) shows prediction quality degrades unless
the models track the changing cluster; Google-trace analyses (arXiv:2308.02358)
confirm failure characteristics drift.  ATLAS's fixed 600 s retrain clock is
kept as a *staleness fallback*; on top of it:

* ``DriftMonitor`` keeps a sliding window of launch-time features, outcomes and
  the probabilities the live model served for them, and flags
  - **feature drift**: mean PSI (population stability index) between the
    training-time feature histograms and the window's, and
  - **score drift**: the window Brier score degrading past the training-time
    reference.

* ``OnlineRefresher`` is the control loop ATLAS calls on its (now finer)
  retrain events: ingest new trace rows, check the monitors, and on a trigger
  fit a *candidate* off to the side, evaluate it against the live model on the
  window, then promote (publish to the ``ModelRegistry`` + swap in) or reject
  (archive the candidate, keep serving the old version) — every transition
  recorded."""

from __future__ import annotations

from collections import deque

import numpy as np


class DriftMonitor:
    """Sliding-window drift statistics for one model kind (map or reduce)."""

    def __init__(self, window: int = 512, n_hist_bins: int = 8,
                 psi_threshold: float = 0.25, brier_threshold: float = 0.08,
                 min_window: int = 64):
        self.window = window
        self.n_hist_bins = n_hist_bins
        self.psi_threshold = psi_threshold
        self.brier_threshold = brier_threshold
        self.min_window = min_window
        self._rows: deque = deque(maxlen=window)   # (x, y, p)
        self._edges = None                         # (F, bins-1) quantile edges
        self._ref_frac = None                      # (F, bins) reference mass
        self.reference_brier: float | None = None

    # ------------------------------------------------------------ reference
    def set_reference(self, X: np.ndarray, brier: float | None = None):
        """Anchor the monitor to the training distribution (at fit time)."""
        X = np.asarray(X, np.float32)
        qs = np.linspace(0.0, 1.0, self.n_hist_bins + 1)[1:-1]
        self._edges = np.quantile(X, qs, axis=0).T                 # (F, b-1)
        self._ref_frac = self._fractions(X)
        self.reference_brier = brier

    def _fractions(self, X: np.ndarray) -> np.ndarray:
        F = X.shape[1]
        out = np.empty((F, self.n_hist_bins), np.float64)
        for f in range(F):
            idx = np.searchsorted(self._edges[f], X[:, f], side="right")
            out[f] = np.bincount(idx, minlength=self.n_hist_bins) / X.shape[0]
        return out

    # ------------------------------------------------------------ streaming
    def observe(self, X: np.ndarray, y: np.ndarray, p: np.ndarray):
        for row, label, prob in zip(X, y, p):
            self._rows.append((row, float(label), float(prob)))

    def window_arrays(self):
        if not self._rows:
            return (np.zeros((0, 1), np.float32), np.zeros(0, np.float32),
                    np.zeros(0, np.float32))
        X = np.stack([r[0] for r in self._rows])
        y = np.asarray([r[1] for r in self._rows], np.float32)
        p = np.asarray([r[2] for r in self._rows], np.float32)
        return X, y, p

    # ------------------------------------------------------------ signals
    def feature_psi(self) -> float:
        """Mean PSI over features between reference and window histograms."""
        if self._edges is None or len(self._rows) < self.min_window:
            return 0.0
        X, _, _ = self.window_arrays()
        cur = self._fractions(X)
        eps = 1e-4
        q = np.clip(self._ref_frac, eps, None)
        pfrac = np.clip(cur, eps, None)
        psi = ((pfrac - q) * np.log(pfrac / q)).sum(axis=1)        # per feature
        return float(psi.mean())

    def window_brier(self) -> float | None:
        if len(self._rows) < self.min_window:
            return None
        _, y, p = self.window_arrays()
        return float(np.mean((p - y) ** 2))

    def score_drift(self) -> float:
        wb = self.window_brier()
        if wb is None or self.reference_brier is None:
            return 0.0
        return wb - self.reference_brier

    def signals(self) -> dict:
        """All drift signals in one pass: {psi, brier, score_drift} — what
        the telemetry layer records per check and ``drifted`` thresholds."""
        wb = self.window_brier()
        sd = (0.0 if wb is None or self.reference_brier is None
              else wb - self.reference_brier)
        return {"psi": self.feature_psi(), "brier": wb, "score_drift": sd}

    def drifted(self) -> tuple[bool, str | None]:
        s = self.signals()
        if s["psi"] > self.psi_threshold:
            return True, f"feature_psi={s['psi']:.3f}"
        if s["score_drift"] > self.brier_threshold:
            return True, f"brier_drift={s['score_drift']:.3f}"
        return False, None


class OnlineRefresher:
    """Drift-aware predictor lifecycle: monitor -> candidate -> promote/reject.

    Deterministic given the trace: no wall-clock, no randomness beyond the
    predictor's own seeded subsampling."""

    def __init__(self, *, registry=None, name: str = "online",
                 retrain_every: float = 600.0, check_every: float = 60.0,
                 min_new_rows: int = 16, promote_tolerance: float = 0.02,
                 monitor_kw: dict | None = None):
        self.registry = registry
        self.name = name
        self.retrain_every = retrain_every
        self.check_every = check_every
        self.min_new_rows = min_new_rows
        self.promote_tolerance = promote_tolerance
        self.monitors = {k: DriftMonitor(**(monitor_kw or {}))
                         for k in ("map", "reduce")}
        self.predictor = None
        self.obs = None            # optional repro.obs.SimObserver
        self.events: list[dict] = []
        self.refreshes = 0
        self.promotions = 0
        self.rollbacks = 0
        self._cursor = {"map": 0, "reduce": 0}
        self._last_fit_at = 0.0
        self._baselined = False
        self._now = 0.0

    def bind_predictor(self, predictor):
        self.predictor = predictor

    # ------------------------------------------------------------ ingestion
    def _new_rows(self, trace):
        (mx, my), (rx, ry) = trace.datasets()
        out = {}
        for kind, X, y in (("map", mx, my), ("reduce", rx, ry)):
            c = self._cursor[kind]
            out[kind] = (X[c:], y[c:])
            self._cursor[kind] = X.shape[0]
        return out

    # ------------------------------------------------------------ control
    def step(self, sim) -> bool:
        """Ingest new outcomes, check drift + staleness, maybe refresh.
        Returns True when a retrain was attempted."""
        pred = self.predictor
        self._now = sim.now
        if pred.ready and not self._baselined:
            # pre-fitted predictor (fleet payload / compare()): anchor the
            # reference now, or both drift signals stay inert until the first
            # staleness-clock promotion gets around to rebaselining
            self._rebaseline(sim.trace)
        new = self._new_rows(sim.trace)
        n_new = 0
        for kind, (X, y) in new.items():
            if X.shape[0] == 0 or pred.model_for_kind(kind) is None:
                continue
            p = pred.predict_batch(kind, X)    # one batched dispatch per kind
            self.monitors[kind].observe(X, y, p)
            n_new += X.shape[0]

        stale = sim.now - self._last_fit_at >= self.retrain_every
        reason = "staleness" if stale else None
        for kind, mon in self.monitors.items():
            if self.obs is None and reason is not None:
                break                          # obs-off: original early exit
            s = mon.signals()
            if self.obs is not None:
                self.obs.record_drift(sim.now, kind, s["psi"], s["brier"],
                                      s["score_drift"])
            if reason is None:
                if s["psi"] > mon.psi_threshold:
                    reason = f"{kind}:feature_psi={s['psi']:.3f}"
                elif s["score_drift"] > mon.brier_threshold:
                    reason = f"{kind}:brier_drift={s['score_drift']:.3f}"
        if reason is None:
            return False
        if not pred.ready and n_new == 0 and not stale:
            return False
        return self._refresh(sim, reason)

    def _holdout_datasets(self, trace):
        """Training data for a candidate, with each monitor's sliding window
        (the most recent rows, ingested in trace order) held out — the duel in
        ``_judge`` scores the candidate on those rows, and a candidate that
        trained on them would win on in-sample fit, not on tracking reality."""
        (mx, my), (rx, ry) = trace.datasets()
        out = []
        for kind, X, y in (("map", mx, my), ("reduce", rx, ry)):
            w = len(self.monitors[kind]._rows)
            if w and X.shape[0] > w:
                X, y = X[:-w], y[:-w]
            out.append((X, y))
        return out

    def _refresh(self, sim, reason: str) -> bool:
        from repro.core.predictor import TaskPredictor
        pred = self.predictor
        self._last_fit_at = sim.now
        self.refreshes += 1
        candidate = TaskPredictor(algo=pred.algo, min_samples=pred.min_samples,
                                  max_train=pred.max_train, seed=pred.seed)
        candidate.fits = pred.fits             # keep the subsample-rng stream
        if not candidate.fit_datasets(*self._holdout_datasets(sim.trace)):
            self._event("skip", reason=reason, detail="not enough samples")
            return True

        verdict, detail = self._judge(candidate)
        if verdict:
            pred.adopt(candidate)
            self.promotions += 1
            version = None
            if self.registry is not None:
                version = self.registry.publish(
                    self.name, pred.snapshot(),
                    meta={"reason": reason, "sim_now": sim.now}, promote=True)
            self._event("promote", reason=reason, detail=detail,
                        version=version)
            self._rebaseline(sim.trace)
        else:
            self.rollbacks += 1
            version = None
            if self.registry is not None:
                version = self.registry.publish(
                    self.name, candidate.snapshot(),
                    meta={"reason": reason, "rejected": True,
                          "sim_now": sim.now}, promote=False)
            self._event("rollback", reason=reason, detail=detail,
                        version=version)
        return True

    def _judge(self, candidate) -> tuple[bool, str]:
        """Hold-out duel on the sliding window: the candidate must not be
        meaningfully worse than the live model on recent reality."""
        pred = self.predictor
        old_b, new_b, n = 0.0, 0.0, 0
        for kind, mon in self.monitors.items():
            X, y, p_old = mon.window_arrays()
            if X.shape[0] < mon.min_window:
                continue
            if candidate.model_for_kind(kind) is None:
                continue
            p_new = candidate.predict_batch(kind, X)
            old_b += float(np.sum((p_old - y) ** 2))
            new_b += float(np.sum((p_new - y) ** 2))
            n += X.shape[0]
        if n == 0:
            return True, "no window evidence; promote"
        old_b, new_b = old_b / n, new_b / n
        ok = new_b <= old_b + self.promote_tolerance
        return ok, f"window_brier old={old_b:.4f} new={new_b:.4f}"

    def _rebaseline(self, trace):
        """Re-anchor the monitors to the live model's training view."""
        (mx, my), (rx, ry) = trace.datasets()
        pred = self.predictor
        for kind, X, y in (("map", mx, my), ("reduce", rx, ry)):
            if X.shape[0] == 0 or pred.model_for_kind(kind) is None:
                continue
            p = pred.predict_batch(kind, X)
            self.monitors[kind].set_reference(
                X, brier=float(np.mean((p - y) ** 2)))
            self._baselined = True

    def _event(self, event: str, **kw):
        self.events.append({"event": event, **kw})
        if self.obs is not None:               # lifecycle marker into frames
            self.obs.record_event(event, self._now, **kw)
