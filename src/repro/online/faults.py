"""Fault injection for the serving stack — seeded, deterministic chaos on
the ``repro.online.transport`` wire.

ATLAS's thesis is that schedulers must absorb failures instead of letting one
unforeseen event kill a job; this module points the same discipline at our
own serving path.  A :class:`FaultPlan` is a typed, bounded point in
fault-space (mirroring ``cluster.scenarios.ScenarioSpec``: declared
:class:`~repro.cluster.scenarios.Bound` ranges, ``validate``, exact
``to_dict``/``from_dict`` round-trip, seeded ``sample``) describing a
schedule of message-level faults:

    drop          a sent message silently vanishes
    delay         a sent message is held for a drawn interval first
    duplicate     a sent message arrives twice
    abrupt_close  the connection dies mid-conversation (no clean EOF)
    restart_after the listener itself goes down and rebinds (broker restart)

:class:`FaultInjector` turns a plan into wrapped comms: every fault draw is
keyed to ``(plan.seed, connection index, message index)`` through one
``random.Random`` stream per connection, so inproc and tcp transports —
which share none of their I/O machinery — exercise *identical* fault
schedules, and a failing chaos run replays exactly from its plan.

The client-side half of the contract lives here too: ``backoff_delay`` is
the capped exponential backoff with deterministic jitter that
``BrokerClient`` sleeps between retries (bounded by ``cap``, monotone in the
``min(cap, base * 2**attempt)`` envelope, bit-reproducible for a given
seed — property-tested in ``tests/test_faults_property.py``), and
:class:`PredictorUnavailableError` is what a client raises once its retry
budget is spent — the signal ``BrokerPredictor`` converts into the paper's
graceful degradation (schedule anyway, never fail the task).
"""

from __future__ import annotations

import asyncio
import dataclasses
import itertools
import random
import zlib

from repro.cluster.scenarios import Bound, _decode_cfg, _encode_cfg, _r6
from repro.online.transport import Comm, CommClosedError


class PredictorUnavailableError(RuntimeError):
    """The broker stayed unreachable past the client's retry/deadline budget.

    Deliberately *not* a ``CommClosedError``: transport errors are retried
    transparently; this is the post-retry verdict that triggers graceful
    degradation (``BrokerPredictor`` falls back to the deterministic
    schedule-anyway decision instead of failing the task)."""


# ---------------------------------------------------------------------------
# Deterministic capped exponential backoff
# ---------------------------------------------------------------------------

def backoff_delay(attempt: int, *, base: float = 0.05, cap: float = 1.0,
                  seed: int = 0) -> float:
    """Retry sleep for ``attempt`` (0-based): jittered capped exponential.

    The envelope is ``min(cap, base * 2**attempt)`` and the jitter scales it
    into ``[envelope/2, envelope]`` — so every delay is bounded by ``cap``,
    the envelope is monotone until it saturates, and the value is a pure
    function of ``(seed, attempt)`` (the jitter comes from a CRC32-seeded
    ``random.Random``, never from global RNG state or the clock)."""
    if attempt < 0:
        raise ValueError(f"attempt must be >= 0, got {attempt}")
    envelope = min(float(cap), float(base) * (2.0 ** attempt))
    u = random.Random(
        zlib.crc32(f"backoff|{seed}|{attempt}".encode())).random()
    return envelope * (0.5 + 0.5 * u)


def backoff_schedule(n: int, *, base: float = 0.05, cap: float = 1.0,
                     seed: int = 0) -> list[float]:
    """The first ``n`` retry delays for a seed (tests/docs convenience)."""
    return [backoff_delay(i, base=base, cap=cap, seed=seed)
            for i in range(n)]


# ---------------------------------------------------------------------------
# FaultPlan — the typed, serialisable fault-space point
# ---------------------------------------------------------------------------

# Declared ranges, ScenarioSpec-style.  Probabilities cap at 0.5: above
# that, retry traffic compounds faster than it drains and the plan stops
# describing a degraded service and starts describing a dead one.
FAULT_BOUNDS: dict[str, Bound] = {
    "seed": Bound(0, 2 ** 31 - 1, kind="int"),
    "drop": Bound(0.0, 0.5),
    "delay": Bound(0.0, 0.5),
    "delay_s": Bound(0.0, 0.25),          # injected latency span (seconds)
    "duplicate": Bound(0.0, 0.5),
    "abrupt_close": Bound(0.0, 0.25),
    "max_events": Bound(0, 4096, kind="int"),
    "request_timeout_s": Bound(0.01, 60.0, log=True),
    "deadline_s": Bound(0.1, 600.0, log=True),
}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One seeded fault schedule plus the client resilience knobs that make
    it survivable.  Frozen + exactly serialisable: a chaos run is reproduced
    from nothing but its plan dict.

    ``drop``/``delay``/``duplicate``/``abrupt_close`` are per-message
    probabilities (one uniform draw per sent message picks at most one
    fault); ``delay_s`` is the (lo, hi) span injected delays are drawn from;
    ``restart_after`` lists server-side received-message counts at which the
    listener restarts (the broker-restart event); ``max_events`` caps total
    injected faults so retry overhead stays bounded.  ``request_timeout_s``
    and ``deadline_s`` ride along because a faulted run and its clean
    control must share one client configuration surface."""

    seed: int = 0
    drop: float = 0.0
    delay: float = 0.0
    delay_s: tuple = (0.001, 0.01)
    duplicate: float = 0.0
    abrupt_close: float = 0.0
    restart_after: tuple = ()
    max_events: int = 64
    request_timeout_s: float = 0.25
    deadline_s: float = 30.0

    # ------------------------------------------------------------ validation
    def validate(self) -> "FaultPlan":
        for name in ("drop", "delay", "duplicate", "abrupt_close"):
            v = getattr(self, name)
            b = FAULT_BOUNDS[name]
            if not (b.lo <= v <= b.hi):
                raise ValueError(
                    f"{name}={v} outside [{b.lo}, {b.hi}]")
        mass = self.drop + self.delay + self.duplicate + self.abrupt_close
        if mass > 1.0:
            raise ValueError(
                f"fault probabilities sum to {mass} > 1 (one draw per "
                "message picks at most one fault)")
        lo, hi = self.delay_s
        b = FAULT_BOUNDS["delay_s"]
        if not (b.lo <= lo <= hi <= b.hi):
            raise ValueError(f"delay_s span {self.delay_s} invalid "
                             f"(want {b.lo} <= lo <= hi <= {b.hi})")
        if not (FAULT_BOUNDS["seed"].lo <= self.seed
                <= FAULT_BOUNDS["seed"].hi):
            raise ValueError(f"seed {self.seed} out of range")
        if not (FAULT_BOUNDS["max_events"].lo <= self.max_events
                <= FAULT_BOUNDS["max_events"].hi):
            raise ValueError(f"max_events {self.max_events} out of range")
        prev = 0
        for r in self.restart_after:
            if not isinstance(r, int) or r <= prev:
                raise ValueError(
                    f"restart_after must be strictly increasing positive "
                    f"ints, got {self.restart_after}")
            prev = r
        for name in ("request_timeout_s", "deadline_s"):
            v = getattr(self, name)
            b = FAULT_BOUNDS[name]
            if not (b.lo <= v <= b.hi):
                raise ValueError(f"{name}={v} outside [{b.lo}, {b.hi}]")
        return self

    # ------------------------------------------------------------ round trip
    def to_dict(self) -> dict:
        return _encode_cfg(self)

    @classmethod
    def from_dict(cls, payload: dict) -> "FaultPlan":
        plan = _decode_cfg(cls, dict(payload))
        return dataclasses.replace(
            plan,
            delay_s=tuple(float(v) for v in plan.delay_s),
            restart_after=tuple(int(v) for v in plan.restart_after),
        ).validate()

    # ------------------------------------------------------------ sampling
    @classmethod
    def sample(cls, rng: random.Random) -> "FaultPlan":
        """A random valid plan (property tests / chaos search seeds)."""
        probs = {name: _r6(rng.uniform(0.0, FAULT_BOUNDS[name].hi / 2))
                 for name in ("drop", "delay", "duplicate", "abrupt_close")}
        mass = sum(probs.values())
        if mass > 1.0:
            probs = {k: _r6(v / mass) for k, v in probs.items()}
        b = FAULT_BOUNDS["delay_s"]
        lo = _r6(rng.uniform(b.lo, b.hi))
        hi = _r6(rng.uniform(lo, b.hi))
        n_restarts = rng.randint(0, 2)
        at, restarts = 0, []
        for _ in range(n_restarts):
            at += rng.randint(1, 64)
            restarts.append(at)
        return cls(seed=rng.randint(0, 2 ** 31 - 1), delay_s=(lo, hi),
                   restart_after=tuple(restarts),
                   max_events=rng.randint(0, 256), **probs).validate()


# ---------------------------------------------------------------------------
# Injection machinery: plan -> wrapped comms
# ---------------------------------------------------------------------------

_NO_FAULT = "none"


class FaultInjector:
    """Shared schedule state for one plan: per-connection RNG streams, the
    global injected-event budget, the listener-restart trigger, and the
    fault counters a chaos gate asserts on.

    ``wrap(comm)`` returns a :class:`FaultyComm`; ``wrap_handler(handler)``
    produces a listener handler that wraps every accepted server-side comm
    (and counts its received messages toward ``restart_after``).  All
    mutation happens on the owning event loop's thread."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan.validate()
        self._conn_seq = itertools.count()
        self._restarts_pending = list(plan.restart_after)
        self.on_restart = None           # callback set by the server owner
        self.active: set = set()         # live wrapped server-side comms
        # counters (reporting only)
        self.n_events = 0
        self.n_drops = 0
        self.n_delays = 0
        self.n_duplicates = 0
        self.n_closes = 0
        self.n_restarts = 0
        self.n_messages_in = 0           # server-side received messages

    # ------------------------------------------------------------ wrapping
    def _rng_for_conn(self, conn_index: int) -> random.Random:
        return random.Random(zlib.crc32(
            f"faults|{self.plan.seed}|conn{conn_index}".encode()))

    def wrap(self, comm: Comm, *, side: str = "client") -> "FaultyComm":
        return FaultyComm(comm, self, next(self._conn_seq), side=side)

    def wrap_handler(self, handler):
        """Wrap a listener handler so every accepted comm is fault-injected
        and tracked (for abrupt close-all on a listener restart)."""
        async def faulty_handler(comm):
            wrapped = self.wrap(comm, side="server")
            self.active.add(wrapped)
            try:
                await handler(wrapped)
            finally:
                self.active.discard(wrapped)
        return faulty_handler

    # ------------------------------------------------------------ scheduling
    def _budget_left(self) -> bool:
        return self.n_events < self.plan.max_events

    def draw(self, rng: random.Random) -> tuple[str, float]:
        """One fault decision for one outgoing message.  Exactly one
        ``rng.random()`` (plus one more for a delay value) per message, so
        the schedule depends only on the per-connection draw sequence —
        never on which faults actually fire or on transport internals."""
        u = rng.random()
        p = self.plan
        delay_v = 0.0
        if u < p.delay + p.drop + p.duplicate + p.abrupt_close:
            # keep the stream position independent of which branch fires
            lo, hi = p.delay_s
            delay_v = lo + (hi - lo) * rng.random()
        if not self._budget_left():
            return _NO_FAULT, 0.0
        if u < p.drop:
            return "drop", 0.0
        if u < p.drop + p.delay:
            return "delay", delay_v
        if u < p.drop + p.delay + p.duplicate:
            return "duplicate", 0.0
        if u < p.drop + p.delay + p.duplicate + p.abrupt_close:
            return "abrupt_close", 0.0
        return _NO_FAULT, 0.0

    def record(self, fault: str):
        self.n_events += 1
        if fault == "drop":
            self.n_drops += 1
        elif fault == "delay":
            self.n_delays += 1
        elif fault == "duplicate":
            self.n_duplicates += 1
        elif fault == "abrupt_close":
            self.n_closes += 1

    # ------------------------------------------------------------ restarts
    def note_message_in(self):
        """Count one server-side received message; fire a listener restart
        when the count crosses the next ``restart_after`` threshold."""
        self.n_messages_in += 1
        if (self._restarts_pending
                and self.n_messages_in >= self._restarts_pending[0]
                and self.on_restart is not None):
            self._restarts_pending.pop(0)
            self.n_restarts += 1
            self.on_restart()

    async def close_active(self):
        """Abruptly close every live wrapped comm (a restart severs all
        established connections, not just the accept socket)."""
        for wrapped in list(self.active):
            try:
                await wrapped.inner.close()
            except Exception:           # already dying — that's the point
                pass
        self.active.clear()

    def stats(self) -> dict:
        return {"events": self.n_events, "drops": self.n_drops,
                "delays": self.n_delays, "duplicates": self.n_duplicates,
                "closes": self.n_closes, "restarts": self.n_restarts,
                "messages_in": self.n_messages_in}


class FaultyComm(Comm):
    """A ``Comm`` decorator applying the plan's faults on ``send``.

    Receiving passes through untouched (drops/dups/delays are modelled at
    the sender, which covers both directions once both sides wrap), except
    that server-side receives tick the injector's restart trigger.  Faults
    never change message *content* — only whether/when/how often a message
    arrives — so a retried request replays bit-identically."""

    def __init__(self, inner: Comm, injector: FaultInjector,
                 conn_index: int, *, side: str = "client"):
        self.inner = inner
        self.injector = injector
        self.conn_index = conn_index
        self.side = side
        self._rng = injector._rng_for_conn(conn_index)
        self.local_addr = inner.local_addr
        self.peer_addr = inner.peer_addr

    async def send(self, msg) -> None:
        fault, delay_v = self.injector.draw(self._rng)
        if fault != _NO_FAULT:
            self.injector.record(fault)
        if fault == "drop":
            return                       # vanished on the wire
        if fault == "delay":
            await asyncio.sleep(delay_v)
            await self.inner.send(msg)
            return
        if fault == "duplicate":
            await self.inner.send(msg)
            await self.inner.send(msg)
            return
        if fault == "abrupt_close":
            await self.inner.close()
            raise CommClosedError(
                f"fault injection: abrupt close on conn {self.conn_index}")
        await self.inner.send(msg)

    async def recv(self):
        msg = await self.inner.recv()
        if self.side == "server":
            self.injector.note_message_in()
        return msg

    async def close(self) -> None:
        await self.inner.close()

    @property
    def closed(self) -> bool:
        return self.inner.closed
