"""Scheduler interface + the three Hadoop baselines (§2.3).

A scheduler turns the simulator's pending queue into (task, node) launches.  The
baselines also carry Hadoop's stock straggler speculation (one copy for slow tasks),
so ATLAS's *multiple predicted-failure* speculation is measured against a fair
baseline."""

from __future__ import annotations

import dataclasses
from collections import defaultdict

from repro.cluster.simulator import MAP, Node, Task


@dataclasses.dataclass
class SchedulerStats:
    """The documented ``Scheduler.stats()`` schema, shared by all four
    schedulers (FIFO/Fair/Capacity return exactly these two counters; ATLAS
    returns the :class:`repro.core.atlas.AtlasStats` extension).

    launches            every attempt handed to ``Simulator.launch``
    speculative_copies  redundant copies among them, whatever the trigger
                        (straggler speculation here; predicted-failure
                        replication under ATLAS)
    """
    launches: int = 0
    speculative_copies: int = 0

    def to_dict(self) -> dict:
        """JSON-ready form: field order, ``None``-valued optional extension
        fields omitted — byte-compatible with the pre-PR8 ad-hoc dicts."""
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}


class Scheduler:
    name = "base"

    def __init__(self):
        self.n_launches = 0
        self.n_speculative_copies = 0

    def bind(self, sim):
        self.sim = sim

    # --- hooks
    def on_tick(self):
        self.schedule()
        self.speculate_stragglers()

    def on_heartbeat(self, node: Node):
        pass

    def on_retrain(self):
        pass

    # --- helpers shared by all policies
    def _runnable(self):
        """Pending task keys, resolved and filtered (drops stale keys)."""
        sim = self.sim
        out = []
        seen = set()
        while sim.pending:
            key = sim.pending.popleft()
            if key in seen:
                continue
            seen.add(key)
            t = sim._task_by_key(key)
            if t is not None and t.status == "pending":
                out.append(t)
        return out

    def _requeue(self, tasks):
        for t in tasks:
            self.sim.pending.append(t.key)

    def _free_nodes(self, kind: str):
        """Nodes the JobTracker *believes* are schedulable with a free slot —
        read from the simulator's incremental indices (O(free) per call, not
        a rebuild over the whole fleet)."""
        return self.sim.free_nodes(kind)

    def _pick_node(self, task: Task, nodes):
        """Prefer data-local nodes for maps, then least loaded."""
        if not nodes:
            return None
        if task.kind == MAP and task.block_nodes:
            local = [n for n in nodes if n.nid in task.block_nodes]
            if local:
                nodes = local
        return min(nodes, key=lambda n: (len(n.running), n.nid))

    def launch(self, task: Task, node: Node, *, speculative=False):
        self.n_launches += 1
        self.n_speculative_copies += int(speculative)
        return self.sim.launch(task, node, speculative=speculative)

    def stats(self) -> SchedulerStats:
        """Uniform per-run counters every scheduler exposes; the fleet sweep
        surfaces ``stats().to_dict()`` per cell (ATLAS extends the schema
        with its Algorithm-1 counters — see :class:`SchedulerStats`)."""
        return SchedulerStats(launches=self.n_launches,
                              speculative_copies=self.n_speculative_copies)

    def frame_stats(self) -> dict:
        """Cheap live-state snapshot for the obs layer's per-frame gather:
        ``{"penalty_box": int, "pred": dict | None}``.  Base schedulers have
        no penalty box and no predictor; ATLAS overrides both fields."""
        return {"penalty_box": 0, "pred": None}

    # --- policy body
    def schedule(self):
        raise NotImplementedError

    # --- stock Hadoop speculation (single copy for stragglers)
    def speculate_stragglers(self):
        sim = self.sim
        for job in sim.jobs.values():
            if job.status != "running":
                continue
            # counter gate first: the task scan only runs for jobs already
            # half-done (this loop fires on every simulator event)
            if job.n_finished_tasks < max(2, len(job.tasks) // 2):
                continue
            done = [t for t in job.tasks.values() if t.status == "finished"]
            med = sorted(t.done_time - t.first_submit for t in done)[len(done) // 2]
            for t in job.tasks.values():
                if t.status != "running" or len(t.live_attempts) != 1:
                    continue
                (aid,) = t.live_attempts
                att = sim.attempts[aid]
                if att.speculative or sim.now - att.start < 1.5 * max(med, 30.0):
                    continue
                nodes = self._free_nodes(t.kind)
                nodes = [n for n in nodes if n.nid != att.node.nid]
                if nodes:
                    self.launch(t, self._pick_node(t, nodes), speculative=True)


class FIFOScheduler(Scheduler):
    """Strict submission order; head-of-line blocking included."""
    name = "fifo"

    def schedule(self):
        tasks = self._runnable()
        tasks.sort(key=lambda t: (self.sim.jobs[t.job_id].submit_time, t.job_id,
                                  t.tid))
        blocked = []
        for t in tasks:
            nodes = self._free_nodes(t.kind)
            if not nodes:
                blocked.append(t)
                continue
            self.launch(t, self._pick_node(t, nodes))
        self._requeue(blocked)


class FairScheduler(Scheduler):
    """Fair sharing: repeatedly grant a slot to the job with the smallest
    running-share (weighted by priority)."""
    name = "fair"

    def schedule(self):
        sim = self.sim
        tasks = self._runnable()
        if not tasks:
            return
        by_job = defaultdict(list)
        for t in tasks:
            by_job[t.job_id].append(t)
        running = defaultdict(int)
        for att in sim.attempts.values():
            if att.status == "running":
                running[att.task.job_id] += 1
        progress = True
        while progress and by_job:
            progress = False
            # job with min share that still has a placeable task
            order = sorted(by_job, key=lambda j: (
                running[j] / max(sim.jobs[j].priority + 1, 1), j))
            for jid in order:
                queue = by_job[jid]
                placed_idx = None
                for i, t in enumerate(queue):
                    nodes = self._free_nodes(t.kind)
                    if nodes:
                        self.launch(t, self._pick_node(t, nodes))
                        running[jid] += 1
                        placed_idx = i
                        break
                if placed_idx is not None:
                    queue.pop(placed_idx)
                    if not queue:
                        del by_job[jid]
                    progress = True
                    break
        self._requeue([t for q in by_job.values() for t in q])


class CapacityScheduler(Scheduler):
    """Two queues split by job priority with capacity caps, FIFO within a queue.
    Reproduces the documented over-memory kill: when a node oversubscribes memory,
    the newest task on it is killed (counted as a failed attempt) — the behaviour
    the paper cites to explain Capacity's task-failure profile."""
    name = "capacity"
    queue_caps = (0.5, 0.5)

    def schedule(self):
        sim = self.sim
        tasks = self._runnable()
        if not tasks:
            self._memory_police()
            return
        queues = ([], [])
        for t in tasks:
            q = 0 if sim.jobs[t.job_id].priority >= 2 else 1
            queues[q].append(t)
        total_slots = sum(n.spec.map_slots + n.spec.reduce_slots
                          for n in sim.nodes if n.known_alive)
        used = defaultdict(int)
        for att in sim.attempts.values():
            if att.status == "running":
                q = 0 if sim.jobs[att.task.job_id].priority >= 2 else 1
                used[q] += 1
        leftovers = []
        for qi, queue in enumerate(queues):
            cap = int(self.queue_caps[qi] * total_slots) + 1
            queue.sort(key=lambda t: (sim.jobs[t.job_id].submit_time, t.tid))
            for t in queue:
                if used[qi] >= cap:
                    leftovers.append(t)
                    continue
                nodes = self._free_nodes(t.kind)
                if not nodes:
                    leftovers.append(t)
                    continue
                self.launch(t, self._pick_node(t, nodes))
                used[qi] += 1
        self._requeue(leftovers)
        self._memory_police()

    def _memory_police(self):
        sim = self.sim
        for n in sim.nodes:
            if not n.tt_alive:
                continue
            # crude memory model: each running task needs ~1.2 GB
            need = len(n.running) * 1.2
            if need <= n.spec.mem_gb:
                continue
            # kill the newest attempt
            newest = max((sim.attempts[a] for a in n.running),
                         key=lambda a: a.start, default=None)
            if newest is None:
                continue
            newest.status = "failed"
            sim._release(newest)
            sim._charge_resources(newest, sim.now - newest.start)
            newest.task.failed_attempts += 1
            n.failed_count += 1
            n.record_failure(sim.now)
            if sim.trace is not None:
                sim.trace.record_outcome(sim, newest, False)
            sim._task_attempt_failed(newest.task)


BASELINES = {"fifo": FIFOScheduler, "fair": FairScheduler,
             "capacity": CapacityScheduler}
