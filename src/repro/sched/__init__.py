from repro.sched.base import (
    BASELINES, CapacityScheduler, FIFOScheduler, FairScheduler, Scheduler,
)

__all__ = ["BASELINES", "CapacityScheduler", "FIFOScheduler", "FairScheduler",
           "Scheduler"]
