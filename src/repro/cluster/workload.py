"""Workload generation — the paper's job mix (§4.1.1, §5.1):

single jobs (WordCount / TeraGen / TeraSort with varying map/reduce counts) plus
chained jobs (sequential, parallel and mixed chains of 3-20 units), over large input
files split into HDFS blocks (block count drives the map count, as in the paper)."""

from __future__ import annotations

import dataclasses
import random

from repro.cluster.simulator import Job, MAP, REDUCE, Task

# per-unit duration profile: (map base secs, reduce base secs, input MB per map)
# scaled so baseline job times land near the paper's (~20 min avg, ~2.3 min maps)
JOB_PROFILES = {
    "wordcount": (110.0, 170.0, 64.0),
    "teragen": (75.0, 0.0, 128.0),     # generation: map-only
    "terasort": (140.0, 260.0, 128.0),
}


@dataclasses.dataclass
class WorkloadConfig:
    n_single: int = 48
    n_chains: int = 8
    chain_len_range: tuple = (3, 8)
    maps_range: tuple = (6, 16)
    reduces_range: tuple = (4, 15)
    max_map_attempts: int = 4
    max_reduce_attempts: int = 4
    submit_horizon: float = 14400.0     # jobs arrive over this window
    n_nodes: int = 13                   # slaves holding HDFS blocks
    replication: int = 3
    seed: int = 7


def _make_job(jid: int, jtype: str, rng: random.Random, cfg: WorkloadConfig,
              submit: float, chain_id=-1, chain_kind="single", chain_pos=0) -> Job:
    mb, rb, in_mb = JOB_PROFILES[jtype]
    n_maps = rng.randint(*cfg.maps_range)
    n_reduces = 0 if jtype == "teragen" else rng.randint(*cfg.reduces_range)
    job = Job(jid=jid, jtype=jtype, n_maps=n_maps, n_reduces=n_reduces,
              priority=rng.randint(0, 2), chain_id=chain_id,
              chain_kind=chain_kind, chain_pos=chain_pos, submit_time=submit)
    tid = 0
    for _ in range(n_maps):
        blocks = tuple(rng.sample(range(cfg.n_nodes), k=min(cfg.replication,
                                                            cfg.n_nodes)))
        job.tasks[tid] = Task(
            job_id=jid, tid=tid, kind=MAP,
            duration_base=mb * (0.7 + 0.6 * rng.random()),
            input_mb=in_mb * (0.7 + 0.6 * rng.random()),
            block_nodes=blocks, max_attempts=cfg.max_map_attempts)
        tid += 1
    for _ in range(n_reduces):
        job.tasks[tid] = Task(
            job_id=jid, tid=tid, kind=REDUCE,
            duration_base=rb * (0.7 + 0.6 * rng.random()),
            input_mb=in_mb * n_maps / max(n_reduces, 1) * 0.4,
            block_nodes=(), max_attempts=cfg.max_reduce_attempts)
        tid += 1
    return job


def make_workload(cfg: WorkloadConfig | None = None):
    """Returns (immediate_jobs, deferred_sequential) — deferred lists must be handed
    to the simulator via ``install_chains``."""
    cfg = cfg or WorkloadConfig()
    rng = random.Random(cfg.seed)
    types = list(JOB_PROFILES)
    jobs: list[Job] = []
    deferred: dict[int, list[Job]] = {}
    jid = 0
    for _ in range(cfg.n_single):
        t = rng.uniform(0, cfg.submit_horizon)
        jobs.append(_make_job(jid, rng.choice(types), rng, cfg, t))
        jid += 1
    for c in range(cfg.n_chains):
        kind = rng.choice(["sequential", "parallel", "mix"])
        n = rng.randint(*cfg.chain_len_range)
        t0 = rng.uniform(0, cfg.submit_horizon)
        chain_jobs = []
        for pos in range(n):
            j = _make_job(jid, rng.choice(types), rng, cfg, t0,
                          chain_id=c, chain_kind=kind, chain_pos=pos)
            jid += 1
            chain_jobs.append(j)
        if kind == "parallel":
            jobs.extend(chain_jobs)
        elif kind == "sequential":
            jobs.append(chain_jobs[0])
            deferred[c] = chain_jobs[1:]
        else:  # mix: first half parallel now, second half sequential after
            half = max(1, n // 2)
            jobs.extend(chain_jobs[:half])
            if chain_jobs[half:]:
                deferred[c] = chain_jobs[half:]
    return jobs, deferred


def install(sim, workload):
    jobs, deferred = workload
    sim.submit_workload(jobs)
    for cid, chain in deferred.items():
        sim.blocked_chains[cid] = list(chain)
