from repro.cluster.chaos import ChaosConfig, ChaosInjector
from repro.cluster.simulator import (
    DEFAULT_FLEET, MACHINE_TYPES, MAP, REDUCE, Job, Node, Simulator, Task,
)
from repro.cluster.telemetry import FEATURE_NAMES, N_FEATURES, TelemetryTrace
from repro.cluster.workload import WorkloadConfig, install, make_workload

__all__ = [
    "ChaosConfig", "ChaosInjector", "DEFAULT_FLEET", "MACHINE_TYPES", "MAP",
    "REDUCE", "Job", "Node", "Simulator", "Task", "FEATURE_NAMES", "N_FEATURES",
    "TelemetryTrace", "WorkloadConfig", "install", "make_workload",
]
