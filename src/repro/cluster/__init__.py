from repro.cluster.chaos import ChaosConfig, ChaosInjector
from repro.cluster.invariants import InvariantChecker, InvariantViolation
from repro.cluster.scenarios import (CHAOS_BOUNDS, SCENARIOS, WORKLOAD_BOUNDS,
                                     WORKLOAD_SHAPES, Bound, Scenario,
                                     ScenarioSpec, get_scenario, get_workload,
                                     get_workload_shape, make_spec,
                                     scenario_chaos, scenario_scope,
                                     workload_for_seed)
from repro.cluster.simulator import (
    DEFAULT_FLEET, MACHINE_TYPES, MAP, REDUCE, Job, Node, Simulator, Task,
)
from repro.cluster.telemetry import FEATURE_NAMES, N_FEATURES, TelemetryTrace
from repro.cluster.workload import WorkloadConfig, install, make_workload

# fleet engine exports are lazy (PEP 562): repro.cluster.fleet pulls in the
# predictor stack (JAX), and eagerly importing it here both slows package
# import and trips runpy's double-import warning for `python -m
# repro.cluster.fleet`
_FLEET_NAMES = ("CellSpec", "SweepSpec", "aggregate", "cell_seed", "expand",
                "run_sweep", "sweep_json", "sweep_markdown")
_SEARCH_NAMES = ("SearchConfig", "evaluate", "run_search", "search_json",
                 "search_markdown")


def __getattr__(name):
    if name in _FLEET_NAMES:
        from repro.cluster import fleet
        return getattr(fleet, name)
    if name in _SEARCH_NAMES:
        from repro.cluster import search
        return getattr(search, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "Bound", "CHAOS_BOUNDS", "ChaosConfig", "ChaosInjector", "DEFAULT_FLEET",
    "InvariantChecker", "InvariantViolation", "MACHINE_TYPES", "MAP",
    "REDUCE", "Job", "Node", "SCENARIOS", "Scenario", "ScenarioSpec",
    "Simulator", "Task", "FEATURE_NAMES", "N_FEATURES", "TelemetryTrace",
    "WORKLOAD_BOUNDS", "WORKLOAD_SHAPES", "WorkloadConfig", "get_scenario",
    "get_workload", "get_workload_shape", "install", "make_spec",
    "make_workload", "scenario_chaos", "scenario_scope", "workload_for_seed",
    *_FLEET_NAMES, *_SEARCH_NAMES,
]
