"""Discrete-event cluster simulator — the testbed for ATLAS vs FIFO/Fair/Capacity.

Models the paper's Amazon EMR setup: a heterogeneous fleet (m3.large / m4.xlarge /
c4.xlarge), a JobTracker with heartbeat-based liveness (failures between heartbeats
are invisible to the scheduler, reproducing Dinu et al.'s observations), per-node
map/reduce slots, HDFS block locality, task attempt retry budgets (K maps, L
reduces), and a *hidden* failure-generating hazard whose drivers match the
correlations the paper reports (co-located failures on a TaskTracker, locality,
previous failed attempts, resource pressure).

The same simulator drives the TPU-fleet runtime (repro.runtime): there the nodes are
TPU hosts and tasks are training step-shards; here they are Hadoop tasks, which is
what the paper's tables measure.

Everything is deterministic given (seed, workload, scheduler).
"""

from __future__ import annotations

import dataclasses
import heapq
import math
import random
from collections import defaultdict, deque
from typing import Any

# ---------------------------------------------------------------------------
# Machine fleet (Table 2 of the paper)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MachineSpec:
    name: str
    vcpu: int
    mem_gb: float
    net: str           # "moderate" | "high"
    speed: float       # relative task speed factor
    map_slots: int
    reduce_slots: int


MACHINE_TYPES = {
    "m3.large": MachineSpec("m3.large", 1, 3.75, "moderate", 1.00, 2, 1),
    "m4.xlarge": MachineSpec("m4.xlarge", 2, 8.0, "high", 1.30, 3, 2),
    "c4.xlarge": MachineSpec("c4.xlarge", 4, 7.5, "high", 1.60, 4, 2),
}

# paper: 15 machines — 1 master, 1 secondary master, 13 slaves of 3 types
DEFAULT_FLEET = (["m3.large"] * 5 + ["m4.xlarge"] * 4 + ["c4.xlarge"] * 4)


def make_fleet(n_nodes: int) -> list[str]:
    """A fleet of ``n_nodes`` machines cycling the paper's Table-2 mix — the
    scale axis beyond the 15-machine EMR cluster (0 -> the paper's fleet)."""
    if n_nodes <= 0:
        return list(DEFAULT_FLEET)
    return [DEFAULT_FLEET[i % len(DEFAULT_FLEET)] for i in range(n_nodes)]


# failure-history window (seconds) behind Node.recent_failure_count — also the
# eviction cutoff, so the deque holds O(window) entries however long the run
FAILURE_WINDOW = 600.0


@dataclasses.dataclass
class Node:
    nid: int
    spec: MachineSpec
    tt_alive: bool = True          # TaskTracker process
    dn_alive: bool = True          # DataNode process
    suspended: bool = False
    net_quality: float = 1.0       # 1 ok, 0.3 slow, 0 dropped
    health: float = 1.0            # latent degradation in [0,1] (hidden from sched)
    last_heartbeat: float = 0.0
    known_alive: bool = True       # what the JobTracker believes
    running: set = dataclasses.field(default_factory=set)      # attempt ids
    running_maps: int = 0
    running_reduces: int = 0
    recent_failures: deque = dataclasses.field(
        default_factory=deque)     # failure times on node, window-evicted
    finished_count: int = 0
    failed_count: int = 0
    restarts: int = 0

    def free_map_slots(self) -> int:
        return self.spec.map_slots - self.running_maps

    def free_reduce_slots(self) -> int:
        return self.spec.reduce_slots - self.running_reduces

    def record_failure(self, now: float):
        """Append a failure timestamp, evicting entries past the window — the
        deque stays O(window) over arbitrarily long chaos runs.  (Unlike the
        old fixed maxlen=64 deque, a node with >64 failures inside the window
        now reports its true count.)"""
        dq = self.recent_failures
        dq.append(now)
        cutoff = now - FAILURE_WINDOW
        while dq[0] < cutoff:
            dq.popleft()

    def recent_failure_count(self, now: float,
                             horizon: float = FAILURE_WINDOW) -> int:
        """Failures within the horizon: O(evicted) amortised, not a scan.
        Eviction always uses FAILURE_WINDOW (a shorter query horizon must not
        destroy entries still inside the retention window); timestamps are
        appended in event order, so the post-eviction deque IS the window."""
        dq = self.recent_failures
        cutoff = now - FAILURE_WINDOW
        while dq and dq[0] < cutoff:
            dq.popleft()
        if horizon >= FAILURE_WINDOW:
            return len(dq)
        return sum(1 for t in dq if now - t <= horizon)


# ---------------------------------------------------------------------------
# Jobs / tasks / attempts
# ---------------------------------------------------------------------------

MAP, REDUCE = "map", "reduce"


@dataclasses.dataclass
class Task:
    job_id: int
    tid: int
    kind: str                      # map | reduce
    duration_base: float           # seconds on a speed-1.0 node
    input_mb: float
    block_nodes: tuple             # nodes holding the HDFS block (maps)
    max_attempts: int
    status: str = "pending"        # pending | running | finished | failed | blocked
    finished_attempts: int = 0
    failed_attempts: int = 0
    reschedules: int = 0
    penalty: int = 0
    first_submit: float = 0.0
    done_time: float = 0.0
    live_attempts: set = dataclasses.field(default_factory=set)
    # resource usage accumulated over ALL attempts (paper Table 4)
    cpu_ms: float = 0.0
    mem_bytes: float = 0.0
    hdfs_read: float = 0.0
    hdfs_write: float = 0.0

    @property
    def key(self):
        return (self.job_id, self.tid)


@dataclasses.dataclass
class Job:
    jid: int
    jtype: str                     # wordcount | teragen | terasort
    n_maps: int
    n_reduces: int
    priority: int = 1
    chain_id: int = -1             # chained-job group (-1: single)
    chain_kind: str = "single"     # single | sequential | parallel | mix
    chain_pos: int = 0
    submit_time: float = 0.0
    status: str = "pending"        # pending | running | finished | failed
    done_time: float = 0.0
    tasks: dict = dataclasses.field(default_factory=dict)
    # incrementally maintained by the Simulator (exactly equal to scanning
    # tasks for the matching status — the predictor reads these per decision)
    n_finished_tasks: int = 0
    n_failed_tasks: int = 0
    n_finished_maps: int = 0
    n_map_tasks: int = -1          # resolved at submit

    def map_tasks(self):
        return [t for t in self.tasks.values() if t.kind == MAP]

    def reduce_tasks(self):
        return [t for t in self.tasks.values() if t.kind == REDUCE]


@dataclasses.dataclass
class Attempt:
    aid: int
    task: Task
    node: Node
    start: float
    duration: float                # planned wall duration
    will_fail: bool
    fail_at: float                 # absolute failure time if will_fail
    speculative: bool = False
    local: bool = True
    status: str = "running"        # running | finished | failed | killed | stalled


# ---------------------------------------------------------------------------
# Events
# ---------------------------------------------------------------------------

(EV_SUBMIT, EV_ATTEMPT_END, EV_HEARTBEAT, EV_CHAOS, EV_TIMEOUT,
 EV_NODE_RECOVER, EV_RETRAIN) = range(7)


class Simulator:
    """Single cluster run under one scheduler.  Usage:

        sim = Simulator(scheduler=FIFOScheduler(), seed=0)
        sim.submit_workload(make_workload(...))
        sim.run()
        sim.metrics  ->  aggregate results
    """

    def __init__(self, scheduler, *, fleet=None, seed: int = 0,
                 heartbeat_interval: float = 600.0, task_timeout: float = 1800.0,
                 chaos=None, trace=None, time_limit: float = 10_000_000.0,
                 hazard_noise: float = 0.55, obs=None, invariants=None):
        self.rng = random.Random(seed)
        fleet = fleet or DEFAULT_FLEET
        self.nodes = [Node(i, MACHINE_TYPES[m]) for i, m in enumerate(fleet)]
        self.scheduler = scheduler
        self.heartbeat_interval = heartbeat_interval  # may be adapted by ATLAS
        self.task_timeout = task_timeout
        self.chaos = chaos
        self.trace = trace                    # TelemetryTrace or None
        self.obs = obs                        # repro.obs.SimObserver or None
        self.time_limit = time_limit
        self.hazard_noise = hazard_noise

        self.now = 0.0
        self._heap: list = []
        self._seq = 0
        self.jobs: dict[int, Job] = {}
        self.pending: deque = deque()         # runnable task keys (FIFO arrival order)
        self.blocked_chains: dict[int, list] = defaultdict(list)
        self.attempts: dict[int, Attempt] = {}
        self._next_aid = 0
        self.waiting_submits = 0
        self.n_running_jobs = 0
        # observable signals the scheduler/ATLAS may read (JT-side knowledge)
        self.hb_failures_window: int = 0      # TT failures since last heartbeat sweep
        # incrementally maintained node indices — the per-decision candidate
        # generators read these instead of rebuilding list comprehensions over
        # the whole fleet every tick (the 100-1000-node hot path).  Slot sets
        # change only in launch/_release; known_alive changes only in
        # detect_tt_failure/_on_heartbeat — all Simulator methods.
        self._free_map: set = {n.nid for n in self.nodes}
        self._free_reduce: set = {n.nid for n in self.nodes}
        self._known_alive: set = {n.nid for n in self.nodes}

        scheduler.bind(self)
        # invariant checker (repro.cluster.invariants): read-only observer, so
        # results are byte-identical with checking on or off
        self.invariants = invariants
        if invariants is not None:
            invariants.bind(self)
        if obs is not None:
            obs.bind(self)
        for n in self.nodes:
            self._push(self.heartbeat_interval * (0.5 + 0.5 * self.rng.random()),
                       EV_HEARTBEAT, n.nid)
        if chaos is not None:
            chaos.bind(self)
            chaos.schedule_initial()

    # ------------------------------------------------------------------ utils
    def _push(self, t: float, kind: int, payload: Any = None):
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, kind, payload))

    def alive_nodes(self):
        return [n for n in self.nodes if n.tt_alive and not n.suspended]

    def jt_believed_alive(self):
        nodes = self.nodes
        return [nodes[i] for i in sorted(self._known_alive)]

    def _sync_free(self, node: Node):
        """Refresh the node's membership in the free-slot indices (called on
        every running-count change — launch and release only)."""
        nid = node.nid
        if node.running_maps < node.spec.map_slots:
            self._free_map.add(nid)
        else:
            self._free_map.discard(nid)
        if node.running_reduces < node.spec.reduce_slots:
            self._free_reduce.add(nid)
        else:
            self._free_reduce.discard(nid)

    def free_nodes(self, kind: str, *, liveness: str = "jt") -> list[Node]:
        """Nodes with a free slot of ``kind``, in nid order (deterministic
        candidate lists), read from the incremental indices.

        liveness: "jt" — the JobTracker believes them alive (scheduler view);
        "actual" — TaskTracker up and not suspended (ATLAS's active probe);
        "any" — slot availability only (the broker's tick-priming superset)."""
        idx = self._free_map if kind == MAP else self._free_reduce
        nodes = self.nodes
        if liveness == "jt":
            return [nodes[i] for i in sorted(idx & self._known_alive)]
        if liveness == "actual":
            out = []
            for i in sorted(idx):
                n = nodes[i]
                if n.tt_alive and not n.suspended:
                    out.append(n)
            return out
        return [nodes[i] for i in sorted(idx)]

    # ------------------------------------------------------------------ workload
    def submit_workload(self, jobs: list[Job]):
        for job in jobs:
            self._push(job.submit_time, EV_SUBMIT, job)
            self.waiting_submits += 1

    # ------------------------------------------------------------------ hazard
    def _attempt_outcome(self, task: Task, node: Node, local: bool,
                         speculative: bool):
        """Hidden ground-truth generator: duration + failure decision.  The drivers
        mirror the paper's observed correlates so the Table-1 features are genuinely
        predictive."""
        spec = node.spec
        net_pen = 1.0 + (1.0 - node.net_quality) * 1.5
        loc_pen = 1.0 if local else 1.35
        load = (node.running_maps + node.running_reduces) \
            / max(spec.map_slots + spec.reduce_slots, 1)
        load_pen = 1.0 + 0.45 * load
        dur = (task.duration_base / spec.speed) * net_pen * loc_pen * load_pen
        dur *= 0.85 + 0.3 * self.rng.random()

        # failure drivers are predominantly node-exogenous (injected chaos, node
        # degradation, network, data availability) as on the paper's EMR cluster;
        # load contention contributes mildly
        # NOTE: no explicit "failures beget failures" term — the correlation the
        # paper observes between co-located failures and outcomes emerges from the
        # shared hidden cause (node health / network), which is what makes the
        # tt_failed_recent *feature* informative without a runaway feedback loop.
        logit = -3.0
        logit += 2.3 * (1.0 - node.net_quality)
        logit += 0.5 * load
        logit += 0.0 if local else 0.7
        logit += 0.25 * min(task.failed_attempts, 4)
        logit += 2.6 * (1.0 - node.health)
        # idiosyncratic, unobservable component: bounds any predictor's accuracy
        # (the paper's best model reaches ~84% map / ~95% reduce accuracy, not 100%)
        logit += self.rng.gauss(0.0, self.hazard_noise)
        if task.kind == MAP and task.block_nodes and not any(
                self.nodes[b].dn_alive for b in task.block_nodes):
            logit += 3.5                       # input block unavailable
        p_fail = 1.0 / (1.0 + math.exp(-logit))
        will_fail = self.rng.random() < p_fail
        fail_at = self.now + dur * (0.15 + 0.8 * self.rng.random())
        return dur, will_fail, fail_at, p_fail

    # ------------------------------------------------------------------ actions
    def launch(self, task: Task, node: Node, *, speculative: bool = False) -> Attempt:
        if self.invariants is not None:    # pre-mutation state is what L1-L3 check
            self.invariants.check_launch(self, task, node, speculative)
        local = task.kind == REDUCE or node.nid in task.block_nodes
        dur, will_fail, fail_at, p_fail = self._attempt_outcome(
            task, node, local, speculative)
        aid = self._next_aid
        self._next_aid += 1
        att = Attempt(aid, task, node, self.now, dur, will_fail, fail_at,
                      speculative=speculative, local=local)
        self.attempts[aid] = att
        task.live_attempts.add(aid)
        task.status = "running"
        node.running.add(aid)
        if task.kind == MAP:
            node.running_maps += 1
        else:
            node.running_reduces += 1
        self._sync_free(node)
        if self.trace is not None:
            self.trace.record_launch(self, att, p_fail)
        end = fail_at if will_fail else self.now + dur
        # node death may pre-empt; handled when the node dies
        self._push(end, EV_ATTEMPT_END, aid)
        return att

    def _release(self, att: Attempt):
        node = att.node
        node.running.discard(att.aid)
        if att.task.kind == MAP:
            node.running_maps = max(0, node.running_maps - 1)
        else:
            node.running_reduces = max(0, node.running_reduces - 1)
        self._sync_free(node)
        att.task.live_attempts.discard(att.aid)

    def _charge_resources(self, att: Attempt, ran_for: float):
        t = att.task
        spec = att.node.spec
        cpu_frac = 0.8 if t.kind == MAP else 0.6
        t.cpu_ms += ran_for * 1000.0 * cpu_frac
        t.mem_bytes += ran_for * (0.9 if t.kind == MAP else 1.4) * 1e5
        read = t.input_mb * 1e3 * (1.0 if att.local else 1.6)
        write = t.input_mb * 1e3 * (0.35 if t.kind == MAP else 1.0)
        frac = min(1.0, ran_for / max(att.duration, 1e-9))
        t.hdfs_read += read * frac
        t.hdfs_write += write * frac

    # ------------------------------------------------------------------ event handlers
    def _on_submit(self, job: Job):
        self.waiting_submits -= 1
        job.status = "running"
        self.n_running_jobs += 1
        self.jobs[job.jid] = job
        maps = job.map_tasks()
        job.n_map_tasks = len(maps)
        for t in maps:
            t.first_submit = self.now
            self.pending.append(t.key)
        # reduces become runnable once all maps finish (coarse barrier, as in the
        # paper's formulation eq. (2))
        if self.trace is not None:
            self.trace.record_job_submit(self, job)

    def _maybe_release_reduces(self, job: Job):
        if job.n_finished_maps == job.n_map_tasks:
            for t in job.reduce_tasks():
                if t.status == "pending" and not t.first_submit:
                    t.first_submit = self.now
                    self.pending.append(t.key)

    def _on_attempt_end(self, aid: int):
        att = self.attempts.get(aid)
        if att is None or att.status != "running":
            return
        node, task = att.node, att.task
        if not node.tt_alive:
            return  # node died first; resolution happens via heartbeat detection
        if node.suspended:
            # stalled: retry this event later
            self._push(self.now + 30.0, EV_ATTEMPT_END, aid)
            return
        self._release(att)
        ran_for = self.now - att.start
        self._charge_resources(att, ran_for)
        if att.will_fail:
            att.status = "failed"
            # a failed *speculative* copy doesn't burn the task's retry budget
            # while another attempt is still live (it was insurance, not the task)
            if not (att.speculative and task.live_attempts):
                task.failed_attempts += 1
            node.failed_count += 1
            node.record_failure(self.now)
            if self.trace is not None:
                self.trace.record_outcome(self, att, False)
            self._task_attempt_failed(task)
        else:
            att.status = "finished"
            node.finished_count += 1
            if self.trace is not None:
                self.trace.record_outcome(self, att, True)
            self._task_finished(task)

    def _task_attempt_failed(self, task: Task):
        if task.status in ("finished", "failed"):
            return
        if task.live_attempts:
            return  # other (speculative) copies still running
        if task.failed_attempts >= task.max_attempts:
            self._task_failed(task)
        else:
            task.reschedules += 1
            task.status = "pending"
            self.pending.append(task.key)

    def _task_finished(self, task: Task):
        if task.status == "finished":
            return
        task.status = "finished"
        task.finished_attempts += 1
        task.done_time = self.now
        job_of = self.jobs[task.job_id]
        job_of.n_finished_tasks += 1
        if task.kind == MAP:
            job_of.n_finished_maps += 1
        # kill outstanding speculative copies
        for aid in list(task.live_attempts):
            a = self.attempts[aid]
            a.status = "killed"
            self._release(a)
            self._charge_resources(a, self.now - a.start)
        job = self.jobs[task.job_id]
        if task.kind == MAP:
            self._maybe_release_reduces(job)
        self._maybe_finish_job(job)

    def _task_failed(self, task: Task):
        task.status = "failed"
        task.done_time = self.now
        job = self.jobs[task.job_id]
        job.n_failed_tasks += 1
        if job.status == "running":
            job.status = "failed"
            job.done_time = self.now
            self.n_running_jobs -= 1
            # map failure cascades to dependent reduces (paper Fig. 2)
            for t in job.tasks.values():
                if t.status in ("pending", "running"):
                    t.status = "failed"
                    t.done_time = self.now
                    job.n_failed_tasks += 1
                    for aid in list(t.live_attempts):
                        a = self.attempts[aid]
                        a.status = "killed"
                        self._release(a)
            self._fail_chain_siblings(job)
        if self.trace is not None:
            self.trace.record_job_end(self, job)

    def _fail_chain_siblings(self, job: Job):
        if job.chain_id < 0:
            return
        for j in self.jobs.values():
            if j.chain_id == job.chain_id and j.status == "running" \
                    and j.jid != job.jid and j.chain_kind == "sequential":
                pass  # running siblings in parallel chains keep going; sequential
                      # successors simply never get submitted
        # drop queued successors of a sequential chain
        self.blocked_chains.pop(job.chain_id, None)

    def _maybe_finish_job(self, job: Job):
        if job.status != "running":
            return
        if job.n_finished_tasks == len(job.tasks):
            job.status = "finished"
            job.done_time = self.now
            self.n_running_jobs -= 1
            if self.trace is not None:
                self.trace.record_job_end(self, job)
            # release next job of a sequential chain
            if job.chain_id >= 0 and self.blocked_chains.get(job.chain_id):
                nxt = self.blocked_chains[job.chain_id].pop(0)
                nxt.submit_time = self.now
                self._push(self.now, EV_SUBMIT, nxt)
                self.waiting_submits += 1

    def detect_tt_failure(self, node: Node):
        """The JobTracker learns a TaskTracker is dead (heartbeat timeout, or an
        ATLAS active probe): every attempt stranded on it fails now."""
        if not node.known_alive:
            return
        node.known_alive = False
        self._known_alive.discard(node.nid)
        self.hb_failures_window += 1
        for aid in list(node.running):
            att = self.attempts[aid]
            att.status = "failed"
            self._release(att)
            self._charge_resources(att, self.now - att.start)
            if not (att.speculative and att.task.live_attempts):
                att.task.failed_attempts += 1
            node.failed_count += 1
            node.record_failure(self.now)
            if self.trace is not None:
                self.trace.record_outcome(self, att, False)
            self._task_attempt_failed(att.task)

    def _on_heartbeat(self, nid: int):
        node = self.nodes[nid]
        if node.tt_alive:
            node.last_heartbeat = self.now
            if not node.known_alive:
                node.known_alive = True
                self._known_alive.add(nid)
        else:
            self.detect_tt_failure(node)
        self.scheduler.on_heartbeat(node)
        self._push(self.now + self.heartbeat_interval, EV_HEARTBEAT, nid)

    def _on_timeout(self, payload):
        kind, key = payload
        if kind == "task":
            task = self._task_by_key(key)
            if task is not None and task.status == "running":
                # attempt exceeded the scheduler timeout -> failed + requeue
                for aid in list(task.live_attempts):
                    att = self.attempts[aid]
                    if self.now - att.start >= self.task_timeout:
                        att.status = "failed"
                        self._release(att)
                        self._charge_resources(att, self.now - att.start)
                        task.failed_attempts += 1
                        att.node.failed_count += 1
                        att.node.record_failure(self.now)
                        if self.trace is not None:
                            self.trace.record_outcome(self, att, False)
                self._task_attempt_failed(task)

    def _task_by_key(self, key):
        job = self.jobs.get(key[0])
        return None if job is None else job.tasks.get(key[1])

    # ------------------------------------------------------------------ loop
    def run(self):
        obs = self.obs
        # telemetry hot path inlined: a list add + one float compare per
        # event (a per-event method call costs ~10x as much).  Read-only —
        # never touches the RNG or any scheduling input.
        ev_counts = obs.event_counts if obs is not None else None
        # invariant hot path inlined like the telemetry one: the E1/E2
        # compares run on loop locals and the checker method is entered only
        # on a violation or a sweep boundary
        inv = self.invariants
        inv_every = inv.sweep_interval if inv is not None else 0
        inv_last = self.now
        inv_events = 0
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if t > self.time_limit:
                break
            self.now = t
            if kind == EV_SUBMIT:
                self._on_submit(payload)
            elif kind == EV_ATTEMPT_END:
                self._on_attempt_end(payload)
            elif kind == EV_HEARTBEAT:
                self._on_heartbeat(payload)
            elif kind == EV_CHAOS:
                self.chaos.fire(payload)
            elif kind == EV_TIMEOUT:
                self._on_timeout(payload)
            elif kind == EV_RETRAIN:
                self.scheduler.on_retrain()
            self.scheduler.on_tick()
            if inv is not None:
                inv_events += 1
                if (t < inv_last or self.n_running_jobs < 0
                        or inv_events % inv_every == 0):
                    inv.on_event(self, inv_last)
                inv_last = t
            if ev_counts is not None:
                ev_counts[kind] += 1
                if t >= obs.next_frame_t:
                    obs.maybe_frame(self)
            if self._done():
                break
        if inv is not None:
            inv.finish(self, inv_events)
        if obs is not None:
            obs.finish(self)
        return self.metrics()

    def _done(self) -> bool:
        if self.waiting_submits > 0 or self.pending:
            return False
        if self.n_running_jobs > 0:
            return False
        if any(self.blocked_chains.values()):
            return False
        return True

    # ------------------------------------------------------------------ results
    def metrics(self) -> dict:
        jobs = list(self.jobs.values())
        tasks = [t for j in jobs for t in j.tasks.values()]
        fin_j = [j for j in jobs if j.status == "finished"]
        fail_j = [j for j in jobs if j.status == "failed"]
        fin_t = [t for t in tasks if t.status == "finished"]
        fail_t = [t for t in tasks if t.status == "failed"]
        fin_m = [t for t in fin_t if t.kind == MAP]
        fin_r = [t for t in fin_t if t.kind == REDUCE]
        fail_m = [t for t in fail_t if t.kind == MAP]
        fail_r = [t for t in fail_t if t.kind == REDUCE]

        def avg(xs):
            xs = list(xs)
            return sum(xs) / len(xs) if xs else 0.0

        job_time = avg(j.done_time - j.submit_time for j in fin_j)
        map_time = avg(t.done_time - t.first_submit for t in fin_m)
        red_time = avg(t.done_time - t.first_submit for t in fin_r)
        # direct failures (retry budget exhausted) vs cascade (Fig. 2 teardown)
        direct_fail = [t for t in fail_t if t.failed_attempts >= t.max_attempts]
        out = {
            "jobs_total": len(jobs), "jobs_finished": len(fin_j),
            "jobs_failed": len(fail_j),
            "pct_jobs_failed": 100.0 * len(fail_j) / max(len(jobs), 1),
            "tasks_total": len(tasks), "tasks_finished": len(fin_t),
            "tasks_failed": len(fail_t),
            "tasks_failed_direct": len(direct_fail),
            "pct_tasks_failed": 100.0 * len(fail_t) / max(len(tasks), 1),
            "maps_finished": len(fin_m), "maps_failed": len(fail_m),
            "reduces_finished": len(fin_r), "reduces_failed": len(fail_r),
            "job_exec_time": job_time, "map_exec_time": map_time,
            "reduce_exec_time": red_time,
            "cpu_ms_per_job": avg(sum(t.cpu_ms for t in j.tasks.values())
                                  for j in jobs),
            "mem_per_job": avg(sum(t.mem_bytes for t in j.tasks.values())
                               for j in jobs),
            "hdfs_read_per_job": avg(sum(t.hdfs_read for t in j.tasks.values())
                                     for j in jobs),
            "hdfs_write_per_job": avg(sum(t.hdfs_write for t in j.tasks.values())
                                      for j in jobs),
            "cpu_ms_per_task": avg(t.cpu_ms for t in tasks),
            "mem_per_task": avg(t.mem_bytes for t in tasks),
            "hdfs_read_per_task": avg(t.hdfs_read for t in tasks),
            "hdfs_write_per_task": avg(t.hdfs_write for t in tasks),
            "sim_time": self.now,
        }
        if self.invariants is not None:
            out["invariant_checks"] = self.invariants.n_checks
            out["invariant_violations"] = self.invariants.n_violations
        return out
