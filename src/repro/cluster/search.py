"""Adversarial scenario search — where does ATLAS stop paying for itself?

The paper evaluates ATLAS on a handful of hand-picked chaos regimes (§5); this
module searches the *typed scenario space* (repro.cluster.scenarios) for
regimes that maximise **ATLAS regret** — the seed-paired degradation of
ATLAS-<base> relative to its base scheduler on identical scenario bytes:

    regret = w_tasks * (pct_tasks_failed[atlas] - pct_tasks_failed[base])
           + w_jobs  * (pct_jobs_failed[atlas]  - pct_jobs_failed[base])
           + w_makespan * 100 * (sim_time[atlas] - sim_time[base])
                              / max(sim_time[base], 1)

averaged over seeds.  Positive regret = ATLAS made things worse; the search is
a budgeted hill-climb (``ScenarioSpec.perturb``) with random restarts
(``ScenarioSpec.sample``) after ``restart_after`` non-improving evaluations.

Every candidate is evaluated through the *existing* fleet engine
(``run_sweep``: two-wave training-trace reuse, process pool, per-cell CRC32
seeds) under a ``scenario_scope`` registration with fixed synthetic names, so
every candidate sees byte-identical per-seed workload + failure storms and the
paired delta is a true like-for-like comparison.  With ``check_invariants``
(default on) every evaluation doubles as a model-checking run — a regime that
breaks a scheduler invariant is a bug report, not just a bad regime.

Determinism + resumability: the iteration-``i`` move is drawn from
``random.Random(cell_seed("search", seed, i))`` and acceptance state is a pure
function of the eval ledger, so replaying ``experiments/SEARCH.json`` (written
atomically after every eval) resumes bit-for-bit: run 1 eval, resume for 2
more == run 3 straight.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import random

import repro
from repro.cluster.fleet import SweepSpec, _round_floats, cell_seed, run_sweep
from repro.cluster.scenarios import ScenarioSpec, make_spec, scenario_scope

# fixed synthetic registry names: part of every cell's env_key, so keeping
# them constant keeps per-seed chaos/workload/sim seeds identical across
# candidates (paired comparisons stay seed-matched along the whole search)
SEARCH_NAME = "search"


def _r6(x) -> float:
    return round(float(x), 6)


@dataclasses.dataclass
class SearchConfig:
    """Knobs of one search run.  ``budget`` counts candidate evaluations; each
    evaluation is a small paired sweep (base + atlas-<base>) over ``seeds``."""
    base: str = "fifo"                # base scheduler; atlas-<base> is paired
    budget: int = 24
    seeds: int = 2                    # seed indices 0..n-1 per evaluation
    fleet_size: int = 20
    scenario: str = "baseline"        # named starting point of the climb
    workload: str = "smoke"
    scale: float = 0.25               # perturbation size (fraction of bounds)
    restart_after: int = 6            # non-improving evals before a restart
    seed: int = 0                     # search-level seed (move generation)
    executor: str = "process"
    workers: int | None = None
    hazard: str = "cluster"
    check_invariants: bool = True
    algo: str = "R.F."
    min_samples: int = 150
    max_train: int = 20000
    heartbeat_interval: float = 600.0
    w_tasks: float = 1.0
    w_jobs: float = 1.0
    w_makespan: float = 0.25

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Candidate evaluation (one paired sweep through the fleet engine)
# ---------------------------------------------------------------------------

def regret_for(base: dict, atlas: dict, cfg: SearchConfig) -> float:
    """Seed-paired ATLAS regret from two metrics dicts (positive = worse)."""
    return _r6(
        cfg.w_tasks * (atlas["pct_tasks_failed"] - base["pct_tasks_failed"])
        + cfg.w_jobs * (atlas["pct_jobs_failed"] - base["pct_jobs_failed"])
        + cfg.w_makespan * 100.0 * (atlas["sim_time"] - base["sim_time"])
        / max(base["sim_time"], 1.0))


def evaluate(point: ScenarioSpec, cfg: SearchConfig, *, log=None) -> dict:
    """Regret of one scenario point: {regret, per_seed, violations, checks}."""
    spec = SweepSpec(
        schedulers=(cfg.base, f"atlas-{cfg.base}"), seeds=cfg.seeds,
        scenarios=(SEARCH_NAME,), workloads=(SEARCH_NAME,),
        fleet_sizes=(cfg.fleet_size,), hazard=cfg.hazard, algo=cfg.algo,
        heartbeat_interval=cfg.heartbeat_interval,
        min_samples=cfg.min_samples, max_train=cfg.max_train,
        check_invariants=cfg.check_invariants)
    with scenario_scope(point, scenario_name=SEARCH_NAME,
                        workload_name=SEARCH_NAME):
        result = run_sweep(spec, executor=cfg.executor, workers=cfg.workers,
                           log=log or (lambda *a, **k: None))
    cells = {(c["scheduler"], c["seed_index"]): c["metrics"]
             for c in result["cells"]}
    per_seed, violations, checks = [], 0, 0
    for si in spec.seed_indices():
        b = cells[(cfg.base, si)]
        a = cells[(f"atlas-{cfg.base}", si)]
        per_seed.append(regret_for(b, a, cfg))
        for m in (b, a):
            violations += int(m.get("invariant_violations", 0))
            checks += int(m.get("invariant_checks", 0))
    return {"regret": _r6(sum(per_seed) / max(len(per_seed), 1)),
            "per_seed": per_seed, "violations": violations, "checks": checks}


# ---------------------------------------------------------------------------
# Hill-climb state machine (shared by the live loop and ledger replay)
# ---------------------------------------------------------------------------

def _fresh_state() -> dict:
    return {"cur_point": None, "cur_regret": None, "since_improve": 0,
            "best": None}


def _propose(state: dict, cfg: SearchConfig, i: int):
    """Deterministic move for iteration ``i``: the rng derives from the ledger
    coordinates alone, so a resumed search proposes the same candidates."""
    rng = random.Random(cell_seed("search", cfg.seed, i))
    if state["cur_point"] is None:
        return make_spec(cfg.scenario, cfg.workload), "init"
    if state["since_improve"] >= cfg.restart_after:
        return ScenarioSpec.sample(rng, name=f"restart-{i}"), "restart"
    return state["cur_point"].perturb(rng, cfg.scale), "perturb"


def _advance(state: dict, rec: dict) -> None:
    """Fold one completed eval record into the climb state (used identically
    while searching and while replaying a ledger on resume)."""
    if rec["accepted"]:
        state["cur_point"] = ScenarioSpec.from_dict(rec["point"])
        state["cur_regret"] = rec["regret"]
        state["since_improve"] = 0
    else:
        state["since_improve"] += 1
    if state["best"] is None or rec["regret"] > state["best"]["regret"]:
        state["best"] = rec


def _accepts(state: dict, origin: str, regret: float) -> bool:
    if origin in ("init", "restart"):      # unconditional moves
        return True
    return state["cur_regret"] is None or regret > state["cur_regret"]


# ---------------------------------------------------------------------------
# Ledger (atomic, resumable) + rendering
# ---------------------------------------------------------------------------

def search_json(result: dict) -> str:
    return json.dumps(_round_floats(result), indent=2, sort_keys=True) + "\n"


def _write_atomic(path: pathlib.Path, text: str) -> None:
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_text(text)
    os.replace(tmp, path)


def _ranking(evals: list[dict], top: int = 10) -> list[dict]:
    worst = sorted(evals, key=lambda e: (-e["regret"], e["i"]))[:top]
    return [{"i": e["i"], "origin": e["origin"], "regret": e["regret"],
             "violations": e["violations"],
             "intensity": e["point"]["chaos"]["intensity"],
             "mean_interarrival": e["point"]["chaos"]["mean_interarrival"],
             "burst_prob": e["point"]["chaos"]["burst_prob"]}
            for e in worst]


def _result(cfg: SearchConfig, evals: list[dict], best: dict | None) -> dict:
    return {"config": cfg.to_json(),
            "provenance": {"pr": repro.PR_TAG},
            "n_evals": len(evals), "evals": evals,
            "best": best, "ranking": _ranking(evals)}


def search_markdown(result: dict) -> str:
    cfg = result["config"]
    lines = [
        "# Adversarial scenario search",
        "",
        f"Objective: ATLAS regret of `atlas-{cfg['base']}` vs `{cfg['base']}`"
        f" (w_tasks={cfg['w_tasks']}, w_jobs={cfg['w_jobs']},"
        f" w_makespan={cfg['w_makespan']}); positive = ATLAS worse.",
        f"Budget {cfg['budget']} evals x {cfg['seeds']} seeds, "
        f"{cfg['fleet_size']}-node fleet, invariants "
        f"{'on' if cfg['check_invariants'] else 'off'}.",
        "",
        "| rank | eval | origin | regret | violations | intensity "
        "| interarrival | burst_prob |",
        "|---:|---:|---|---:|---:|---:|---:|---:|",
    ]
    for rank, e in enumerate(result["ranking"], 1):
        lines.append(
            f"| {rank} | {e['i']} | {e['origin']} | {e['regret']:.3f} "
            f"| {e['violations']} | {e['intensity']:.3f} "
            f"| {e['mean_interarrival']:.0f} | {e['burst_prob']:.3f} |")
    best = result["best"]
    if best is not None:
        lines += ["",
                  f"Worst regime: eval {best['i']} "
                  f"(regret {best['regret']:.3f}, origin {best['origin']}).",
                  "```json",
                  json.dumps(_round_floats(best["point"]), indent=2,
                             sort_keys=True),
                  "```"]
    return "\n".join(lines) + "\n"


# operational knobs a resume may legitimately change: a bigger budget extends
# the climb, and the executor/worker choice never affects cell results (the
# fleet engine guarantees byte-identical cells across executors)
_RESUME_FREE = ("budget", "executor", "workers")


def _load_ledger(path: pathlib.Path, cfg: SearchConfig) -> list[dict]:
    data = json.loads(path.read_text())
    old = {k: v for k, v in (data.get("config") or {}).items()
           if k not in _RESUME_FREE}
    new = {k: v for k, v in cfg.to_json().items() if k not in _RESUME_FREE}
    if old != new:
        raise ValueError(
            f"{path} was written by a different SearchConfig; "
            "delete it or match the original parameters to resume")
    return data["evals"]


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run_search(cfg: SearchConfig, *, out_dir=None, resume: bool = True,
               log=print) -> dict:
    """Run (or resume) the climb up to ``cfg.budget`` evaluations.

    Writes ``SEARCH.json`` atomically after every evaluation when ``out_dir``
    is given, so an interrupted search loses at most the in-flight eval."""
    out_path = md_path = None
    if out_dir is not None:
        out_dir = pathlib.Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        out_path = out_dir / "SEARCH.json"
        md_path = out_dir / "SEARCH.md"

    state = _fresh_state()
    evals: list[dict] = []
    if resume and out_path is not None and out_path.exists():
        evals = _load_ledger(out_path, cfg)[:cfg.budget]
        for rec in evals:
            _advance(state, rec)
        if evals:
            log(f"[search] resumed {len(evals)} evals from {out_path}")

    for i in range(len(evals), cfg.budget):
        point, origin = _propose(state, cfg, i)
        ev = evaluate(point, cfg)
        accepted = _accepts(state, origin, ev["regret"])
        best_so_far = max(ev["regret"],
                          state["best"]["regret"] if state["best"] else
                          ev["regret"])
        rec = {"i": i, "origin": origin, "point": point.to_dict(),
               "regret": ev["regret"], "per_seed": ev["per_seed"],
               "violations": ev["violations"], "checks": ev["checks"],
               "accepted": accepted, "best_so_far": _r6(best_so_far)}
        evals.append(rec)
        _advance(state, rec)
        log(f"[search] eval {i + 1}/{cfg.budget} ({origin}): "
            f"regret {ev['regret']:+.3f}"
            + (" ACCEPT" if accepted else "")
            + (f" [{ev['violations']} INVARIANT VIOLATIONS]"
               if ev["violations"] else ""))
        if out_path is not None:
            result = _result(cfg, evals, state["best"])
            _write_atomic(out_path, search_json(result))
            _write_atomic(md_path, search_markdown(result))

    result = _result(cfg, evals, state["best"])
    if out_path is not None:
        _write_atomic(out_path, search_json(result))
        _write_atomic(md_path, search_markdown(result))
        log(f"[search] wrote {out_path} and {md_path}")
    return result
