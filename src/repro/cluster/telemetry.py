"""Telemetry: per-attempt feature logging (the paper's Table 1 attributes) and the
training-set builder for the failure predictors.

Features are captured at *launch time* (what the scheduler can know when deciding),
the label is the attempt outcome.  Separate datasets for map and reduce tasks, as the
paper trains two models."""

from __future__ import annotations

import dataclasses

import numpy as np

FEATURE_NAMES = [
    "is_reduce",            # task type
    "priority",             # job priority (penalties lower it)
    "locality",             # data-local?
    "speculative",          # execution type
    "prev_finished_attempts",
    "prev_failed_attempts",
    "reschedule_events",
    "job_finished_tasks",
    "job_failed_tasks",
    "job_total_tasks",
    "tt_running_tasks",
    "tt_finished_tasks",
    "tt_failed_recent",
    "tt_free_slot_frac",
    "tt_net_rtt",           # heartbeat RTT proxy for net quality
    "tt_since_heartbeat",
    "tt_restarts",
    "input_mb",
    "penalty",
    "jt_is_wordcount",
    "jt_is_teragen",
    "jt_is_terasort",
]
N_FEATURES = len(FEATURE_NAMES)


def attempt_features(sim, task, node, speculative: bool,
                     out: np.ndarray | None = None) -> np.ndarray:
    """Feature vector for (task -> node) at time sim.now.  Everything here is
    JobTracker-observable (no hidden sim state).

    Job-level finished/failed counts read the simulator's incrementally
    maintained counters (exactly equal to scanning ``job.tasks``) so building
    a row is O(1) in job size — this runs once per scored placement, the
    hottest per-decision loop in the repo.  ``out`` writes the row into a
    caller-provided float32 buffer row (columnar append) instead of
    allocating."""
    job = sim.jobs[task.job_id]
    jt = job.jtype
    total_slots = node.spec.map_slots + node.spec.reduce_slots
    free = node.free_map_slots() + node.free_reduce_slots()
    local = 1.0 if (task.kind == "reduce" or node.nid in task.block_nodes) else 0.0
    # RTT proxy: degraded network AND a degraded TaskTracker process both inflate
    # the observed heartbeat round-trip (the JT genuinely sees this)
    rtt = (1.0 / max(node.net_quality, 0.05)) * (1.0 + 0.8 * (1.0 - node.health))
    vals = (
        1.0 if task.kind == "reduce" else 0.0,
        float(job.priority - task.penalty),
        local,
        1.0 if speculative else 0.0,
        float(task.finished_attempts),
        float(task.failed_attempts),
        float(task.reschedules),
        float(job.n_finished_tasks), float(job.n_failed_tasks),
        float(len(job.tasks)),
        float(len(node.running)),
        float(node.finished_count),
        float(node.recent_failure_count(sim.now)),
        free / max(total_slots, 1),
        rtt,
        (sim.now - node.last_heartbeat) / max(sim.heartbeat_interval, 1.0),
        float(node.restarts),
        task.input_mb,
        float(task.penalty),
        1.0 if jt == "wordcount" else 0.0,
        1.0 if jt == "teragen" else 0.0,
        1.0 if jt == "terasort" else 0.0,
    )
    if out is None:
        return np.array(vals, dtype=np.float32)
    out[:] = vals
    return out


@dataclasses.dataclass
class TelemetryTrace:
    """Collects (features, label) per attempt + job/task ledger rows."""
    map_X: list = dataclasses.field(default_factory=list)
    map_y: list = dataclasses.field(default_factory=list)
    red_X: list = dataclasses.field(default_factory=list)
    red_y: list = dataclasses.field(default_factory=list)
    _pending: dict = dataclasses.field(default_factory=dict)  # aid -> features
    jobs: dict = dataclasses.field(default_factory=dict)      # jid -> ledger row

    def record_launch(self, sim, att, p_fail_hidden):
        self._pending[att.aid] = attempt_features(sim, att.task, att.node,
                                                  att.speculative)

    def record_outcome(self, sim, att, finished: bool):
        feats = self._pending.pop(att.aid, None)
        if feats is None:
            return
        if att.task.kind == "map":
            self.map_X.append(feats)
            self.map_y.append(1.0 if finished else 0.0)
        else:
            self.red_X.append(feats)
            self.red_y.append(1.0 if finished else 0.0)
        row = self.jobs.get(att.task.job_id)
        if row is not None:
            row["failed_attempts" if not finished else
                "finished_attempts"] += 1

    def record_job_submit(self, sim, job):
        """Open a ledger row at submit — fires when sim.now == job.submit_time,
        so `submit` below is exactly job.submit_time."""
        self.jobs[job.jid] = {
            "job": job.jid, "jtype": job.jtype, "chain_id": job.chain_id,
            "submit": float(sim.now), "end": None, "outcome": None,
            "tasks": len(job.tasks), "maps": job.n_map_tasks,
            "reduces": len(job.tasks) - job.n_map_tasks,
            "finished_attempts": 0, "failed_attempts": 0,
        }

    def record_job_end(self, sim, job):
        """Close the row — fires when sim.now == job.done_time, so ledger
        durations equal ``done_time - submit_time`` recomputed from sim.jobs
        (the experiment-summary scans reuse this instead of rescanning)."""
        row = self.jobs.get(job.jid)
        if row is not None:
            row["end"] = float(sim.now)
            row["outcome"] = job.status

    def job_times(self, *, jtypes=None, outcome="finished") -> list[float]:
        """Completion durations straight from the ledger (submit order)."""
        out = []
        for jid in sorted(self.jobs):
            row = self.jobs[jid]
            if row["end"] is None or row["outcome"] != outcome:
                continue
            if jtypes is not None and row["jtype"] not in jtypes:
                continue
            out.append(row["end"] - row["submit"])
        return out

    def datasets(self):
        mx = np.stack(self.map_X) if self.map_X else np.zeros((0, N_FEATURES),
                                                              np.float32)
        my = np.asarray(self.map_y, np.float32)
        rx = np.stack(self.red_X) if self.red_X else np.zeros((0, N_FEATURES),
                                                              np.float32)
        ry = np.asarray(self.red_y, np.float32)
        return (mx, my), (rx, ry)
