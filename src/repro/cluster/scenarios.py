"""Named chaos scenarios + workload shapes — the sweep axes of the fleet engine.

The paper's §5 evaluation (and the follow-up literature: model-checking sweeps of
Hadoop schedulers, Google-trace failure studies) compares schedulers over a
*matrix* of failure regimes, not a single chaos configuration.  Each scenario here
is a named, documented point in that matrix, expressed as a ``ChaosConfig``
template on top of the existing injector:

  baseline          the paper's calibrated default (§5.1 Google-trace ceiling)
  bursty_tt         frequent correlated TaskTracker crash bursts (power events)
  dn_loss           DataNode-dominated failures -> input-block unavailability
  slot_degradation  latent thread-kill degradation: nodes stay up but rot
  net_flap          rapid short network slow-downs/drops (flapping switches)
  rack_failure      rare but huge correlated outages with long recovery
  straggler_heavy   suspensions + slow links: few hard failures, many stragglers
  kitchen_sink      everything at once at high intensity (stress ceiling)

The branch weights feed ``ChaosInjector.fire``'s cumulative draw: kill_tt,
suspend_tt, kill_dn, net_slow, net_drop are consumed in order and the residual
mass is the thread-kill (latent degradation) branch, so weights must sum to <= 1.

Workload shapes are the second declarative axis: named ``WorkloadConfig``
templates (job mix size/shape), including the tiny ``smoke`` shape CI sweeps use.

Per-cell seeds are injected by the fleet (``scenario_chaos``), never baked into
the templates, so one scenario fans out across any number of seeded repeats.
"""

from __future__ import annotations

import dataclasses

from repro.cluster.chaos import ChaosConfig
from repro.cluster.workload import WorkloadConfig


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    chaos: ChaosConfig

    def chaos_for_seed(self, seed: int) -> ChaosConfig:
        return dataclasses.replace(self.chaos, seed=seed)


def _chaos(**kw) -> ChaosConfig:
    cfg = ChaosConfig(**kw)
    event_mass = (cfg.kill_tt + cfg.suspend_tt + cfg.kill_dn + cfg.net_slow
                  + cfg.net_drop)
    if event_mass > 1.0 + 1e-9:
        raise ValueError(f"chaos branch weights sum to {event_mass} > 1")
    return cfg


SCENARIOS: dict[str, Scenario] = {}


def _register(name: str, description: str, chaos: ChaosConfig) -> Scenario:
    sc = Scenario(name, description, chaos)
    SCENARIOS[name] = sc
    return sc


_register(
    "baseline",
    "Paper §5.1 calibrated default: mixed failures near the Google-trace ceiling",
    _chaos())

_register(
    "bursty_tt",
    "Correlated TaskTracker crash bursts (power events) dominate; the regime the "
    "adaptive heartbeat's 1/3-of-TTs rule targets",
    _chaos(intensity=6.0, kill_tt=0.50, suspend_tt=0.10, kill_dn=0.05,
           net_slow=0.10, net_drop=0.05, burst_prob=0.30, burst_size=(5, 9),
           mean_outage=700.0))

_register(
    "dn_loss",
    "DataNode-dominated failures: HDFS block replicas vanish, maps hit "
    "input-unavailable faults",
    _chaos(intensity=5.5, kill_tt=0.08, suspend_tt=0.05, kill_dn=0.60,
           net_slow=0.10, net_drop=0.05, mean_outage=1200.0, burst_prob=0.02))

_register(
    "slot_degradation",
    "Nodes stay nominally alive but thread kills rot their latent health; "
    "failures look idiopathic to a liveness-only scheduler",
    _chaos(intensity=6.5, kill_tt=0.05, suspend_tt=0.05, kill_dn=0.04,
           net_slow=0.08, net_drop=0.03, mean_outage=1500.0, burst_prob=0.01))

_register(
    "net_flap",
    "Flapping network: frequent short slow-downs and drops, quick recovery",
    _chaos(intensity=7.5, kill_tt=0.05, suspend_tt=0.05, kill_dn=0.05,
           net_slow=0.50, net_drop=0.25, mean_outage=300.0,
           mean_interarrival=180.0, burst_prob=0.01))

_register(
    "rack_failure",
    "Rare correlated rack-scale outages with long recovery (paper §1: power "
    "problems take down large machine groups at once)",
    _chaos(intensity=3.5, kill_tt=0.30, suspend_tt=0.05, kill_dn=0.20,
           net_slow=0.10, net_drop=0.05, burst_prob=0.45, burst_size=(6, 10),
           mean_outage=1800.0))

_register(
    "straggler_heavy",
    "Few hard failures, many stragglers: suspensions and slow links stretch "
    "task runtimes (the speculative-execution battleground)",
    _chaos(intensity=6.0, kill_tt=0.04, suspend_tt=0.40, kill_dn=0.03,
           net_slow=0.40, net_drop=0.03, mean_outage=900.0, burst_prob=0.01))

_register(
    "kitchen_sink",
    "Everything at once at high intensity — the stress ceiling every scheduler "
    "should degrade gracefully under",
    _chaos(intensity=9.0, kill_tt=0.22, suspend_tt=0.12, kill_dn=0.16,
           net_slow=0.22, net_drop=0.08, burst_prob=0.10, burst_size=(4, 8),
           mean_outage=1100.0))


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        known = ", ".join(sorted(SCENARIOS))
        raise KeyError(f"unknown scenario {name!r}; known: {known}") from None


def scenario_chaos(name: str, seed: int) -> ChaosConfig:
    """ChaosConfig for a named scenario with the fleet's per-cell seed."""
    return get_scenario(name).chaos_for_seed(seed)


# ---------------------------------------------------------------------------
# Workload shapes (the fourth sweep axis)
# ---------------------------------------------------------------------------

WORKLOAD_SHAPES: dict[str, WorkloadConfig] = {
    # the paper's §5.1 mix
    "default": WorkloadConfig(),
    # tiny shape for CI smoke sweeps and unit tests: seconds per cell
    "smoke": WorkloadConfig(n_single=6, n_chains=1, chain_len_range=(3, 4),
                            maps_range=(4, 8), reduces_range=(2, 6),
                            submit_horizon=2400.0),
    # long chained pipelines dominate (cascade-failure sensitivity)
    "chain_heavy": WorkloadConfig(n_single=12, n_chains=16,
                                  chain_len_range=(6, 14)),
    # many small map-dominated jobs (TeraGen-ish scan shape)
    "map_heavy": WorkloadConfig(n_single=64, n_chains=4, maps_range=(10, 24),
                                reduces_range=(1, 4)),
}


def get_workload_shape(name: str) -> WorkloadConfig:
    try:
        return WORKLOAD_SHAPES[name]
    except KeyError:
        known = ", ".join(sorted(WORKLOAD_SHAPES))
        raise KeyError(f"unknown workload shape {name!r}; known: {known}") \
            from None


def workload_for_seed(name: str, seed: int) -> WorkloadConfig:
    return dataclasses.replace(get_workload_shape(name), seed=seed)
