"""Typed scenario space: named chaos scenarios + workload shapes + search bounds.

The paper's §5 evaluation (and the follow-up literature: model-checking sweeps of
Hadoop schedulers, Google-trace failure studies) compares schedulers over a
*matrix* of failure regimes, not a single chaos configuration.  Each scenario here
is a named, documented point in that matrix, expressed as a ``ChaosConfig``
template on top of the existing injector:

  baseline          the paper's calibrated default (§5.1 Google-trace ceiling)
  bursty_tt         frequent correlated TaskTracker crash bursts (power events)
  dn_loss           DataNode-dominated failures -> input-block unavailability
  slot_degradation  latent thread-kill degradation: nodes stay up but rot
  net_flap          rapid short network slow-downs/drops (flapping switches)
  rack_failure      rare but huge correlated outages with long recovery
  straggler_heavy   suspensions + slow links: few hard failures, many stragglers
  kitchen_sink      everything at once at high intensity (stress ceiling)

The branch weights feed ``ChaosInjector.fire``'s cumulative draw: kill_tt,
suspend_tt, kill_dn, net_slow, net_drop are consumed in order and the residual
mass is the thread-kill (latent degradation) branch, so weights must sum to <= 1.

Workload shapes are the second declarative axis: named ``WorkloadConfig``
templates (job mix size/shape), including the tiny ``smoke`` shape CI sweeps use.

Since PR 8 the canonical unit is ``ScenarioSpec``: a (chaos, workload) pair with
per-parameter ``Bound`` metadata.  The bounds double as the *search space* of the
adversarial driver in ``repro.cluster.search`` — ``perturb``/``sample`` never
leave them, and they are calibrated against the Google-trace failure
characterisation (arXiv 2308.02358): event interarrivals of minutes-to-tens-of-
minutes, outages of minutes-to-an-hour, burst footprints up to roughly a rack.

Per-cell seeds are injected by the fleet (``ScenarioSpec.chaos_for_seed``),
never baked into the templates, so one scenario fans out across any number of
seeded repeats.  The pre-PR8 free functions (``scenario_chaos``,
``get_workload_shape``, ``workload_for_seed``) and the ``Scenario`` name remain
as thin deprecated wrappers.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import random
import warnings

from repro.cluster.chaos import ChaosConfig
from repro.cluster.workload import WorkloadConfig


# ---------------------------------------------------------------------------
# Parameter bounds — the typed search space
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Bound:
    """Closed interval for one searchable parameter.

    kind: "float" (linear), "weight" (linear float, part of the branch-mass
    simplex), "int" (scalar integer), or "span" (an (lo, hi) integer pair such
    as ``burst_size``).  ``log=True`` floats mutate multiplicatively — right for
    scale parameters (rates, durations) whose realistic regimes span decades.
    """
    lo: float
    hi: float
    kind: str = "float"
    log: bool = False


# Chaos bounds follow the Google-trace failure characterisation (arXiv
# 2308.02358) and the paper's EMR calibration: event interarrivals from one
# minute to twenty, outages from two minutes to an hour, correlated bursts up
# to ~a rack of the reference 13-slave fleet.
CHAOS_BOUNDS: dict[str, Bound] = {
    "intensity": Bound(0.5, 12.0, log=True),
    "mean_interarrival": Bound(60.0, 1200.0, log=True),
    "kill_tt": Bound(0.0, 0.7, "weight"),
    "suspend_tt": Bound(0.0, 0.7, "weight"),
    "kill_dn": Bound(0.0, 0.7, "weight"),
    "net_slow": Bound(0.0, 0.7, "weight"),
    "net_drop": Bound(0.0, 0.7, "weight"),
    "mean_outage": Bound(120.0, 3600.0, log=True),
    "burst_prob": Bound(0.0, 0.5),
    "burst_size": Bound(1, 12, "span"),
}

# Workload bounds bracket the four named shapes (smoke ... map_heavy) so every
# named scenario is an interior point of the space the search mutates.
WORKLOAD_BOUNDS: dict[str, Bound] = {
    "n_single": Bound(2, 96, "int"),
    "n_chains": Bound(0, 24, "int"),
    "chain_len_range": Bound(2, 20, "span"),
    "maps_range": Bound(2, 32, "span"),
    "reduces_range": Bound(1, 24, "span"),
    "max_map_attempts": Bound(2, 6, "int"),
    "max_reduce_attempts": Bound(2, 6, "int"),
    "submit_horizon": Bound(1200.0, 21600.0, log=True),
}

# branch weights share a simplex: their combined mass is capped below 1 so the
# thread-kill residual branch never fully vanishes from a searched point
WEIGHT_FIELDS = ("kill_tt", "suspend_tt", "kill_dn", "net_slow", "net_drop")
MAX_EVENT_MASS = 0.95


def _r6(x: float) -> float:
    # ledger floats are canonicalised with round(6); rounding at creation time
    # keeps in-memory values identical to resumed-from-JSON values
    return round(float(x), 6)


def _renorm_weights(chaos_kw: dict) -> None:
    mass = sum(chaos_kw[w] for w in WEIGHT_FIELDS)
    if mass > MAX_EVENT_MASS:
        f = MAX_EVENT_MASS / mass
        for w in WEIGHT_FIELDS:
            chaos_kw[w] = _r6(chaos_kw[w] * f)


def _mutate(rng: random.Random, value, b: Bound, scale: float):
    lo_i, hi_i = int(b.lo), int(b.hi)
    if b.kind == "span":
        step = max(1, round(scale * (hi_i - lo_i) * 0.5))
        lo, hi = value
        lo = min(max(lo_i, lo + rng.randint(-step, step)), hi_i)
        hi = min(max(lo_i, hi + rng.randint(-step, step)), hi_i)
        return (lo, hi) if lo <= hi else (hi, lo)
    if b.kind == "int":
        step = max(1, round(scale * (hi_i - lo_i) * 0.5))
        return min(max(lo_i, int(value) + rng.randint(-step, step)), hi_i)
    if b.log:
        nv = value * math.exp(rng.gauss(0.0, scale))
    else:
        nv = value + rng.gauss(0.0, scale) * (b.hi - b.lo) * 0.5
    return _r6(min(max(b.lo, nv), b.hi))


def _draw(rng: random.Random, b: Bound):
    lo_i, hi_i = int(b.lo), int(b.hi)
    if b.kind == "span":
        a, c = rng.randint(lo_i, hi_i), rng.randint(lo_i, hi_i)
        return (a, c) if a <= c else (c, a)
    if b.kind == "int":
        return rng.randint(lo_i, hi_i)
    if b.log:
        return _r6(math.exp(rng.uniform(math.log(b.lo), math.log(b.hi))))
    return _r6(rng.uniform(b.lo, b.hi))


def _encode_cfg(cfg) -> dict:
    return {k: list(v) if isinstance(v, tuple) else v
            for k, v in dataclasses.asdict(cfg).items()}


def _decode_cfg(cls, payload: dict):
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = set(payload) - names
    if unknown:
        raise ValueError(f"unknown {cls.__name__} fields: {sorted(unknown)}")
    return cls(**{k: tuple(v) if isinstance(v, list) else v
                  for k, v in payload.items()})


# ---------------------------------------------------------------------------
# ScenarioSpec — the typed (chaos, workload) point
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One typed point in the scenario space: a chaos regime paired with a
    workload shape, serialisable (``to_dict``/``from_dict``) and mutable within
    the declared bounds (``perturb``/``sample``)."""

    name: str
    description: str
    chaos: ChaosConfig
    workload: WorkloadConfig = dataclasses.field(default_factory=WorkloadConfig)

    def __post_init__(self):
        # hook point: the deprecated Scenario subclass warns from here
        pass

    # --- per-cell seed injection (templates stay untouched) ----------------
    def chaos_for_seed(self, seed: int) -> ChaosConfig:
        return dataclasses.replace(self.chaos, seed=seed)

    def workload_for_seed(self, seed: int) -> WorkloadConfig:
        return dataclasses.replace(self.workload, seed=seed)

    # --- validity ----------------------------------------------------------
    def validate(self) -> "ScenarioSpec":
        c, w = self.chaos, self.workload
        mass = sum(getattr(c, f) for f in WEIGHT_FIELDS)
        if mass > 1.0 + 1e-9:
            raise ValueError(f"chaos branch weights sum to {mass} > 1")
        if min(getattr(c, f) for f in WEIGHT_FIELDS) < 0.0:
            raise ValueError("chaos branch weights must be >= 0")
        if c.intensity <= 0 or c.mean_interarrival <= 0 or c.mean_outage <= 0:
            raise ValueError("chaos rate/duration parameters must be > 0")
        if not 0.0 <= c.burst_prob <= 1.0:
            raise ValueError(f"burst_prob {c.burst_prob} outside [0, 1]")
        lo, hi = c.burst_size
        if not 1 <= lo <= hi:
            raise ValueError(f"burst_size {c.burst_size} must satisfy 1<=lo<=hi")
        if w.n_single < 0 or w.n_chains < 0:
            raise ValueError("workload job counts must be >= 0")
        for rng_name in ("chain_len_range", "maps_range", "reduces_range"):
            rlo, rhi = getattr(w, rng_name)
            if not 0 <= rlo <= rhi:
                raise ValueError(f"{rng_name} {(rlo, rhi)} must be ordered")
        if w.max_map_attempts < 1 or w.max_reduce_attempts < 1:
            raise ValueError("attempt caps must be >= 1")
        if w.submit_horizon <= 0 or w.n_nodes < 1 or w.replication < 1:
            raise ValueError("submit_horizon/n_nodes/replication out of range")
        return self

    # --- serialisation (round-trip identity) -------------------------------
    def to_dict(self) -> dict:
        return {"name": self.name, "description": self.description,
                "chaos": _encode_cfg(self.chaos),
                "workload": _encode_cfg(self.workload)}

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioSpec":
        return cls(name=d["name"], description=d.get("description", ""),
                   chaos=_decode_cfg(ChaosConfig, d["chaos"]),
                   workload=_decode_cfg(WorkloadConfig, d["workload"]))

    # --- search moves ------------------------------------------------------
    def perturb(self, rng: random.Random, scale: float = 0.25) -> "ScenarioSpec":
        """One hill-climb move: mutate 1-3 searchable parameters, clip to
        bounds, renormalise the branch-weight simplex.  Deterministic given the
        rng state; float outputs are pre-rounded to the ledger's 6 decimals."""
        chaos_kw = dataclasses.asdict(self.chaos)
        wl_kw = dataclasses.asdict(self.workload)
        fields = ([("chaos", n, b) for n, b in CHAOS_BOUNDS.items()]
                  + [("workload", n, b) for n, b in WORKLOAD_BOUNDS.items()])
        for which, fname, b in rng.sample(fields, rng.randint(1, 3)):
            target = chaos_kw if which == "chaos" else wl_kw
            target[fname] = _mutate(rng, target[fname], b, scale)
        _renorm_weights(chaos_kw)
        return dataclasses.replace(
            self, chaos=ChaosConfig(**chaos_kw),
            workload=WorkloadConfig(**wl_kw)).validate()

    @classmethod
    def sample(cls, rng: random.Random, *, name: str = "sampled",
               description: str = "uniform draw from the search bounds",
               ) -> "ScenarioSpec":
        """Uniform (log-uniform for scale parameters) draw within the bounds —
        the random-restart move of the search driver."""
        chaos_kw = {n: _draw(rng, b) for n, b in CHAOS_BOUNDS.items()}
        _renorm_weights(chaos_kw)
        wl_kw = {n: _draw(rng, b) for n, b in WORKLOAD_BOUNDS.items()}
        return cls(name=name, description=description,
                   chaos=ChaosConfig(**chaos_kw),
                   workload=WorkloadConfig(**wl_kw)).validate()


class Scenario(ScenarioSpec):
    """Deprecated pre-PR8 name for :class:`ScenarioSpec`."""

    def __post_init__(self):
        warnings.warn("repro.cluster.Scenario is deprecated; use ScenarioSpec",
                      DeprecationWarning, stacklevel=3)


def _chaos(**kw) -> ChaosConfig:
    cfg = ChaosConfig(**kw)
    event_mass = sum(getattr(cfg, f) for f in WEIGHT_FIELDS)
    if event_mass > 1.0 + 1e-9:
        raise ValueError(f"chaos branch weights sum to {event_mass} > 1")
    return cfg


SCENARIOS: dict[str, ScenarioSpec] = {}


def _register(name: str, description: str, chaos: ChaosConfig) -> ScenarioSpec:
    sc = ScenarioSpec(name, description, chaos)
    SCENARIOS[name] = sc
    return sc


_register(
    "baseline",
    "Paper §5.1 calibrated default: mixed failures near the Google-trace ceiling",
    _chaos())

_register(
    "bursty_tt",
    "Correlated TaskTracker crash bursts (power events) dominate; the regime the "
    "adaptive heartbeat's 1/3-of-TTs rule targets",
    _chaos(intensity=6.0, kill_tt=0.50, suspend_tt=0.10, kill_dn=0.05,
           net_slow=0.10, net_drop=0.05, burst_prob=0.30, burst_size=(5, 9),
           mean_outage=700.0))

_register(
    "dn_loss",
    "DataNode-dominated failures: HDFS block replicas vanish, maps hit "
    "input-unavailable faults",
    _chaos(intensity=5.5, kill_tt=0.08, suspend_tt=0.05, kill_dn=0.60,
           net_slow=0.10, net_drop=0.05, mean_outage=1200.0, burst_prob=0.02))

_register(
    "slot_degradation",
    "Nodes stay nominally alive but thread kills rot their latent health; "
    "failures look idiopathic to a liveness-only scheduler",
    _chaos(intensity=6.5, kill_tt=0.05, suspend_tt=0.05, kill_dn=0.04,
           net_slow=0.08, net_drop=0.03, mean_outage=1500.0, burst_prob=0.01))

_register(
    "net_flap",
    "Flapping network: frequent short slow-downs and drops, quick recovery",
    _chaos(intensity=7.5, kill_tt=0.05, suspend_tt=0.05, kill_dn=0.05,
           net_slow=0.50, net_drop=0.25, mean_outage=300.0,
           mean_interarrival=180.0, burst_prob=0.01))

_register(
    "rack_failure",
    "Rare correlated rack-scale outages with long recovery (paper §1: power "
    "problems take down large machine groups at once)",
    _chaos(intensity=3.5, kill_tt=0.30, suspend_tt=0.05, kill_dn=0.20,
           net_slow=0.10, net_drop=0.05, burst_prob=0.45, burst_size=(6, 10),
           mean_outage=1800.0))

_register(
    "straggler_heavy",
    "Few hard failures, many stragglers: suspensions and slow links stretch "
    "task runtimes (the speculative-execution battleground)",
    _chaos(intensity=6.0, kill_tt=0.04, suspend_tt=0.40, kill_dn=0.03,
           net_slow=0.40, net_drop=0.03, mean_outage=900.0, burst_prob=0.01))

_register(
    "kitchen_sink",
    "Everything at once at high intensity — the stress ceiling every scheduler "
    "should degrade gracefully under",
    _chaos(intensity=9.0, kill_tt=0.22, suspend_tt=0.12, kill_dn=0.16,
           net_slow=0.22, net_drop=0.08, burst_prob=0.10, burst_size=(4, 8),
           mean_outage=1100.0))


def get_scenario(name: str) -> ScenarioSpec:
    try:
        return SCENARIOS[name]
    except KeyError:
        known = ", ".join(sorted(SCENARIOS))
        raise KeyError(f"unknown scenario {name!r}; known: {known}") from None


# ---------------------------------------------------------------------------
# Workload shapes (the fourth sweep axis)
# ---------------------------------------------------------------------------

WORKLOAD_SHAPES: dict[str, WorkloadConfig] = {
    # the paper's §5.1 mix
    "default": WorkloadConfig(),
    # tiny shape for CI smoke sweeps and unit tests: seconds per cell
    "smoke": WorkloadConfig(n_single=6, n_chains=1, chain_len_range=(3, 4),
                            maps_range=(4, 8), reduces_range=(2, 6),
                            submit_horizon=2400.0),
    # long chained pipelines dominate (cascade-failure sensitivity)
    "chain_heavy": WorkloadConfig(n_single=12, n_chains=16,
                                  chain_len_range=(6, 14)),
    # many small map-dominated jobs (TeraGen-ish scan shape)
    "map_heavy": WorkloadConfig(n_single=64, n_chains=4, maps_range=(10, 24),
                                reduces_range=(1, 4)),
}


def get_workload(name: str) -> WorkloadConfig:
    try:
        return WORKLOAD_SHAPES[name]
    except KeyError:
        known = ", ".join(sorted(WORKLOAD_SHAPES))
        raise KeyError(f"unknown workload shape {name!r}; known: {known}") \
            from None


def make_spec(scenario: str, workload: str = "default") -> ScenarioSpec:
    """Combine a named chaos scenario with a named workload shape into one
    typed ScenarioSpec — the canonical way fleet cells resolve their axes."""
    sc = get_scenario(scenario)
    return ScenarioSpec(name=sc.name, description=sc.description,
                        chaos=sc.chaos, workload=get_workload(workload))


@contextlib.contextmanager
def scenario_scope(spec: ScenarioSpec, *, scenario_name: str | None = None,
                   workload_name: str | None = None):
    """Temporarily register ``spec`` under fresh names in both registries, so
    the fleet engine (which resolves scenario/workload *names* in the parent
    process before fanning cells out to workers) can sweep a synthetic point.

    Yields ``(scenario_name, workload_name)``; always unregisters on exit.
    """
    s_name = scenario_name or spec.name
    w_name = workload_name or spec.name
    if s_name in SCENARIOS:
        raise ValueError(f"scenario name {s_name!r} already registered")
    if w_name in WORKLOAD_SHAPES:
        raise ValueError(f"workload name {w_name!r} already registered")
    SCENARIOS[s_name] = spec
    WORKLOAD_SHAPES[w_name] = spec.workload
    try:
        yield s_name, w_name
    finally:
        SCENARIOS.pop(s_name, None)
        WORKLOAD_SHAPES.pop(w_name, None)


# ---------------------------------------------------------------------------
# Deprecated pre-PR8 free functions (thin wrappers; emit DeprecationWarning)
# ---------------------------------------------------------------------------

def _deprecated(old: str, new: str) -> None:
    warnings.warn(f"{old} is deprecated; use {new}", DeprecationWarning,
                  stacklevel=3)


def scenario_chaos(name: str, seed: int) -> ChaosConfig:
    """Deprecated: use ``get_scenario(name).chaos_for_seed(seed)``."""
    _deprecated("scenario_chaos()", "get_scenario(name).chaos_for_seed(seed)")
    return get_scenario(name).chaos_for_seed(seed)


def get_workload_shape(name: str) -> WorkloadConfig:
    """Deprecated: use ``get_workload(name)``."""
    _deprecated("get_workload_shape()", "get_workload(name)")
    return get_workload(name)


def workload_for_seed(name: str, seed: int) -> WorkloadConfig:
    """Deprecated: use ``dataclasses.replace(get_workload(name), seed=seed)``
    or ``ScenarioSpec.workload_for_seed``."""
    _deprecated("workload_for_seed()", "ScenarioSpec.workload_for_seed")
    return dataclasses.replace(get_workload(name), seed=seed)
