"""Per-tick machine-checkable scheduler invariants — the model-checking layer.

The companion line of work on Hadoop schedulers (PAPERS.md, arXiv 2109.04196)
verifies scheduler behaviour by model checking + simulation; this module is the
simulation half of that idea for our fast simulator: a catalogue of predicates
that must hold at every step of *any* run, checked live behind a cheap
``check_invariants`` flag so every adversarial-search evaluation doubles as a
model-checking run.

Catalogue (see docs/SEARCH.md for the full rationale):

  launch-time (every ``Simulator.launch``, O(1)):
    L1  free slot: the target node has a free slot of the task's kind
    L2  liveness: no launch on a node the JobTracker knows is dead unless the
        TaskTracker is actually up (ATLAS's active probe may legally launch on
        an up node the JT hasn't re-learned yet; a launch that is dead in BOTH
        views can never run)
    L3  status: non-speculative launches take a *pending* task, speculative
        copies shadow a *running* one

  per-event (every simulator event, O(1)):
    E1  time is monotone non-decreasing
    E2  the running-job counter never goes negative

  full sweep (every ``sweep_every`` events + at end of run, O(nodes+tasks)):
    S1  slot conservation: 0 <= running_maps <= map_slots (same for reduces)
        and |node.running| == running_maps + running_reduces
    S2  index consistency: the incremental free-slot / known-alive index sets
        exactly mirror per-node state
    S3  node counters (failed/finished/restarts) are monotone
    S4  outage => recovery: every node in an outage state (TT/DN dead,
        suspended, degraded network) has >= 1 chaos recovery scheduled
        (``ChaosInjector.pending_recoveries``); latent health is excluded —
        recovery restores the *degradation amount*, not health == 1.0
    S5  penalty-box monotonicity: enqueue timestamps are non-decreasing along
        the deque and every boxed task has penalty >= 1
    S6  task counters (failed/finished attempts, reschedules, penalty) are
        monotone

Violations are recorded (bounded examples + a total count) and surface in
``Simulator.metrics()['invariant_violations']``; ``raise_on_violation=True``
turns the first one into an :class:`InvariantViolation` for property tests.
The checker only *reads* simulator state — decisions and results are
byte-identical with checking on or off.
"""

from __future__ import annotations

from repro.cluster import simulator as S


class InvariantViolation(AssertionError):
    """A per-tick scheduler invariant failed (raise_on_violation mode)."""


class InvariantChecker:
    """Attachable invariant monitor for one :class:`Simulator` run.

    Cost model: the E1/E2 per-event checks are INLINED in the simulator run
    loop (a couple of compares on loop locals); this class is only entered on
    a violation, a sweep boundary, or a launch.  The O(nodes + tasks) full
    sweep runs every ``max(sweep_every, 2 * n_nodes)`` events plus once at end
    of run, so its amortised cost stays O(1) per event at any fleet size —
    together this keeps the checker inside the <=10% runtime budget on
    500-node cells.
    """

    def __init__(self, *, sweep_every: int = 128,
                 raise_on_violation: bool = False, max_examples: int = 16):
        self.sweep_every = max(int(sweep_every), 1)
        self.raise_on_violation = raise_on_violation
        self.max_examples = max_examples
        self.n_checks = 0          # events + launches + sweeps examined
        self.n_sweeps = 0
        self.n_violations = 0
        self.violations: list[dict] = []   # bounded examples
        self.sweep_interval = self.sweep_every   # effective; set in bind()
        self._node_mono: list[tuple] = []
        self._task_mono: dict = {}

    # ------------------------------------------------------------------ wiring
    def bind(self, sim: "S.Simulator"):
        self.sim = sim
        self._node_mono = [(0, 0, 0)] * len(sim.nodes)
        # amortise the O(nodes) sweep to O(1)/event regardless of fleet size
        self.sweep_interval = max(self.sweep_every, 2 * len(sim.nodes))

    def _viol(self, sim, name: str, detail: str):
        self.n_violations += 1
        if len(self.violations) < self.max_examples:
            self.violations.append(
                {"invariant": name, "t": round(sim.now, 3), "detail": detail})
        if self.raise_on_violation:
            raise InvariantViolation(f"[{name}] t={sim.now:.1f}: {detail}")

    # ------------------------------------------------------------------ launch
    def check_launch(self, sim, task, node, speculative: bool):
        self.n_checks += 1
        if task.kind == S.MAP:
            free = node.spec.map_slots - node.running_maps
        else:
            free = node.spec.reduce_slots - node.running_reduces
        if free <= 0:
            self._viol(sim, "launch_no_free_slot",
                       f"{task.kind} task {task.key} on node {node.nid} "
                       f"with no free {task.kind} slot")
        if not node.known_alive and not (node.tt_alive and not node.suspended):
            self._viol(sim, "launch_on_dead_node",
                       f"task {task.key} on node {node.nid} "
                       f"(known_alive=False, tt_alive={node.tt_alive}, "
                       f"suspended={node.suspended})")
        if speculative:
            if task.status != "running":
                self._viol(sim, "speculative_copy_of_nonrunning",
                           f"speculative copy of {task.status} task {task.key}")
        elif task.status != "pending":
            self._viol(sim, "launch_of_nonpending",
                       f"primary launch of {task.status} task {task.key}")

    # ------------------------------------------------------------------ events
    def on_event(self, sim, prev_now: float):
        """Slow path behind the inlined E1/E2 compares in ``Simulator.run``:
        entered only on a violation or a sweep boundary."""
        if sim.now < prev_now:
            self._viol(sim, "time_regression",
                       f"now {sim.now} < previous event time {prev_now}")
        if sim.n_running_jobs < 0:
            self._viol(sim, "negative_running_jobs",
                       f"n_running_jobs == {sim.n_running_jobs}")
        self.full_sweep(sim)

    def finish(self, sim, n_events: int = 0):
        self.n_checks += n_events      # inlined per-event checks, tallied once
        self.full_sweep(sim)

    # ------------------------------------------------------------------ sweep
    def full_sweep(self, sim):
        self.n_checks += 1
        self.n_sweeps += 1
        free_map, free_reduce = sim._free_map, sim._free_reduce
        known = sim._known_alive
        pend_rec = getattr(sim.chaos, "pending_recoveries", None)
        node_mono = self._node_mono
        for n in sim.nodes:
            rm, rr = n.running_maps, n.running_reduces
            if not 0 <= rm <= n.spec.map_slots:
                self._viol(sim, "map_slot_conservation",
                           f"node {n.nid}: running_maps={rm} "
                           f"slots={n.spec.map_slots}")
            if not 0 <= rr <= n.spec.reduce_slots:
                self._viol(sim, "reduce_slot_conservation",
                           f"node {n.nid}: running_reduces={rr} "
                           f"slots={n.spec.reduce_slots}")
            if len(n.running) != rm + rr:
                self._viol(sim, "running_set_mismatch",
                           f"node {n.nid}: |running|={len(n.running)} "
                           f"!= maps {rm} + reduces {rr}")
            if (n.nid in free_map) != (rm < n.spec.map_slots):
                listed = "in" if n.nid in free_map else "out"
                self._viol(sim, "free_map_index_stale",
                           f"node {n.nid}: index={listed} "
                           f"running_maps={rm}/{n.spec.map_slots}")
            if (n.nid in free_reduce) != (rr < n.spec.reduce_slots):
                listed = "in" if n.nid in free_reduce else "out"
                self._viol(sim, "free_reduce_index_stale",
                           f"node {n.nid}: index={listed} "
                           f"running_reduces={rr}/{n.spec.reduce_slots}")
            if (n.nid in known) != n.known_alive:
                self._viol(sim, "known_alive_index_stale",
                           f"node {n.nid}: known_alive={n.known_alive} "
                           f"index={'in' if n.nid in known else 'out'}")
            prev = node_mono[n.nid]
            cur = (n.failed_count, n.finished_count, n.restarts)
            if cur[0] < prev[0] or cur[1] < prev[1] or cur[2] < prev[2]:
                self._viol(sim, "node_counter_regression",
                           f"node {n.nid}: {prev} -> {cur}")
            node_mono[n.nid] = cur
            if pend_rec is not None and (
                    not n.tt_alive or not n.dn_alive or n.suspended
                    or n.net_quality < 1.0) and pend_rec.get(n.nid, 0) <= 0:
                self._viol(sim, "outage_without_recovery",
                           f"node {n.nid} in outage state "
                           f"(tt={n.tt_alive} dn={n.dn_alive} "
                           f"susp={n.suspended} net={n.net_quality}) "
                           "with no recovery scheduled")
        self._check_penalty_box(sim)
        self._check_task_monotone(sim)

    def _check_penalty_box(self, sim):
        box = getattr(sim.scheduler, "penalty_box", None)
        if not box:
            return
        last_t = None
        for key, enq in box:
            if last_t is not None and enq < last_t:
                self._viol(sim, "penalty_box_order",
                           f"enqueue time {enq} after {last_t} for {key}")
            last_t = enq
            task = sim._task_by_key(key)
            if task is not None and task.penalty < 1:
                self._viol(sim, "penalty_box_unpenalized",
                           f"boxed task {key} has penalty={task.penalty}")

    def _check_task_monotone(self, sim):
        mono = self._task_mono
        for job in sim.jobs.values():
            for task in job.tasks.values():
                cur = (task.failed_attempts, task.finished_attempts,
                       task.reschedules, task.penalty)
                prev = mono.get(task.key)
                if prev is not None and any(c < p for c, p in zip(cur, prev)):
                    self._viol(sim, "task_counter_regression",
                               f"task {task.key}: {prev} -> {cur}")
                mono[task.key] = cur

    # ------------------------------------------------------------------ report
    def summary(self) -> dict:
        return {"checks": self.n_checks, "sweeps": self.n_sweeps,
                "violations": self.n_violations,
                "examples": list(self.violations)}
