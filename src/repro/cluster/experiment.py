"""Experiment driver reproducing the paper's §5 protocol:

  1. run the base scheduler on a workload with chaos injection, collecting logs
     (the training run — the paper built per-scheduler models from such logs);
  2. fit the failure predictor on those logs;
  3. re-run the *same* workload/chaos seeds under the base scheduler and under
     ATLAS-<base> (pre-trained predictor + 10-min online retraining);
  4. compare metrics (Figures 4-12, Table 4).
"""

from __future__ import annotations

import dataclasses

from repro.cluster.chaos import ChaosConfig, ChaosInjector
from repro.cluster.simulator import Simulator, make_fleet
from repro.cluster.telemetry import TelemetryTrace
from repro.cluster.workload import WorkloadConfig, install, make_workload
from repro.core.atlas import ATLASScheduler
from repro.core.predictor import TaskPredictor
from repro.sched.base import BASELINES


@dataclasses.dataclass
class ExperimentConfig:
    workload: WorkloadConfig = dataclasses.field(default_factory=WorkloadConfig)
    chaos: ChaosConfig = dataclasses.field(default_factory=ChaosConfig)
    seed: int = 0
    heartbeat_interval: float = 600.0
    algo: str = "R.F."
    threshold: float = 0.5
    n_speculative: int = 2
    retrain_every: float = 600.0
    hazard_noise: float = 0.55
    min_samples: int = 150
    # training-set cap; a small fixed cap also pins the train-batch shape so
    # online retraining reuses one jitted program instead of recompiling
    max_train: int = 20000
    # drift-aware refresh (repro.online.drift) instead of the fixed clock
    drift: bool = False
    drift_check_every: float = 60.0
    # fleet-size scale axis: 0 = the paper's 13-slave EMR fleet, N = an
    # N-node fleet cycling the same machine mix (simulator.make_fleet)
    fleet_size: int = 0
    # live telemetry (repro.obs): when obs_path is set each run streams
    # per-tick NDJSON frames there and metrics gain a deterministic "obs"
    # roll-up.  Observers only read sim state, so results are byte-identical
    # with telemetry on or off.
    obs_path: str | None = None
    obs_frame_every: float = 60.0
    # live telemetry wire: stream the same frames to a serving AsyncBroker /
    # TelemetryCollector over inproc://‌ or tcp:// (repro.obs.TransportSink).
    # obs_source names this run on the wire (fleet uses the cell id).  The
    # live path observes, never perturbs: results stay byte-identical.
    obs_live_addr: str | None = None
    obs_source: str | None = None
    # per-tick invariant checking (repro.cluster.invariants): violations are
    # recorded (never raised) and surface in metrics["invariant_violations"];
    # the checker only reads sim state, so decisions are unchanged
    check_invariants: bool = False


def _fleet_for(cfg: "ExperimentConfig"):
    return make_fleet(cfg.fleet_size) if cfg.fleet_size else None


def _make_obs(cfg: ExperimentConfig):
    if not cfg.obs_path and not cfg.obs_live_addr:
        return None
    from repro.obs import NDJSONSink, SimObserver, TeeSink, TransportSink
    from repro.obs.sink import telemetry_loop
    sinks = []
    if cfg.obs_path:
        sinks.append(NDJSONSink(cfg.obs_path))
    if cfg.obs_live_addr:
        # tcp sinks share the process loop and batch frames per send —
        # per-run thread churn and per-frame send round-trips both land
        # inside the live overhead budget (benchmarks/live_overhead.py).
        # reconnect=True: a collector crash/restart mid-run must read as a
        # telemetry gap (bounded buffer + backoff re-dial), never as a
        # failed simulation
        loop = (telemetry_loop()
                if cfg.obs_live_addr.startswith("tcp://") else None)
        sinks.append(TransportSink(cfg.obs_live_addr, loop=loop,
                                   source=cfg.obs_source, flush_every=8,
                                   reconnect=True, max_buffer=4096))
    return SimObserver(sink=sinks[0] if len(sinks) == 1 else TeeSink(*sinks),
                       frame_every=cfg.obs_frame_every)


def _new_sim(scheduler, cfg: ExperimentConfig, trace) -> Simulator:
    invariants = None
    if cfg.check_invariants:
        from repro.cluster.invariants import InvariantChecker
        invariants = InvariantChecker()
    sim = Simulator(scheduler, fleet=_fleet_for(cfg), seed=cfg.seed,
                    heartbeat_interval=cfg.heartbeat_interval,
                    chaos=ChaosInjector(cfg.chaos), trace=trace,
                    hazard_noise=cfg.hazard_noise, obs=_make_obs(cfg),
                    invariants=invariants)
    install(sim, make_workload(cfg.workload))
    return sim


def run_baseline(name: str, cfg: ExperimentConfig, *, with_trace=True):
    trace = TelemetryTrace() if with_trace else None
    sim = _new_sim(BASELINES[name](), cfg, trace)
    metrics = sim.run()
    if sim.obs is not None:
        metrics["obs"] = sim.obs.summary()
    return metrics, trace, sim


def run_atlas(name: str, cfg: ExperimentConfig,
              predictor: TaskPredictor | None = None):
    trace = TelemetryTrace()
    refresher = None
    if cfg.drift:
        from repro.online.drift import OnlineRefresher
        refresher = OnlineRefresher(retrain_every=cfg.retrain_every,
                                    check_every=cfg.drift_check_every)
    sched = ATLASScheduler(
        BASELINES[name](),
        predictor=predictor or TaskPredictor(algo=cfg.algo,
                                             min_samples=cfg.min_samples,
                                             max_train=cfg.max_train),
        threshold=cfg.threshold, n_speculative=cfg.n_speculative,
        retrain_every=cfg.retrain_every, refresher=refresher)
    sim = _new_sim(sched, cfg, trace)
    if refresher is not None and sim.obs is not None:
        refresher.obs = sim.obs        # drift/lifecycle markers into frames
    metrics = sim.run()
    metrics["atlas"] = sched.stats().to_dict()
    if sim.obs is not None:
        metrics["obs"] = sim.obs.summary()
    return metrics, trace, sim


def atlas_base_name(name: str) -> str | None:
    """'atlas-fifo' -> 'fifo'; None for a plain baseline name."""
    if name.startswith("atlas-"):
        base = name[len("atlas-"):]
        if base not in BASELINES:
            raise KeyError(f"unknown ATLAS base scheduler {base!r}")
        return base
    if name not in BASELINES:
        raise KeyError(f"unknown scheduler {name!r}")
    return None


def run_scheduler(name: str, cfg: ExperimentConfig,
                  predictor: TaskPredictor | None = None, *, with_trace=True):
    """One simulator run as a *pure function* of (scheduler name, config,
    optional pre-trained predictor) — the unit the fleet sweep fans out.

    For 'atlas-<base>' names a predictor trained on a prior base-scheduler run
    should be passed in; the fleet reuses one training trace per (scenario,
    workload, seed) across every ATLAS variant instead of re-training per cell.
    Returns (metrics, trace, sim); metrics['sched_stats'] carries the
    scheduler's per-run counters uniformly for every policy.
    """
    base = atlas_base_name(name)
    if base is None:
        metrics, trace, sim = run_baseline(name, cfg, with_trace=with_trace)
    else:
        metrics, trace, sim = run_atlas(base, cfg, predictor)
    metrics["sched_stats"] = sim.scheduler.stats().to_dict()
    return metrics, trace, sim


def _finished_times(sim) -> dict:
    """jid -> exec time for finished jobs, read from the telemetry job ledger
    when one was recorded (the ledger rows close at exactly job.done_time, so
    this equals the sim.jobs rescan bit-for-bit) and recomputed otherwise."""
    trace = getattr(sim, "trace", None)
    rows = getattr(trace, "jobs", None)
    if rows:
        return {r["job"]: r["end"] - r["submit"] for r in rows.values()
                if r["outcome"] == "finished"}
    return {j.jid: j.done_time - j.submit_time for j in sim.jobs.values()
            if j.status == "finished"}


def _matched_job_times(sim_a, sim_b):
    """Mean exec time over jobs finished under BOTH runs (same jids) — removes the
    survivor bias of comparing different finished-job populations."""
    fa = _finished_times(sim_a)
    fb = _finished_times(sim_b)
    common = sorted(set(fa) & set(fb))
    if not common:
        return 0.0, 0.0
    return (sum(fa[j] for j in common) / len(common),
            sum(fb[j] for j in common) / len(common))


def _matched_long_job_times(sim_a, sim_b, quantile: float = 0.75):
    """Same, restricted to LONG jobs (top quartile of baseline exec time) — the
    paper reports its biggest win (up to 54%) on 40-50-minute jobs."""
    fa = _finished_times(sim_a)
    fb = _finished_times(sim_b)
    common = sorted(set(fa) & set(fb))
    if len(common) < 4:
        return 0.0, 0.0
    cutoff = sorted(fa[j] for j in common)[int(len(common) * quantile)]
    longs = [j for j in common if fa[j] >= cutoff]
    if not longs:
        return 0.0, 0.0
    return (sum(fa[j] for j in longs) / len(longs),
            sum(fb[j] for j in longs) / len(longs))


def compare(name: str, cfg: ExperimentConfig) -> dict:
    """Full §5 protocol for one base scheduler.  Returns {base, atlas, deltas}."""
    base_cfg, atlas_cfg = cfg, cfg
    if cfg.obs_path:                 # two runs: split the frame streams
        import pathlib
        p = pathlib.Path(cfg.obs_path)
        suffix = p.suffix or ".ndjson"
        base_cfg = dataclasses.replace(
            cfg, obs_path=str(p.with_name(f"{p.stem}__base{suffix}")))
        atlas_cfg = dataclasses.replace(
            cfg, obs_path=str(p.with_name(f"{p.stem}__atlas{suffix}")))
    if cfg.obs_live_addr:            # distinct wire sources per run too
        src = cfg.obs_source or name
        base_cfg = dataclasses.replace(base_cfg, obs_source=f"{src}__base")
        atlas_cfg = dataclasses.replace(atlas_cfg,
                                        obs_source=f"{src}__atlas")
    base_metrics, train_trace, base_sim = run_baseline(name, base_cfg)
    predictor = TaskPredictor(algo=cfg.algo, seed=cfg.seed,
                              min_samples=cfg.min_samples,
                              max_train=cfg.max_train)
    predictor.fit(train_trace)
    atlas_metrics, _, atlas_sim = run_atlas(name, atlas_cfg, predictor)
    mt_base, mt_atlas = _matched_job_times(base_sim, atlas_sim)
    base_metrics["job_exec_time_matched"] = mt_base
    atlas_metrics["job_exec_time_matched"] = mt_atlas
    lt_base, lt_atlas = _matched_long_job_times(base_sim, atlas_sim)
    base_metrics["long_job_exec_time"] = lt_base
    atlas_metrics["long_job_exec_time"] = lt_atlas

    def pct_drop(a, b):  # reduction from base a to atlas b
        return 100.0 * (a - b) / a if a else 0.0

    # the paper reports *percentages* of failed jobs/tasks (the workloads differ
    # slightly between runs because finished chains release more successor jobs)
    deltas = {
        "failed_tasks_drop_pct": pct_drop(base_metrics["pct_tasks_failed"],
                                          atlas_metrics["pct_tasks_failed"]),
        "failed_jobs_drop_pct": pct_drop(base_metrics["pct_jobs_failed"],
                                         atlas_metrics["pct_jobs_failed"]),
        "finished_tasks_gain_pct": -pct_drop(
            100.0 * base_metrics["tasks_finished"]
            / max(base_metrics["tasks_total"], 1),
            100.0 * atlas_metrics["tasks_finished"]
            / max(atlas_metrics["tasks_total"], 1)),
        "finished_jobs_gain_pct": -pct_drop(
            100.0 * base_metrics["jobs_finished"]
            / max(base_metrics["jobs_total"], 1),
            100.0 * atlas_metrics["jobs_finished"]
            / max(atlas_metrics["jobs_total"], 1)),
        "job_time_drop_pct": pct_drop(base_metrics["job_exec_time"],
                                      atlas_metrics["job_exec_time"]),
        "job_time_matched_drop_pct": pct_drop(mt_base, mt_atlas),
        "long_job_time_drop_pct": pct_drop(lt_base, lt_atlas),
        "direct_failed_tasks_drop_pct": pct_drop(
            base_metrics["tasks_failed_direct"],
            atlas_metrics["tasks_failed_direct"]),
    }
    return {"base": base_metrics, "atlas": atlas_metrics, "deltas": deltas}
