"""Chaos injection — the AnarchyApe equivalent (Faghri et al., FSaaS).

Injects the same failure classes the paper used on its EMR cluster:
  - kill / suspend TaskTrackers and DataNodes (+ recovery)
  - network slow-down / drop on a node
  - random thread kills inside a TT (transient latent-health degradation)
  - data loss (an HDFS block replica disappears with its DataNode)

Rates are calibrated by a single ``intensity`` knob; intensity=1.0 targets the
paper's Google-trace-derived ceiling (~40% task/job failure rates on the FIFO
baseline — §5.1)."""

from __future__ import annotations

import dataclasses
import random
from collections import defaultdict

from repro.cluster import simulator as S


# the paper's 13-slave EMR fleet: ``intensity`` is calibrated against it, so
# per-node hazard scaling is defined relative to this size
REFERENCE_FLEET = 13


@dataclasses.dataclass
class ChaosConfig:
    # intensity 5.0 calibrates the FIFO baseline near the paper's Google-trace
    # ceiling (~30-40% failed jobs); see EXPERIMENTS.md §Calibration
    intensity: float = 5.0
    # hazard scaling across fleet sizes.  "cluster" (default, the historical
    # behaviour) keeps the event rate cluster-wide, so a 1000-node fleet sees
    # the same events/second as the paper's 13 slaves — proportionally ~77x
    # less chaos per node, which silently softens every large --fleet-size
    # cell.  "per-node" scales the event rate by n_nodes/REFERENCE_FLEET
    # (burst footprints stay absolute, so per-node burst hazard scales
    # identically): failure *rates* stay comparable across fleet sizes.
    hazard: str = "cluster"
    mean_interarrival: float = 240.0   # seconds between chaos events at intensity 1
    kill_tt: float = 0.22
    suspend_tt: float = 0.12
    kill_dn: float = 0.16
    net_slow: float = 0.22
    net_drop: float = 0.08
    thread_kill: float = 0.20
    mean_outage: float = 900.0         # node downtime before recovery
    # correlated "power event" bursts (paper §1: power problems bring down large
    # groups of machines at once) — these are what the adaptive heartbeat's
    # 1/3-of-TTs rule reacts to
    burst_prob: float = 0.04
    burst_size: tuple = (4, 7)
    seed: int = 1234


class ChaosInjector:
    def __init__(self, cfg: ChaosConfig | None = None):
        self.cfg = cfg or ChaosConfig()
        if self.cfg.hazard not in ("cluster", "per-node"):
            raise ValueError(f"unknown hazard mode {self.cfg.hazard!r} "
                             "(cluster|per-node)")
        self.rng = random.Random(self.cfg.seed)
        self.sim: S.Simulator | None = None
        self.events_fired = 0
        # outage => recovery bookkeeping (read by the invariant checker):
        # nid -> scheduled-but-unfired recovery closures.  Every injected
        # outage schedules exactly one recovery, so a node stuck in an outage
        # state with a zero count here is a lost-recovery bug.
        self.pending_recoveries: dict[int, int] = defaultdict(int)

    def bind(self, sim: "S.Simulator"):
        self.sim = sim

    def schedule_initial(self):
        self._schedule_next()

    def hazard_scale(self) -> float:
        """Event-rate multiplier: 1 for cluster-wide hazard, fleet-size
        proportional (n/13) in per-node mode."""
        if self.cfg.hazard == "per-node" and self.sim is not None:
            return max(len(self.sim.nodes), 1) / REFERENCE_FLEET
        return 1.0

    def _schedule_next(self):
        rate = self.cfg.intensity * self.hazard_scale()
        lam = self.cfg.mean_interarrival / max(rate, 1e-6)
        dt = self.rng.expovariate(1.0 / lam)
        self.sim._push(self.sim.now + dt, S.EV_CHAOS, None)

    def fire(self, payload):
        if callable(payload):       # a scheduled recovery closure
            payload(None)
            return
        sim = self.sim
        self.events_fired += 1
        c = self.cfg
        if self.rng.random() < c.burst_prob:
            # power event: several TaskTrackers go down at once
            k = self.rng.randint(*c.burst_size)
            victims = self.rng.sample(sim.nodes, min(k, len(sim.nodes)))
            for v in victims:
                self._kill_tt(v, self.rng.expovariate(1.0 / c.mean_outage))
            self._schedule_next()
            return
        node = self.rng.choice(sim.nodes)
        r = self.rng.random()
        outage = self.rng.expovariate(1.0 / c.mean_outage)
        if r < c.kill_tt:
            self._kill_tt(node, outage)
        elif r < c.kill_tt + c.suspend_tt:
            self._suspend(node, outage * 0.5)
        elif r < c.kill_tt + c.suspend_tt + c.kill_dn:
            self._kill_dn(node, outage)
        elif r < c.kill_tt + c.suspend_tt + c.kill_dn + c.net_slow:
            self._net(node, 0.3, outage * 0.7)
        elif r < c.kill_tt + c.suspend_tt + c.kill_dn + c.net_slow + c.net_drop:
            self._net(node, 0.0, outage * 0.4)
        else:
            # thread kill: latent health degradation; recovers after the outage
            amount = 0.35 + 0.3 * self.rng.random()
            node.health = max(0.0, node.health - amount)
            self._recover_later(node, outage, health=amount)
        self._schedule_next()

    # --- helpers: all recoveries are scheduled closures via EV_CHAOS payloads
    def _recover_later(self, node, dt, *, tt=False, dn=False, net=False,
                       susp=False, health: float = 0.0):
        self.pending_recoveries[node.nid] += 1

        def recover(_):
            self.pending_recoveries[node.nid] -= 1
            if tt and not node.tt_alive:
                node.tt_alive = True
                node.restarts += 1
                node.health = min(1.0, node.health + 0.5)
            if dn:
                node.dn_alive = True
            if net:
                node.net_quality = 1.0
            if susp:
                node.suspended = False
            if health:
                # restore the full degradation (no permanent ratchet)
                node.health = min(1.0, node.health + health)
        self.sim._push(self.sim.now + dt, S.EV_CHAOS, recover)

    def fire_payload(self, fn):
        fn(None)

    def _kill_tt(self, node, outage):
        if not node.tt_alive:
            return
        node.tt_alive = False
        node.health = max(0.0, node.health - 0.2)
        # NOTE: the JobTracker does NOT learn this until the next heartbeat
        self._recover_later(node, outage, tt=True)

    def _suspend(self, node, outage):
        node.suspended = True
        self._recover_later(node, outage, susp=True)

    def _kill_dn(self, node, outage):
        node.dn_alive = False
        self._recover_later(node, outage, dn=True)

    def _net(self, node, quality, outage):
        node.net_quality = quality
        self._recover_later(node, outage, net=True)
