"""Fleet sweep engine: declarative (schedulers x seeds x scenarios x workloads)
run matrices, executed in parallel with per-run isolation and reduced into the
paper's Figures 4-12 aggregates.

The paper's §5 evaluation is a cross-scheduler, cross-failure-regime comparison;
``repro.cluster.experiment`` runs exactly one (scheduler, seed, chaos) triple.
This module is the scale layer on top of it:

  SweepSpec ──expand──> [CellSpec...] ──fan-out──> per-cell metrics ──reduce──>
      aggregates (mean / 95% CI of failed-job %, failed-task %, exec times)
      + SWEEP.json (machine-readable) + SWEEP.md (ranking tables)

Design points:

* **Pure cells.**  Every cell is a pure function of its ``CellSpec`` via
  ``experiment.run_scheduler``; cell seeds derive from a stable CRC32 of the
  (scenario, workload, seed-index) coordinates, so the same spec always expands
  to the same runs and the same ``SWEEP.json`` bytes — regardless of executor
  kind, worker count, or completion order.
* **Scheduler-matched conditions.**  Workload/chaos/hazard seeds deliberately
  exclude the scheduler name: every scheduler in a sweep faces the identical
  failure storm, as in the paper's protocol.
* **Train-trace reuse.**  ATLAS cells need a predictor trained on a base-
  scheduler trace.  The fleet runs one training wave per (base, scenario,
  workload, seed) — reusing requested base cells as training runs when the base
  matches — and ships the trace *datasets* (plain arrays) to the ATLAS wave,
  instead of re-running the training simulation once per ATLAS cell.
* **Process isolation.**  Cells run in a spawn-context process pool (fresh JAX
  runtime per worker, no fork-after-init hazards); ``thread`` and ``serial``
  executors exist for tests and debugging.

* **Online serving (PR 4).**  ``--executor broker`` runs every ATLAS cell as a
  client of one ``repro.online`` PredictionBroker: all p_success traffic is
  flushed in deterministic lock-step rounds as single fused forest passes —
  identical SWEEP cells, an order of magnitude fewer predictor dispatches
  (reported under ``perf.broker``).  ``--registry DIR`` publishes each training
  wave's models to a versioned ``ModelRegistry`` and ships *version ids* to the
  ATLAS wave instead of raw trace arrays.

* **Live telemetry (PR 6).**  ``--obs`` streams per-cell NDJSON frame files
  (repro.obs) under ``<out>/obs/`` and stamps each cell's deterministic
  telemetry roll-up into ``SWEEP.json`` under ``perf.obs`` — simulation
  results stay byte-identical with telemetry on or off (observers only read
  sim state; the roll-ups carry no wall-clock).

* **Async serving (PR 7).**  ``--executor async`` serves the ATLAS wave
  through one ``repro.online.server.AsyncBroker`` over the transport layer
  (policy="barrier"), reproducing the broker executor's SWEEP.json byte for
  byte — the stepping stone to out-of-process serving.  ``--hazard per-node``
  scales chaos event rates with fleet size (``repro.cluster.chaos``) so
  failure rates stay comparable across ``--fleet-size``.

CLI:

  python -m repro.cluster.fleet \
      --schedulers fifo,atlas-fifo --seeds 4 \
      --scenarios baseline,bursty_tt,dn_loss [--workloads default] \
      [--executor process|thread|serial|broker|async] [--workers N] \
      [--hazard cluster|per-node] \
      [--registry DIR] [--obs] [--out experiments]
"""

from __future__ import annotations

import argparse
import concurrent.futures
import dataclasses
import json
import math
import multiprocessing
import os
import pathlib
import sys
import time
import zlib

from repro.cluster.experiment import (ExperimentConfig, atlas_base_name,
                                      run_scheduler)
from repro.cluster.scenarios import SCENARIOS, WORKLOAD_SHAPES, make_spec
from repro.core.predictor import TaskPredictor

# metrics reported in the ranking tables (subset of Simulator.metrics keys)
TABLE_METRICS = ("pct_tasks_failed", "pct_jobs_failed", "job_exec_time",
                 "sim_time")


# ---------------------------------------------------------------------------
# Spec + matrix expansion
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CellSpec:
    """One run of the matrix: a scheduler at a (scenario, workload,
    fleet-size, seed).  ``fleet_size`` 0 is the paper's 13-slave fleet and is
    omitted from ids/keys so default sweeps keep their PR-3/4 coordinates."""
    scheduler: str
    scenario: str
    workload: str
    seed_index: int
    fleet_size: int = 0

    @property
    def env_key(self) -> tuple:
        """Scheduler-independent coordinates: every scheduler sees the same
        workload + failure storm at a given env_key (paper §5 protocol)."""
        if self.fleet_size:
            return (self.scenario, self.workload, f"n{self.fleet_size}",
                    self.seed_index)
        return (self.scenario, self.workload, self.seed_index)

    @property
    def env_label(self) -> str:
        env = f"{self.scenario}/{self.workload}"
        if self.fleet_size:
            env += f"/n{self.fleet_size}"
        return env

    @property
    def cell_id(self) -> str:
        return f"{self.env_label}/{self.scheduler}/s{self.seed_index}"


@dataclasses.dataclass
class SweepSpec:
    """Declarative sweep: the cross product of four axes plus shared knobs."""
    schedulers: tuple = ("fifo", "atlas-fifo")
    seeds: int | tuple = 3            # count (0..n-1) or explicit indices
    scenarios: tuple = ("baseline",)
    workloads: tuple = ("default",)
    fleet_sizes: tuple = (0,)         # 0 = paper fleet; N = make_fleet(N)
    hazard: str = "cluster"           # chaos scaling: cluster | per-node
    algo: str = "R.F."
    threshold: float = 0.5
    n_speculative: int = 2
    heartbeat_interval: float = 600.0
    min_samples: int = 150
    max_train: int = 20000
    check_invariants: bool = False    # per-tick invariant checker in every cell

    def seed_indices(self) -> tuple:
        if isinstance(self.seeds, int):
            return tuple(range(self.seeds))
        return tuple(self.seeds)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["seeds"] = list(self.seed_indices())
        for k in ("schedulers", "scenarios", "workloads", "fleet_sizes"):
            d[k] = list(d[k])
        if not d["check_invariants"]:
            # keep historical SWEEP.json spec bytes when the checker is off
            d.pop("check_invariants")
        return d


def cell_seed(*parts) -> int:
    """Stable, platform-independent seed from cell coordinates (CRC32, not
    Python's salted hash) — same spec => same seeds => same SWEEP.json."""
    return zlib.crc32("|".join(str(p) for p in parts).encode()) & 0x7FFFFFFF


def expand(spec: SweepSpec) -> list[CellSpec]:
    """Expand the spec into its deduplicated, deterministically ordered matrix."""
    for s in spec.scenarios:
        if s not in SCENARIOS:
            raise KeyError(f"unknown scenario {s!r}; known: "
                           f"{', '.join(sorted(SCENARIOS))}")
    for w in spec.workloads:
        if w not in WORKLOAD_SHAPES:
            raise KeyError(f"unknown workload shape {w!r}; known: "
                           f"{', '.join(sorted(WORKLOAD_SHAPES))}")
    for name in spec.schedulers:
        atlas_base_name(name)  # raises on unknown scheduler
    from repro.ml.models import ALL_MODELS
    if spec.algo not in ALL_MODELS:
        raise KeyError(f"unknown predictor algo {spec.algo!r}; known: "
                       f"{', '.join(sorted(ALL_MODELS))}")
    for fs in spec.fleet_sizes:
        if fs < 0:
            raise KeyError(f"negative fleet size {fs}")
    if spec.hazard not in ("cluster", "per-node"):
        raise KeyError(f"unknown hazard mode {spec.hazard!r} "
                       "(cluster|per-node)")
    cells = {
        CellSpec(scheduler=sched, scenario=sc, workload=wl, seed_index=si,
                 fleet_size=fs)
        for sc in spec.scenarios for wl in spec.workloads
        for fs in spec.fleet_sizes
        for sched in spec.schedulers for si in spec.seed_indices()
    }
    return sorted(cells, key=lambda c: (c.scenario, c.workload, c.fleet_size,
                                        c.scheduler, c.seed_index))


def cell_config(spec: SweepSpec, cell: CellSpec) -> ExperimentConfig:
    env = cell.env_key
    # scenario/workload *names* resolve here, in the parent process, so
    # temporarily registered search points (scenario_scope) work with the
    # spawn process pool — workers receive fully resolved configs
    point = make_spec(cell.scenario, cell.workload)
    # hazard mode rides on the chaos config; "cluster" (the default) leaves
    # the scenario's historical bytes untouched, "per-node" scales event
    # rates with fleet size so failure rates compare across --fleet-size
    chaos = point.chaos_for_seed(cell_seed("chaos", *env))
    if spec.hazard != "cluster":
        chaos = dataclasses.replace(chaos, hazard=spec.hazard)
    return ExperimentConfig(
        workload=point.workload_for_seed(cell_seed("workload", *env)),
        chaos=chaos,
        seed=cell_seed("sim", *env),
        heartbeat_interval=spec.heartbeat_interval,
        algo=spec.algo, threshold=spec.threshold,
        n_speculative=spec.n_speculative, min_samples=spec.min_samples,
        max_train=spec.max_train, fleet_size=cell.fleet_size,
        check_invariants=spec.check_invariants)


# ---------------------------------------------------------------------------
# Cell execution (top-level functions: picklable into spawn workers)
# ---------------------------------------------------------------------------

def _numeric_metrics(metrics: dict) -> dict:
    return {k: float(v) for k, v in metrics.items()
            if isinstance(v, (int, float))}


def _train_model_name(cell: CellSpec) -> str:
    """Registry entry for a training run: one model per (base, env)."""
    return (f"{cell.scheduler}/{cell.scenario}/{cell.workload}"
            f"/s{cell.seed_index}")


def _run_base_cell(args):
    """Wave 1: a base-scheduler cell.  When some ATLAS cell needs this
    (base, env) as a training run, the trained state ships either as raw trace
    datasets or — with a registry — as a published model *version*."""
    cell, cfg, want_trace, registry_dir = args
    metrics, trace, _ = run_scheduler(cell.scheduler, cfg,
                                      with_trace=want_trace)
    payload = None
    if want_trace:
        datasets = trace.datasets()
        if registry_dir is not None:
            from repro.online.registry import ModelRegistry
            predictor = TaskPredictor(algo=cfg.algo, seed=cfg.seed,
                                      min_samples=cfg.min_samples,
                                      max_train=cfg.max_train)
            predictor.fit_datasets(*datasets)
            name = _train_model_name(cell)
            version = ModelRegistry(registry_dir).publish(
                name, predictor.snapshot(),
                meta={"cell": cell.cell_id, "role": "train"})
            payload = ("registry", name, version)
        else:
            payload = ("datasets", datasets)
    return (cell, _numeric_metrics(metrics), metrics["sched_stats"], payload,
            metrics.get("obs"))


def _load_predictor(predictor: TaskPredictor, payload, registry_dir):
    """Initialise a wave-2 predictor from its shipped training payload."""
    if payload is None:
        return predictor
    kind = payload[0]
    if kind == "datasets":
        predictor.fit_datasets(*payload[1])
    elif kind == "registry":
        from repro.online.registry import ModelRegistry
        _, name, version = payload
        predictor.load_snapshot(
            ModelRegistry(registry_dir).load(name, version))
    else:
        raise ValueError(f"unknown training payload {kind!r}")
    return predictor


def _run_atlas_cell(args):
    """Wave 2: an ATLAS cell; the predictor comes pre-trained from the shipped
    payload (one simulated training run shared across the matrix)."""
    cell, cfg, payload, registry_dir = args
    predictor = _load_predictor(
        TaskPredictor(algo=cfg.algo, seed=cfg.seed,
                      min_samples=cfg.min_samples, max_train=cfg.max_train),
        payload, registry_dir)
    metrics, _, _ = run_scheduler(cell.scheduler, cfg, predictor)
    return (cell, _numeric_metrics(metrics), metrics["sched_stats"],
            metrics.get("obs"))


def _run_atlas_wave_brokered(wave2, registry_dir, workers=None,
                             obs_dir=None):
    """Run every ATLAS cell concurrently as a client of one shared
    PredictionBroker.  Clients are registered before any thread starts so the
    lock-step rounds (and hence dispatch counts) are a pure function of the
    decision streams, not of thread scheduling.  Returns (records, perf)."""
    import concurrent.futures as cf

    from repro.online.broker import BrokerPredictor, PredictionBroker

    broker = PredictionBroker(impl="numpy")
    broker_obs = None
    if obs_dir is not None:
        from repro.obs import BrokerObserver, NDJSONSink
        broker_obs = BrokerObserver(
            sink=NDJSONSink(pathlib.Path(obs_dir) / "broker.ndjson"))
        broker.obs = broker_obs
    broker.add_clients(len(wave2))
    predictors = []

    def run_one(args):
        cell, cfg, payload = args
        try:  # broker.done() exactly once, or the barrier waits forever
            predictor = _load_predictor(
                BrokerPredictor(broker=broker, algo=cfg.algo, seed=cfg.seed,
                                min_samples=cfg.min_samples,
                                max_train=cfg.max_train),
                payload, registry_dir)
            predictors.append(predictor)
            metrics, _, _ = run_scheduler(cell.scheduler, cfg, predictor)
        finally:
            broker.done()
        return (cell, _numeric_metrics(metrics), metrics["sched_stats"],
                metrics.get("obs"))

    # every cell MUST get a thread: all clients are registered up front, and a
    # round only flushes once every registered client has queued — capping
    # max_workers below len(wave2) would leave unstarted cells registered but
    # silent, deadlocking the running ones inside broker.submit
    with cf.ThreadPoolExecutor(max_workers=max(len(wave2), 1)) as pool:
        out = list(pool.map(run_one, wave2))
    demand_calls = sum(p.n_demand_calls for p in predictors)
    demand_rows = sum(p.n_demand_rows for p in predictors)
    perf = {"broker": {
        **broker.stats(),
        "demand_calls": demand_calls,
        "demand_rows": demand_rows,
        "dispatch_reduction": round(
            demand_calls / max(broker.n_dispatches, 1), 2),
    }}
    if broker_obs is not None:
        broker_obs.close()
        perf["broker_obs"] = broker_obs.summary(deterministic_only=True)
    return out, perf


def _run_atlas_wave_async(wave2, registry_dir, workers=None, obs_dir=None,
                          fault_plan=None, fault_stats=None):
    """Run every ATLAS cell as a *transport client* of one serving
    ``AsyncBroker`` (policy="barrier"): the same lock-step rounds as
    ``--executor broker``, driven by an event loop over ``repro.online.
    transport`` comms instead of a condition variable.  Rounds are a pure
    function of each client's request sequence, so the SWEEP.json bytes —
    including ``perf.broker`` — match the threaded broker executor exactly.

    ``fault_plan`` (``repro.online.faults.FaultPlan``) injects the plan's
    seeded fault schedule into the serving path (reply drops/delays/
    duplicates, abrupt closes, listener restarts); clients then run with the
    plan's retry budget and the broker's request replay keeps retried
    flushes idempotent — the SWEEP bytes still match a fault-free run.
    ``fault_stats`` (a caller-owned dict) receives the retry/replay/fallback
    counters; they are reported there and *only* there so the deterministic
    ``perf.broker`` block stays byte-identical under chaos.
    Returns (records, perf)."""
    import concurrent.futures as cf

    from repro.online.broker import BrokerPredictor
    from repro.online.server import AsyncBroker, BrokerClient

    server = AsyncBroker(impl="numpy", policy="barrier")
    broker_obs = None
    if obs_dir is not None:
        from repro.obs import BrokerObserver, NDJSONSink
        broker_obs = BrokerObserver(
            sink=NDJSONSink(pathlib.Path(obs_dir) / "broker.ndjson"))
        server.obs = broker_obs
    server.start()
    address = server.serve(fault_plan=fault_plan)
    server.add_clients(len(wave2))
    predictors = []
    clients = []
    client_kw = {}
    if fault_plan is not None:
        client_kw = dict(request_timeout_s=fault_plan.request_timeout_s,
                         deadline_s=fault_plan.deadline_s,
                         retry_seed=fault_plan.seed,
                         # backoff scaled to the timeout: retry pacing should
                         # track how fast this client detects a lost reply,
                         # not a wall-clock constant sized for remote links
                         backoff_base_s=fault_plan.request_timeout_s / 4,
                         backoff_cap_s=fault_plan.request_timeout_s * 4)

    def run_one(args):
        cell, cfg, payload = args
        client = BrokerClient(address, server.loop, **client_kw)
        clients.append(client)
        try:  # client.done() exactly once, or the round waits forever
            predictor = _load_predictor(
                BrokerPredictor(broker=client, algo=cfg.algo, seed=cfg.seed,
                                min_samples=cfg.min_samples,
                                max_train=cfg.max_train),
                payload, registry_dir)
            predictors.append(predictor)
            metrics, _, _ = run_scheduler(cell.scheduler, cfg, predictor)
        finally:
            client.done()
            client.close()
        return (cell, _numeric_metrics(metrics), metrics["sched_stats"],
                metrics.get("obs"))

    try:
        # same rule as the threaded broker wave: every registered client
        # needs a live thread or the barrier round can never complete
        with cf.ThreadPoolExecutor(max_workers=max(len(wave2), 1)) as pool:
            out = list(pool.map(run_one, wave2))
        demand_calls = sum(p.n_demand_calls for p in predictors)
        demand_rows = sum(p.n_demand_rows for p in predictors)
        perf = {"broker": {
            **server.stats(),
            "demand_calls": demand_calls,
            "demand_rows": demand_rows,
            "dispatch_reduction": round(
                demand_calls / max(server.n_dispatches, 1), 2),
        }}
        if fault_stats is not None:
            fault_stats.update(server.fault_stats())
            fault_stats["client_retries"] = sum(
                c.n_retries for c in clients)
            fault_stats["client_reconnects"] = sum(
                c.n_reconnects for c in clients)
            fault_stats["fallbacks"] = sum(
                p.n_fallbacks for p in predictors)
            fault_stats["fallback_rows"] = sum(
                p.n_fallback_rows for p in predictors)
    finally:
        server.stop()
    if broker_obs is not None:
        broker_obs.close()
        perf["broker_obs"] = broker_obs.summary(deterministic_only=True)
    return out, perf


class _SerialExecutor:
    def map(self, fn, it):
        return list(map(fn, it))

    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def _make_executor(kind: str, workers: int | None):
    if kind in ("serial", "broker", "async"):
        # "broker"/"async" batch only the ATLAS wave (threads sharing one
        # broker); wave 1 runs serially in-process so payloads stay local
        return _SerialExecutor()
    if kind == "thread":
        return concurrent.futures.ThreadPoolExecutor(max_workers=workers)
    if kind == "process":
        # spawn, not fork: workers get a fresh JAX runtime (fork after backend
        # init deadlocks) and behave identically across platforms
        ctx = multiprocessing.get_context("spawn")
        return concurrent.futures.ProcessPoolExecutor(
            max_workers=workers or os.cpu_count(), mp_context=ctx)
    raise ValueError(
        f"unknown executor {kind!r} (process|thread|serial|broker|async)")


# ---------------------------------------------------------------------------
# Resumable sweeps: atomic per-cell ledger
# ---------------------------------------------------------------------------

class _CellLedger:
    """Atomic per-cell result ledger — the resumable-sweep substrate.

    Every finished cell lands as one JSON file written tmp-then-
    ``os.replace``, so a SIGKILL anywhere leaves either a complete record or
    none.  Training payloads ride along (registry versions inline, raw trace
    datasets as an ``.npz`` sidecar written *before* its record, so a record
    always implies a readable payload).  ``MANIFEST.json`` carries a
    fingerprint over (spec, executor, registry, obs): a restart with the
    same coordinates skips finished cells and reassembles byte-identical
    ``SWEEP.json``; any mismatch wipes the ledger rather than mixing cells
    from different sweeps.

    The broker/async ATLAS wave is reused all-or-nothing: its
    ``perf.broker`` counters are a function of the *entire* barrier-round
    schedule, so partial reuse would stitch together a schedule no real run
    produces.  That wave only resumes when every cell record plus the wave
    perf record (``w2__PERF.json``) survived; otherwise the whole wave
    reruns — which regenerates the exact same bytes anyway."""

    def __init__(self, dir, spec: SweepSpec, executor: str,
                 registry: str | None, obs: bool):
        self.dir = pathlib.Path(dir)
        self.fingerprint = cell_seed(
            "ledger", json.dumps(spec.to_json(), sort_keys=True), executor,
            registry or "", int(obs))
        self.dir.mkdir(parents=True, exist_ok=True)
        manifest = self.dir / "MANIFEST.json"
        keep = False
        try:
            keep = (json.loads(manifest.read_text())
                    .get("fingerprint") == self.fingerprint)
        except (OSError, ValueError):
            keep = False
        if not keep:
            for pat in ("*.json", "*.npz", "*.tmp"):
                for p in self.dir.glob(pat):
                    p.unlink()
            self._write(manifest, {"fingerprint": self.fingerprint})

    def _write(self, path: pathlib.Path, obj: dict):
        tmp = path.with_name(path.name + ".tmp")
        tmp.write_text(json.dumps(obj, sort_keys=True))
        os.replace(tmp, path)

    def _path(self, wave: int, cell: CellSpec) -> pathlib.Path:
        return self.dir / (f"w{wave}__"
                           + cell.cell_id.replace("/", "__") + ".json")

    def load(self, wave: int, cell: CellSpec) -> dict | None:
        try:
            return json.loads(self._path(wave, cell).read_text())
        except (OSError, ValueError):
            return None

    def store_wave1(self, cell, metrics, stats, payload, obs):
        rec = {"metrics": metrics, "stats": stats, "obs": obs,
               "payload": None}
        if payload is not None:
            if payload[0] == "registry":
                rec["payload"] = list(payload)
            else:
                import numpy as np
                (mx, my), (rx, ry) = payload[1]
                npz = self._path(1, cell).with_suffix(".npz")
                tmp = npz.with_name(npz.name + ".tmp")
                with open(tmp, "wb") as f:
                    np.savez(f, map_X=mx, map_y=my, red_X=rx, red_y=ry)
                os.replace(tmp, npz)
                rec["payload"] = ["datasets", npz.name]
        self._write(self._path(1, cell), rec)

    def payload_from(self, rec: dict):
        pl = rec.get("payload")
        if pl is None:
            return None
        if pl[0] == "registry":
            return (pl[0], pl[1], pl[2])
        import numpy as np
        with np.load(self.dir / pl[1]) as z:
            # .copy() detaches the arrays from the npz file handle
            return ("datasets", ((z["map_X"].copy(), z["map_y"].copy()),
                                 (z["red_X"].copy(), z["red_y"].copy())))

    def store_wave2(self, cell, metrics, stats, obs):
        self._write(self._path(2, cell),
                    {"metrics": metrics, "stats": stats, "obs": obs})

    def store_wave2_perf(self, perf: dict):
        self._write(self.dir / "w2__PERF.json", perf)

    def load_wave2_batch(self, cells):
        """All-or-nothing reuse of the broker/async wave: (records, perf)
        when every cell and the wave perf record are present, else None."""
        try:
            perf = json.loads((self.dir / "w2__PERF.json").read_text())
        except (OSError, ValueError):
            return None
        out = []
        for cell in cells:
            rec = self.load(2, cell)
            if rec is None:
                return None
            out.append((cell, rec["metrics"], rec["stats"], rec["obs"]))
        return out, perf


# ---------------------------------------------------------------------------
# Sweep driver
# ---------------------------------------------------------------------------

def _obs_path(obs_dir, cell: CellSpec) -> str:
    """Frame-stream path for one cell: cell_id with '/' flattened to '__'."""
    return str(pathlib.Path(obs_dir)
               / (cell.cell_id.replace("/", "__") + ".ndjson"))


def run_sweep(spec: SweepSpec, *, executor: str = "process",
              workers: int | None = None, registry: str | None = None,
              obs_dir: str | None = None, obs_live: str | None = None,
              resume_dir: str | None = None, fault_plan=None,
              fault_stats: dict | None = None, log=print) -> dict:
    """Execute the full matrix; returns the SWEEP result dict (see sweep_json).

    Two waves: (1) all base-scheduler cells plus any training-only runs ATLAS
    cells require, (2) all ATLAS cells with pre-trained predictors.  Cells
    within a wave run in parallel; results are keyed by cell id so completion
    order never affects the output.

    ``executor="broker"`` serves wave 2 through one shared PredictionBroker
    (identical cells, far fewer predictor dispatches — see ``perf.broker``).
    ``registry=DIR`` ships model *versions* through a ModelRegistry instead of
    raw trace arrays (forest-family algos).  ``obs_dir=DIR`` streams per-cell
    telemetry frames there and stamps per-cell roll-ups under ``perf.obs`` —
    cells/aggregates/rankings stay byte-identical either way.
    ``obs_live=ADDR`` additionally streams every cell's frames to a live
    TelemetryCollector over the serving transport (source = cell id); use a
    ``tcp://`` address with the process/spawn executors — ``inproc://``
    channels don't cross process boundaries.  The live path only observes:
    SWEEP output bytes are identical with it on or off.

    ``resume_dir=DIR`` keeps an atomic per-cell ledger there
    (:class:`_CellLedger`): a sweep killed mid-run and restarted with the
    same coordinates skips finished cells and reassembles the identical
    ``SWEEP.json`` bytes.  ``fault_plan`` (async executor only) injects a
    seeded fault schedule into the serving path; ``fault_stats`` (a caller-
    owned dict) receives the retry/replay/fallback counters, kept out of
    the returned result so SWEEP bytes match a fault-free run."""
    if fault_plan is not None and executor != "async":
        raise ValueError("fault_plan requires executor='async' "
                         "(the transport-served ATLAS wave)")
    t0 = time.perf_counter()
    cells = expand(spec)
    base_cells = [c for c in cells if atlas_base_name(c.scheduler) is None]
    atlas_cells = [c for c in cells if atlas_base_name(c.scheduler) is not None]

    def _cfg(cell: CellSpec) -> ExperimentConfig:
        cfg = cell_config(spec, cell)
        if obs_dir is not None:
            cfg = dataclasses.replace(cfg, obs_path=_obs_path(obs_dir, cell))
        if obs_live is not None:
            cfg = dataclasses.replace(cfg, obs_live_addr=obs_live,
                                      obs_source=cell.cell_id)
        return cfg

    # training runs needed: one per (base, env) over the ATLAS cells
    needed_cells: dict[tuple, CellSpec] = {}
    for c in atlas_cells:
        base = atlas_base_name(c.scheduler)
        needed_cells.setdefault(
            (base,) + c.env_key, dataclasses.replace(c, scheduler=base))
    needed_train = set(needed_cells)
    covered = {(c.scheduler,) + c.env_key for c in base_cells}
    # env_key tuples vary in length across fleet sizes: sort on stringified
    # coordinates so the wave order stays total and deterministic
    train_only = sorted(needed_train - covered,
                        key=lambda k: tuple(str(p) for p in k))
    train_cells = [needed_cells[k] for k in train_only]

    wave1 = [(c, _cfg(c), (c.scheduler,) + c.env_key
              in needed_train, registry) for c in base_cells]
    wave1 += [(c, _cfg(c), True, registry) for c in train_cells]

    log(f"[fleet] {len(cells)} cells "
        f"({len(base_cells)} base + {len(atlas_cells)} atlas), "
        f"{len(train_cells)} extra training runs, executor={executor}"
        + (f", registry={registry}" if registry else "")
        + (f", obs={obs_dir}" if obs_dir else "")
        + (f", obs_live={obs_live}" if obs_live else ""))

    ledger = None
    if resume_dir is not None:
        ledger = _CellLedger(resume_dir, spec, executor, registry,
                             obs_dir is not None)

    results: dict[str, dict] = {}
    train_data: dict[tuple, object] = {}
    perf: dict = {}
    obs_cells: dict[str, dict] = {}

    def _fold1(cell, metrics, stats, payload, obs):
        if payload is not None:
            train_data[(cell.scheduler,) + cell.env_key] = payload
        results[cell.cell_id] = _cell_record(cell, metrics, stats)
        if obs is not None:
            obs_cells[cell.cell_id] = obs

    def _fold2(cell, metrics, stats, obs):
        results[cell.cell_id] = _cell_record(cell, metrics, stats)
        if obs is not None:
            obs_cells[cell.cell_id] = obs

    wave1_todo, n1_resumed = [], 0
    for args in wave1:
        rec = ledger.load(1, args[0]) if ledger is not None else None
        if rec is None:
            wave1_todo.append(args)
        else:
            _fold1(args[0], rec["metrics"], rec["stats"],
                   ledger.payload_from(rec), rec["obs"])
            n1_resumed += 1

    n2_resumed = 0
    with _make_executor(executor, workers) as pool:
        for cell, metrics, stats, payload, obs in pool.map(_run_base_cell,
                                                           wave1_todo):
            if ledger is not None:
                ledger.store_wave1(cell, metrics, stats, payload, obs)
            _fold1(cell, metrics, stats, payload, obs)
        log(f"[fleet] wave 1 done: {len(wave1)} runs"
            + (f" ({n1_resumed} resumed)" if n1_resumed else "")
            + f", {len(train_data)} training payloads "
              f"({time.perf_counter() - t0:.1f}s)")

        wave2 = [(c, _cfg(c),
                  train_data.get((atlas_base_name(c.scheduler),) + c.env_key))
                 for c in atlas_cells]
        if executor in ("broker", "async"):
            cached = (ledger.load_wave2_batch([w[0] for w in wave2])
                      if ledger is not None else None)
            if cached is not None:
                wave2_out, perf = cached
                n2_resumed = len(wave2_out)
            elif executor == "broker":
                wave2_out, perf = _run_atlas_wave_brokered(
                    wave2, registry, workers, obs_dir)
            else:
                wave2_out, perf = _run_atlas_wave_async(
                    wave2, registry, workers, obs_dir,
                    fault_plan=fault_plan, fault_stats=fault_stats)
            if ledger is not None and not n2_resumed:
                for cell, metrics, stats, obs in wave2_out:
                    ledger.store_wave2(cell, metrics, stats, obs)
                ledger.store_wave2_perf(perf)
            for cell, metrics, stats, obs in wave2_out:
                _fold2(cell, metrics, stats, obs)
        else:
            wave2_todo = []
            for w in wave2:
                rec = ledger.load(2, w[0]) if ledger is not None else None
                if rec is None:
                    wave2_todo.append(w)
                else:
                    _fold2(w[0], rec["metrics"], rec["stats"], rec["obs"])
                    n2_resumed += 1
            for cell, metrics, stats, obs in pool.map(
                    _run_atlas_cell, [w + (registry,) for w in wave2_todo]):
                if ledger is not None:
                    ledger.store_wave2(cell, metrics, stats, obs)
                _fold2(cell, metrics, stats, obs)
    log(f"[fleet] wave 2 done: {len(atlas_cells)} atlas runs"
        + (f" ({n2_resumed} resumed)" if n2_resumed else "")
        + f" ({time.perf_counter() - t0:.1f}s total)")
    if perf.get("broker"):
        b = perf["broker"]
        log(f"[fleet] broker: {b['demand_calls']} demand calls -> "
            f"{b['dispatches']} dispatches "
            f"({b['dispatch_reduction']}x reduction, "
            f"{b['flushes']} flushes, max batch {b['max_flush_rows']} rows)")

    # keep only requested cells (training-only runs served their purpose)
    wanted = {c.cell_id for c in cells}
    records = [results[cid] for cid in sorted(wanted)]
    aggregates = aggregate(records)
    # telemetry roll-ups live ONLY under perf.obs: strip perf.obs (and an
    # emptied perf) from SWEEP.json and the bytes match an obs-off run
    if obs_dir is not None:
        obs_block = {"cells": {cid: obs_cells[cid]
                               for cid in sorted(obs_cells) if cid in wanted}}
        broker_obs = perf.pop("broker_obs", None)
        if broker_obs is not None:
            obs_block["broker"] = broker_obs
        perf["obs"] = obs_block
    import repro
    return {
        "spec": spec.to_json(),
        "provenance": {"pr": repro.PR_TAG},
        "cells": records,
        "aggregates": aggregates,
        "rankings": rank(aggregates),
        **({"perf": perf} if perf else {}),
    }


def _cell_record(cell: CellSpec, metrics: dict, stats: dict) -> dict:
    return {
        "cell_id": cell.cell_id,
        "scheduler": cell.scheduler,
        "scenario": cell.scenario,
        "workload": cell.workload,
        "seed_index": cell.seed_index,
        "fleet_size": cell.fleet_size,
        "metrics": metrics,
        "stats": dict(stats),
    }


# ---------------------------------------------------------------------------
# Reduction: aggregates + rankings + rendering
# ---------------------------------------------------------------------------

def mean_ci(values) -> dict:
    """Mean and normal-approximation 95% CI half-width (sample std, ddof=1)."""
    xs = [float(v) for v in values]
    n = len(xs)
    mean = sum(xs) / n if n else 0.0
    if n > 1:
        var = sum((x - mean) ** 2 for x in xs) / (n - 1)
        ci95 = 1.96 * math.sqrt(var) / math.sqrt(n)
    else:
        ci95 = 0.0
    return {"mean": mean, "ci95": ci95, "n": n}


def aggregate(records: list[dict]) -> dict:
    """Reduce per-cell metrics over seeds: {scenario/workload/scheduler:
    {metric: {mean, ci95, n}}}."""
    groups: dict[str, list[dict]] = {}
    for r in records:
        env = f"{r['scenario']}/{r['workload']}"
        if r.get("fleet_size"):
            env += f"/n{r['fleet_size']}"
        groups.setdefault(f"{env}/{r['scheduler']}", []).append(r)
    out = {}
    for key, rs in sorted(groups.items()):
        metric_names = sorted({m for r in rs for m in r["metrics"]})
        out[key] = {m: mean_ci([r["metrics"][m] for r in rs
                                if m in r["metrics"]])
                    for m in metric_names}
    return out


def rank(aggregates: dict) -> dict:
    """Per (scenario, workload): schedulers best-first by mean failed-task %,
    then mean job runtime; plus an overall ranking averaged over scenarios."""
    per_env: dict[str, list] = {}
    overall: dict[str, list] = {}
    for key, metrics in aggregates.items():
        scenario, workload, scheduler = key.rsplit("/", 2)
        env = f"{scenario}/{workload}"
        row = (metrics["pct_tasks_failed"]["mean"],
               metrics["job_exec_time"]["mean"], scheduler)
        per_env.setdefault(env, []).append(row)
        overall.setdefault(scheduler, []).append(row[:2])
    rankings = {env: [{"scheduler": s, "pct_tasks_failed": ft,
                       "job_exec_time": jt}
                      for ft, jt, s in sorted(rows)]
                for env, rows in sorted(per_env.items())}
    overall_rows = sorted(
        (sum(ft for ft, _ in rows) / len(rows),
         sum(jt for _, jt in rows) / len(rows), s)
        for s, rows in overall.items())
    rankings["overall"] = [{"scheduler": s, "pct_tasks_failed": ft,
                            "job_exec_time": jt}
                           for ft, jt, s in overall_rows]
    return rankings


def _round_floats(obj, ndigits: int = 6):
    if isinstance(obj, float):
        return round(obj, ndigits)
    if isinstance(obj, dict):
        return {k: _round_floats(v, ndigits) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_round_floats(v, ndigits) for v in obj]
    return obj


def sweep_json(result: dict) -> str:
    """Canonical byte-stable serialisation: sorted keys, floats rounded to 6
    decimals, no timestamps — re-running the same spec reproduces these bytes."""
    return json.dumps(_round_floats(result), indent=2, sort_keys=True) + "\n"


def sweep_markdown(result: dict) -> str:
    """Ranking tables (schedulers best-first by failed-task %, then runtime)."""
    agg = result["aggregates"]
    rankings = result["rankings"]
    lines = ["# Fleet sweep", ""]
    spec = result["spec"]
    lines.append(f"Schedulers: {', '.join(spec['schedulers'])} — "
                 f"seeds: {len(spec['seeds'])} — "
                 f"scenarios: {', '.join(spec['scenarios'])} — "
                 f"workloads: {', '.join(spec['workloads'])}")
    sizes = spec.get("fleet_sizes", [0])
    if any(sizes):
        lines.append("Fleet sizes: " + ", ".join(
            "paper (13)" if s == 0 else str(s) for s in sizes))
    pr = result.get("provenance", {}).get("pr")
    if pr:
        lines += ["", f"Produced by: {pr}"]
    broker = result.get("perf", {}).get("broker")
    if broker:
        lines += ["", f"Broker: {broker['demand_calls']} demand calls -> "
                      f"{broker['dispatches']} dispatches "
                      f"({broker['dispatch_reduction']}x reduction)"]
    header = ("| scheduler | failed tasks % | failed jobs % | job time (s) "
              "| sim time (s) |")
    sep = "|---|---|---|---|---|"

    def fmt(m):
        return f"{m['mean']:.2f} ± {m['ci95']:.2f}"

    for env, rows in rankings.items():
        if env == "overall":
            continue
        lines += ["", f"## {env}", "", header, sep]
        for row in rows:
            m = agg[f"{env}/{row['scheduler']}"]
            lines.append("| " + " | ".join(
                [row["scheduler"]] + [fmt(m[k]) for k in TABLE_METRICS]) + " |")
    lines += ["", "## overall (mean over scenarios)", "",
              "| rank | scheduler | failed tasks % | job time (s) |",
              "|---|---|---|---|"]
    for i, row in enumerate(rankings["overall"], 1):
        lines.append(f"| {i} | {row['scheduler']} | "
                     f"{row['pct_tasks_failed']:.2f} | "
                     f"{row['job_exec_time']:.1f} |")
    return "\n".join(lines) + "\n"


def write_outputs(result: dict, out_dir) -> tuple[pathlib.Path, pathlib.Path]:
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    jp = out / "SWEEP.json"
    mp = out / "SWEEP.md"
    jp.write_text(sweep_json(result))
    mp.write_text(sweep_markdown(result))
    return jp, mp


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _parse_seeds(s: str):
    if "," in s:
        return tuple(int(x) for x in s.split(",") if x != "")
    return int(s)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.cluster.fleet",
        description="Fleet-scale scheduler sweep over chaos scenarios")
    ap.add_argument("--schedulers", default="fifo,atlas-fifo",
                    help="comma list: fifo,fair,capacity,atlas-<base>")
    ap.add_argument("--seeds", default="3", type=_parse_seeds,
                    help="seed count (N => 0..N-1) or comma list of indices")
    ap.add_argument("--scenarios", default="baseline",
                    help=f"comma list or 'all' ({', '.join(sorted(SCENARIOS))})")
    ap.add_argument("--workloads", default="default",
                    help="comma list: " + ", ".join(sorted(WORKLOAD_SHAPES)))
    ap.add_argument("--fleet-size", default="0", dest="fleet_sizes",
                    metavar="SIZES",
                    help="comma list of fleet sizes (0 = the paper's "
                         "13-slave fleet; N = an N-node fleet of the same "
                         "machine mix) — a sweep axis")
    ap.add_argument("--algo", default="R.F.")
    ap.add_argument("--min-samples", type=int, default=150,
                    help="min labelled rows before a model trains")
    ap.add_argument("--executor", default="process",
                    choices=("process", "thread", "serial", "broker",
                             "async"))
    ap.add_argument("--hazard", default="cluster",
                    choices=("cluster", "per-node"),
                    help="chaos scaling: 'cluster' keeps the historical "
                         "cluster-wide event rate; 'per-node' scales it "
                         "with fleet size so failure rates stay comparable "
                         "across --fleet-size")
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--registry", default=None,
                    help="model-registry dir: ship trained model versions "
                         "to ATLAS cells instead of raw trace arrays")
    ap.add_argument("--check-invariants", action="store_true",
                    help="run the per-tick invariant checker in every cell "
                         "and stamp violation counts into cell metrics "
                         "(repro.cluster.invariants)")
    ap.add_argument("--obs", action="store_true",
                    help="stream per-cell telemetry frames to <out>/obs/ and "
                         "stamp deterministic roll-ups under perf.obs "
                         "(simulation results unchanged)")
    ap.add_argument("--obs-live", default=None, metavar="ADDR",
                    help="also stream every cell's frames to a live "
                         "TelemetryCollector at this transport address "
                         "(tcp://host:port — see python -m repro.obs.live); "
                         "simulation results unchanged")
    ap.add_argument("--resume", action="store_true",
                    help="keep an atomic per-cell ledger in <out>/cells and "
                         "skip cells it already holds: a sweep killed "
                         "mid-run restarts to byte-identical SWEEP.json "
                         "without re-running finished cells")
    ap.add_argument("--faults", default=None, metavar="FILE",
                    help="JSON FaultPlan (repro.online.faults) injected "
                         "into the --executor async serving path; "
                         "retry/replay/fallback counters land in "
                         "<out>/FAULTS.json — SWEEP.json bytes are "
                         "unaffected")
    ap.add_argument("--out", default="experiments",
                    help="directory for SWEEP.json + SWEEP.md")
    ap.add_argument("--list-scenarios", action="store_true")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_scenarios:
        for name, sc in sorted(SCENARIOS.items()):
            print(f"{name:18s} {sc.description}")
        return 0
    scenarios = (tuple(sorted(SCENARIOS)) if args.scenarios == "all"
                 else tuple(args.scenarios.split(",")))
    spec = SweepSpec(
        schedulers=tuple(args.schedulers.split(",")),
        seeds=args.seeds,
        scenarios=scenarios,
        workloads=tuple(args.workloads.split(",")),
        fleet_sizes=tuple(int(s) for s in args.fleet_sizes.split(",")),
        hazard=args.hazard,
        algo=args.algo, min_samples=args.min_samples,
        check_invariants=args.check_invariants)
    try:
        expand(spec)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    obs_dir = str(pathlib.Path(args.out) / "obs") if args.obs else None
    fault_plan = None
    if args.faults:
        from repro.online.faults import FaultPlan
        if args.executor != "async":
            print("error: --faults requires --executor async",
                  file=sys.stderr)
            return 2
        fault_plan = FaultPlan.from_dict(
            json.loads(pathlib.Path(args.faults).read_text()))
    resume_dir = (str(pathlib.Path(args.out) / "cells")
                  if args.resume else None)
    fault_stats = {} if fault_plan is not None else None
    result = run_sweep(spec, executor=args.executor, workers=args.workers,
                       registry=args.registry, obs_dir=obs_dir,
                       obs_live=args.obs_live, resume_dir=resume_dir,
                       fault_plan=fault_plan, fault_stats=fault_stats)
    jp, mp = write_outputs(result, args.out)
    if fault_stats is not None:
        fp = pathlib.Path(args.out) / "FAULTS.json"
        fp.write_text(json.dumps(fault_stats, indent=2, sort_keys=True)
                      + "\n")
        print(f"[fleet] fault stats in {fp}: "
              f"{fault_stats.get('client_retries', 0)} retries, "
              f"{fault_stats.get('fallbacks', 0)} fallbacks, "
              f"{fault_stats['injected']['events']} injected events")
    sys.stdout.write(sweep_markdown(result))
    print(f"[fleet] wrote {jp} and {mp}"
          + (f" (+ telemetry frames in {obs_dir})" if obs_dir else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
