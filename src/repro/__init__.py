"""repro — ATLAS (Adaptive Failure-aware Scheduler) rebuilt as a JAX/TPU framework.

Layers:
  core/        ATLAS scheduler (Algorithm 1), adaptive heartbeat, penalty queues,
               speculative execution, online predictor retraining.
  ml/          the paper's six predictive models (GLM, Tree, CTree, RF, Boost, NN)
               implemented in JAX + the 10-fold CV harness.
  cluster/     discrete-event fleet simulator + chaos (AnarchyApe equivalent).
  sched/       FIFO / Fair / Capacity baselines.
  models/      architecture zoo (dense GQA, MoE, RWKV6, Mamba2 hybrid, whisper,
               llama-vision) — pure JAX, train_step + serve_step.
  kernels/     Pallas TPU kernels (+ jnp oracles): forest inference, flash attention,
               decode attention, rwkv6 scan, mamba2 ssd.
  parallel/    mesh + logical-axis sharding rules (DP/FSDP/TP/EP/SP).
  optim/       AdamW, schedules, grad accumulation, int8 error-feedback compression.
  checkpoint/  async sharded checkpoint/restore with digests.
  data/        deterministic synthetic pipelines, sharded loaders.
  runtime/     training control loop wired to ATLAS decisions.
  configs/     assigned architectures + paper job profiles.
  launch/      make_production_mesh, dryrun, train, serve entry points.
"""

__version__ = "1.0.0"

# Stamped into SWEEP.json / ONLINE.json / BENCH_<n>.json so the perf
# trajectory across PRs is readable from one artifact.  Bump per PR.
PR_TAG = "PR10-faults"
