"""Async sharded checkpointing with integrity digests and elastic restore.

Layout (one directory per step):
    <root>/step_000123/
        meta.json              step, tree structure, shard table, digests
        shard_00000.npz        flattened leaves (or per-host slices)
        ...
Writes are atomic (tmp dir + rename) and can run on a background thread (the train
loop keeps stepping — the paper's lesson that recovery cost must not dominate).
Restore re-shards to whatever mesh the *new* process uses (elastic: the leaf arrays
are device_put against the target shardings, which may differ from the writer's)."""

from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
import threading
import time

import jax
import numpy as np

from repro.util import array_digest


@dataclasses.dataclass
class CheckpointManager:
    root: pathlib.Path
    keep: int = 3
    async_write: bool = True

    def __post_init__(self):
        self.root = pathlib.Path(self.root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None
        self.last_saved_step: int = -1
        self.save_count: int = 0

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, *, block: bool = False):
        """Snapshot `tree` (host-fetch now, serialize async)."""
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]  # device->host copy happens here
        self.wait()

        def write():
            tmp = self.root / f".tmp_step_{step:09d}"
            final = self.root / f"step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            digests = []
            shard_path = tmp / "shard_00000.npz"
            np.savez(shard_path, **{f"leaf_{i}": a for i, a in enumerate(host)})
            digests = [array_digest(a) for a in host]
            meta = {
                "step": step,
                "n_leaves": len(host),
                "digests": digests,
                "treedef": str(treedef),
                "shapes": [list(a.shape) for a in host],
                "dtypes": [str(a.dtype) for a in host],
                "time": time.time(),
            }
            (tmp / "meta.json").write_text(json.dumps(meta))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self.last_saved_step = step
            self.save_count += 1
            self._gc()

        if self.async_write and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step_{s:09d}", ignore_errors=True)

    # ------------------------------------------------------------------ load
    def all_steps(self):
        out = []
        for p in self.root.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like_tree, *, shardings=None, verify: bool = True):
        """Restore into the structure of `like_tree`.  `shardings` (same structure)
        re-shards onto the *current* mesh — elastic restore after a fleet change."""
        d = self.root / f"step_{step:09d}"
        meta = json.loads((d / "meta.json").read_text())
        data = np.load(d / "shard_00000.npz")
        leaves, treedef = jax.tree.flatten(like_tree)
        assert meta["n_leaves"] == len(leaves), "tree structure changed"
        out = []
        shard_leaves = jax.tree.flatten(shardings)[0] if shardings is not None \
            else [None] * len(leaves)
        for i, (ref, shard) in enumerate(zip(leaves, shard_leaves)):
            arr = data[f"leaf_{i}"]
            if verify and array_digest(arr) != meta["digests"][i]:
                raise IOError(f"checkpoint leaf {i} digest mismatch (corrupt?)")
            assert tuple(arr.shape) == tuple(ref.shape), \
                f"leaf {i}: {arr.shape} vs {ref.shape}"
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
        return jax.tree.unflatten(treedef, out)
