# Used verbatim by .github/workflows/ci.yml.
PY ?= python

.PHONY: test lint sweep-smoke online-smoke bench-smoke obs-smoke serve-smoke \
	search-smoke live-smoke chaos-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

lint:
	ruff check .

# fast fleet smoke sweep: 2 schedulers x 2 seeds x 2 scenarios on the tiny
# workload shape; emits experiments/SWEEP.json + SWEEP.md
sweep-smoke:
	PYTHONPATH=src $(PY) -m repro.cluster.fleet \
		--schedulers fifo,atlas-fifo --seeds 2 \
		--scenarios baseline,bursty_tt --workloads smoke \
		--out experiments

# tiny broker load-gen run: exits non-zero unless the batched path shows
# throughput and bit-parity with scalar scoring; stamps the broker numbers
# into experiments/SWEEP.json when the smoke sweep already produced one
online-smoke:
	PYTHONPATH=src $(PY) -m repro.online.bench --smoke \
		--out experiments --stamp-sweep experiments/SWEEP.json

# tiny perf-trajectory run: benches the block-diagonal serving path on the
# paper fleet AND a 100-node fleet, emits experiments/BENCH_<pr>.json, stamps
# per-size throughput/latency into SWEEP.json, and exits non-zero on a parity
# break or zero batched throughput
bench-smoke:
	PYTHONPATH=src $(PY) -m repro.online.bench --smoke \
		--fleet-sizes 0,100 \
		--out experiments --stamp-sweep experiments/SWEEP.json

# async-serving smoke: (1) fleet --executor async must reproduce the broker
# executor's SWEEP.json byte-for-byte on the smoke matrix, (2) the open-loop
# bench on the inproc backend must hold the p99 tail budget (p99 <= max(10x
# p50, 25 ms)) with bit-parity — non-zero exit on either break; emits
# experiments/BENCH_<pr>.json
serve-smoke:
	PYTHONPATH=src $(PY) -m repro.cluster.fleet \
		--schedulers fifo,atlas-fifo --seeds 2 \
		--scenarios baseline --workloads smoke \
		--executor async --out experiments/serve_async
	PYTHONPATH=src $(PY) -m repro.cluster.fleet \
		--schedulers fifo,atlas-fifo --seeds 2 \
		--scenarios baseline --workloads smoke \
		--executor broker --out experiments/serve_broker
	cmp experiments/serve_async/SWEEP.json experiments/serve_broker/SWEEP.json
	PYTHONPATH=src $(PY) -m repro.online.bench --smoke \
		--open-backends inproc --out experiments

# observability smoke: a tiny fleet cell with --obs (per-cell NDJSON frames +
# per-cell roll-ups under perf.obs), the dashboard rendered from the frames
# (non-zero exit when no frames land), and the telemetry overhead guard
obs-smoke:
	PYTHONPATH=src $(PY) -m repro.cluster.fleet \
		--schedulers fifo,atlas-fifo --seeds 1 \
		--scenarios bursty_tt --workloads smoke \
		--obs --out experiments
	PYTHONPATH=src $(PY) -m repro.obs.dashboard \
		experiments/obs/bursty_tt__smoke__fifo__s0.ndjson \
		-o experiments/obs/dashboard.html
	PYTHONPATH=src $(PY) benchmarks/obs_overhead.py

# live-telemetry smoke: the smoke fleet matrix streamed to a live
# TelemetryCollector over tcp:// while a poller curls /delta mid-run —
# gates SWEEP.json byte-parity with the wire on, a nonzero-frame /snapshot,
# gapless delta seqs that replay to the live aggregates (wire == NDJSON),
# and live-wire overhead <=5% on the bench-smoke cell; stamps live stats
# into experiments/BENCH_<pr>.json
live-smoke:
	PYTHONPATH=src $(PY) benchmarks/live_overhead.py

# chaos smoke: the fault-injection gate — (1) the async smoke sweep under a
# seeded FaultPlan (drops + delays + duplicates + one broker restart) emits
# SWEEP.json byte-identical to the fault-free control with nonzero
# retry/replay counters, (2) the armed-but-fault-free resilience machinery
# costs <=10%, (3) an injected predictor outage completes every cell with
# nonzero fallback counters (graceful degradation), (4) a --resume sweep
# SIGKILLed mid-run resumes to byte-identical SWEEP.json; stamps chaos stats
# into experiments/BENCH_<pr>.json
chaos-smoke:
	PYTHONPATH=src $(PY) benchmarks/chaos_smoke.py

# adversarial-search smoke: a tiny deterministic hill-climb (8 evals, 20-node
# fleet, invariants ON in every cell) gating (a) a valid resumable
# experiments/SEARCH.json ledger, (b) zero invariant violations, (c) >=1
# nonzero-regret regime, (d) byte-identical ledger on a from-scratch rerun;
# then the check_invariants runtime guard on a 100-node bench-smoke cell
search-smoke:
	PYTHONPATH=src $(PY) benchmarks/scenario_search.py --smoke --fresh
	PYTHONPATH=src $(PY) benchmarks/scenario_search.py --overhead \
		--fleet-size 100 --gate 10
