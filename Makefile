# Used verbatim by .github/workflows/ci.yml.
PY ?= python

.PHONY: test lint sweep-smoke online-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

lint:
	ruff check .

# fast fleet smoke sweep: 2 schedulers x 2 seeds x 2 scenarios on the tiny
# workload shape; emits experiments/SWEEP.json + SWEEP.md
sweep-smoke:
	PYTHONPATH=src $(PY) -m repro.cluster.fleet \
		--schedulers fifo,atlas-fifo --seeds 2 \
		--scenarios baseline,bursty_tt --workloads smoke \
		--out experiments

# tiny broker load-gen run: exits non-zero unless the batched path shows
# throughput and bit-parity with scalar scoring; stamps the broker numbers
# into experiments/SWEEP.json when the smoke sweep already produced one
online-smoke:
	PYTHONPATH=src $(PY) -m repro.online.bench --smoke \
		--out experiments --stamp-sweep experiments/SWEEP.json
