# Used verbatim by .github/workflows/ci.yml.
PY ?= python

.PHONY: test lint sweep-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

lint:
	ruff check .

# fast fleet smoke sweep: 2 schedulers x 2 seeds x 2 scenarios on the tiny
# workload shape; emits experiments/SWEEP.json + SWEEP.md
sweep-smoke:
	PYTHONPATH=src $(PY) -m repro.cluster.fleet \
		--schedulers fifo,atlas-fifo --seeds 2 \
		--scenarios baseline,bursty_tt --workloads smoke \
		--out experiments
