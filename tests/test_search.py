"""Adversarial scenario search (PR 8): move generation, acceptance state,
regret objective, ledger determinism, and bit-for-bit resume."""

import json

import pytest

from repro.cluster.scenarios import ScenarioSpec
from repro.cluster.search import (SearchConfig, _accepts, _advance,
                                  _fresh_state, _propose, regret_for,
                                  run_search, search_json, search_markdown)

FAST = dict(budget=2, seeds=1, fleet_size=0, workload="smoke",
            executor="serial", min_samples=40, max_train=2000)


def _rec(i, regret, origin="perturb", accepted=False, point=None):
    point = point or ScenarioSpec.sample(__import__("random").Random(i))
    return {"i": i, "origin": origin, "point": point.to_dict(),
            "regret": regret, "per_seed": [regret], "violations": 0,
            "checks": 1, "accepted": accepted, "best_so_far": regret}


# ---------------------------------------------------------------------------
# objective
# ---------------------------------------------------------------------------

def test_regret_positive_when_atlas_worse():
    cfg = SearchConfig()
    base = {"pct_tasks_failed": 10.0, "pct_jobs_failed": 5.0,
            "sim_time": 1000.0}
    atlas = {"pct_tasks_failed": 14.0, "pct_jobs_failed": 7.0,
             "sim_time": 1100.0}
    # 1*(14-10) + 1*(7-5) + 0.25*100*(1100-1000)/1000 = 4 + 2 + 2.5
    assert regret_for(base, atlas, cfg) == pytest.approx(8.5)
    assert regret_for(atlas, base, cfg) < 0       # symmetric sign


def test_regret_weights():
    cfg = SearchConfig(w_tasks=0.0, w_jobs=0.0, w_makespan=1.0)
    base = {"pct_tasks_failed": 10.0, "pct_jobs_failed": 5.0,
            "sim_time": 2000.0}
    atlas = {"pct_tasks_failed": 99.0, "pct_jobs_failed": 99.0,
             "sim_time": 2200.0}
    assert regret_for(base, atlas, cfg) == pytest.approx(10.0)


# ---------------------------------------------------------------------------
# climb state machine (pure logic, no sims)
# ---------------------------------------------------------------------------

def test_propose_init_then_perturb_then_restart():
    cfg = SearchConfig(restart_after=2, scenario="baseline", workload="smoke")
    state = _fresh_state()
    point, origin = _propose(state, cfg, 0)
    assert origin == "init" and point.name == "baseline"
    _advance(state, _rec(0, 1.0, origin="init", accepted=True, point=point))
    _, origin = _propose(state, cfg, 1)
    assert origin == "perturb"
    _advance(state, _rec(1, 0.5))            # two non-improving evals...
    _advance(state, _rec(2, 0.2))
    assert state["since_improve"] == 2
    p3, origin = _propose(state, cfg, 3)
    assert origin == "restart"               # ...trigger a restart
    p3b, _ = _propose(state, cfg, 3)
    assert p3 == p3b                         # moves are pure functions of i


def test_accepts_greedy_with_unconditional_restarts():
    state = _fresh_state()
    assert _accepts(state, "init", -99.0)
    state["cur_regret"] = 5.0
    assert not _accepts(state, "perturb", 5.0)   # ties rejected
    assert _accepts(state, "perturb", 5.1)
    assert _accepts(state, "restart", -99.0)     # restarts always move


def test_advance_tracks_best_across_rejections():
    state = _fresh_state()
    _advance(state, _rec(0, 1.0, origin="init", accepted=True))
    _advance(state, _rec(1, 7.0))            # rejected but still the worst seen
    _advance(state, _rec(2, 3.0))
    assert state["best"]["regret"] == 7.0
    assert state["cur_regret"] == 1.0
    assert state["since_improve"] == 2


# ---------------------------------------------------------------------------
# end-to-end: deterministic, resumable ledger (tiny real sweeps)
# ---------------------------------------------------------------------------

def test_search_ledger_deterministic_and_resumable(tmp_path):
    cfg = SearchConfig(**FAST)
    a = run_search(cfg, out_dir=tmp_path / "a", log=lambda *x: None)
    b = run_search(cfg, out_dir=tmp_path / "b", log=lambda *x: None)
    assert search_json(a) == search_json(b)
    assert (tmp_path / "a" / "SEARCH.json").read_bytes() == \
        (tmp_path / "b" / "SEARCH.json").read_bytes()

    # interrupted search: 1 eval now, budget extended to 2 on resume
    short = SearchConfig(**{**FAST, "budget": 1})
    run_search(short, out_dir=tmp_path / "c", log=lambda *x: None)
    resumed = run_search(cfg, out_dir=tmp_path / "c", log=lambda *x: None)
    assert search_json(resumed) == search_json(a)

    data = json.loads((tmp_path / "a" / "SEARCH.json").read_text())
    assert data["n_evals"] == 2
    assert [e["i"] for e in data["evals"]] == [0, 1]
    assert data["evals"][0]["origin"] == "init"
    assert data["best"]["regret"] == max(e["regret"] for e in data["evals"])
    assert data["ranking"][0]["regret"] == data["best"]["regret"]
    assert all(e["violations"] == 0 for e in data["evals"])
    md = search_markdown(data)
    assert "| rank |" in md and "Worst regime" in md


def test_resume_rejects_divergent_config(tmp_path):
    cfg = SearchConfig(**FAST)
    run_search(cfg, out_dir=tmp_path, log=lambda *x: None)
    other = SearchConfig(**{**FAST, "scale": 0.5})
    with pytest.raises(ValueError, match="different SearchConfig"):
        run_search(other, out_dir=tmp_path, log=lambda *x: None)
    # budget/executor/workers are operational: resume must tolerate them
    more = SearchConfig(**{**FAST, "workers": 2})
    run_search(more, out_dir=tmp_path, log=lambda *x: None)
