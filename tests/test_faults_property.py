"""Property tests (hypothesis) for the fault-tolerance primitives: the
retry backoff is bounded, monotone in its capped envelope, and a pure
function of (seed, attempt); FaultPlan dicts round-trip exactly for every
valid point in fault-space."""

import random

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.online.faults import (FAULT_BOUNDS, FaultPlan,  # noqa: E402
                                 backoff_delay)

_seeds = st.integers(min_value=0, max_value=2 ** 31 - 1)
_attempts = st.integers(min_value=0, max_value=64)
_bases = st.floats(min_value=1e-4, max_value=1.0,
                   allow_nan=False, allow_infinity=False)
_caps = st.floats(min_value=1e-3, max_value=60.0,
                  allow_nan=False, allow_infinity=False)


@given(seed=_seeds, attempt=_attempts, base=_bases, cap=_caps)
@settings(max_examples=200, deadline=None)
def test_backoff_bounded_by_cap_and_inside_jitter_band(seed, attempt, base,
                                                       cap):
    env = min(cap, base * 2.0 ** attempt)
    d = backoff_delay(attempt, base=base, cap=cap, seed=seed)
    assert 0.0 <= d <= cap
    assert env / 2 <= d <= env


@given(seed=_seeds, base=_bases, cap=_caps)
@settings(max_examples=100, deadline=None)
def test_backoff_envelope_monotone_until_cap(seed, base, cap):
    """The *envelope* doubles until it saturates at the cap: each delay's
    band never sits below the previous attempt's band floor."""
    prev_env = 0.0
    for attempt in range(20):
        env = min(cap, base * 2.0 ** attempt)
        assert env >= prev_env
        d = backoff_delay(attempt, base=base, cap=cap, seed=seed)
        assert d >= prev_env / 2         # band floors are monotone too
        prev_env = env


@given(seed=_seeds, attempt=_attempts)
@settings(max_examples=200, deadline=None)
def test_backoff_bit_deterministic_per_seed_and_attempt(seed, attempt):
    a = backoff_delay(attempt, seed=seed)
    b = backoff_delay(attempt, seed=seed)
    assert a == b                        # ==, not approx: bit reproducible
    # neighbouring attempts draw independent jitter (no shared global state)
    backoff_delay(attempt + 1, seed=seed)
    assert backoff_delay(attempt, seed=seed) == a


@given(sample_seed=st.integers(min_value=0, max_value=2 ** 16))
@settings(max_examples=150, deadline=None)
def test_fault_plan_round_trip_is_exact_over_fault_space(sample_seed):
    plan = FaultPlan.sample(random.Random(sample_seed))
    payload = plan.to_dict()
    assert FaultPlan.from_dict(payload) == plan
    # and the dict is plain-JSON material: a second encode is identical
    assert FaultPlan.from_dict(payload).to_dict() == payload


@given(
    seed=_seeds,
    drop=st.floats(min_value=0.0, max_value=0.25),
    delay=st.floats(min_value=0.0, max_value=0.25),
    duplicate=st.floats(min_value=0.0, max_value=0.25),
    abrupt_close=st.floats(min_value=0.0, max_value=0.25),
    max_events=st.integers(min_value=0,
                           max_value=FAULT_BOUNDS["max_events"].hi),
)
@settings(max_examples=150, deadline=None)
def test_fault_plan_explicit_points_validate_and_round_trip(
        seed, drop, delay, duplicate, abrupt_close, max_events):
    plan = FaultPlan(seed=seed, drop=drop, delay=delay, duplicate=duplicate,
                     abrupt_close=abrupt_close,
                     max_events=max_events).validate()
    assert FaultPlan.from_dict(plan.to_dict()) == plan
