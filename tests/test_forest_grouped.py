"""Block-diagonal grouped inference tests: bit-parity of the packed single
pass against the per-model loop (ragged segments, padded tails, heterogeneous
shapes), the pack cache, and Pallas/XLA grouped-kernel parity."""

import numpy as np
import pytest

from repro.ml.forest import (GROUPED_KERNEL_ROWS, fit_oblivious_forest,
                             forest_predict_grouped, forest_predict_np,
                             pack_forests)


def _data(n=300, f=12, seed=0):
    rng = np.random.RandomState(seed)
    X = rng.rand(n, f).astype(np.float32)
    y = (X[:, 0] + 0.3 * rng.rand(n) > 0.8).astype(np.float32)
    return X, y


@pytest.fixture(scope="module")
def models():
    X, y = _data()
    return {
        "a": fit_oblivious_forest(X, y, n_trees=24, depth=5, seed=0),
        "b": fit_oblivious_forest(X, 1 - y, n_trees=24, depth=5, seed=1),
        # ragged shapes: fewer trees, shallower depth -> padded tail in the
        # packed block
        "c": fit_oblivious_forest(X, y, n_trees=8, depth=3, seed=2),
        "d": fit_oblivious_forest(X, 1 - y, n_trees=16, depth=4, seed=3),
    }


def _check_bitwise(groups):
    outs, passes = forest_predict_grouped(groups)
    for (params, rows), out in zip(groups, outs):
        assert np.array_equal(out, forest_predict_np(params, rows)), \
            "block-diagonal pass differs from the per-model loop"
    return passes


@pytest.mark.parametrize("batches", [
    (1,), (1, 1, 1), (7, 33), (1, 64, 2), (65, 1, 5, 12),
])
def test_blockdiag_bitwise_same_shape(models, batches):
    Xq = _data(seed=4)[0]
    names = ["a", "b"]
    groups, at = [], 0
    for i, b in enumerate(batches):
        groups.append((models[names[i % 2]], Xq[at:at + b]))
        at += b
    assert _check_bitwise(groups) == 1


def test_blockdiag_bitwise_heterogeneous_shapes_single_pass(models):
    """Mixed (T, D) shapes pad into ONE block: still one pass, still
    bit-identical per model (the padded tail never enters the tree mean)."""
    Xq = _data(seed=5)[0]
    groups = [(models["a"], Xq[:9]), (models["c"], Xq[9:40]),
              (models["d"], Xq[40:41]), (models["a"], Xq[41:100]),
              (models["c"], Xq[100:103])]
    assert _check_bitwise(groups) == 1


def test_blockdiag_empty_and_single_groups(models):
    Xq = _data(seed=6)[0]
    outs, passes = forest_predict_grouped([(models["a"], Xq[:0])])
    assert passes == 0 and outs[0].shape == (0,)
    # single model takes the shared-block mirror; still bit-identical
    assert _check_bitwise([(models["a"], Xq[:50]),
                           (models["a"], Xq[50:51])]) == 1


def test_blockdiag_row_order_between_segments_irrelevant(models):
    """Interleaved group order (a, b, a, b) must score each row identically
    to contiguous per-model calls — the segment reshuffle is internal."""
    Xq = _data(seed=7)[0]
    groups = [(models["a"], Xq[:5]), (models["b"], Xq[5:30]),
              (models["a"], Xq[30:60]), (models["b"], Xq[60:61])]
    _check_bitwise(groups)


def test_pack_forests_padded_tail_layout(models):
    packed = pack_forests([models["a"], models["c"]])
    M, T, D = packed.feat_idx.shape
    assert (M, T, D) == (2, 24, 5)
    assert packed.n_trees.tolist() == [24, 8]
    # padded levels test +inf (bits identically False), padded trees have
    # all-zero leaves (contribute exactly 0 to any sum)
    assert np.all(np.isinf(packed.thresholds[1, :8, 3:]))
    assert np.all(np.isinf(packed.thresholds[1, 8:]))
    assert np.all(packed.leaves[1, 8:] == 0.0)
    # model c's leaf l lives at l << (5 - 3)
    c = models["c"]
    assert np.array_equal(packed.leaves[1][:8][:, np.arange(8) << 2], c.leaves)


def test_grouped_kernel_parity_xla_and_interpret(models):
    pytest.importorskip("jax.experimental.pallas")
    Xq = _data(seed=8, n=700)[0]
    groups = [(models["a"], Xq[:300]), (models["b"], Xq[300:550]),
              (models["c"], Xq[550:]), (models["a"], Xq[:0])]
    want, _ = forest_predict_grouped(groups)
    for impl in ("xla", "interpret"):
        outs, passes = forest_predict_grouped(groups, impl=impl)
        assert passes == 1
        for w, o in zip(want, outs):
            np.testing.assert_allclose(o, w, rtol=2e-5, atol=2e-5)


def test_auto_routes_fat_flushes_to_kernel(models):
    n = GROUPED_KERNEL_ROWS + 64
    Xq = np.random.RandomState(9).rand(n, 12).astype(np.float32)
    small, _ = forest_predict_grouped(
        [(models["a"], Xq[:8])], impl="auto")        # numpy path
    assert np.array_equal(small[0], forest_predict_np(models["a"], Xq[:8]))
    fat, passes = forest_predict_grouped(
        [(models["a"], Xq[:n // 2]), (models["b"], Xq[n // 2:])], impl="auto")
    assert passes == 1
    np.testing.assert_allclose(
        fat[0], forest_predict_np(models["a"], Xq[:n // 2]),
        rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(
        fat[1], forest_predict_np(models["b"], Xq[n // 2:]),
        rtol=2e-5, atol=2e-5)
