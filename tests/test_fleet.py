"""Fleet sweep engine tests: matrix expansion, deterministic per-cell seeding,
aggregate reducer math, end-to-end reproducibility, and per-cell stats."""

import math

import pytest

from repro.cluster.experiment import atlas_base_name
from repro.cluster.fleet import (CellSpec, SweepSpec, aggregate, cell_config,
                                 cell_seed, expand, mean_ci, rank, run_sweep,
                                 sweep_json, sweep_markdown)


def _spec(**kw):
    base = dict(schedulers=("fifo", "atlas-fifo"), seeds=2,
                scenarios=("baseline", "bursty_tt"), workloads=("smoke",))
    base.update(kw)
    return SweepSpec(**base)


# ---------------------------------------------------------------------------
# Matrix expansion
# ---------------------------------------------------------------------------

def test_expand_full_cross_product():
    spec = _spec(schedulers=("fifo", "fair", "atlas-fifo"), seeds=3,
                 scenarios=("baseline", "dn_loss"), workloads=("smoke",))
    cells = expand(spec)
    assert len(cells) == 3 * 3 * 2 * 1
    assert len(set(cells)) == len(cells)
    # deterministic ordering
    assert cells == sorted(cells, key=lambda c: (c.scenario, c.workload,
                                                 c.scheduler, c.seed_index))
    assert expand(spec) == cells


def test_expand_explicit_seed_indices_and_dedup():
    spec = _spec(schedulers=("fifo", "fifo"), seeds=(0, 5),
                 scenarios=("baseline",))
    cells = expand(spec)
    assert len(cells) == 2                       # duplicate scheduler deduped
    assert sorted(c.seed_index for c in cells) == [0, 5]


@pytest.mark.parametrize("bad", [
    dict(scenarios=("no_such_scenario",)),
    dict(workloads=("no_such_shape",)),
    dict(schedulers=("atlas-nope",)),
    dict(schedulers=("srtf",)),
])
def test_expand_rejects_unknown_axis_values(bad):
    with pytest.raises(KeyError):
        expand(_spec(**bad))


# ---------------------------------------------------------------------------
# Deterministic per-cell seeding
# ---------------------------------------------------------------------------

def test_cell_seed_stable_and_distinct():
    a = cell_seed("chaos", "baseline", "smoke", 0)
    assert a == cell_seed("chaos", "baseline", "smoke", 0)
    others = {cell_seed("chaos", sc, "smoke", si)
              for sc in ("baseline", "bursty_tt", "dn_loss")
              for si in range(4)}
    assert len(others) == 12                     # no collisions on real axes


def test_cell_config_scheduler_independent_conditions():
    """Every scheduler must face the identical workload + failure storm at a
    given (scenario, workload, seed) — the paper's matched-conditions protocol."""
    spec = _spec()
    fifo = CellSpec("fifo", "baseline", "smoke", 1)
    atlas = CellSpec("atlas-fifo", "baseline", "smoke", 1)
    cf, ca = cell_config(spec, fifo), cell_config(spec, atlas)
    assert cf.workload == ca.workload
    assert cf.chaos == ca.chaos
    assert cf.seed == ca.seed
    # ...but different coordinates get different seeds
    other = cell_config(spec, CellSpec("fifo", "bursty_tt", "smoke", 1))
    assert other.chaos.seed != cf.chaos.seed
    assert other.workload.seed != cf.workload.seed


# ---------------------------------------------------------------------------
# Reducer math
# ---------------------------------------------------------------------------

def test_mean_ci_math():
    r = mean_ci([1.0, 2.0, 3.0, 4.0])
    assert r["n"] == 4 and r["mean"] == pytest.approx(2.5)
    sd = math.sqrt(sum((x - 2.5) ** 2 for x in (1, 2, 3, 4)) / 3)
    assert r["ci95"] == pytest.approx(1.96 * sd / 2.0)
    assert mean_ci([7.0]) == {"mean": 7.0, "ci95": 0.0, "n": 1}


def _rec(sched, scen, seed, **metrics):
    return {"cell_id": f"{scen}/smoke/{sched}/s{seed}", "scheduler": sched,
            "scenario": scen, "workload": "smoke", "seed_index": seed,
            "metrics": metrics, "stats": {}}


def test_aggregate_groups_over_seeds():
    recs = [_rec("fifo", "baseline", 0, pct_tasks_failed=10.0,
                 job_exec_time=100.0),
            _rec("fifo", "baseline", 1, pct_tasks_failed=20.0,
                 job_exec_time=300.0),
            _rec("fifo", "dn_loss", 0, pct_tasks_failed=50.0,
                 job_exec_time=500.0)]
    agg = aggregate(recs)
    assert set(agg) == {"baseline/smoke/fifo", "dn_loss/smoke/fifo"}
    base = agg["baseline/smoke/fifo"]
    assert base["pct_tasks_failed"]["mean"] == pytest.approx(15.0)
    assert base["pct_tasks_failed"]["n"] == 2
    assert agg["dn_loss/smoke/fifo"]["job_exec_time"]["ci95"] == 0.0


def test_rank_orders_by_failed_tasks_then_runtime():
    recs = [_rec("fifo", "baseline", 0, pct_tasks_failed=30.0,
                 pct_jobs_failed=1.0, job_exec_time=100.0, sim_time=1.0),
            _rec("atlas-fifo", "baseline", 0, pct_tasks_failed=10.0,
                 pct_jobs_failed=1.0, job_exec_time=200.0, sim_time=1.0)]
    rk = rank(aggregate(recs))
    assert [r["scheduler"] for r in rk["baseline/smoke"]] == \
        ["atlas-fifo", "fifo"]
    assert rk["overall"][0]["scheduler"] == "atlas-fifo"


# ---------------------------------------------------------------------------
# End-to-end: reproducibility + per-cell stats surfaced
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_sweep():
    spec = _spec(scenarios=("baseline",))
    return spec, run_sweep(spec, executor="serial", log=lambda *a: None)


def test_sweep_json_reproducible_byte_identical(small_sweep):
    spec, result = small_sweep
    again = run_sweep(spec, executor="serial", log=lambda *a: None)
    assert sweep_json(result) == sweep_json(again)


def test_sweep_covers_every_cell_with_stats(small_sweep):
    spec, result = small_sweep
    cells = expand(spec)
    assert [r["cell_id"] for r in result["cells"]] == \
        sorted(c.cell_id for c in cells)
    for r in result["cells"]:
        assert r["metrics"]["jobs_total"] > 0
        assert "launches" in r["stats"]
        if atlas_base_name(r["scheduler"]) is not None:
            # ATLAS Algorithm-1 stats surfaced per cell
            assert "predictions" in r["stats"]
            assert r["stats"]["predictions"] > 0


def test_sweep_thread_executor_matches_serial(small_sweep):
    spec, result = small_sweep
    threaded = run_sweep(spec, executor="thread", workers=2,
                         log=lambda *a: None)
    assert sweep_json(threaded) == sweep_json(result)


def test_sweep_atlas_only_spawns_training_runs():
    """With no base-scheduler cells to reuse, the fleet must add training-only
    runs for each (base, scenario, workload, seed) and still report only the
    requested cells."""
    spec = _spec(schedulers=("atlas-fifo",), seeds=1, scenarios=("baseline",))
    result = run_sweep(spec, executor="serial", log=lambda *a: None)
    assert [r["scheduler"] for r in result["cells"]] == ["atlas-fifo"]
    assert result["cells"][0]["stats"]["predictions"] > 0


def test_sweep_markdown_mentions_every_scheduler_and_scenario(small_sweep):
    spec, result = small_sweep
    md = sweep_markdown(result)
    for s in spec.schedulers:
        assert s in md
    for sc in spec.scenarios:
        assert sc in md
    assert "## overall" in md


# ---------------------------------------------------------------------------
# Fleet-size scale axis (PR 5)
# ---------------------------------------------------------------------------

def test_expand_fleet_sizes_axis_and_ids():
    spec = _spec(scenarios=("baseline",), fleet_sizes=(0, 100))
    cells = expand(spec)
    assert len(cells) == 2 * 2 * 2          # scheds x seeds x sizes
    default_ids = {c.cell_id for c in cells if c.fleet_size == 0}
    sized_ids = {c.cell_id for c in cells if c.fleet_size == 100}
    # default cells keep their PR-3/4 coordinates (no fleet segment)...
    assert default_ids == {"baseline/smoke/fifo/s0", "baseline/smoke/fifo/s1",
                           "baseline/smoke/atlas-fifo/s0",
                           "baseline/smoke/atlas-fifo/s1"}
    # ...and sized cells carry the axis in id + env_key (seeds differ too)
    assert sized_ids == {"baseline/smoke/n100/fifo/s0",
                         "baseline/smoke/n100/fifo/s1",
                         "baseline/smoke/n100/atlas-fifo/s0",
                         "baseline/smoke/n100/atlas-fifo/s1"}
    c0 = next(c for c in cells if c.fleet_size == 0)
    c100 = next(c for c in cells if c.fleet_size == 100)
    assert cell_config(spec, c100).fleet_size == 100
    assert cell_config(spec, c0).fleet_size == 0
    with pytest.raises(KeyError):
        expand(_spec(fleet_sizes=(-5,)))


def test_fleet_size_sweep_cells_and_aggregate_keys():
    spec = _spec(schedulers=("fifo", "atlas-fifo"), seeds=1,
                 scenarios=("baseline",), fleet_sizes=(40,),
                 min_samples=40, max_train=40)
    result = run_sweep(spec, executor="serial", log=lambda *a: None)
    assert sorted(r["cell_id"] for r in result["cells"]) == [
        "baseline/smoke/n40/atlas-fifo/s0", "baseline/smoke/n40/fifo/s0"]
    assert set(result["aggregates"]) == {"baseline/smoke/n40/fifo",
                                         "baseline/smoke/n40/atlas-fifo"}
    assert "baseline/smoke/n40" in result["rankings"]
    assert all(r["fleet_size"] == 40 for r in result["cells"])
    # byte-stable like every other sweep
    again = run_sweep(spec, executor="serial", log=lambda *a: None)
    assert sweep_json(result) == sweep_json(again)
