"""Regression tests for make_bins de-duplication (the docstring always
promised it; duplicate quantiles of constant/low-cardinality features used to
survive as repeated zero-gain candidate splits)."""

import numpy as np

from repro.ml.forest import fit_oblivious_forest, make_bins


def test_make_bins_deduplicates_constant_and_low_cardinality_features():
    rs = np.random.RandomState(0)
    X = np.stack([
        np.full(200, 3.7, np.float32),            # constant
        (np.arange(200) % 2).astype(np.float32),  # binary
        rs.rand(200).astype(np.float32),          # continuous
    ], axis=1)
    thr = make_bins(X, 8)
    assert thr.shape == (3, 8)                    # grid shape preserved
    # constant feature: one finite threshold, +inf padding
    finite0 = thr[0][np.isfinite(thr[0])]
    assert finite0.tolist() == [np.float32(3.7)]
    assert np.isinf(thr[0, 1:]).all()
    # every row is strictly increasing over its finite prefix (no duplicates)
    for f in range(3):
        row = thr[f][np.isfinite(thr[f])]
        assert (np.diff(row) > 0).all()
    # continuous feature keeps its full quantile ladder
    assert np.isfinite(thr[2]).all()
    # the +inf sentinels can never split: x > inf is identically False
    assert not (X[:, 0:1] > thr[0, 1:][None]).any()


def test_constant_feature_never_selected_over_informative_split():
    rs = np.random.RandomState(3)
    X = rs.randn(800, 5).astype(np.float32)
    logit = 1.2 * X[:, 1] - 0.8 * X[:, 2]
    y = (rs.rand(800) < 1 / (1 + np.exp(-logit))).astype(np.float32)
    Xc = np.concatenate([np.full((800, 1), 5.0, np.float32), X], axis=1)
    params = fit_oblivious_forest(Xc, y, n_trees=4, depth=4, n_bins=8,
                                  bootstrap=False, seed=0)
    assert not (params.feat_idx == 0).any()       # constant column unused
    assert np.isfinite(params.thresholds).all()
