"""Validate the loop-aware HLO cost analyzer against analytic expectations."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_dot_flops_multiplied_by_trip_count():
    L, M, K, N = 10, 128, 256, 256

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=L)
        return c

    txt = _compiled_text(f, jax.ShapeDtypeStruct((M, K), jnp.float32),
                         jax.ShapeDtypeStruct((K, N), jnp.float32))
    got = hlo_cost.analyze(txt)
    want_flops = L * 2 * M * K * N
    assert got["flops"] == pytest.approx(want_flops, rel=0.01), got
    assert got["unknown_trip_loops"] == 0
    # traffic: at least L * (read c + w + write c) for the dot operands
    assert got["traffic_bytes"] >= L * (M * K + K * N + M * N) * 4


def test_single_dot_flops_exact():
    M, K, N = 64, 32, 48

    def f(a, b):
        return a @ b

    txt = _compiled_text(f, jax.ShapeDtypeStruct((M, K), jnp.float32),
                         jax.ShapeDtypeStruct((K, N), jnp.float32))
    got = hlo_cost.analyze(txt)
    assert got["flops"] == pytest.approx(2 * M * K * N, rel=0.01)


def test_batched_dot_flops():
    B, M, K, N = 4, 16, 32, 24

    def f(a, b):
        return jnp.einsum("bmk,bkn->bmn", a, b)

    txt = _compiled_text(f, jax.ShapeDtypeStruct((B, M, K), jnp.float32),
                         jax.ShapeDtypeStruct((B, K, N), jnp.float32))
    got = hlo_cost.analyze(txt)
    assert got["flops"] == pytest.approx(2 * B * M * K * N, rel=0.01)


def test_nested_scan_multiplies_both_trip_counts():
    L1, L2, M = 5, 7, 64

    def f(x, w):
        def inner(c, _):
            return jnp.tanh(c @ w), None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=L2)
            return c, None
        c, _ = jax.lax.scan(outer, x, None, length=L1)
        return c

    txt = _compiled_text(f, jax.ShapeDtypeStruct((M, M), jnp.float32),
                         jax.ShapeDtypeStruct((M, M), jnp.float32))
    got = hlo_cost.analyze(txt)
    assert got["flops"] == pytest.approx(L1 * L2 * 2 * M * M * M, rel=0.01)


def test_collectives_counted_with_trip_multiplier():
    # 8 fake devices via a sub-mesh of the CPU host platform
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run under forced host device count)")


def test_xla_cost_analysis_undercounts_loops_demo():
    """Documents the bug this module works around."""
    def f(x, w):
        def body(c, _):
            return c @ w, None
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):        # older jaxlib returns [dict] per partition
        ca = ca[0]
    xla_flops = ca["flops"]
    ours = hlo_cost.analyze(compiled.as_text())["flops"]
    assert ours == pytest.approx(10 * xla_flops, rel=0.05)
