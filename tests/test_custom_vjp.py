"""The linear-recurrence custom VJPs (RWKV6 WKV, Mamba2 SSD) must match plain
scan autodiff exactly — these back the memory fix documented in EXPERIMENTS §Perf
(scan-AD stores the state per timestep; the chunked adjoint stores boundaries)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref


def _rwkv_inputs(B, S, H, Dh, seed=0):
    key = jax.random.PRNGKey(seed)
    mk = lambda i: jax.random.normal(jax.random.fold_in(key, i), (B, S, H, Dh))
    r, k, v = mk(0), mk(1), mk(2)
    w = jax.nn.sigmoid(mk(3))
    u = jax.random.normal(jax.random.fold_in(key, 4), (H, Dh)) * 0.2
    s0 = jax.random.normal(jax.random.fold_in(key, 5), (B, H, Dh, Dh))
    return r, k, v, w, u, s0


@pytest.mark.parametrize("B,S,H,Dh", [(2, 64, 3, 8), (1, 96, 2, 16)])
def test_rwkv6_custom_vjp_matches_autodiff(B, S, H, Dh):
    args = _rwkv_inputs(B, S, H, Dh)

    def loss(fn, *a):
        y, sf = fn(*a)
        return jnp.sin(y).sum() + (sf ** 2).sum() * 0.1

    g1 = jax.grad(lambda *a: loss(ref.rwkv6_scan_ref, *a),
                  argnums=tuple(range(6)))(*args)
    g2 = jax.grad(lambda *a: loss(ref._rwkv6_fwd_scan, *a),
                  argnums=tuple(range(6)))(*args)
    for name, a, b in zip("r k v w u s0".split(), g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-5, err_msg=name)


@pytest.mark.parametrize("B,S,H,P,N", [(2, 64, 3, 8, 5), (1, 96, 2, 16, 8)])
def test_mamba2_custom_vjp_matches_autodiff(B, S, H, P, N):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(jax.random.fold_in(key, 0), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, N))
    s0 = jax.random.normal(jax.random.fold_in(key, 5), (B, H, P, N))

    def loss(fn, *a):
        y, sf = fn(*a)
        return jnp.sin(y).sum() + (sf ** 2).sum() * 0.1

    g1 = jax.grad(lambda *a: loss(ref.mamba2_ssd_ref, *a),
                  argnums=tuple(range(6)))(x, dt, A, Bm, Cm, s0)
    g2 = jax.grad(lambda *a: loss(ref._ssd_fwd_scan, *a),
                  argnums=tuple(range(6)))(x, dt, A, Bm, Cm, s0)
    for name, a, b in zip("x dt A B C s0".split(), g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-5, err_msg=name)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 200), S=st.sampled_from([32, 48, 64]))
def test_property_rwkv6_vjp_any_seed(seed, S):
    args = _rwkv_inputs(1, S, 2, 8, seed=seed)

    def loss(fn, *a):
        y, sf = fn(*a)
        return (y ** 2).sum() + sf.sum()

    g1 = jax.grad(lambda *a: loss(ref.rwkv6_scan_ref, *a), argnums=(1, 3))(*args)
    g2 = jax.grad(lambda *a: loss(ref._rwkv6_fwd_scan, *a), argnums=(1, 3))(*args)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)


def test_forward_unchanged_by_vjp_wrapper():
    args = _rwkv_inputs(2, 64, 3, 8)
    y1, s1 = ref.rwkv6_scan_ref(*args)
    y2, s2 = ref._rwkv6_fwd_scan(*args)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-6)
