"""Online broker tests: batch-shape-invariant forest scoring, fused group
flushes, broker/scalar decision parity, cross-client dispatch reduction, and
the fleet's broker executor reproducing the serial sweep byte-for-byte."""

import threading
import time

import numpy as np
import pytest

from repro.cluster.fleet import SweepSpec, run_sweep, sweep_json
from repro.core.predictor import TaskPredictor
from repro.ml.forest import (fit_oblivious_forest, forest_predict_grouped,
                             forest_predict_np)
from repro.ml.models import ALL_MODELS
from repro.online.broker import (BrokerPredictor, PredictionBroker,
                                 score_groups)


def _forest_data(n=400, f=10, seed=0):
    rng = np.random.RandomState(seed)
    X = rng.rand(n, f).astype(np.float32)
    y = (X[:, 0] + 0.3 * rng.rand(n) > 0.8).astype(np.float32)
    return X, y


# ---------------------------------------------------------------------------
# Numeric groundwork: scoring must not depend on how rows are batched
# ---------------------------------------------------------------------------

def test_forest_predict_np_is_batch_shape_invariant():
    X, y = _forest_data()
    params = fit_oblivious_forest(X, y, n_trees=24, depth=5, n_bins=8)
    Xq = _forest_data(seed=1)[0]
    batch = forest_predict_np(params, Xq)
    rows = np.array([forest_predict_np(params, Xq[i:i + 1])[0]
                     for i in range(Xq.shape[0])], np.float32)
    assert np.array_equal(batch, rows)          # bitwise, not approx
    mid = forest_predict_np(params, Xq[:17])
    assert np.array_equal(batch[:17], mid)


def test_forest_predict_grouped_bitwise_and_single_pass():
    X, y = _forest_data()
    pa = fit_oblivious_forest(X, y, n_trees=24, depth=5, seed=0)
    pb = fit_oblivious_forest(X, 1 - y, n_trees=24, depth=5, seed=1)
    Xq = _forest_data(seed=2)[0]
    groups = [(pa, Xq[:7]), (pb, Xq[7:40]), (pa, Xq[40:41]), (pb, Xq[41:])]
    outs, passes = forest_predict_grouped(groups)
    assert passes == 1                          # same shape -> one fused pass
    for (params, rows), out in zip(groups, outs):
        assert np.array_equal(out, forest_predict_np(params, rows))


def test_score_groups_matches_model_predict_proba_bitwise():
    # request sizes mirror the scheduler's candidate sets (<= SMALL_BATCH),
    # where predict_proba takes the numpy fast path the broker fuses over
    X, y = _forest_data()
    models = {k: ALL_MODELS["R.F."]().fit(X, y) for k in ("a", "b")}
    Xq = _forest_data(seed=3)[0]
    groups = [(models["a"], Xq[:5]), (models["b"], Xq[5:60]),
              (models["a"], Xq[60:61]), (models["a"], Xq[:0])]
    outs, passes = score_groups(groups)
    assert passes == 1
    for (model, rows), out in zip(groups, outs):
        assert np.array_equal(out, np.asarray(model.predict_proba(rows),
                                              np.float32))


# ---------------------------------------------------------------------------
# Cross-client broker: parity + >=10x fewer dispatches under concurrency
# ---------------------------------------------------------------------------

def test_cross_client_broker_parity_and_dispatch_reduction():
    X, y = _forest_data(n=600)
    model = ALL_MODELS["R.F."]().fit(X, y)
    stream = _forest_data(n=600, seed=4)[0]
    requests = [stream[i:i + 1 + (i % 3)] for i in range(0, 540, 3)]
    scalar = [np.asarray(model.predict_proba(r), np.float32)
              for r in requests]

    n_clients = 12
    broker = PredictionBroker()
    broker.add_clients(n_clients)
    outs = [None] * len(requests)

    def client(idxs):
        try:
            for qi in idxs:
                (outs[qi],) = broker.submit([(model, requests[qi])])
        finally:
            broker.done()

    threads = [threading.Thread(
        target=client, args=(range(c, len(requests), n_clients),))
        for c in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    for a, b in zip(scalar, outs):
        assert np.array_equal(a, b)
    # per-decision path: one dispatch per request; barrier rounds fuse ~12
    assert broker.n_dispatches * 10 <= len(requests)


def test_broker_survives_uneven_client_exits():
    """Clients with very different request counts must drain without deadlock
    (the barrier must release rounds as clients deregister)."""
    X, y = _forest_data()
    model = ALL_MODELS["R.F."]().fit(X, y)
    stream = _forest_data(seed=5)[0]
    counts = [1, 3, 40]
    broker = PredictionBroker()
    broker.add_clients(len(counts))
    got = []

    def client(n):
        try:
            for i in range(n):
                (out,) = broker.submit([(model, stream[i:i + 1])])
                got.append(out)
        finally:
            broker.done()

    threads = [threading.Thread(target=client, args=(n,)) for n in counts]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive(), "broker deadlocked"
    assert len(got) == sum(counts)


def test_broker_propagates_scoring_errors():
    class Broken:
        def predict_proba(self, X):
            raise RuntimeError("boom")

    broker = PredictionBroker()
    with pytest.raises(RuntimeError, match="boom"):
        broker.submit([(Broken(), np.ones((2, 4), np.float32))])


# ---------------------------------------------------------------------------
# Drop-in parity: a brokered ATLAS cell decides exactly like the scalar one
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_cell():
    from repro.cluster.experiment import run_scheduler
    from repro.cluster.fleet import CellSpec, cell_config
    spec = SweepSpec(schedulers=("fifo", "atlas-fifo"), seeds=1,
                     scenarios=("baseline",), workloads=("smoke",),
                     min_samples=40, max_train=40)
    cfg = cell_config(spec, CellSpec("atlas-fifo", "baseline", "smoke", 0))
    _, trace, _ = run_scheduler("fifo", cfg, with_trace=True)
    return cfg, trace.datasets()


def _run_atlas(cfg, datasets, predictor):
    from repro.cluster.experiment import run_scheduler
    predictor.fit_datasets(*datasets)
    metrics, _, _ = run_scheduler("atlas-fifo", cfg, predictor)
    return metrics


def test_broker_predictor_identical_decisions(smoke_cell):
    cfg, datasets = smoke_cell
    kw = dict(algo=cfg.algo, seed=cfg.seed, min_samples=cfg.min_samples,
              max_train=cfg.max_train)
    scalar = TaskPredictor(**kw)
    m_scalar = _run_atlas(cfg, datasets, scalar)
    brokered = BrokerPredictor(**kw)
    m_broker = _run_atlas(cfg, datasets, brokered)
    assert m_scalar == m_broker                 # every metric + sched stat
    assert brokered.n_demand_calls == scalar.n_dispatches
    # tick priming alone already beats per-call dispatching
    assert brokered.n_dispatches < scalar.n_dispatches
    assert brokered.n_memo_hits > 0


# ---------------------------------------------------------------------------
# Fleet acceptance: broker executor == serial executor, >=10x fewer dispatches
# ---------------------------------------------------------------------------

def test_fleet_broker_executor_matches_serial_with_10x_fewer_dispatches():
    spec = SweepSpec(schedulers=("fifo", "atlas-fifo"), seeds=12,
                     scenarios=("baseline",), workloads=("smoke",),
                     min_samples=40, max_train=40)
    brokered = run_sweep(spec, executor="broker", log=lambda *a: None)
    serial = run_sweep(spec, executor="serial", log=lambda *a: None)
    strip = lambda r: {k: v for k, v in r.items() if k != "perf"}  # noqa: E731
    assert sweep_json(strip(brokered)) == sweep_json(strip(serial))
    b = brokered["perf"]["broker"]
    assert b["demand_calls"] >= 10 * b["dispatches"]
    # deterministic accounting: same spec -> same rounds -> same counts
    again = run_sweep(spec, executor="broker", log=lambda *a: None)
    assert sweep_json(brokered) == sweep_json(again)


# ---------------------------------------------------------------------------
# Skewed waves + queue-depth flush policy (PR 5)
# ---------------------------------------------------------------------------

def test_skewed_wave_solo_bypass():
    """One long cell + N short cells: once the short clients deregister, the
    survivor's requests must NOT pay the barrier round-trip per request — the
    solo bypass scores them inline, with identical outputs and flush
    accounting."""
    X, y = _forest_data(n=500)
    model = ALL_MODELS["R.F."]().fit(X, y)
    stream = _forest_data(seed=7)[0]
    counts = [60, 1, 1, 1]                     # one long + three short cells
    broker = PredictionBroker()
    broker.add_clients(len(counts))
    outs = {}

    def client(ci, n):
        try:
            for i in range(n):
                (out,) = broker.submit([(model, stream[i:i + 1 + (i % 2)])])
                outs[(ci, i)] = out
        finally:
            broker.done()

    threads = [threading.Thread(target=client, args=(ci, n))
               for ci, n in enumerate(counts)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "broker deadlocked on the skewed wave"

    # bit-parity with scalar scoring for every request of every client
    for (ci, i), out in outs.items():
        rows = stream[i:i + 1 + (i % 2)]
        assert np.array_equal(
            out, np.asarray(model.predict_proba(rows), np.float32))
    # the long tail ran solo: most of its requests must have bypassed the
    # barrier (flush accounting still counts them as one flush each)
    assert broker.n_solo_flushes >= 40
    assert broker.n_flushes >= broker.n_solo_flushes
    assert broker.n_requests == sum(counts)


def test_queue_depth_policy_flushes_on_depth():
    """policy="depth": requests accumulate until the row threshold, then one
    fat flush serves everyone (no client registration involved)."""
    X, y = _forest_data()
    model = ALL_MODELS["R.F."]().fit(X, y)
    stream = _forest_data(seed=8)[0]
    n_clients, rows_each = 10, 3
    broker = PredictionBroker(policy="depth",
                              depth=n_clients * rows_each, max_delay=30.0)
    outs = [None] * n_clients

    def client(ci):
        (outs[ci],) = broker.submit(
            [(model, stream[ci * rows_each:(ci + 1) * rows_each])])

    threads = [threading.Thread(target=client, args=(ci,))
               for ci in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "depth policy deadlocked"
    for ci, out in enumerate(outs):
        rows = stream[ci * rows_each:(ci + 1) * rows_each]
        assert np.array_equal(
            out, np.asarray(model.predict_proba(rows), np.float32))
    # every request waited for the fat flush: one flush, one fused dispatch
    assert broker.n_flushes == 1
    assert broker.n_dispatches == 1
    assert broker.max_flush_rows == n_clients * rows_each


def test_queue_depth_policy_bounded_delay():
    """A lone sub-threshold request must not wait forever: the deadline timer
    flushes it within max_delay."""
    X, y = _forest_data()
    model = ALL_MODELS["R.F."]().fit(X, y)
    stream = _forest_data(seed=9)[0]
    broker = PredictionBroker(policy="depth", depth=10_000, max_delay=0.05)
    t0 = time.perf_counter()
    (out,) = broker.submit([(model, stream[:3])])
    waited = time.perf_counter() - t0
    assert np.array_equal(
        out, np.asarray(model.predict_proba(stream[:3]), np.float32))
    assert broker.n_deadline_flushes == 1
    assert 0.04 <= waited < 5.0


def test_broker_rejects_unknown_policy():
    with pytest.raises(ValueError, match="policy"):
        PredictionBroker(policy="vibes")


# ---------------------------------------------------------------------------
# Exact-feature memo bound (PR 7)
# ---------------------------------------------------------------------------

def test_broker_predictor_memo_cap_evicts_oldest_first():
    """A serving-mode predictor (no per-tick memo clears) must hold the memo
    at memo_cap entries, evicting oldest insertions and counting evictions;
    surviving entries keep their exact values."""
    from repro.cluster.telemetry import N_FEATURES
    from repro.online.broker import feature_hashes

    pred = BrokerPredictor(memo_cap=8, algo="R.F.", seed=0)
    X = np.arange(16 * N_FEATURES, dtype=np.float32).reshape(16, N_FEATURES)
    probs = np.linspace(0.0, 1.0, 16).astype(np.float32)
    pred._memoize("map", X[:8], probs[:8])
    assert len(pred._memo) == 8 and pred.n_memo_evictions == 0
    pred._memoize("map", X[8:], probs[8:])
    assert len(pred._memo) == 8
    assert pred.n_memo_evictions == 8
    h1, h2 = feature_hashes(X)
    for i in range(8):       # the first insertions are gone ...
        assert ("map", int(h1[i]), int(h2[i])) not in pred._memo
    for i in range(8, 16):   # ... the newest half survives, values intact
        assert pred._memo[("map", int(h1[i]), int(h2[i]))] == probs[i]


def test_default_memo_cap_never_evicts_in_fleet_ticks():
    """The default cap sits far above max_prime_rows, so deterministic fleet
    sweeps (which clear the memo every tick) can never hit eviction."""
    pred = BrokerPredictor(algo="R.F.", seed=0)
    assert pred.memo_cap > 4 * pred.max_prime_rows
