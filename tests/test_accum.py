"""Gradient-accumulation semantics: microbatched steps match the full-batch step,
and the bf16 accumulator's drift is bounded."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_reduce
from repro.models.layers import ShardCtx
from repro.models.steps import init_train_state, make_train_step
from repro.optim import AdamWConfig


def _arch(accum, opt_dtype="fp32"):
    a = smoke_reduce(get_arch("stablelm-1.6b"))
    return dataclasses.replace(a, n_layers=2, d_model=64, d_ff=128,
                               vocab_size=128, n_heads=2, n_kv_heads=2,
                               head_dim=32, accum_steps=accum,
                               opt_dtype=opt_dtype)


def _run(arch, tokens):
    opt = AdamWConfig(warmup_steps=1, total_steps=4, grad_clip=0.0)
    step, _ = make_train_step(arch, opt)
    state = init_train_state(arch, jax.random.PRNGKey(0), opt)
    state, m = jax.jit(step)(state, {"tokens": tokens})
    return state, m


def test_accum_matches_full_batch():
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 128,
                                jnp.int32)
    _, m1 = _run(_arch(1), tokens)
    _, m4 = _run(_arch(4), tokens)
    # mean loss identical; grad norm equal (mean over microbatches == full batch
    # for mean-CE losses with equal microbatch sizes)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m4["grad_norm"]),
                               rtol=5e-4)


def test_accum_bf16_drift_bounded():
    tokens = jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, 128,
                                jnp.int32)
    _, m32 = _run(_arch(4, "fp32"), tokens)
    _, m16 = _run(_arch(4, "bf16"), tokens)
    np.testing.assert_allclose(float(m16["grad_norm"]), float(m32["grad_norm"]),
                               rtol=2e-2)


def test_accum_clamped_to_shardable_microbatch():
    """accum_steps larger than batch/data_shards gets clamped, not crash."""
    arch = _arch(64)  # absurdly high accum vs batch 8
    tokens = jax.random.randint(jax.random.PRNGKey(3), (8, 32), 0, 128,
                                jnp.int32)
    opt = AdamWConfig(warmup_steps=1, total_steps=4)
    step, _ = make_train_step(arch, opt, ctx=ShardCtx(n_groups=4))
    state = init_train_state(arch, jax.random.PRNGKey(0), opt)
    state, m = jax.jit(step)(state, {"tokens": tokens})
    assert np.isfinite(float(m["loss"]))
