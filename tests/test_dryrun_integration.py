"""End-to-end dry-run integration: run one real (reduced-device) lower+compile
through repro.launch.dryrun machinery in a subprocess with a forced device count,
exactly as the production 512-dev run does."""

import json
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, jax
from repro.configs import get_arch, smoke_reduce, SHAPES
from repro.launch.specs import build_cell
from repro.launch import hlo_cost

arch = smoke_reduce(get_arch("stablelm-1.6b"))
arch = dataclasses.replace(arch, accum_steps=2)
shape = dataclasses.replace(SHAPES["train_4k"], seq_len=128, global_batch=8)
mesh = jax.make_mesh((4, 2), ("data", "model"))
with mesh:
    cell = build_cell(arch, shape, mesh)
    compiled = jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                       out_shardings=cell["out_shardings"],
                       donate_argnums=cell["donate_argnums"]) \
        .lower(*cell["args"]).compile()
    mem = compiled.memory_analysis()
    la = hlo_cost.analyze(compiled.as_text())
print(json.dumps({
    "temp": mem.temp_size_in_bytes,
    "flops": la["flops"],
    "collective_total": la["collectives"].get("total", 0),
    "unknown_loops": la["unknown_trip_loops"],
}))
"""


def test_dryrun_cell_subprocess():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["temp"] > 0
    assert rec["collective_total"] > 0        # grads all-reduce at minimum
    assert rec["unknown_loops"] == 0


def test_dryrun_artifacts_complete_if_present():
    """If the full 512-dev grid has been run, assert its integrity: 40 cells x 2
    meshes, correct skip set, zero errors."""
    d = ROOT / "experiments" / "dryrun"
    files = list(d.glob("*.json")) if d.exists() else []
    if len(files) < 80:
        pytest.skip("full dry-run grid not present")
    recs = [json.loads(f.read_text()) for f in files]
    assert len(recs) == 80
    by_status = {}
    for r in recs:
        by_status.setdefault(r["status"], []).append(r)
    assert len(by_status.get("error", [])) == 0, \
        [(r["arch"], r["shape"]) for r in by_status["error"]]
    skipped = {(r["arch"], r["shape"]) for r in by_status.get("skipped", [])}
    assert all(s == "long_500k" for _, s in skipped)
    assert len(by_status.get("ok", [])) == 64
