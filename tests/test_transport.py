"""Transport comm-layer tests: frame serialization round-trips, inproc/TCP
echo, failure semantics (oversized frames both directions, mid-message
disconnect, clean EOF), bounded-channel backpressure, and the SyncComm
blocking facade."""

import asyncio
import struct
import threading
import time

import numpy as np
import pytest

from repro.online.transport import (CommClosedError, FrameTooLargeError,
                                    SyncComm, connect, dumps, listen, loads,
                                    parse_address)

try:
    import msgpack  # noqa: F401
    HAVE_MSGPACK = True
except ImportError:                      # pragma: no cover
    HAVE_MSGPACK = False


def _run(coro):
    return asyncio.run(coro)


async def _echo(comm):
    try:
        while True:
            await comm.send(await comm.recv())
    except CommClosedError:
        pass


# ---------------------------------------------------------------------------
# Serialization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("serializer", (["msgpack"] if HAVE_MSGPACK else [])
                         + ["json"])
def test_frame_roundtrip_ndarrays_and_scalars(serializer):
    msg = {"op": "predict", "id": 3, "flag": True,
           "X": np.arange(12, dtype=np.float32).reshape(3, 4),
           "nested": {"w": [np.float32(1.5), 2, "s"],
                      "p64": np.arange(4, dtype=np.float64)}}
    fmt, payload = dumps(msg, serializer)
    out = loads(fmt, payload)
    assert out["op"] == "predict" and out["id"] == 3 and out["flag"] is True
    assert np.array_equal(out["X"], msg["X"])
    assert out["X"].dtype == np.float32          # dtype survives the wire
    assert np.array_equal(out["nested"]["p64"], msg["nested"]["p64"])
    assert out["nested"]["p64"].dtype == np.float64
    assert out["nested"]["w"][0] == 1.5 and out["nested"]["w"][2] == "s"


def test_parse_address_rejects_unknown_schemes():
    assert parse_address("inproc://x") == ("inproc", "x")
    assert parse_address("tcp://127.0.0.1:0") == ("tcp", "127.0.0.1:0")
    for bad in ("udp://x", "no-scheme", "inproc:/oops"):
        with pytest.raises(ValueError):
            parse_address(bad)


# ---------------------------------------------------------------------------
# Echo round-trips
# ---------------------------------------------------------------------------

def test_inproc_echo_is_zero_copy():
    async def go():
        lst = await listen("inproc://t-echo", _echo)
        comm = await connect("inproc://t-echo")
        X = np.random.rand(4, 3).astype(np.float32)
        await comm.send({"X": X})
        reply = await comm.recv()
        assert reply["X"] is X           # the object itself crossed, no copy
        await comm.close()
        await lst.stop()
    _run(go())


def test_inproc_connect_without_listener_raises():
    async def go():
        with pytest.raises(CommClosedError):
            await connect("inproc://never-bound")
    _run(go())


def test_tcp_echo_ndarray_lossless():
    async def go():
        lst = await listen("tcp://127.0.0.1:0", _echo)
        assert lst.address.startswith("tcp://127.0.0.1:")
        comm = await connect(lst.address)
        X = np.linspace(-1, 1, 10, dtype=np.float64).reshape(2, 5)
        await comm.send({"op": "echo", "X": X, "n": 7})
        r = await comm.recv()
        assert np.array_equal(r["X"], X) and r["X"].dtype == X.dtype
        assert r["X"] is not X           # crossed the real socket stack
        assert r["n"] == 7
        await comm.close()
        await lst.stop()
    _run(go())


# ---------------------------------------------------------------------------
# Failure semantics
# ---------------------------------------------------------------------------

def test_tcp_oversized_outgoing_frame_rejected_sender_side():
    async def go():
        lst = await listen("tcp://127.0.0.1:0", _echo)
        comm = await connect(lst.address, max_frame=1024)
        with pytest.raises(FrameTooLargeError):
            await comm.send({"X": np.zeros(100000, np.float32)})
        # the refused send wrote nothing: the comm stays usable
        await comm.send({"ok": 1})
        assert (await comm.recv())["ok"] == 1
        await comm.close()
        await lst.stop()
    _run(go())


def test_tcp_oversized_incoming_header_rejected_without_allocating():
    async def go():
        errs = []

        async def handler(comm):
            try:
                await comm.recv()
            except FrameTooLargeError as e:
                errs.append(e)

        lst = await listen("tcp://127.0.0.1:0", handler, max_frame=512)
        host, port = lst.address.split("://")[1].rsplit(":", 1)
        # a raw peer claims a 1 GiB frame: the reader must refuse on the
        # header alone instead of trying to buffer it
        _, writer = await asyncio.open_connection(host, int(port))
        writer.write(b"M" + struct.pack("!I", 1 << 30))
        await writer.drain()
        for _ in range(100):
            if errs:
                break
            await asyncio.sleep(0.01)
        assert errs and isinstance(errs[0], FrameTooLargeError)
        writer.close()
        await lst.stop()
    _run(go())


def test_tcp_mid_message_disconnect_raises_comm_closed():
    async def go():
        errs = []

        async def handler(comm):
            try:
                await comm.recv()
            except CommClosedError as e:
                errs.append(e)

        lst = await listen("tcp://127.0.0.1:0", handler)
        host, port = lst.address.split("://")[1].rsplit(":", 1)
        _, writer = await asyncio.open_connection(host, int(port))
        # promise 1000 payload bytes, deliver 10, vanish
        writer.write(b"J" + struct.pack("!I", 1000) + b"0123456789")
        await writer.drain()
        writer.close()
        for _ in range(100):
            if errs:
                break
            await asyncio.sleep(0.01)
        assert errs and isinstance(errs[0], CommClosedError)
        await lst.stop()
    _run(go())


def test_tcp_clean_peer_close_raises_comm_closed_between_frames():
    async def go():
        async def handler(comm):
            await comm.recv()
            await comm.close()

        lst = await listen("tcp://127.0.0.1:0", handler)
        comm = await connect(lst.address)
        await comm.send({"bye": 1})
        with pytest.raises(CommClosedError):
            await comm.recv()
        assert comm.closed
        await lst.stop()
    _run(go())


def test_inproc_close_wakes_parked_reader():
    async def go():
        lst = await listen("inproc://t-close", _echo)
        comm = await connect("inproc://t-close")

        async def close_soon():
            await asyncio.sleep(0.02)
            await comm.close()

        asyncio.ensure_future(close_soon())
        with pytest.raises(CommClosedError):
            await comm.recv()            # parked with nothing queued
        await lst.stop()
    _run(go())


# ---------------------------------------------------------------------------
# Backpressure
# ---------------------------------------------------------------------------

def test_inproc_backpressure_parks_fast_sender_behind_slow_consumer():
    async def go():
        drained = []

        async def slow(comm):
            try:
                while True:
                    drained.append(await comm.recv())
                    await asyncio.sleep(0.005)
            except CommClosedError:
                pass

        lst = await listen("inproc://t-bp", slow, capacity=4)
        comm = await connect("inproc://t-bp")
        t0 = time.perf_counter()
        for i in range(12):
            await comm.send({"i": i})
        dt = time.perf_counter() - t0
        # 12 sends into a capacity-4 channel drained at 5 ms/message: the
        # sender must have parked for ~8 drain intervals, not raced ahead
        assert dt > 0.02
        await comm.close()
        await lst.stop()
        assert [m["i"] for m in drained] == list(range(len(drained)))
    _run(go())


# ---------------------------------------------------------------------------
# SyncComm facade
# ---------------------------------------------------------------------------

def test_tcp_undecodable_payload_raises_comm_closed_not_decode_error():
    async def go():
        errs = []

        async def handler(comm):
            try:
                await comm.recv()
            except Exception as e:                   # noqa: BLE001 (asserting type)
                errs.append(e)

        lst = await listen("tcp://127.0.0.1:0", handler)
        host, port = lst.address.split("://")[1].rsplit(":", 1)
        _, writer = await asyncio.open_connection(host, int(port))
        # well-formed header, garbage payload: the decode failure must
        # surface as CommClosedError (the stream can no longer be trusted),
        # never as a raw json/msgpack/struct error from the codec
        writer.write(b"J" + struct.pack("!I", 4) + b"\xff\x00{[")
        await writer.drain()
        for _ in range(100):
            if errs:
                break
            await asyncio.sleep(0.01)
        assert errs and isinstance(errs[0], CommClosedError)
        writer.close()
        await lst.stop()
    _run(go())


def test_tcp_abrupt_close_mid_frame_raises_comm_closed_client_side():
    async def go():
        async def slam(comm):
            # read the request, then vanish mid-reply: header promises a
            # payload that never arrives before the transport drops
            await comm.recv()
            comm._writer.write(b"J" + struct.pack("!I", 500) + b"{\"par")
            await comm._writer.drain()
            comm._writer.close()

        lst = await listen("tcp://127.0.0.1:0", slam)
        comm = await connect(lst.address)
        await comm.send({"op": "x"})
        with pytest.raises(CommClosedError):
            await comm.recv()
        assert comm.closed
        await lst.stop()
    _run(go())


def test_sync_comm_recv_timeout_cancels_and_raises():
    import concurrent.futures

    loop = asyncio.new_event_loop()
    t = threading.Thread(target=loop.run_forever, daemon=True)
    t.start()
    try:
        async def silent(comm):
            try:
                while True:
                    await comm.recv()    # absorb, never reply
            except CommClosedError:
                pass

        lst = asyncio.run_coroutine_threadsafe(
            listen("inproc://t-sync-timeout", silent), loop).result(10)
        sc = SyncComm.connect("inproc://t-sync-timeout", loop)
        sc.send({"op": "x"})
        with pytest.raises(concurrent.futures.TimeoutError):
            sc.recv(timeout=0.1)
        sc.close()                       # timed-out comm still closes cleanly
        asyncio.run_coroutine_threadsafe(lst.stop(), loop).result(10)
    finally:
        loop.call_soon_threadsafe(loop.stop)
        t.join(5)


def test_sync_comm_blocking_roundtrip_from_foreign_thread():
    loop = asyncio.new_event_loop()
    t = threading.Thread(target=loop.run_forever, daemon=True)
    t.start()
    try:
        lst = asyncio.run_coroutine_threadsafe(
            listen("inproc://t-sync", _echo), loop).result(10)
        sc = SyncComm.connect("inproc://t-sync", loop)
        for i in range(5):
            sc.send({"i": i, "X": np.full(3, i, np.float32)})
            r = sc.recv()
            assert r["i"] == i and np.array_equal(r["X"],
                                                  np.full(3, i, np.float32))
        sc.close()
        asyncio.run_coroutine_threadsafe(lst.stop(), loop).result(10)
    finally:
        loop.call_soon_threadsafe(loop.stop)
        t.join(5)
