"""MoE dispatch unit + property tests: capacity bounds, dropless decode mode,
aux-loss behaviour, and group-count invariance."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch, smoke_reduce
from repro.configs.base import MoEConfig
from repro.models import layers as L


def _cfg(capacity_factor=1.0, top_k=2, n_experts=8, d_model=32, expert_ff=16,
         n_shared=0):
    base = smoke_reduce(get_arch("deepseek-moe-16b"))
    return dataclasses.replace(
        base, d_model=d_model,
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, expert_ff=expert_ff,
                      n_shared_experts=n_shared, capacity_factor=capacity_factor,
                      first_dense=0))


def _params(cfg, key=0):
    from repro.parallel.axes import init_params
    return init_params(L.moe_defs(cfg), jax.random.PRNGKey(key), jnp.float32)


def test_moe_output_shape_and_finite():
    cfg = _cfg()
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = L.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.0


def test_moe_dropless_is_permutation_invariant():
    """Dropless mode: shuffling tokens within the (single) group must produce the
    same per-token outputs (no capacity interaction)."""
    cfg = _cfg()
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, cfg.d_model))
    y, _ = L.moe_apply(p, x, cfg, dropless=True)
    perm = np.random.RandomState(0).permutation(32)
    y2, _ = L.moe_apply(p, x[:, perm], cfg, dropless=True)
    np.testing.assert_allclose(np.asarray(y)[:, perm], np.asarray(y2),
                               rtol=2e-5, atol=2e-6)


def test_moe_tight_capacity_drops_tokens():
    """At capacity_factor ~ k/E * tiny, most tokens must drop -> output is mostly
    the shared/zero path; with generous capacity nothing drops."""
    cfg_tight = _cfg(capacity_factor=0.126)   # C = ~1 slot per expert
    cfg_loose = _cfg(capacity_factor=8.0)
    p = _params(cfg_tight)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, cfg_tight.d_model))
    y_tight, _ = L.moe_apply(p, x, cfg_tight)
    y_loose, _ = L.moe_apply(p, x, cfg_loose)
    norm_tight = float(jnp.linalg.norm(y_tight))
    norm_loose = float(jnp.linalg.norm(y_loose))
    assert norm_tight < norm_loose  # dropped tokens contribute nothing


def test_moe_shared_expert_always_active():
    cfg = _cfg(capacity_factor=0.01, n_shared=1)  # drop nearly everything routed
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 32, cfg.d_model))
    y, _ = L.moe_apply(p, x, cfg)
    assert float(jnp.linalg.norm(y)) > 0.0  # shared path still flows


def test_moe_group_split_changes_only_capacity_locality():
    """n_groups=2 vs 1 with dropless: identical results (groups are independent
    and dropless removes capacity coupling)."""
    cfg = _cfg()
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, cfg.d_model))
    from repro.models.layers import ShardCtx
    y1, _ = L.moe_apply(p, x, cfg, ShardCtx(n_groups=1), dropless=True)
    y2, _ = L.moe_apply(p, x, cfg, ShardCtx(n_groups=2), dropless=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-5,
                               atol=2e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), topk=st.integers(1, 4),
       cf=st.floats(0.25, 8.0))
def test_property_moe_aux_loss_bounded_and_output_finite(seed, topk, cf):
    cfg = _cfg(capacity_factor=cf, top_k=topk)
    p = _params(cfg, key=seed)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 24, cfg.d_model))
    y, aux = L.moe_apply(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    # Switch aux loss with uniform routing ~= router_aux_weight; allow headroom
    assert 0.0 <= float(aux) < cfg.moe.router_aux_weight * cfg.moe.n_experts
