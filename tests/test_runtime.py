"""Runtime substrate tests: checkpoint/restore, gradient compression, data
pipeline determinism, and the ATLAS elastic trainer."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch, smoke_reduce
from repro.data import DataConfig, SyntheticStream
from repro.optim.compression import BLOCK, compress, compressed_psum, decompress
from repro.runtime import ElasticTrainer, RuntimeConfig


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (16, 8)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    t = _tree()
    mgr.save(7, t)
    got = mgr.restore(7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    t = _tree()
    mgr.save(1, t)
    # corrupt the shard
    shard = next((tmp_path / "step_000000001").glob("*.npz"))
    data = dict(np.load(shard))
    data["leaf_0"] = data["leaf_0"] + 1.0
    np.savez(shard, **data)
    with pytest.raises(IOError, match="digest"):
        mgr.restore(1, t)


def test_checkpoint_async_write(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=True)
    t = _tree()
    mgr.save(3, t)
    mgr.wait()
    assert mgr.latest_step() == 3


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compress_roundtrip_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q, scale, resid = compress(g)
    deq = decompress(q, scale, g.shape)
    # error bounded by scale/2 per element
    err = np.abs(np.asarray(deq) - np.asarray(g))
    per_block_scale = np.repeat(np.asarray(scale, np.float32),
                                BLOCK)[: g.size]
    assert (err <= per_block_scale * 0.5 + 1e-6).all()
    np.testing.assert_allclose(np.asarray(resid), np.asarray(g) - np.asarray(deq),
                               rtol=1e-5, atol=1e-6)


def test_error_feedback_mean_converges():
    """With error feedback, the time-average of dequantised gradients converges to
    the true mean gradient (the residual doesn't accumulate)."""
    rs = np.random.RandomState(0)
    g_true = jnp.asarray(rs.randn(512).astype(np.float32))
    resid = jnp.zeros_like(g_true)
    total = jnp.zeros_like(g_true)
    T = 50
    for _ in range(T):
        q, scale, resid = compress(g_true + resid)
        total = total + decompress(q, scale, g_true.shape)
    np.testing.assert_allclose(np.asarray(total / T), np.asarray(g_true),
                               rtol=0.05, atol=0.02)


def test_compressed_psum_single_device():
    g = jnp.ones((300,)) * 0.5
    mesh = jax.make_mesh((1,), ("x",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    out, resid = shard_map(
        lambda g: compressed_psum(g, "x"), mesh=mesh,
        in_specs=(P(),), out_specs=(P(), P()))(g)
    np.testing.assert_allclose(np.asarray(out), 0.5, rtol=1e-2)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_stream_deterministic_and_resharding_consistent():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=8, seed=3)
    s = SyntheticStream(cfg)
    b1 = s.batch(5, 0, 2)
    b2 = s.batch(5, 0, 2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = s.batch(5, 1, 2)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 32)
    full = s.batch(5, 0, 1)
    assert full["tokens"].shape == (8, 32)


def test_stream_tokens_in_vocab():
    cfg = DataConfig(vocab_size=64, seq_len=16, global_batch=4, seed=0)
    b = SyntheticStream(cfg).batch(0, 0, 1)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 64


# ---------------------------------------------------------------------------
# elastic trainer
# ---------------------------------------------------------------------------

def _tiny_arch():
    import jax.numpy as jnp
    arch = smoke_reduce(get_arch("stablelm-1.6b"))
    return dataclasses.replace(arch, n_layers=2, d_model=64, d_ff=128,
                               vocab_size=256, n_heads=2, n_kv_heads=2,
                               head_dim=32)


def test_elastic_trainer_no_chaos_trains(tmp_path):
    arch = _tiny_arch()
    rcfg = RuntimeConfig(n_hosts=4, steps=12, fail_rate=0.0, degrade_rate=0.0,
                         checkpoint_every=5, seed=0)
    out = ElasticTrainer(arch, rcfg, tmp_path / "ck",
                         data_cfg=DataConfig(vocab_size=arch.vocab_size,
                                             seq_len=32, global_batch=8)).run()
    assert out["committed"] == 12
    assert out["rollbacks"] == 0
    assert out["final_loss"] < out["first_loss"]  # it actually learns


def test_elastic_trainer_survives_chaos(tmp_path):
    arch = _tiny_arch()
    rcfg = RuntimeConfig(n_hosts=4, steps=15, fail_rate=0.06, degrade_rate=0.15,
                         checkpoint_every=3, seed=1)
    out = ElasticTrainer(arch, rcfg, tmp_path / "ck",
                         data_cfg=DataConfig(vocab_size=arch.vocab_size,
                                             seq_len=32, global_batch=8)).run()
    # reaches the target step count despite failures (via rollbacks)
    assert out["committed"] >= 15
    assert np.isfinite(out["final_loss"])


def test_atlas_reduces_lost_steps_vs_baseline(tmp_path):
    """The headline property transported to training: ATLAS placement +
    speculative duplication loses fewer steps under the same chaos seed."""
    arch = _tiny_arch()
    dc = DataConfig(vocab_size=arch.vocab_size, seq_len=32, global_batch=8)
    results = {}
    for atlas in (False, True):
        rcfg = RuntimeConfig(n_hosts=4, steps=20, fail_rate=0.05,
                             degrade_rate=0.2, checkpoint_every=4,
                             atlas=atlas, seed=7)
        out = ElasticTrainer(arch, rcfg, tmp_path / f"ck_{atlas}",
                             data_cfg=dc).run()
        results[atlas] = out
    assert results[True]["lost_steps"] <= results[False]["lost_steps"] + 1
