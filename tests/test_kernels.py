"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle in ref.py,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.forest import forest_infer
from repro.kernels.mamba2_ssd import mamba2_ssd
from repro.kernels.rwkv6_scan import rwkv6_scan


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,D,qb,kb", [
    (1, 128, 4, 4, 64, 64, 64),      # MHA
    (2, 256, 8, 2, 64, 128, 64),     # GQA 4:1
    (1, 512, 4, 1, 128, 128, 256),   # MQA, head_dim 128
    (2, 128, 6, 2, 32, 32, 64),      # odd head count
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
def test_flash_attention(dtype, B, S, H, Hkv, D, qb, kb, causal, window):
    key = jax.random.PRNGKey(42)
    q = jax.random.normal(key, (B, S, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D), dtype)
    want = ref.attention_naive(q, k, v, causal=causal, window=window)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=qb, kv_block=kb, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_ref_matches_naive(dtype):
    """The chunked XLA path (used by models + dry-run) against the naive oracle."""
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (2, 256, 8, 64), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 256, 4, 64), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 256, 4, 64), dtype)
    want = ref.attention_naive(q, k, v, causal=True)
    got = ref.flash_attention_ref(q, k, v, causal=True, q_chunk=64, kv_chunk=128)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,D,Smax,kb", [
    (2, 4, 4, 64, 512, 128),
    (3, 8, 2, 64, 1024, 256),
    (1, 8, 1, 128, 2048, 512),
])
def test_decode_attention(dtype, B, H, Hkv, D, Smax, kb):
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, 1, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Smax, Hkv, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Smax, Hkv, D), dtype)
    kv_len = jnp.asarray(
        np.random.RandomState(0).randint(1, Smax + 1, (B,)), jnp.int32)
    want = ref.decode_attention_ref(q, k, v, kv_len)
    got = decode_attention(q, k, v, kv_len, kv_block=kb, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_window():
    key = jax.random.PRNGKey(4)
    B, H, Hkv, D, Smax = 2, 4, 2, 64, 1024
    q = jax.random.normal(key, (B, 1, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Smax, Hkv, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Smax, Hkv, D), jnp.float32)
    kv_len = jnp.array([1024, 700], jnp.int32)
    want = ref.decode_attention_ref(q, k, v, kv_len, window=256)
    got = decode_attention(q, k, v, kv_len, window=256, kv_block=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Dh,chunk", [
    (1, 64, 2, 16, 16),
    (2, 128, 4, 64, 64),
    (1, 256, 8, 32, 128),
])
def test_rwkv6_scan(dtype, B, S, H, Dh, chunk):
    key = jax.random.PRNGKey(5)
    r = jax.random.normal(key, (B, S, H, Dh), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, Dh), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, Dh), dtype)
    w = jax.nn.sigmoid(jax.random.normal(
        jax.random.fold_in(key, 3), (B, S, H, Dh), jnp.float32) * 2).astype(dtype)
    u = (jax.random.normal(jax.random.fold_in(key, 4), (H, Dh), jnp.float32)
         * 0.3).astype(dtype)
    s0 = jax.random.normal(jax.random.fold_in(key, 5), (B, H, Dh, Dh), jnp.float32)
    want_y, want_s = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    got_y, got_s = rwkv6_scan(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(got_y, np.float32),
                               np.asarray(want_y, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=1e-3, atol=1e-3)


def test_rwkv6_scan_chunk_boundary_consistency():
    """Chunk size must not change results (state carry across chunks is exact)."""
    key = jax.random.PRNGKey(6)
    B, S, H, Dh = 1, 128, 2, 32
    mk = lambda i: jax.random.normal(jax.random.fold_in(key, i), (B, S, H, Dh),
                                     jnp.float32)
    r, k, v = mk(0), mk(1), mk(2)
    w = jax.nn.sigmoid(mk(3))
    u = jax.random.normal(jax.random.fold_in(key, 4), (H, Dh)) * 0.1
    s0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)
    y32, s32 = rwkv6_scan(r, k, v, w, u, s0, chunk=32, interpret=True)
    y128, s128 = rwkv6_scan(r, k, v, w, u, s0, chunk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y128), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s32), np.asarray(s128), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 16, 16),
    (2, 128, 4, 64, 64, 64),
    (1, 256, 8, 32, 16, 128),
])
def test_mamba2_ssd(dtype, B, S, H, P, N, chunk):
    key = jax.random.PRNGKey(8)
    x = jax.random.normal(key, (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(
        jax.random.fold_in(key, 1), (B, S, H), jnp.float32)).astype(dtype)
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.5)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N), dtype)
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, N), dtype)
    s0 = jax.random.normal(jax.random.fold_in(key, 5), (B, H, P, N), jnp.float32)
    want_y, want_s = ref.mamba2_ssd_ref(x, dt, A, Bm, Cm, s0)
    got_y, got_s = mamba2_ssd(x, dt, A.astype(jnp.float32), Bm, Cm, s0,
                              chunk=chunk, interpret=True)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(got_y, np.float32),
                               np.asarray(want_y, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("B,F,T,D,bb", [
    (32, 16, 8, 4, 16),
    (100, 32, 64, 6, 32),    # non-divisible batch -> padding path
    (256, 24, 128, 6, 128),
])
def test_forest_infer(B, F, T, D, bb):
    rs = np.random.RandomState(1)
    x = jnp.asarray(rs.randn(B, F), jnp.float32)
    feat_idx = jnp.asarray(rs.randint(0, F, (T, D)), jnp.int32)
    thr = jnp.asarray(rs.randn(T, D), jnp.float32)
    leaves = jnp.asarray(rs.randn(T, 2 ** D), jnp.float32)
    want = ref.forest_infer_ref(x, feat_idx, thr, leaves)
    got = forest_infer(x, feat_idx, thr, leaves, block_b=bb, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_forest_infer_vs_sklearn_style_traversal():
    """Independent python traversal (no jnp) as a second oracle."""
    rs = np.random.RandomState(2)
    B, F, T, D = 17, 8, 5, 3
    x = rs.randn(B, F).astype(np.float32)
    feat_idx = rs.randint(0, F, (T, D))
    thr = rs.randn(T, D).astype(np.float32)
    leaves = rs.randn(T, 2 ** D).astype(np.float32)
    want = np.zeros(B)
    for b in range(B):
        for t in range(T):
            leaf = 0
            for d in range(D):
                leaf = (leaf << 1) | int(x[b, feat_idx[t, d]] > thr[t, d])
            want[b] += leaves[t, leaf]
    want /= T
    got = forest_infer(jnp.asarray(x), jnp.asarray(feat_idx, jnp.int32),
                       jnp.asarray(thr), jnp.asarray(leaves), interpret=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


def test_forest_predict_np_matches_kernel_reference():
    """The numpy small-batch mirror (the scheduler's per-decision inference
    path) must agree with the XLA/ref kernel path — including tree_slice —
    for batches on both sides of the SMALL_BATCH routing threshold."""
    from repro.ml.forest import (ForestParams, SMALL_BATCH, forest_predict,
                                 forest_predict_np)
    rs = np.random.RandomState(3)
    F, T, D = 22, 24, 5
    params = ForestParams(
        feat_idx=rs.randint(0, F, (T, D)).astype(np.int32),
        thresholds=rs.randn(T, D).astype(np.float32),
        leaves=rs.rand(T, 2 ** D).astype(np.float32))
    for B in (1, 13, SMALL_BATCH, SMALL_BATCH + 1, 200):
        x = rs.randn(B, F).astype(np.float32)
        want = np.asarray(ref.forest_infer_ref(
            jnp.asarray(x), jnp.asarray(params.feat_idx),
            jnp.asarray(params.thresholds), jnp.asarray(params.leaves)))
        got_np = forest_predict_np(params, x)
        got_routed = forest_predict(params, x)          # auto small/large path
        np.testing.assert_allclose(got_np, want, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(got_routed, want, rtol=1e-5, atol=1e-6)
    # tree_slice parity on a sub-forest
    x = rs.randn(9, F).astype(np.float32)
    sl = slice(4, 16)
    want = np.asarray(ref.forest_infer_ref(
        jnp.asarray(x), jnp.asarray(params.feat_idx[sl]),
        jnp.asarray(params.thresholds[sl]), jnp.asarray(params.leaves[sl])))
    got = forest_predict_np(params, x, tree_slice=sl)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
