"""Live telemetry wire tests: TelemetryCollector fold/delta semantics, the
SimObserver → TransportSink → AsyncBroker → TelemetryCollector path over
both inproc:// and tcp://, slow-collector backpressure, mid-stream
disconnect/reconnect, TransportSink lifecycle, read_ndjson partial-tail
tolerance, and the HTTP /snapshot + /delta + /view endpoints."""

import json
import time
import urllib.error
import urllib.request

import pytest

from repro.cluster.chaos import ChaosConfig
from repro.cluster.experiment import ExperimentConfig, run_scheduler
from repro.cluster.workload import WorkloadConfig
from repro.obs import (LiveServer, SimObserver, TelemetryCollector,
                       TransportSink, read_ndjson)
from repro.online.server import AsyncBroker


def _sim_frame(i, t, occ=0.5, fails=(0, 0, 0, 0)):
    return {"type": "frame", "i": i, "t": t, "occ": occ, "running": 2,
            "pending": 1, "penalty_box": 0, "running_jobs": 1, "alive": 4,
            "hb_stale_max": 0.5, "node_occ": [occ] * 4,
            "node_fail": list(fails)}


def _wait(pred, timeout=10.0):
    deadline = time.time() + timeout
    while not pred():
        if time.time() > deadline:
            raise AssertionError("timed out waiting for condition")
        time.sleep(0.01)


# ---------------------------------------------------------------------------
# Collector fold + delta semantics
# ---------------------------------------------------------------------------

def test_collector_folds_sim_and_broker_streams():
    c = TelemetryCollector()
    c.ingest({"type": "meta", "t": 0.0, "frame_every": 60.0, "n_nodes": 4,
              "scheduler": "fifo"}, source="cell")
    for i in range(4):
        c.ingest(_sim_frame(i, 60.0 * (i + 1), occ=0.4 + 0.1 * i,
                            fails=(1, 0, 0, 0)), source="cell")
    c.ingest({"type": "flush", "i": 0, "rows": 48, "requests": 6,
              "dispatches": 2, "latency_ms": 1.5}, source="cell")
    c.ingest({"type": "final", "t": 300.0, "summary": {}}, source="cell")
    agg = c.aggregates()["cell"]
    assert agg["frames"] == 7 and agg["done"]    # meta+4 sim+flush+final
    assert agg["meta"]["scheduler"] == "fifo"
    sim = agg["sim"]
    assert sim["frames"] == 4 and sim["failures"] == 4
    assert sim["occupancy"]["last"] == pytest.approx(0.7)
    assert sim["occupancy"]["min"] == pytest.approx(0.4)
    # windowed failure rate: 4 failures over ring span 60..240 = 3/180
    assert sim["failure_rate_w"] == pytest.approx(3 / 180, abs=1e-6)
    broker = agg["broker"]
    assert broker["flushes"] == 1 and broker["rows"] == 48
    assert broker["flush_rows_p50"] == 64.0       # upper-edge bucket
    assert broker["queue_depth_p50"] == 8.0


def test_collector_delta_is_gapless_and_chains():
    c = TelemetryCollector()
    for i in range(20):
        c.ingest({"type": "meta"}, source=f"s{i % 3}")
    seen, since = [], 0
    while True:
        r = c.delta(since)
        assert "resync" not in r
        if not r["frames"]:
            break
        seen.extend(e["seq"] for e in r["frames"])
        since = r["frames"][-1]["seq"]
    assert seen == list(range(1, 21))
    assert c.delta(20) == {"seq": 20, "frames": []}


def test_collector_delta_resync_after_eviction():
    c = TelemetryCollector(delta_capacity=4)
    for _ in range(10):
        c.ingest({"type": "meta"}, source="s")
    r = c.delta(2)                      # oldest retained seq is 7
    assert r["resync"] is True and r["dropped"] == 4
    assert [e["seq"] for e in r["frames"]] == [7, 8, 9, 10]
    assert c.health()["delta_log_evicted"] == 6


def test_collector_replay_reproduces_aggregates():
    c = TelemetryCollector()
    c.ingest({"type": "meta", "scheduler": "fifo"}, source="a", n=1)
    for i in range(6):
        c.ingest(_sim_frame(i, 60.0 * i, fails=(i % 2, 0, 0, 0)),
                 source="a" if i % 2 else "b", n=i + 2)
    replay = TelemetryCollector()
    for e in c.delta(0)["frames"]:
        replay.ingest(e["frame"], source=e["source"])
    assert replay.aggregates() == c.aggregates()


def test_collector_wire_gap_and_reconnect_accounting():
    c = TelemetryCollector()
    c.ingest({"type": "meta"}, source="s", n=1)
    c.ingest({"type": "meta"}, source="s", n=2)
    c.ingest({"type": "meta"}, source="s", n=6)      # 3,4,5 lost
    c.ingest({"type": "meta"}, source="s", n=1)      # producer restarted
    h = c.health()["sources"]["s"]
    assert h["wire_gaps"] == 3
    assert h["reconnects"] == 1
    # wire accounting is health-side only: aggregates ignore n entirely
    c2 = TelemetryCollector()
    for _ in range(4):
        c2.ingest({"type": "meta"}, source="s")
    assert c2.aggregates() == c.aggregates()


# ---------------------------------------------------------------------------
# E2E wire path: SimObserver -> TransportSink -> AsyncBroker -> collector
# ---------------------------------------------------------------------------

class _Node:
    def __init__(self):
        self.spec = type("S", (), {"map_slots": 2, "reduce_slots": 2,
                                   "name": "n"})()
        self.running_maps = 1
        self.running_reduces = 0
        self.last_heartbeat = 0.0
        self.failed_count = 0


class _Sim:
    def __init__(self):
        self.nodes = [_Node()]
        self.pending = ()
        self.n_running_jobs = 0
        self.heartbeat_interval = 600.0
        self._known_alive = {0}
        self.scheduler = type("Sch", (), {
            "name": "fifo",
            "frame_stats": lambda self: {"penalty_box": 0, "pred": None},
        })()
        self.now = 0.0


def test_e2e_inproc_simobserver_to_collector():
    with AsyncBroker() as srv:
        coll = TelemetryCollector()
        srv.collector = coll
        addr = srv.serve()
        # inproc channels are loop-local: the sink must use the broker loop
        sink = TransportSink(addr, loop=srv.loop, source="cellA")
        obs = SimObserver(sink=sink, frame_every=10.0,
                          min_events_per_frame=1)
        sim = _Sim()
        obs.bind(sim)
        for t in (1.0, 12.0, 23.0, 34.0, 45.0):
            sim.now = t
            obs.after_event(sim, 0)
        obs.finish(sim)                  # final frame + closes the sink
        n_sent = sink.n_frames
        assert n_sent >= 3               # meta + frames + final
        _wait(lambda: coll.seq >= n_sent)
        agg = coll.aggregates()["cellA"]
        assert agg["done"] and agg["sim"]["frames"] >= 1
        assert agg["meta"]["scheduler"] == "fifo"
        st = srv.telemetry_stats()["sources"]["cellA"]
        assert st["frames"] == n_sent and st["gaps"] == 0


def test_batched_wire_form_preserves_per_frame_accounting():
    # flush_every > 1 ships {"frames": [{"frame",  "n"}, ...]} messages;
    # the server must unbatch with per-frame seq/gap accounting intact
    with AsyncBroker() as srv:
        coll = TelemetryCollector()
        srv.collector = coll
        addr = srv.serve()
        sink = TransportSink(addr, loop=srv.loop, source="cellB",
                             flush_every=4)
        for i in range(6):
            sink.emit(_sim_frame(i, 10.0 * (i + 1)))
        assert sink.n_frames == 6        # 4 sent + 2 still buffered
        _wait(lambda: coll.seq >= 4)
        sink.close()                     # flushes the 2-frame tail
        _wait(lambda: coll.seq >= 6)
        st = srv.telemetry_stats()["sources"]["cellB"]
        assert st["frames"] == 6
        assert st["gaps"] == 0 and st["reconnects"] == 0
        assert st["last_n"] == 6
        assert coll.aggregates()["cellB"]["sim"]["frames"] == 6


def test_e2e_tcp_run_scheduler_obs_live_does_not_perturb():
    cfg = ExperimentConfig(
        workload=WorkloadConfig(n_single=10, n_chains=2, seed=5),
        chaos=ChaosConfig(intensity=2.0, seed=6),
        seed=3, min_samples=32, max_train=256, obs_frame_every=120.0)
    plain, _, _ = run_scheduler("fifo", cfg)
    with AsyncBroker() as srv:
        coll = TelemetryCollector()
        srv.collector = coll
        addr = srv.serve("tcp://127.0.0.1:0")
        import dataclasses
        live_cfg = dataclasses.replace(cfg, obs_live_addr=addr,
                                       obs_source="fifo/s3")
        live, _, _ = run_scheduler("fifo", live_cfg)
        n_emitted = live["obs"]["frames"] + 2      # + meta + final
        _wait(lambda: coll.seq >= n_emitted)
    stripped = {k: v for k, v in live.items() if k != "obs"}
    assert stripped == plain, "live telemetry changed simulation results"
    agg = coll.aggregates()["fifo/s3"]
    assert agg["done"]
    assert agg["sim"]["frames"] == live["obs"]["frames"]
    assert srv.telemetry_stats()["sources"]["fifo/s3"]["gaps"] == 0


def test_e2e_slow_collector_applies_backpressure_without_loss():
    class _Slow(TelemetryCollector):
        def ingest(self, frame, **kw):
            time.sleep(0.002)
            return super().ingest(frame, **kw)

    with AsyncBroker() as srv:
        coll = _Slow()
        srv.collector = coll
        # tiny channel: emit must block on the full channel, not drop
        addr = srv.serve(capacity=2)
        sink = TransportSink(addr, loop=srv.loop, source="s")
        frames = [_sim_frame(i, 60.0 * i) for i in range(40)]
        for f in frames:
            sink.emit(f)
        sink.close()
        _wait(lambda: coll.seq >= 40)
    assert coll.seq == 40
    assert [e["frame"] for e in coll.delta(0)["frames"]] == frames
    h = coll.health()["sources"]["s"]
    assert h["wire_gaps"] == 0 and h["reconnects"] == 0


def test_e2e_mid_stream_disconnect_reconnect():
    with AsyncBroker() as srv:
        coll = TelemetryCollector()
        srv.collector = coll
        addr = srv.serve("tcp://127.0.0.1:0")
        first = TransportSink(addr, source="cell")
        for i in range(5):
            first.emit(_sim_frame(i, 60.0 * i))
        first.close()                    # mid-stream disconnect
        second = TransportSink(addr, source="cell")   # fresh counter
        for i in range(3):
            second.emit(_sim_frame(5 + i, 60.0 * (5 + i)))
        second.close()
        _wait(lambda: coll.seq >= 8)
    assert coll.aggregates()["cell"]["sim"]["frames"] == 8
    h = coll.health()["sources"]["cell"]
    assert h["reconnects"] == 1 and h["last_n"] == 3
    assert srv.telemetry_stats()["sources"]["cell"]["reconnects"] == 1


# ---------------------------------------------------------------------------
# Consumer crash/restart (PR 10): producers reconnect, pollers resync
# ---------------------------------------------------------------------------

def test_collector_delta_resync_on_consumer_restart_seq_regression():
    # a dashboard that was polling seq 5 keeps polling after the collector
    # process restarts (fresh seq counter): since > seq must answer with a
    # full-resync form, not an empty delta that wedges the poller forever
    stale_cursor = 5
    fresh = TelemetryCollector()
    fresh.ingest({"type": "meta"}, source="s")
    r = fresh.delta(stale_cursor)
    assert r["resync"] is True and r["dropped"] == 0
    assert [e["seq"] for e in r["frames"]] == [1]
    # in-range cursors keep the plain gapless form
    assert "resync" not in fresh.delta(1)


def test_consumer_restart_producers_reconnect_gaplessly():
    coll = TelemetryCollector()
    srv = AsyncBroker().start()
    srv.collector = coll
    addr = srv.serve("tcp://127.0.0.1:0")
    sink = TransportSink(addr, source="cell", backoff_base_s=0.01,
                         backoff_cap_s=0.05)
    try:
        for i in range(3):
            sink.emit(_sim_frame(i, 60.0 * i))
        _wait(lambda: coll.seq >= 3)
        srv.stop()                       # the consumer dies mid-run

        # emits during the outage mark the comm down and buffer — the
        # producer (the simulation) must never see the failure
        for i in range(3, 6):
            sink.emit(_sim_frame(i, 60.0 * i))
        assert sink.n_send_errors >= 1
        assert sink._comm is None

        srv2 = AsyncBroker().start()
        srv2.resume_collector(coll)      # seed wire accounting, not zeros
        srv2.serve(addr)                 # rebind the same concrete port
        try:
            deadline = time.time() + 10.0
            i = 6
            while coll.seq < 7:          # outage frames + at least one more
                assert time.time() < deadline, "sink never reconnected"
                sink.emit(_sim_frame(i, 60.0 * i))
                i += 1
                time.sleep(0.02)
            sink.close()
            _wait(lambda: coll.aggregates()["cell"]["sim"]["frames"] >= 7)
        finally:
            srv2.stop()
    finally:
        sink.close()
        srv.stop()
    assert sink.n_reconnects == 1 and sink.n_dropped == 0
    # per-frame n survived the outage contiguously and resume_collector
    # seeded the broker's accounting, so the wire shows NO gap and no
    # spurious restart
    h = coll.health()["sources"]["cell"]
    assert h["wire_gaps"] == 0 and h["reconnects"] == 0


def test_live_server_handler_timeout_closes_stalled_connection():
    import socket

    c = TelemetryCollector()
    with LiveServer(c, handler_timeout=0.3) as http:
        host, port = http.address[len("http://"):].rsplit(":", 1)
        s = socket.create_connection((host, int(port)))
        try:
            # stall mid-request-line: without the socket timeout this
            # parks a handler thread (and the connection) forever
            s.sendall(b"GET /snapshot HTTP/1.1\r\nHost: x")
            s.settimeout(10.0)
            t0 = time.time()
            data = s.recv(65536)
            assert data == b"", "server should close the stalled connection"
            assert time.time() - t0 < 8.0
        finally:
            s.close()
        # the server itself is still healthy
        status, _ = _get(http.address + "/snapshot")
        assert status == 200


# ---------------------------------------------------------------------------
# TransportSink lifecycle (satellite: close joins its own loop thread)
# ---------------------------------------------------------------------------

def test_transport_sink_close_joins_private_loop_thread():
    with AsyncBroker() as srv:
        addr = srv.serve("tcp://127.0.0.1:0")
        sink = TransportSink(addr, source="x")
        thread = sink._thread
        assert thread is not None and thread.is_alive()
        sink.emit({"type": "meta"})
        sink.close()
        assert not thread.is_alive(), "private loop thread not joined"
        assert sink._loop.is_closed()
        sink.close()                     # idempotent


def test_transport_sink_emit_after_close_raises_clearly():
    with AsyncBroker() as srv:
        addr = srv.serve("tcp://127.0.0.1:0")
        sink = TransportSink(addr, source="x")
        sink.close()
        with pytest.raises(RuntimeError, match="closed"):
            sink.emit({"type": "meta"})


def test_transport_sink_without_source_keeps_bare_wire_format():
    """Back-compat: no source => the two-key message, no per-source row."""
    with AsyncBroker() as srv:
        coll = TelemetryCollector()
        srv.collector = coll
        addr = srv.serve()
        sink = TransportSink(addr, loop=srv.loop)
        sink.emit({"type": "meta"})
        sink.close()
        _wait(lambda: coll.seq >= 1)
    assert coll.source_names() == ["default"]
    assert srv.telemetry_stats()["sources"]["default"]["last_n"] == 0


# ---------------------------------------------------------------------------
# read_ndjson partial-tail tolerance (satellite)
# ---------------------------------------------------------------------------

def test_read_ndjson_tolerates_truncated_tail(tmp_path):
    p = tmp_path / "frames.ndjson"
    frames = [{"i": 0}, {"i": 1}, {"i": 2}]
    lines = [json.dumps(f) for f in frames]
    p.write_text("\n".join(lines) + '\n{"i": 3, "tru')   # racing a flush
    assert read_ndjson(p) == frames
    got, n_partial = read_ndjson(p, return_partial=True)
    assert got == frames and n_partial == 1


def test_read_ndjson_complete_file_has_no_partial(tmp_path):
    p = tmp_path / "frames.ndjson"
    p.write_text('{"i": 0}\n{"i": 1}\n')
    got, n_partial = read_ndjson(p, return_partial=True)
    assert got == [{"i": 0}, {"i": 1}] and n_partial == 0
    assert read_ndjson(tmp_path / "missing.ndjson",
                       return_partial=True) == ([], 0)


def test_read_ndjson_mid_file_corruption_still_raises(tmp_path):
    p = tmp_path / "frames.ndjson"
    p.write_text('{"i": 0}\n{"i": 1, "tru\n{"i": 2}\n')
    with pytest.raises(json.JSONDecodeError):
        read_ndjson(p)


# ---------------------------------------------------------------------------
# HTTP endpoints
# ---------------------------------------------------------------------------

@pytest.fixture()
def live_http():
    c = TelemetryCollector()
    c.ingest({"type": "meta", "t": 0.0, "frame_every": 60.0, "n_nodes": 4,
              "scheduler": "fifo"}, source="cell", n=1)
    for i in range(3):
        c.ingest(_sim_frame(i, 60.0 * (i + 1)), source="cell", n=i + 2)
    c.ingest({"type": "flush", "i": 0, "rows": 16, "requests": 4,
              "dispatches": 1, "latency_ms": 0.9}, source="bench", n=1)
    with LiveServer(c, refresh=1.0) as http:
        yield c, http


def _get(url):
    with urllib.request.urlopen(url, timeout=10) as r:
        return r.status, r.read().decode()


def test_http_snapshot_and_delta(live_http):
    c, http = live_http
    status, body = _get(http.address + "/snapshot")
    snap = json.loads(body)
    assert status == 200 and snap["seq"] == c.seq
    assert snap["aggregates"]["cell"]["sim"]["frames"] == 3
    status, body = _get(http.address + "/delta?since=2")
    delta = json.loads(body)
    assert [e["seq"] for e in delta["frames"]] == [3, 4, 5]
    # bad since is a 400, unknown path a 404
    with pytest.raises(urllib.error.HTTPError) as e:
        _get(http.address + "/delta?since=nope")
    assert e.value.code == 400
    with pytest.raises(urllib.error.HTTPError) as e:
        _get(http.address + "/nope")
    assert e.value.code == 404


def test_http_views_render_incrementally(live_http):
    c, http = live_http
    _, index = _get(http.address + "/")
    assert "cell" in index and "bench" in index
    _, view = _get(http.address + "/view?source=cell")
    assert 'http-equiv="refresh"' in view       # self-refreshing
    assert "Fleet occupancy" in view
    # new frames show up on the next render without any file reads
    c.ingest(_sim_frame(3, 240.0, occ=0.9), source="cell", n=5)
    _, view2 = _get(http.address + "/view?source=cell")
    assert view2 != view
    # broker-only sources render the flush cards
    _, bview = _get(http.address + "/view?source=bench")
    assert "Broker" in bview
    with pytest.raises(urllib.error.HTTPError) as e:
        _get(http.address + "/view?source=ghost")
    assert e.value.code == 404


def test_static_dashboard_has_no_refresh(tmp_path):
    """The split keeps the static artifact static: no auto-refresh meta."""
    from repro.obs.dashboard import render_html
    frames = [{"type": "meta", "t": 0.0, "frame_every": 60.0, "n_nodes": 4,
               "scheduler": "fifo"}] + [_sim_frame(i, 60.0 * (i + 1))
                                        for i in range(3)]
    doc = render_html(frames)
    assert 'http-equiv="refresh"' not in doc
    assert "Fleet occupancy" in doc
