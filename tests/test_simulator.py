"""Simulator + scheduler behaviour tests (unit + property-based)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.cluster.chaos import ChaosConfig
from repro.cluster.experiment import (ExperimentConfig, compare, run_atlas,
                                      run_baseline)
from repro.cluster.simulator import Simulator
from repro.cluster.telemetry import N_FEATURES
from repro.cluster.workload import WorkloadConfig, install, make_workload
from repro.sched.base import BASELINES


def _small_cfg(seed=0, intensity=3.0):
    return ExperimentConfig(
        workload=WorkloadConfig(n_single=12, n_chains=2, seed=seed,
                                submit_horizon=3600.0),
        chaos=ChaosConfig(intensity=intensity, seed=seed + 1),
        seed=seed)


@pytest.mark.parametrize("sched", ["fifo", "fair", "capacity"])
def test_simulation_terminates_and_accounts_every_job(sched):
    m, trace, sim = run_baseline(sched, _small_cfg())
    assert m["jobs_total"] == m["jobs_finished"] + m["jobs_failed"] + \
        sum(1 for j in sim.jobs.values() if j.status == "running")
    assert m["jobs_total"] > 0
    # no job left running at termination
    assert all(j.status in ("finished", "failed") for j in sim.jobs.values())
    # every task of every job reached a terminal state
    for j in sim.jobs.values():
        for t in j.tasks.values():
            assert t.status in ("finished", "failed"), (j.jid, t.tid, t.status)


def test_determinism_same_seed_same_metrics():
    m1, _, _ = run_baseline("fifo", _small_cfg(seed=5))
    m2, _, _ = run_baseline("fifo", _small_cfg(seed=5))
    assert m1 == m2


def test_different_seeds_differ():
    m1, _, _ = run_baseline("fifo", _small_cfg(seed=5))
    m2, _, _ = run_baseline("fifo", _small_cfg(seed=6))
    assert m1 != m2


def test_no_chaos_no_failures():
    cfg = _small_cfg(intensity=0.0)
    cfg.chaos.intensity = 0.0
    cfg.hazard_noise = 0.0
    # with zero chaos the only failure driver is the (small) ambient hazard; at
    # logit -3 with no noise some attempts still fail, but *jobs* should rarely die
    m, _, _ = run_baseline("fifo", cfg)
    assert m["pct_jobs_failed"] <= 15.0


def test_heartbeat_detection_delay():
    """A killed TaskTracker is only detected at its next heartbeat; its running
    attempts resolve then (the Dinu et al. effect ATLAS attacks)."""
    sched = BASELINES["fifo"]()
    sim = Simulator(sched, seed=0, heartbeat_interval=600.0)
    install(sim, make_workload(WorkloadConfig(n_single=4, n_chains=0,
                                              submit_horizon=1.0, seed=0)))
    # run a few events to get attempts placed, then kill a busy node
    for _ in range(50):
        if not sim._heap:
            break
        import heapq
        t, _, kind, payload = heapq.heappop(sim._heap)
        sim.now = t
        if kind == 0:
            sim._on_submit(payload)
        elif kind == 1:
            sim._on_attempt_end(payload)
        elif kind == 2:
            sim._on_heartbeat(payload)
        sim.scheduler.on_tick()
        busy = [n for n in sim.nodes if n.running]
        if busy:
            break
    busy = [n for n in sim.nodes if n.running]
    if busy:
        node = busy[0]
        node.tt_alive = False
        assert node.known_alive          # JT doesn't know yet
        sim.detect_tt_failure(node)
        assert not node.known_alive
        assert not node.running          # stranded attempts were failed


def test_telemetry_features_shape_and_observability():
    m, trace, sim = run_baseline("fifo", _small_cfg())
    (mx, my), (rx, ry) = trace.datasets()
    assert mx.shape[1] == N_FEATURES
    assert set(np.unique(my)) <= {0.0, 1.0}
    assert len(mx) == len(my) and len(rx) == len(ry)
    assert np.isfinite(mx).all()


def test_atlas_stats_and_improvement_direction():
    """On the calibrated default config ATLAS must not *increase* the failed-job
    percentage (seeded)."""
    cfg = _small_cfg(seed=2, intensity=4.0)
    out = compare("fifo", cfg)
    assert out["atlas"]["pct_jobs_failed"] <= out["base"]["pct_jobs_failed"] + 5.0
    assert out["atlas"]["atlas"]["predictions"] > 0


def test_capacity_memory_police_kills_overcommit():
    from repro.sched.base import CapacityScheduler
    sched = CapacityScheduler()
    sim = Simulator(sched, seed=0)
    install(sim, make_workload(WorkloadConfig(n_single=10, n_chains=0, seed=3,
                                              submit_horizon=10.0)))
    sim.run()
    # the m3.large nodes (3.75 GB, 3 slots) can host at most 3 tasks => with the
    # 1.2 GB/task model they occasionally overcommit and the police must fire;
    # we only assert the sim stays consistent (no negative slot counts)
    for n in sim.nodes:
        assert n.running_maps >= 0 and n.running_reduces >= 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), intensity=st.floats(0.0, 8.0))
def test_property_simulator_invariants(seed, intensity):
    """Any seed/intensity: terminal states consistent, counters non-negative,
    resource usage non-negative, time monotone."""
    cfg = ExperimentConfig(
        workload=WorkloadConfig(n_single=6, n_chains=1, seed=seed,
                                submit_horizon=1800.0),
        chaos=ChaosConfig(intensity=intensity, seed=seed + 1), seed=seed)
    m, trace, sim = run_baseline("fifo", cfg)
    assert m["tasks_finished"] + m["tasks_failed"] <= m["tasks_total"]
    assert 0 <= m["pct_jobs_failed"] <= 100.0
    assert m["sim_time"] >= 0
    for j in sim.jobs.values():
        for t in j.tasks.values():
            assert t.failed_attempts <= t.max_attempts + 2  # spec copies tolerated
            assert t.cpu_ms >= 0 and t.hdfs_read >= 0


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_atlas_runs_any_seed(seed):
    cfg = ExperimentConfig(
        workload=WorkloadConfig(n_single=5, n_chains=1, seed=seed,
                                submit_horizon=1200.0),
        chaos=ChaosConfig(intensity=4.0, seed=seed), seed=seed)
    m, _, _ = run_atlas("fifo", cfg)
    assert m["jobs_total"] > 0
    assert all(v >= 0 for v in m["atlas"].values())
