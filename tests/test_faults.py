"""Fault-injection + fault-tolerance tests: FaultPlan as a typed fault-space
point (bounds, exact round-trip, seeded sampling), transport-independent
fault schedules, client retry + broker idempotent replay keeping the
deterministic counters byte-clean, broker restart ride-through, registry
crash recovery, graceful degradation with probe recovery, and the fleet
acceptance claim — a faulted async sweep emits SWEEP.json byte-identical to
its fault-free control."""

import asyncio
import random

import numpy as np
import pytest

from repro.cluster.fleet import SweepSpec, run_sweep, sweep_json
from repro.core.predictor import TaskPredictor
from repro.ml.models import ALL_MODELS
from repro.online.faults import (FaultInjector, FaultPlan,
                                 PredictorUnavailableError, backoff_delay,
                                 backoff_schedule)
from repro.online.server import AsyncBroker, BrokerClient
from repro.online.transport import connect, listen


def _forest_data(n=400, f=10, seed=0):
    rng = np.random.RandomState(seed)
    X = rng.rand(n, f).astype(np.float32)
    y = (X[:, 0] + 0.3 * rng.rand(n) > 0.8).astype(np.float32)
    return X, y


def _model(seed=0):
    X, y = _forest_data(seed=seed)
    return ALL_MODELS["R.F."]().fit(X, y)


# ---------------------------------------------------------------------------
# FaultPlan: bounds, round-trip, sampling
# ---------------------------------------------------------------------------

def test_fault_plan_round_trips_exactly():
    plans = [FaultPlan(),
             FaultPlan(seed=7, drop=0.2, delay=0.1, delay_s=(0.002, 0.05),
                       duplicate=0.15, abrupt_close=0.05,
                       restart_after=(5, 12), max_events=32,
                       request_timeout_s=0.2, deadline_s=45.0)]
    plans += [FaultPlan.sample(random.Random(k)) for k in range(20)]
    for plan in plans:
        assert FaultPlan.from_dict(plan.to_dict()) == plan


def test_fault_plan_validate_rejects_out_of_space_points():
    for bad in (dict(drop=0.6),                       # above per-fault cap
                dict(drop=0.4, delay=0.4, duplicate=0.3),   # mass > 1
                dict(delay_s=(0.02, 0.01)),           # inverted span
                dict(delay_s=(0.0, 0.5)),             # span above bound
                dict(restart_after=(3, 3)),           # not strictly increasing
                dict(restart_after=(0,)),             # not positive
                dict(seed=-1),
                dict(max_events=5000),
                dict(request_timeout_s=0.001),
                dict(deadline_s=0.01)):
        with pytest.raises(ValueError):
            FaultPlan(**bad).validate()


def test_fault_plan_sample_is_seeded_and_always_valid():
    for k in range(30):
        a = FaultPlan.sample(random.Random(k))
        b = FaultPlan.sample(random.Random(k))
        assert a == b                    # pure function of the rng state
        a.validate()


# ---------------------------------------------------------------------------
# Deterministic backoff (the property file goes deeper; this is the contract)
# ---------------------------------------------------------------------------

def test_backoff_is_bounded_enveloped_and_reproducible():
    sched = backoff_schedule(12, base=0.05, cap=1.0, seed=3)
    assert sched == backoff_schedule(12, base=0.05, cap=1.0, seed=3)
    for i, d in enumerate(sched):
        env = min(1.0, 0.05 * 2 ** i)
        assert env / 2 <= d <= env <= 1.0
    with pytest.raises(ValueError):
        backoff_delay(-1)


# ---------------------------------------------------------------------------
# Transport-independent fault schedules
# ---------------------------------------------------------------------------

def _faulted_echo_run(address, plan, n_msgs=60):
    """Send n id'd messages through a fault-wrapped client comm; return the
    sequence of message ids the server actually received."""
    async def go():
        got = []

        async def sink_handler(comm):
            from repro.online.transport import CommClosedError
            try:
                while True:
                    got.append((await comm.recv())["i"])
            except CommClosedError:
                pass

        lst = await listen(address, sink_handler)
        injector = FaultInjector(plan)
        comm = injector.wrap(await connect(lst.address), side="client")
        for i in range(n_msgs):
            await comm.send({"i": i})
        await comm.send({"i": -1})       # flush marker past any delays
        while not got or got[-1] != -1:
            await asyncio.sleep(0.01)
        await comm.close()
        await lst.stop()
        return got[:-1], injector.stats()
    return asyncio.run(go())


def test_fault_schedule_identical_on_inproc_and_tcp():
    plan = FaultPlan(seed=11, drop=0.2, delay=0.1, delay_s=(0.0, 0.002),
                     duplicate=0.15, max_events=4096)
    got_inproc, st_inproc = _faulted_echo_run("inproc://t-faults", plan)
    got_tcp, st_tcp = _faulted_echo_run("tcp://127.0.0.1:0", plan)
    # the two transports share no I/O machinery, yet the seeded schedule —
    # which messages vanish, which arrive twice — is bit-identical
    assert got_inproc == got_tcp
    assert st_inproc == st_tcp
    assert st_inproc["drops"] > 0 and st_inproc["duplicates"] > 0
    # and it matches the schedule derived from the plan alone
    ref = FaultInjector(plan)
    rng = ref._rng_for_conn(0)
    expect = []
    for i in range(60):
        fault, _ = ref.draw(rng)
        if fault != "none":
            ref.record(fault)
        if fault == "drop":
            continue
        expect.extend([i, i] if fault == "duplicate" else [i])
    assert got_inproc == expect


def test_fault_budget_caps_injected_events():
    plan = FaultPlan(seed=1, drop=0.5, max_events=3)
    got, st = _faulted_echo_run("inproc://t-budget", plan, n_msgs=50)
    assert st["drops"] == 3 and st["events"] == 3
    assert len(got) == 50 - 3            # budget spent: the rest fly clean


# ---------------------------------------------------------------------------
# Client retry + broker idempotent replay
# ---------------------------------------------------------------------------

def test_retries_and_replays_keep_deterministic_stats_byte_clean():
    model = _model()
    stream = _forest_data(seed=1)[0]
    requests = [stream[i:i + 1 + (i % 3)] for i in range(0, 90, 3)]

    def run(plan):
        with AsyncBroker({"map": model}, policy="vt") as server:
            addr = server.serve(fault_plan=plan)
            kw = {} if plan is None else dict(
                request_timeout_s=plan.request_timeout_s,
                deadline_s=plan.deadline_s, retry_seed=plan.seed)
            client = BrokerClient(addr, server.loop, **kw)
            try:
                outs = [client.predict("map", X) for X in requests]
            finally:
                client.close()
            return outs, server.stats(), server.fault_stats(), client

    plan = FaultPlan(seed=5, drop=0.25, delay=0.1, delay_s=(0.0, 0.01),
                     duplicate=0.1, abrupt_close=0.05, max_events=48,
                     request_timeout_s=0.2, deadline_s=60.0)
    clean_outs, clean_stats, clean_faults, _ = run(None)
    fault_outs, fault_stats, faults, client = run(plan)
    for a, b in zip(clean_outs, fault_outs):
        assert np.array_equal(a, b)      # every retry replayed bit-identically
    # the chaos was real…
    assert faults["injected"]["events"] > 0
    assert client.n_retries > 0
    assert faults["dup_requests"] > 0
    # …and invisible to the deterministic counters
    assert fault_stats == clean_stats
    assert clean_faults == {"replays": 0, "dup_requests": 0,
                            "injected": {"events": 0, "drops": 0, "delays": 0,
                                         "duplicates": 0, "closes": 0,
                                         "restarts": 0, "messages_in": 0}}


def test_listener_restart_rides_through_on_reconnect():
    model = _model()
    stream = _forest_data(seed=2)[0]
    plan = FaultPlan(seed=3, restart_after=(5, 12),
                     request_timeout_s=0.25, deadline_s=60.0)
    with AsyncBroker({"map": model}, policy="vt") as server:
        addr = server.serve(fault_plan=plan)
        client = BrokerClient(addr, server.loop,
                              request_timeout_s=plan.request_timeout_s,
                              deadline_s=plan.deadline_s,
                              backoff_base_s=0.01, backoff_cap_s=0.1)
        try:
            for i in range(25):
                X = stream[i:i + 2]
                out = client.predict("map", X)
                want = np.asarray(model.predict_proba(X), np.float32)
                assert np.array_equal(out, want)
        finally:
            client.close()
        faults = server.fault_stats()
        stats = server.stats()
    # both scheduled broker restarts fired, and the client absorbed them
    assert faults["injected"]["restarts"] == 2
    assert client.n_reconnects >= 2
    assert stats["requests"] == 25       # replay slot: retries never re-admit


def test_done_is_acked_and_deduped_by_client_id():
    with AsyncBroker(policy="barrier") as server:
        addr = server.serve()
        server.add_clients(2)

        async def go():
            comm = await connect(addr)
            for req_id in (1, 2):        # a retried done: same client id
                await comm.send({"op": "done", "id": req_id, "client": "cA"})
                ack = await comm.recv()
                assert ack == {"id": req_id, "ok": True}
            await comm.close()

        asyncio.run_coroutine_threadsafe(go(), server.loop).result(30)
        # barrier membership shrank exactly once despite two done messages
        assert server._clients == 1


# ---------------------------------------------------------------------------
# Crash recovery from the model registry
# ---------------------------------------------------------------------------

def test_from_registry_rebuilds_bit_identical_scoring(tmp_path):
    from repro.online.registry import ModelRegistry
    mx, my = _forest_data(seed=4)
    pred = TaskPredictor(min_samples=40, max_train=400)
    assert pred.fit_datasets((mx, my), (mx, my))
    ModelRegistry(tmp_path).publish("outcome", pred.snapshot())

    X = _forest_data(seed=5)[0][:16]
    want = pred.predict_batch("map", X)
    with AsyncBroker.from_registry(tmp_path, "outcome") as server:
        addr = server.serve()
        client = BrokerClient(addr, server.loop)
        try:
            out = client.predict("map", X)
        finally:
            client.close()
    assert np.array_equal(out, want)     # the replacement broker serves the
    #                                      dead one's exact floats


def test_damaged_snapshot_fails_loudly_at_load():
    with pytest.raises(ValueError, match="malformed predictor snapshot"):
        TaskPredictor().load_snapshot({"algo": "R.F.", "models": {}})
    with pytest.raises(ValueError, match="unknown"):
        TaskPredictor().load_snapshot(
            {"algo": "nope", "seed": 0, "min_samples": 1, "max_train": 1,
             "fits": 0, "models": {"map": None, "reduce": None}})


# ---------------------------------------------------------------------------
# Graceful degradation: schedule anyway, probe, recover
# ---------------------------------------------------------------------------

class _FlakyBroker:
    """submit() raises PredictorUnavailableError for the first ``fail``
    calls that actually reach it, then serves a recognisable constant."""

    def __init__(self, fail):
        self.fail = fail
        self.n_submits = 0
        self.n_retries = 0
        self.n_reconnects = 0

    def submit(self, groups):
        self.n_submits += 1
        if self.fail > 0:
            self.fail -= 1
            raise PredictorUnavailableError("broker down")
        return [np.full(np.asarray(X).shape[0], 0.25, np.float32)
                for _, X in groups]


def test_degraded_flushes_fall_back_then_probe_recovers():
    from repro.online.broker import BrokerPredictor
    bp = BrokerPredictor(broker=_FlakyBroker(fail=1), fallback_probe_every=2)
    X = np.zeros((3, 4), np.float32)
    groups = [(None, X)]
    # outage: the failed flush degrades, and the answer is p=1.0 per row —
    # the untrained-model semantics, so the ATLAS gate schedules anyway
    (out,) = bp._flush_brokered(groups)
    assert bp.degraded and np.array_equal(out, np.ones(3, np.float32))
    # countdown flushes never touch the broker
    for _ in range(2):
        (out,) = bp._flush_brokered(groups)
        assert np.array_equal(out, np.ones(3, np.float32))
    assert bp.broker.n_submits == 1
    assert bp.n_fallbacks == 3 and bp.n_fallback_rows == 9
    # the probe flush retries for real and clears the degradation
    (out,) = bp._flush_brokered(groups)
    assert not bp.degraded
    assert np.array_equal(out, np.full(3, 0.25, np.float32))
    fs = bp.frame_stats()
    assert fs["fallbacks"] == 3
    assert "retries" in fs and "reconnects" in fs


def test_degraded_decisions_counter_is_none_omitted_in_stats():
    from repro.core.atlas import AtlasStats
    # healthy runs must keep their historical stats bytes: the counter only
    # appears once a degraded decision actually happened
    assert "degraded_decisions" not in AtlasStats().to_dict()
    assert AtlasStats(degraded_decisions=4).to_dict()[
        "degraded_decisions"] == 4


# ---------------------------------------------------------------------------
# Fleet acceptance: faulted async sweep == clean async sweep, byte for byte
# ---------------------------------------------------------------------------

def test_fleet_async_faulted_sweep_matches_clean_bytes():
    spec = SweepSpec(schedulers=("fifo", "atlas-fifo"), seeds=2,
                     scenarios=("baseline",), workloads=("smoke",),
                     min_samples=40, max_train=40)
    clean = run_sweep(spec, executor="async", log=lambda *a: None)
    plan = FaultPlan(seed=7, drop=0.15, delay=0.05, delay_s=(0.0, 0.005),
                     duplicate=0.1, restart_after=(40,), max_events=24,
                     request_timeout_s=0.25, deadline_s=120.0)
    stats = {}
    faulted = run_sweep(spec, executor="async", fault_plan=plan,
                        fault_stats=stats, log=lambda *a: None)
    assert sweep_json(faulted) == sweep_json(clean)
    assert stats["injected"]["events"] > 0
    assert stats["client_retries"] > 0
    assert stats["fallbacks"] == 0       # degraded-free: parity is meaningful


def test_fleet_rejects_fault_plan_on_non_async_executors():
    spec = SweepSpec(schedulers=("fifo",), seeds=1, scenarios=("baseline",),
                     workloads=("smoke",), min_samples=40, max_train=40)
    with pytest.raises(ValueError, match="async"):
        run_sweep(spec, executor="serial", fault_plan=FaultPlan(),
                  log=lambda *a: None)


# ---------------------------------------------------------------------------
# Resumable sweeps: the cell ledger
# ---------------------------------------------------------------------------

def test_fleet_resume_reuses_ledger_cells_byte_identically(tmp_path):
    spec = SweepSpec(schedulers=("fifo", "atlas-fifo"), seeds=2,
                     scenarios=("baseline",), workloads=("smoke",),
                     min_samples=40, max_train=40)
    baseline = sweep_json(run_sweep(spec, executor="serial",
                                    log=lambda *a: None))
    first = sweep_json(run_sweep(spec, executor="serial",
                                 resume_dir=tmp_path, log=lambda *a: None))
    assert first == baseline             # the ledger never changes results
    assert list(tmp_path.glob("w1__*.json"))
    lines = []
    second = sweep_json(run_sweep(
        spec, executor="serial", resume_dir=tmp_path,
        log=lambda *a: lines.append(" ".join(map(str, a)))))
    assert second == baseline
    assert any("resumed" in ln for ln in lines)


def test_fleet_resume_ledger_wipes_on_fingerprint_mismatch(tmp_path):
    spec = SweepSpec(schedulers=("fifo",), seeds=1, scenarios=("baseline",),
                     workloads=("smoke",), min_samples=40, max_train=40)
    run_sweep(spec, executor="serial", resume_dir=tmp_path,
              log=lambda *a: None)
    assert list(tmp_path.glob("w1__*.json"))
    other = SweepSpec(schedulers=("fifo",), seeds=2, scenarios=("baseline",),
                      workloads=("smoke",), min_samples=40, max_train=40)
    lines = []
    run_sweep(other, executor="serial", resume_dir=tmp_path,
              log=lambda *a: lines.append(" ".join(map(str, a))))
    # a different spec must not resume the old cells
    assert not any("resumed" in ln for ln in lines)
