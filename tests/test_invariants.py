"""Per-tick scheduler invariants (PR 8): clean runs stay clean, corrupted
state is caught, and the checker never changes simulation results."""

import pytest

from repro.cluster.chaos import ChaosConfig, ChaosInjector
from repro.cluster.experiment import ExperimentConfig, run_scheduler
from repro.cluster.invariants import InvariantChecker, InvariantViolation
from repro.cluster.scenarios import make_spec
from repro.cluster.simulator import Simulator
from repro.cluster.workload import WorkloadConfig, install, make_workload
from repro.sched.base import FIFOScheduler

TINY = WorkloadConfig(n_single=4, n_chains=1, chain_len_range=(2, 3),
                      maps_range=(2, 4), reduces_range=(1, 3),
                      submit_horizon=1800.0, seed=5)


def _run_sim(*, invariants=None, seed=2):
    sim = Simulator(FIFOScheduler(), seed=seed,
                    chaos=ChaosInjector(ChaosConfig(seed=seed + 100)),
                    invariants=invariants)
    install(sim, make_workload(TINY))
    metrics = sim.run()
    return sim, metrics


def test_clean_run_has_zero_violations_and_counts_checks():
    inv = InvariantChecker()
    sim, metrics = _run_sim(invariants=inv)
    assert metrics["invariant_violations"] == 0
    assert metrics["invariant_checks"] > 0
    assert inv.n_sweeps >= 1                 # at least the end-of-run sweep
    assert inv.summary()["examples"] == []


def test_metrics_keys_absent_without_checker():
    _, metrics = _run_sim()
    assert "invariant_checks" not in metrics
    assert "invariant_violations" not in metrics


def test_checker_never_changes_results():
    _, plain = _run_sim()
    _, checked = _run_sim(invariants=InvariantChecker())
    checked = {k: v for k, v in checked.items()
               if not k.startswith("invariant_")}
    assert checked == plain


def test_sweep_interval_scales_with_fleet():
    inv = InvariantChecker(sweep_every=128)
    cfg = ExperimentConfig(workload=TINY, seed=1, fleet_size=500,
                           min_samples=40, max_train=2000)
    from repro.cluster.experiment import _new_sim
    sim = _new_sim(FIFOScheduler(), cfg, None)
    inv.bind(sim)
    assert inv.sweep_interval == 1000        # 2 * n_nodes dominates


# ---------------------------------------------------------------------------
# corruption detection: each invariant family trips on a seeded bug
# ---------------------------------------------------------------------------

def test_full_sweep_catches_slot_corruption():
    inv = InvariantChecker()
    sim, _ = _run_sim(invariants=inv)
    assert inv.n_violations == 0
    sim.nodes[0].running_maps += 1           # running set no longer matches
    inv.full_sweep(sim)
    names = {v["invariant"] for v in inv.violations}
    assert "running_set_mismatch" in names


def test_full_sweep_catches_stale_free_index():
    inv = InvariantChecker()
    sim, _ = _run_sim(invariants=inv)
    node = sim.nodes[1]
    node.running_maps = node.spec.map_slots  # full, but index still lists it
    sim._free_map.add(node.nid)
    inv.full_sweep(sim)
    names = {v["invariant"] for v in inv.violations}
    assert {"free_map_index_stale", "running_set_mismatch"} & names


def test_full_sweep_catches_counter_regression():
    inv = InvariantChecker()
    sim, _ = _run_sim(invariants=inv)
    sim.nodes[2].finished_count = -1
    inv.full_sweep(sim)
    assert any(v["invariant"] == "node_counter_regression"
               for v in inv.violations)


def test_full_sweep_catches_outage_without_recovery():
    inv = InvariantChecker()
    sim, _ = _run_sim(invariants=inv)
    node = sim.nodes[3]
    node.suspended = True                    # outage with no recovery queued
    sim.chaos.pending_recoveries.pop(node.nid, None)
    inv.full_sweep(sim)
    assert any(v["invariant"] == "outage_without_recovery"
               for v in inv.violations)


def test_check_launch_catches_dead_node_and_bad_status():
    inv = InvariantChecker()
    sim, _ = _run_sim(invariants=inv)
    task = next(t for j in sim.jobs.values() for t in j.tasks.values())
    node = sim.nodes[0]
    node.running_maps = node.running_reduces = 0
    node.known_alive = node.tt_alive = False
    task.status = "pending"
    inv.check_launch(sim, task, node, False)
    assert any(v["invariant"] == "launch_on_dead_node"
               for v in inv.violations)
    before = inv.n_violations
    task.status = "finished"                 # neither pending nor running
    inv.check_launch(sim, task, node, True)
    assert any(v["invariant"] == "speculative_copy_of_nonrunning"
               for v in inv.violations[before:]) or inv.n_violations > before


def test_raise_on_violation_raises():
    inv = InvariantChecker(raise_on_violation=True)
    sim, _ = _run_sim(invariants=inv)
    sim.nodes[0].running_maps += 1
    with pytest.raises(InvariantViolation, match="running_set_mismatch"):
        inv.full_sweep(sim)


def test_examples_are_bounded():
    inv = InvariantChecker(max_examples=2)
    sim, _ = _run_sim(invariants=inv)
    for n in sim.nodes:
        n.finished_count = -1
    inv.full_sweep(sim)
    assert inv.n_violations >= len(sim.nodes)
    assert len(inv.violations) == 2


# ---------------------------------------------------------------------------
# plumbing: the fleet flag reaches every cell
# ---------------------------------------------------------------------------

def test_experiment_config_plumbs_checker_through_atlas():
    point = make_spec("bursty_tt", "smoke")
    cfg = ExperimentConfig(workload=point.workload_for_seed(1),
                           chaos=point.chaos_for_seed(2), seed=1,
                           min_samples=40, max_train=2000,
                           check_invariants=True)
    metrics, _, sim = run_scheduler("atlas-fifo", cfg)
    assert sim.invariants is not None
    assert metrics["invariant_violations"] == 0
    assert metrics["invariant_checks"] > 0
