"""Simulator hot-path index tests: the incrementally maintained free-slot /
liveness sets and job counters must agree exactly with brute-force scans, the
failure-history window must stay O(window), and big fleets must run."""

import numpy as np

from repro.cluster.chaos import ChaosConfig, ChaosInjector
from repro.cluster.experiment import ExperimentConfig, run_scheduler
from repro.cluster.simulator import (DEFAULT_FLEET, MAP, REDUCE,
                                     MACHINE_TYPES, Node, Simulator,
                                     make_fleet)
from repro.cluster.workload import WorkloadConfig, install, make_workload
from repro.sched.base import BASELINES


def _run_sim(sched="fifo", *, fleet=None, seed=0, intensity=5.0,
             n_single=14, n_chains=2):
    sim = Simulator(BASELINES[sched](), fleet=fleet, seed=seed,
                    chaos=ChaosInjector(ChaosConfig(seed=seed + 1,
                                                    intensity=intensity)))
    install(sim, make_workload(WorkloadConfig(n_single=n_single,
                                              n_chains=n_chains, seed=seed)))
    sim.run()
    return sim


def _check_indices(sim):
    for kind, idx in ((MAP, sim._free_map), (REDUCE, sim._free_reduce)):
        brute = {n.nid for n in sim.nodes
                 if (n.free_map_slots() if kind == MAP
                     else n.free_reduce_slots()) > 0}
        assert idx == brute, f"{kind} free-slot index diverged"
    assert sim._known_alive == {n.nid for n in sim.nodes if n.known_alive}


def _check_job_counters(sim):
    for j in sim.jobs.values():
        st = [t.status for t in j.tasks.values()]
        assert j.n_finished_tasks == st.count("finished"), j.jid
        assert j.n_failed_tasks == st.count("failed"), j.jid
        assert j.n_finished_maps == sum(
            1 for t in j.tasks.values()
            if t.kind == MAP and t.status == "finished")
    running = sum(1 for j in sim.jobs.values() if j.status == "running")
    assert sim.n_running_jobs == running


def test_indices_and_counters_match_scans_after_chaos_run():
    for seed in (0, 3, 11):
        sim = _run_sim(seed=seed, intensity=6.0)
        _check_indices(sim)
        _check_job_counters(sim)


def test_free_nodes_matches_bruteforce_views():
    sim = _run_sim(seed=2)
    for kind in (MAP, REDUCE):
        slots = (Node.free_map_slots if kind == MAP
                 else Node.free_reduce_slots)
        want_jt = [n.nid for n in sim.nodes if n.known_alive and slots(n) > 0]
        want_up = [n.nid for n in sim.nodes
                   if n.tt_alive and not n.suspended and slots(n) > 0]
        want_any = [n.nid for n in sim.nodes if slots(n) > 0]
        assert [n.nid for n in sim.free_nodes(kind)] == want_jt
        assert [n.nid for n in
                sim.free_nodes(kind, liveness="actual")] == want_up
        assert [n.nid for n in sim.free_nodes(kind, liveness="any")] == want_any


def test_recent_failures_window_eviction():
    node = Node(0, MACHINE_TYPES["m3.large"])
    for t in range(0, 3000, 10):
        node.record_failure(float(t))
    # only the last window survives in memory — O(window), not O(history)
    assert len(node.recent_failures) <= 61
    assert node.recent_failure_count(2990.0) == len(node.recent_failures)
    assert node.recent_failure_count(2990.0 + 700.0) == 0
    # count == entries within the horizon (same as a linear scan; unlike the
    # old maxlen=64 deque, counts above 64 are no longer truncated)
    node2 = Node(1, MACHINE_TYPES["m3.large"])
    times = [0.0, 100.0, 650.0, 700.0, 701.0]
    for t in times:
        node2.record_failure(t)
    now = 710.0
    assert node2.recent_failure_count(now) == sum(
        1 for t in times if now - t <= 600.0)
    # a shorter query horizon must not destroy entries still inside the
    # retention window
    assert node2.recent_failure_count(now, horizon=20.0) == 2
    assert node2.recent_failure_count(now) == sum(
        1 for t in times if now - t <= 600.0)


def test_make_fleet_cycles_machine_mix():
    assert make_fleet(0) == list(DEFAULT_FLEET)
    f100 = make_fleet(100)
    assert len(f100) == 100
    assert set(f100) == set(DEFAULT_FLEET)
    assert f100[:13] == list(DEFAULT_FLEET)


def test_hundred_node_fleet_runs_and_stays_consistent():
    sim = _run_sim(fleet=make_fleet(100), seed=1, intensity=6.0,
                   n_single=20, n_chains=2)
    assert len(sim.nodes) == 100
    _check_indices(sim)
    _check_job_counters(sim)
    m = sim.metrics()
    assert m["jobs_total"] > 0
    assert all(j.status in ("finished", "failed") for j in sim.jobs.values())


def test_fleet_size_config_runs_atlas_cell():
    cfg = ExperimentConfig(
        workload=WorkloadConfig(n_single=8, n_chains=1, seed=0,
                                submit_horizon=2400.0),
        chaos=ChaosConfig(intensity=4.0, seed=1), seed=0,
        min_samples=40, max_train=400, fleet_size=60)
    metrics, trace, sim = run_scheduler("fifo", cfg, with_trace=True)
    assert len(sim.nodes) == 60
    from repro.core.predictor import TaskPredictor
    pred = TaskPredictor(min_samples=40, max_train=400, seed=0)
    pred.fit_datasets(*trace.datasets())
    m2, _, sim2 = run_scheduler("atlas-fifo", cfg, pred)
    assert len(sim2.nodes) == 60
    assert m2["jobs_total"] > 0
    assert np.isfinite(m2["pct_tasks_failed"])
