"""Per-architecture smoke tests: reduced same-family config, one forward + one
train step on CPU, asserting output shapes and no NaNs.  (Full configs are only
exercised abstractly via the dry-run.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SMOKE_SHAPE, get_arch, smoke_reduce
from repro.models import get_model, param_count
from repro.models.steps import init_train_state, make_train_step
from repro.optim import AdamWConfig


def _batch(model, cfg, key):
    B, S = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens}
    if model.needs_media():
        ms = model.media_struct(B)
        batch["media"] = jnp.ones(ms.shape, ms.dtype) * 0.02
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch_id):
    cfg = smoke_reduce(get_arch(arch_id))
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(model, cfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(lambda p, b: model.apply(p, b["tokens"],
                                                   media=b.get("media")))(params, batch)
    B, S = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert np.isfinite(np.asarray(logits)).all(), f"{arch_id}: non-finite logits"
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_one_train_step(arch_id):
    cfg = smoke_reduce(get_arch(arch_id))
    model = get_model(cfg)
    opt_cfg = AdamWConfig(warmup_steps=2, total_steps=10)
    step_fn, _ = make_train_step(cfg, opt_cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt_cfg)
    batch = _batch(model, cfg, jax.random.PRNGKey(1))
    state, metrics = jax.jit(step_fn)(state, batch)
    assert int(state["step"]) == 1
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch_id}: loss={loss}"
    # random init on vocab V: CE should be near ln(V)
    assert loss < np.log(cfg.vocab_size) * 2.0
    gn = float(metrics["grad_norm"])
    assert np.isfinite(gn) and gn > 0.0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_matches_prefill_tail(arch_id):
    """Prefill S tokens, then decode token S given the cache — logits must match a
    full forward's last-position logits (the KV-cache path is consistent)."""
    cfg = smoke_reduce(get_arch(arch_id))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                                cfg.vocab_size, jnp.int32)
    media = None
    if model.needs_media():
        ms = model.media_struct(B)
        media = jnp.ones(ms.shape, ms.dtype) * 0.02

    # full forward over S+1 tokens -> logits at position S
    logits_full, _ = model.apply(params, tokens, media=media)
    want = np.asarray(logits_full[:, -1], np.float32)

    # prefill first S, decode one
    _, cache = model.prefill(params, tokens[:, :S], media=media, max_len=S + 1)
    # hybrid wrap-cache needs prefill multiple of window; smoke window=0 -> full
    pos = jnp.full((B,), S, jnp.int32)
    got, _ = model.decode(params, cache, tokens[:, S:S + 1], pos)
    got = np.asarray(got, np.float32)
    rtol = 2e-2 if cfg.dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(got, want, rtol=rtol, atol=2e-3)


def test_param_counts_match_assignment_scale():
    """Full configs must land near their nameplate sizes (catches wiring bugs)."""
    expected = {
        "stablelm-12b": 12e9, "mistral-nemo-12b": 12e9, "yi-34b": 34e9,
        "stablelm-1.6b": 1.6e9, "rwkv6-1.6b": 1.6e9, "whisper-large-v3": 1.5e9,
        "llama-3.2-vision-90b": 90e9, "zamba2-1.2b": 1.2e9,
        "deepseek-moe-16b": 16e9, "qwen3-moe-235b-a22b": 235e9,
    }
    for aid, want in expected.items():
        n = param_count(get_arch(aid))
        assert 0.55 * want < n < 1.75 * want, f"{aid}: {n/1e9:.2f}B vs {want/1e9}B"
