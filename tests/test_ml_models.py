"""Predictive-model tests: the six algorithms learn a separable task-failure
pattern; the forest trainer respects its structural invariants."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.ml.cv import cross_validate, metrics
from repro.ml.forest import fit_oblivious_forest, forest_predict
from repro.ml.models import ALL_MODELS


def _synthetic(n=2000, seed=0):
    """Failure pattern similar to the simulator's hazard: outcome depends on a few
    features nonlinearly."""
    rs = np.random.RandomState(seed)
    X = rs.randn(n, 8).astype(np.float32)
    logit = 1.2 * X[:, 0] - 0.8 * X[:, 1] + 1.5 * (X[:, 2] > 0.5) - 0.6
    p = 1 / (1 + np.exp(-logit))
    y = (rs.rand(n) < p).astype(np.float32)
    return X, y


@pytest.mark.parametrize("name", list(ALL_MODELS))
def test_each_model_beats_majority_class(name):
    X, y = _synthetic()
    model = ALL_MODELS[name]()
    model.fit(X[:1500], y[:1500])
    pred = model.predict(X[1500:])
    acc = (pred == y[1500:]).mean()
    base = max(y[1500:].mean(), 1 - y[1500:].mean())
    assert acc > base + 0.02, f"{name}: acc={acc:.3f} vs majority {base:.3f}"


def test_random_forest_best_or_near_best():
    """The paper's finding: RF is the strongest of the six (we allow a small
    tolerance — Boost can tie on easy synthetic data)."""
    X, y = _synthetic(n=3000, seed=1)
    accs = {}
    for name in ALL_MODELS:
        m = ALL_MODELS[name]().fit(X[:2400], y[:2400])
        accs[name] = (m.predict(X[2400:]) == y[2400:]).mean()
    assert accs["R.F."] >= max(accs.values()) - 0.03, accs


def test_forest_leaves_are_probabilities():
    X, y = _synthetic()
    params = fit_oblivious_forest(X, y, n_trees=8, depth=4)
    assert params.leaves.min() >= 0.0 and params.leaves.max() <= 1.0
    p = forest_predict(params, X)
    assert p.min() >= 0.0 and p.max() <= 1.0


def test_forest_fold_masks_train_distinct_models():
    X, y = _synthetic(n=600)
    masks = np.zeros((2, 600), np.float32)
    masks[0, :300] = 1
    masks[1, 300:] = 1
    params = fit_oblivious_forest(X, y, n_trees=4, depth=3, fold_masks=masks)
    assert params.feat_idx.shape == (8, 3)  # 2 folds x 4 trees


def test_cv_metrics_math():
    y_true = np.array([1, 1, 0, 0, 1], np.float32)
    y_pred = np.array([1, 0, 0, 1, 1], np.float32)
    m = metrics(y_true, y_pred)
    assert m["accuracy"] == pytest.approx(3 / 5)
    assert m["precision"] == pytest.approx(2 / 3)
    assert m["recall"] == pytest.approx(2 / 3)
    assert m["error"] == pytest.approx(2 / 5)


def test_cross_validate_runs():
    X, y = _synthetic(n=400)
    out = cross_validate("Glm", X, y, k=4)
    assert 0.5 < out["accuracy"] <= 1.0
    assert out["time_ms"] > 0


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 500), depth=st.integers(1, 5))
def test_property_forest_monotone_leaf_index(seed, depth):
    """Kernel/trainer contract: predictions are averages of leaf values selected by
    threshold comparisons — permuting sample order must not change predictions."""
    rs = np.random.RandomState(seed)
    X = rs.randn(64, 5).astype(np.float32)
    y = (rs.rand(64) > 0.5).astype(np.float32)
    params = fit_oblivious_forest(X, y, n_trees=3, depth=depth, seed=seed)
    p1 = forest_predict(params, X)
    perm = rs.permutation(64)
    p2 = forest_predict(params, X[perm])
    np.testing.assert_allclose(p1[perm], p2, rtol=1e-5, atol=1e-6)
