"""AsyncBroker serving tests: bit-identical outputs under the vt policy,
barrier-round accounting matching the threaded PredictionBroker, the SLO
safety valve, error propagation to clients, telemetry forwarding over the
transport, the open-loop bench path, and ``fleet --executor async``
reproducing the broker executor's SWEEP.json byte for byte."""

import time

import numpy as np
import pytest

from repro.cluster.fleet import SweepSpec, run_sweep, sweep_json
from repro.ml.models import ALL_MODELS
from repro.obs import MemorySink, TransportSink
from repro.online.server import AsyncBroker, BrokerClient, _Req


def _forest_data(n=400, f=10, seed=0):
    rng = np.random.RandomState(seed)
    X = rng.rand(n, f).astype(np.float32)
    y = (X[:, 0] + 0.3 * rng.rand(n) > 0.8).astype(np.float32)
    return X, y


def _model(seed=0):
    X, y = _forest_data(seed=seed)
    return ALL_MODELS["R.F."]().fit(X, y)


# ---------------------------------------------------------------------------
# vt policy: continuous batching, outputs bit-identical to scalar scoring
# ---------------------------------------------------------------------------

def test_vt_policy_predict_bitwise_matches_scalar():
    model = _model()
    stream = _forest_data(seed=1)[0]
    requests = [stream[i:i + 1 + (i % 3)] for i in range(0, 90, 3)]
    with AsyncBroker({"map": model}, policy="vt") as server:
        addr = server.serve()
        client = BrokerClient(addr, server.loop)
        try:
            for X in requests:
                out = client.predict("map", X)
                want = np.asarray(model.predict_proba(X), np.float32)
                assert np.array_equal(out, want)
        finally:
            client.close()
        stats = server.stats()
    assert stats["requests"] == len(requests)
    assert stats["rows"] == sum(X.shape[0] for X in requests)
    assert stats["flushes"] >= 1 and stats["policy"] == "vt"


def test_vt_depth_cap_batches_a_dense_burst_deterministically():
    """20 requests of 3 rows land on the channel before the handler wakes
    (inproc sends never suspend below capacity), so the handler drains them
    in one go: the depth cap (8 rows) closes a batch at 9 rows every third
    request, and the idle drain sweeps the 6-row tail."""
    import asyncio

    from repro.online.transport import connect

    model = _model()
    stream = _forest_data(seed=2)[0]
    with AsyncBroker({"map": model}, policy="vt", depth=8) as server:
        addr = server.serve()

        async def burst():
            comm = await connect(addr)
            for i in range(20):
                await comm.send({"op": "predict", "id": i, "kind": "map",
                                 "X": stream[3 * i:3 * i + 3]})
            replies = [await comm.recv() for _ in range(20)]
            await comm.close()
            return replies

        replies = asyncio.run_coroutine_threadsafe(
            burst(), server.loop).result(60)
        assert server.n_depth_flushes == 6
        assert server.n_idle_flushes == 1
        assert server.max_flush_rows == 9
        for r in replies:
            i = r["id"]
            want = np.asarray(
                model.predict_proba(stream[3 * i:3 * i + 3]), np.float32)
            assert np.array_equal(r["probs"][0], want)


# ---------------------------------------------------------------------------
# barrier policy: PredictionBroker round rules on the event loop
# ---------------------------------------------------------------------------

def test_barrier_rounds_match_lockstep_decomposition():
    """Clients with request counts [4, 2, 7]: 2 full three-way rounds, then
    2 two-way rounds after the short client deregisters, then 3 solo flushes
    — exactly the threaded PredictionBroker's decomposition."""
    import threading

    model = _model()
    stream = _forest_data(seed=3)[0]
    counts = [4, 2, 7]
    with AsyncBroker(policy="barrier") as server:
        addr = server.serve()
        server.add_clients(len(counts))
        outs = {}

        def run_client(ci, n):
            client = BrokerClient(addr, server.loop)
            try:
                for i in range(n):
                    lo = (ci * 31 + i * 3) % 80
                    (out,) = client.submit([(model, stream[lo:lo + 2])])
                    outs[(ci, i)] = (lo, out)
            finally:
                client.done()
                client.close()

        threads = [threading.Thread(target=run_client, args=(ci, n))
                   for ci, n in enumerate(counts)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60)
        stats = server.stats()
    assert stats["flushes"] == 7
    assert stats["requests"] == sum(counts)
    for (ci, i), (lo, out) in outs.items():
        want = np.asarray(model.predict_proba(stream[lo:lo + 2]), np.float32)
        assert np.array_equal(out, want)


def test_empty_submit_short_circuits_client_side():
    with AsyncBroker(policy="barrier") as server:
        addr = server.serve()
        server.add_clients(1)
        client = BrokerClient(addr, server.loop)
        try:
            assert client.submit([]) == []   # no wire traffic, no round
        finally:
            client.done()
            client.close()
        assert server.stats()["requests"] == 0


# ---------------------------------------------------------------------------
# SLO safety valve + error propagation + unknown ops
# ---------------------------------------------------------------------------

def test_slo_safety_valve_flushes_parked_request():
    """The wall-clock valve is defense in depth — the idle drain normally
    beats it — so its mechanics are exercised directly: a request parked on
    the queue with an armed deadline must flush when the deadline passes."""
    model = _model()
    X = _forest_data(seed=4)[0][:3]

    class FakeComm:
        closed = False

        def __init__(self):
            self.sent = []

        async def send(self, msg):
            self.sent.append(msg)

    comm = FakeComm()
    server = AsyncBroker(policy="vt").start()
    try:
        def park():
            server._queue.append(_Req(comm, 1, [(model, X)], 3, 1, None))
            server._queued_rows = 3
            server._arm_slo(time.perf_counter() + 0.02)

        server.loop.call_soon_threadsafe(park)
        deadline = time.time() + 5
        while not comm.sent and time.time() < deadline:
            time.sleep(0.01)
    finally:
        server.stop()
    assert comm.sent and server.n_deadline_flushes == 1
    want = np.asarray(model.predict_proba(X), np.float32)
    assert np.array_equal(comm.sent[0]["probs"][0], want)


def test_unknown_kind_and_scoring_error_propagate_to_client():
    class Broken:
        def predict_proba(self, X):
            raise RuntimeError("boom")

    model = _model()
    stream = _forest_data(seed=5)[0]
    with AsyncBroker({"map": model}, policy="vt") as server:
        addr = server.serve()
        client = BrokerClient(addr, server.loop)
        try:
            with pytest.raises(RuntimeError, match="unknown kind"):
                client.predict("nope", stream[:2])
            with pytest.raises(RuntimeError, match="boom"):
                client.submit([(Broken(), stream[:2])])
            # the serving loop survives both: a good request still works
            out = client.predict("map", stream[:2])
            want = np.asarray(model.predict_proba(stream[:2]), np.float32)
            assert np.array_equal(out, want)
        finally:
            client.close()


# ---------------------------------------------------------------------------
# Telemetry over the transport
# ---------------------------------------------------------------------------

def test_transport_sink_forwards_frames_to_server_sink():
    mem = MemorySink()
    with AsyncBroker(policy="vt") as server:
        server.telemetry_sink = mem
        addr = server.serve()
        sink = TransportSink(addr, loop=server.loop)
        frames = [{"t": i, "gauges": {"x": i * 2}} for i in range(5)]
        for f in frames:
            sink.emit(f)
        sink.close()
        deadline = time.time() + 5
        while server.n_telemetry_frames < len(frames) \
                and time.time() < deadline:
            time.sleep(0.01)
    assert server.n_telemetry_frames == len(frames)
    assert mem.frames == frames          # inproc: the very same dicts


# ---------------------------------------------------------------------------
# Open-loop bench path
# ---------------------------------------------------------------------------

def test_open_loop_parity_and_tail_metrics():
    from repro.online.bench import _parity_mod, run_open_loop

    class _P:
        def __init__(self, m):
            self.m = m

        def model_for_kind(self, kind):
            return self.m

    model = _model()
    stream = _forest_data(seed=6)[0]
    requests = [("map", stream[i:i + 1 + (i % 3)]) for i in range(0, 120, 3)]
    scalar = [np.asarray(model.predict_proba(X), np.float32)
              for _, X in requests]
    for arrivals in ("poisson", "bursty"):
        run = run_open_loop(_P(model), requests, backend="inproc",
                            arrivals=arrivals, clients=3, rate_rps=3000.0,
                            slo_ms=50.0, seed=0)
        assert _parity_mod(scalar, run["outputs"])
        assert run["rows"] == sum(X.shape[0] for _, X in requests)
        lm = run["latency_ms"]
        assert 0 <= lm["p50"] <= lm["p95"] <= lm["p99"]
        assert 0.0 <= run["slo_violation_rate"] <= 1.0
        assert run["flushes"] >= 1
        assert sum(run["flush_causes"].values()) == run["flushes"]


# ---------------------------------------------------------------------------
# fleet --executor async: byte parity with the broker executor
# ---------------------------------------------------------------------------

def test_fleet_async_executor_matches_broker_sweep_bytes():
    spec = SweepSpec(schedulers=("fifo", "atlas-fifo"), seeds=4,
                     scenarios=("baseline",), workloads=("smoke",),
                     min_samples=40, max_train=40)
    asynced = run_sweep(spec, executor="async", log=lambda *a: None)
    brokered = run_sweep(spec, executor="broker", log=lambda *a: None)
    # full equality, perf.broker included: same rounds, same counts
    assert sweep_json(asynced) == sweep_json(brokered)
