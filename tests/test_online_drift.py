"""Drift monitor + online refresher tests: PSI/score triggers, staleness
fallback, candidate promotion vs rollback, registry event recording, and the
end-to-end drift-aware ATLAS cell."""

import types

import numpy as np

from repro.core.predictor import TaskPredictor
from repro.online.drift import DriftMonitor, OnlineRefresher
from repro.online.registry import ModelRegistry


def _data(n=400, seed=0, shift=0.0):
    rng = np.random.RandomState(seed)
    X = (rng.rand(n, 6) + shift).astype(np.float32)
    y = (X[:, 0] % 1.0 > 0.5).astype(np.float32)
    return X, y


# ---------------------------------------------------------------------------
# DriftMonitor
# ---------------------------------------------------------------------------

def test_monitor_quiet_on_stationary_distribution():
    X, y = _data()
    mon = DriftMonitor(min_window=64)
    mon.set_reference(X, brier=0.1)
    X2, y2 = _data(seed=1)
    # served probabilities as good as at training time -> no score drift
    mon.observe(X2, y2, (0.8 * y2 + 0.1).astype(np.float32))
    assert mon.feature_psi() < 0.05
    hit, _ = mon.drifted()
    assert not hit


def test_monitor_fires_on_feature_shift():
    X, _ = _data()
    mon = DriftMonitor(min_window=64)
    mon.set_reference(X, brier=0.1)
    Xs, ys = _data(seed=1, shift=2.0)        # whole distribution moved
    mon.observe(Xs, ys, np.full(len(ys), 0.7, np.float32))
    assert mon.feature_psi() > 0.25
    hit, reason = mon.drifted()
    assert hit and "feature_psi" in reason


def test_monitor_fires_on_score_degradation():
    X, y = _data()
    mon = DriftMonitor(min_window=64)
    mon.set_reference(X, brier=0.02)
    X2, y2 = _data(seed=1)
    # the served probabilities are confidently wrong -> Brier collapses
    mon.observe(X2, y2, (1.0 - y2).astype(np.float32))
    hit, reason = mon.drifted()
    assert hit and "brier_drift" in reason
    assert mon.score_drift() > 0.5


def test_monitor_sliding_window_bounded():
    X, y = _data(n=100)
    mon = DriftMonitor(window=50)
    mon.observe(X, y, np.zeros(100, np.float32))
    assert len(mon.window_arrays()[1]) == 50


# ---------------------------------------------------------------------------
# OnlineRefresher
# ---------------------------------------------------------------------------

def _stub_sim(X, y, now=1000.0):
    trace = types.SimpleNamespace(
        datasets=lambda: ((X, y), (np.zeros((0, X.shape[1]), np.float32),
                                   np.zeros(0, np.float32))))
    return types.SimpleNamespace(trace=trace, now=now)


def _fresh_refresher(registry=None, **kw):
    pred = TaskPredictor(algo="R.F.", min_samples=50)
    ref = OnlineRefresher(registry=registry, retrain_every=600.0,
                          check_every=60.0, **kw)
    ref.bind_predictor(pred)
    return pred, ref


def test_staleness_triggers_first_fit_and_promotion(tmp_path):
    reg = ModelRegistry(tmp_path)
    pred, ref = _fresh_refresher(registry=reg, name="cell0")
    X, y = _data()
    assert ref.step(_stub_sim(X, y, now=700.0))   # past the staleness clock
    assert pred.ready
    assert ref.promotions == 1 and ref.rollbacks == 0
    assert [e["event"] for e in ref.events] == ["promote"]
    assert reg.head("cell0") == 1


def test_no_refresh_inside_clock_without_drift():
    pred, ref = _fresh_refresher()
    X, y = _data()
    ref.step(_stub_sim(X, y, now=700.0))          # trains + rebaselines
    assert not ref.step(_stub_sim(X, y, now=720.0))
    assert ref.refreshes == 1


def test_drift_triggers_refresh_before_clock():
    pred, ref = _fresh_refresher()
    X, y = _data()
    ref.step(_stub_sim(X, y, now=700.0))
    # drifted world arrives well before the next 600 s tick
    Xs, ys = _data(seed=3, shift=2.0)
    X2 = np.concatenate([X, Xs])
    y2 = np.concatenate([y, ys])
    assert ref.step(_stub_sim(X2, y2, now=760.0))
    assert ref.refreshes == 2
    assert any("feature_psi" in (e.get("reason") or "")
               for e in ref.events[1:])


def test_bad_candidate_is_rolled_back(tmp_path):
    reg = ModelRegistry(tmp_path)
    pred, ref = _fresh_refresher(registry=reg, name="cell1")
    X, y = _data(n=600)
    ref.step(_stub_sim(X, y, now=700.0))          # good live model
    head_before = reg.head("cell1")
    # seed the window with reality the live model predicts well...
    Xw, yw = _data(n=300, seed=5)
    ref.monitors["map"].observe(Xw, yw, pred.predict_batch("map", Xw))
    # ...then force a retrain on poisoned labels: the candidate must lose the
    # window duel and be archived, not promoted
    assert ref._refresh(_stub_sim(X, 1 - y, now=1400.0), "test")
    assert ref.rollbacks == 1
    assert ref.events[-1]["event"] == "rollback"
    assert reg.head("cell1") == head_before       # HEAD untouched
    assert len(reg.versions("cell1")) == 2        # candidate archived
    # live predictor still serves the good model
    p = pred.predict_batch("map", Xw)
    assert float(np.mean((p - yw) ** 2)) < 0.2


def test_drift_aware_atlas_cell_end_to_end():
    from repro.cluster.experiment import ExperimentConfig, run_scheduler
    from repro.cluster.scenarios import workload_for_seed
    cfg = ExperimentConfig(workload=workload_for_seed("smoke", 7),
                           min_samples=40, max_train=40, drift=True,
                           drift_check_every=60.0)
    metrics, _, sim = run_scheduler("atlas-fifo", cfg)
    stats = metrics["sched_stats"]
    assert "refreshes" in stats and "promotions" in stats
    assert stats["refreshes"] >= 1
    assert sim.scheduler.refresher.events
