"""Property tests (hypothesis) over the scenario space and the chaos/invariant
contract: perturbations stay in bounds, serialisation round-trips, and every
fired outage schedules a matching recovery."""

import random

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.cluster.chaos import ChaosConfig, ChaosInjector
from repro.cluster.invariants import InvariantChecker
from repro.cluster.scenarios import (CHAOS_BOUNDS, SCENARIOS, WEIGHT_FIELDS,
                                     WORKLOAD_BOUNDS, ScenarioSpec, make_spec)
from repro.cluster.simulator import Simulator
from repro.cluster.workload import WorkloadConfig, install, make_workload
from repro.sched.base import FIFOScheduler


def _check_bounds(spec: ScenarioSpec):
    for fname, b in CHAOS_BOUNDS.items():
        v = getattr(spec.chaos, fname)
        if fname in WEIGHT_FIELDS:
            assert 0.0 <= v <= b.hi            # renorm may push below b.lo
        elif b.kind == "span":
            assert b.lo <= v[0] <= v[1] <= b.hi
        else:
            assert b.lo <= v <= b.hi
    for fname, b in WORKLOAD_BOUNDS.items():
        v = getattr(spec.workload, fname)
        if b.kind == "span":
            assert b.lo <= v[0] <= v[1] <= b.hi
        else:
            assert b.lo <= v <= b.hi


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       scale=st.floats(0.05, 1.0),
       start=st.sampled_from(sorted(SCENARIOS)))
def test_perturb_stays_within_bounds_and_valid(seed, scale, start):
    spec = make_spec(start, "smoke")
    moved = spec
    rng = random.Random(seed)
    for _ in range(4):                         # chained moves stay legal too
        moved = moved.perturb(rng, scale)
        _check_bounds(moved)
        moved.validate()
        mass = sum(getattr(moved.chaos, f) for f in WEIGHT_FIELDS)
        assert mass <= 1.0 + 1e-9


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_sampled_spec_roundtrips_exactly(seed):
    spec = ScenarioSpec.sample(random.Random(seed))
    _check_bounds(spec)
    assert ScenarioSpec.from_dict(spec.to_dict()) == spec


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       intensity=st.floats(1.0, 10.0),
       burst_prob=st.floats(0.0, 0.4))
def test_every_outage_schedules_matching_recovery(seed, intensity, burst_prob):
    """Run a storm under the invariant checker in raise mode: no predicate —
    including outage=>recovery on every sweep — may fail, and once the event
    heap drains every recovery must have fired."""
    chaos = ChaosInjector(ChaosConfig(seed=seed, intensity=intensity,
                                      burst_prob=burst_prob,
                                      mean_outage=400.0))
    inv = InvariantChecker(raise_on_violation=True, sweep_every=32)
    sim = Simulator(FIFOScheduler(), seed=seed, chaos=chaos, invariants=inv)
    install(sim, make_workload(WorkloadConfig(
        n_single=3, n_chains=0, maps_range=(2, 3), reduces_range=(1, 2),
        submit_horizon=900.0, seed=seed)))
    sim.run()
    assert chaos.events_fired >= 0
    # the run ends when the workload drains, not when the heap is empty, so
    # recoveries may still be queued — but never *negative*, and any node
    # still in an outage state must have one pending
    for nid, n_pending in chaos.pending_recoveries.items():
        assert n_pending >= 0, f"node {nid} over-drained its recoveries"
    for n in sim.nodes:
        if not n.tt_alive or not n.dn_alive or n.suspended \
                or n.net_quality < 1.0:
            assert chaos.pending_recoveries[n.nid] >= 1
